"""Thin CLI over ``repro.bench``: fused vs per-bucket RM feature map.

Times the RM family only, with the legacy one-launch-per-degree baseline
enabled (``BenchSpec.include_bucketed``) so the fused-kernel speedup
column keeps its trajectory, at both precision policies. Everything else
— timing discipline, Gram RMSE, analytic roofline counters, the JSON
schema — comes from the unified bench subsystem.

Writes ``BENCH_rm_feature.json`` at the repo root.

Usage: python benchmarks/rm_feature_bench.py [--interpret] [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

_OUT = Path(__file__).resolve().parent.parent / "BENCH_rm_feature.json"


def run(interpret: bool = False, quick: bool = False, repeats: int = 5):
    """Generator of CSV rows (benchmarks/run.py contract); writes the JSON."""
    from repro.bench import default_spec, quick_spec, run_spec

    spec = (quick_spec(interpret=interpret, include_bucketed=True) if quick
            else default_spec(interpret=interpret, repeats=repeats,
                              include_bucketed=True))
    spec = dataclasses.replace(spec, estimators=("rm",))
    rows = []
    payload = run_spec(spec, emit=rows.append)
    yield from rows
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    yield f"wrote {_OUT}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--interpret", action="store_true",
                    help="run the Pallas paths in interpret mode (CPU CI)")
    ap.add_argument("--quick", action="store_true",
                    help="small configs / fewer repeats")
    args = ap.parse_args()
    for row in run(interpret=args.interpret, quick=args.quick,
                   repeats=2 if args.quick else 5):
        print(row)
