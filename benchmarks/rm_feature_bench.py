"""Fused vs per-bucket RM feature-map microbenchmark.

Times one full feature-map application per configuration on three paths:

  * ``fused``     — ONE Pallas launch over the FeaturePlan packed layout
                    (interpret mode off-TPU, compiled on TPU),
  * ``bucketed``  — the legacy path: one Pallas launch per degree bucket
                    plus a concatenate,
  * ``fused_jnp`` / ``bucketed_jnp`` — the XLA mirrors (what CPU runs in
                    production; the Pallas interpreter is a correctness
                    harness, not a performance target).

Reports wall time and achieved useful FLOP/s (2 * B * d per occupied product
slot) and writes ``BENCH_rm_feature.json`` next to the repo root so later PRs
have a perf trajectory. On TPU the fused path additionally saves the
per-bucket HBM re-reads of x and the final concatenate; the expected headroom
is roughly the bucket count (see DESIGN.md §3).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import ExponentialDotProductKernel, PolynomialKernel, make_feature_map
from repro.kernels.rm_feature import apply_feature_map, apply_feature_map_bucketed

# (label, kernel, d, D, batch)
_CONFIGS = [
    ("exp_d64_D256_b1024", ExponentialDotProductKernel(1.0), 64, 256, 1024),
    ("poly7_d32_D512_b512", PolynomialKernel(7, 1.0), 32, 512, 512),
    ("exp_h01_d24_D192_b512", ExponentialDotProductKernel(1.0), 24, 192, 512),
]


def _time_call(fn, x, repeats: int = 5) -> float:
    """Median wall-time (us) of a jitted call, excluding compile."""
    fn(x).block_until_ready()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def _useful_flops(fm, batch: int) -> int:
    plan = fm.plan
    d = plan.input_dim
    slots = sum(c * n for c, n in zip(plan.counts, plan.degrees))
    if plan.h01:
        slots += plan.input_dim  # identity block, degree 1
    return 2 * batch * d * slots


def run():
    on_tpu = jax.default_backend() == "tpu"
    results = {}
    for label, kern, d, D, batch in _CONFIGS:
        h01 = "h01" in label
        fm = make_feature_map(kern, d, D, jax.random.PRNGKey(0), h01=h01)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, d)) * 0.2
        flops = _useful_flops(fm, batch)
        paths = {
            "fused": jax.jit(lambda xx, f=fm: apply_feature_map(
                f, xx, use_pallas=True, interpret=not on_tpu)),
            "bucketed": jax.jit(lambda xx, f=fm: apply_feature_map_bucketed(
                f, xx, use_pallas=True, interpret=not on_tpu)),
            "fused_jnp": jax.jit(lambda xx, f=fm: apply_feature_map(
                f, xx, use_pallas=False)),
            "bucketed_jnp": jax.jit(lambda xx, f=fm: apply_feature_map_bucketed(
                f, xx, use_pallas=False)),
        }
        entry = {"buckets": len(fm.plan.degrees), "flops": flops}
        for path, fn in paths.items():
            us = _time_call(fn, x)
            entry[path + "_us"] = us
            entry[path + "_gflops"] = flops / us / 1e3
            yield f"rm_feature/{label}/{path},{us:.1f},{flops / us / 1e3:.3f}"
        entry["fused_speedup"] = entry["bucketed_us"] / entry["fused_us"]
        entry["fused_jnp_speedup"] = (
            entry["bucketed_jnp_us"] / entry["fused_jnp_us"]
        )
        results[label] = entry
        yield (f"rm_feature/{label}/speedup,"
               f"{entry['fused_speedup']:.3f},{entry['fused_jnp_speedup']:.3f}")

    out = Path(__file__).resolve().parent.parent / "BENCH_rm_feature.json"
    out.write_text(json.dumps(
        {"backend": jax.default_backend(), "results": results}, indent=2
    ))


if __name__ == "__main__":
    for row in run():
        print(row)
