"""Thin CLI over ``repro.bench``: registry-estimator head-to-head.

For each configuration, times one full feature-map application per
registry estimator at BOTH precision policies (fp32, bf16) on the fused
and oracle paths, and measures Gram-estimation quality (RMSE against the
exact kernel matrix) at the SAME feature budget F — the head-to-head the
estimator registry exists to answer. The grid, timing, metrics and JSON
schema all come from the unified bench subsystem (``repro.bench``); this
script only picks the spec and the output name.

Writes ``BENCH_sketch.json`` at the repo root (uploaded as a CI artifact
by the bench-smoke job) so later PRs have a cross-estimator perf
trajectory; docs/estimators.md quotes the matched-budget comparison and
docs/performance.md documents the schema.

Usage: python benchmarks/sketch_bench.py [--interpret] [--quick]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

_OUT = Path(__file__).resolve().parent.parent / "BENCH_sketch.json"


def run(interpret: bool = False, quick: bool = False, repeats: int = 5):
    """Generator of CSV rows (benchmarks/run.py contract); writes the JSON."""
    from repro.bench import default_spec, quick_spec, run_spec

    spec = (quick_spec(interpret=interpret) if quick
            else default_spec(interpret=interpret, repeats=repeats))
    rows = []
    payload = run_spec(spec, emit=rows.append)
    yield from rows
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    yield f"wrote {_OUT}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--interpret", action="store_true",
                    help="run the Pallas paths in interpret mode (CPU CI)")
    ap.add_argument("--quick", action="store_true",
                    help="small configs / fewer repeats (CI smoke)")
    args = ap.parse_args()
    for row in run(interpret=args.interpret, quick=args.quick,
                   repeats=2 if args.quick else 5):
        print(row)
