"""Registry-estimator benchmark (rm / tensor_sketch / ctr) at matched
feature budgets.

For each configuration, times one full feature-map application per registry
estimator (features/sec over the batch) and measures Gram-estimation quality
(RMSE against the exact kernel matrix on a held-out point set) at the SAME
feature budget F — the head-to-head the estimator registry exists to
answer. The sweep iterates ``registry.list_estimators()``, so a newly
registered family lands in the benchmark (and its JSON trajectory) with no
edits here.

Paths per estimator:
  * ``*_fused``  — the fused Pallas launch (``--interpret`` runs the Pallas
                   interpreter off-TPU; compiled on TPU),
  * ``*_jnp``    — the XLA mirror (flat matmul + segmented products for RM,
                   CountSketch + jnp.fft for TensorSketch, complex64
                   products for CTR): what CPU runs in production.

Writes ``BENCH_sketch.json`` at the repo root (uploaded as a CI artifact by
the benchmark smoke job) so later PRs have a cross-estimator perf
trajectory; docs/estimators.md quotes the matched-budget comparison.

Usage: python benchmarks/sketch_bench.py [--interpret] [--quick]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ExponentialDotProductKernel,
    PolynomialKernel,
    make_feature_map,
    registry,
)

# (label, kernel, d, F, batch)
_CONFIGS = [
    ("exp_d64_F256_b1024", ExponentialDotProductKernel(1.0), 64, 256, 1024),
    ("poly7_d32_F512_b512", PolynomialKernel(7, 1.0), 32, 512, 512),
    ("exp_d24_F192_b512", ExponentialDotProductKernel(1.0), 24, 192, 512),
]
_QUICK_CONFIGS = [
    ("exp_d16_F128_b128", ExponentialDotProductKernel(1.0), 16, 128, 128),
    ("poly7_d16_F128_b128", PolynomialKernel(7, 1.0), 16, 128, 128),
]


def _time_call(fn, x, repeats: int = 5) -> float:
    """Median wall-time (us) of a jitted call, excluding compile."""
    fn(x).block_until_ready()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def _gram_rmse(fm, kern, d: int, n_points: int = 64) -> float:
    X = jax.random.normal(jax.random.PRNGKey(7), (n_points, d))
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * 0.8
    K = np.asarray(kern.gram(X))
    est = np.asarray(fm.estimate_gram(X))
    return float(np.sqrt(np.mean((est - K) ** 2)))


def run(interpret: bool = False, quick: bool = False, repeats: int = 5):
    on_tpu = jax.default_backend() == "tpu"
    configs = _QUICK_CONFIGS if quick else _CONFIGS
    results = {}
    for label, kern, d, F, batch in configs:
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, d)) * 0.2
        entry = {"d": d, "F": F, "batch": batch}
        for est in registry.list_estimators():
            fm = make_feature_map(kern, d, F, jax.random.PRNGKey(0),
                                  estimator=est, measure="proportional")
            paths = {
                "fused": jax.jit(lambda xx, f=fm: f.apply(
                    xx, use_pallas=True, interpret=interpret or not on_tpu)),
                "jnp": jax.jit(lambda xx, f=fm: f.apply(
                    xx, use_pallas=False)),
            }
            for path, fn in paths.items():
                us = _time_call(fn, x, repeats=repeats)
                feats_per_s = batch * fm.output_dim / (us * 1e-6)
                entry[f"{est}_{path}_us"] = us
                entry[f"{est}_{path}_feats_per_s"] = feats_per_s
                yield f"sketch/{label}/{est}/{path},{us:.1f},{feats_per_s:.3e}"
            entry[f"{est}_output_dim"] = fm.output_dim
            entry[f"{est}_gram_rmse"] = _gram_rmse(fm, kern, d)
            yield (f"sketch/{label}/{est}/gram_rmse,"
                   f"{entry[f'{est}_gram_rmse']:.5f}")
        # matched-budget speedups vs the RM baseline, one key per family
        for est in registry.list_estimators():
            if est == "rm":
                continue
            short = {"tensor_sketch": "ts"}.get(est, est)
            key = f"{short}_vs_rm_jnp_speedup"
            entry[key] = entry["rm_jnp_us"] / entry[f"{est}_jnp_us"]
            yield f"sketch/{label}/{key},{entry[key]:.3f}"
        results[label] = entry

    out = Path(__file__).resolve().parent.parent / "BENCH_sketch.json"
    out.write_text(json.dumps(
        {"backend": jax.default_backend(), "interpret": interpret,
         "quick": quick, "results": results}, indent=2
    ))
    yield f"wrote {out}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--interpret", action="store_true",
                    help="run the Pallas paths in interpret mode (CPU CI)")
    ap.add_argument("--quick", action="store_true",
                    help="small configs / fewer repeats (CI smoke)")
    args = ap.parse_args()
    for row in run(interpret=args.interpret, quick=args.quick,
                   repeats=2 if args.quick else 5):
        print(row)
