"""Benchmark: paper Figure 1 — kernel approximation error vs D.

Emits ``name,us_per_call,derived`` CSV rows: the derived column is the mean
absolute Gram error; us_per_call times the feature-map application.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    make_feature_map,
)


def run() -> List[str]:
    rows = []
    d = 50
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (100, d))
    x = x / (jnp.linalg.norm(x, axis=1, keepdims=True) * 1.01)
    kernels = {
        "homog10": HomogeneousPolynomialKernel(10),
        "poly10": PolynomialKernel(10, 1.0),
        "exp": ExponentialDotProductKernel(1.0),
    }
    for kname, kern in kernels.items():
        exact = np.asarray(kern.gram(x))
        scale = max(1.0, np.abs(exact).max())
        for D in (100, 1000, 4000):
            fm = make_feature_map(kern, d, D, jax.random.PRNGKey(D))
            apply = jax.jit(lambda xx: fm(xx))
            z = apply(x)
            err = float(np.abs(np.asarray(z @ z.T) - exact).mean() / scale)
            t0 = time.perf_counter()
            for _ in range(5):
                apply(x).block_until_ready()
            us = (time.perf_counter() - t0) / 5 * 1e6
            rows.append(f"fig1/{kname}/D{D},{us:.1f},{err:.5f}")
    return rows
