# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV. Sub-benchmarks: fig1 (approximation error), table1 (SVM suite),
# fig2 (H0/1), rm_attn (fused featurize+attention vs two-launch, writes
# BENCH_rm_attention.json), rm_feature (fused vs per-bucket feature map,
# writes BENCH_rm_feature.json), roofline (dry-run derived terms).
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (  # noqa: WPS433 - runtime import keeps startup light
        fig1_approx,
        fig2_h01,
        rm_attention_bench,
        rm_feature_bench,
        roofline_bench,
        table1_svm,
    )

    print("name,us_per_call,derived")
    suites = [
        ("fig1", fig1_approx.run),
        ("table1", table1_svm.run),
        ("fig2", fig2_h01.run),
        ("rm_attn", rm_attention_bench.run),
        ("rm_feature", rm_feature_bench.run),
        ("roofline", roofline_bench.run),
    ]
    failed = False
    for name, fn in suites:
        try:
            for row in fn():
                print(row, flush=True)
        except Exception:  # noqa: BLE001
            failed = True
            print(f"{name}/ERROR,0,0", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
