"""Thin CLI over ``repro.bench.roofline`` (dry-run roofline rows).

Kept at this path for ``benchmarks/run.py`` and muscle memory; the logic
lives in the bench subsystem.
"""
from __future__ import annotations

from pathlib import Path
from typing import List

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun" / "single"


def run() -> List[str]:
    from repro.bench.roofline import dryrun_roofline_rows

    return dryrun_roofline_rows(RESULTS)


if __name__ == "__main__":
    for row in run():
        print(row)
