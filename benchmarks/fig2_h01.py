"""Benchmark: paper Figure 2 — H0/1 vs plain RF accuracy as D grows.

Row: ``fig2/<dataset>/D<D>/<variant>,us_per_call,acc``.
"""
from __future__ import annotations

import time
from typing import List

import jax

from repro.core import PolynomialKernel, make_feature_map, train_linear
from repro.data.toy import make_classification_dataset

KERNEL = PolynomialKernel(10, 1.0)


def run() -> List[str]:
    rows = []
    for name in ("spambase", "nursery"):
        ds = make_classification_dataset(name)
        d = ds["x_train"].shape[1]
        for D in (25, 100, 400):
            for variant, h01 in (("rf", False), ("h01", True)):
                t0 = time.perf_counter()
                fm = make_feature_map(KERNEL, d, D, jax.random.PRNGKey(D),
                                      h01=h01)
                ztr = fm(ds["x_train"])
                lin = train_linear(ztr, ds["y_train"], lam=1e-5)
                zte = fm(ds["x_test"])
                acc = lin.accuracy(zte, ds["y_test"])
                us = (time.perf_counter() - t0) * 1e6
                rows.append(f"fig2/{name}/D{D}/{variant},{us:.0f},{acc:.4f}")
    return rows
