"""Benchmark: paper Table 1 — exact-kernel classifier vs RF vs H0/1:
accuracy + train/test wall time + speedups, on UCI-like synthetic datasets
(matched N, d — see repro.data.toy).

Row format: ``table1/<dataset>/<method>,us_per_call,acc`` where us_per_call
is the TEST-time cost per example (the paper's headline speedup axis), and a
companion row carries the training time.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.core import (
    PolynomialKernel,
    make_feature_map,
    train_kernel_svm,
    train_linear,
)
from repro.data.toy import make_classification_dataset

DATASETS = ("nursery", "spambase", "ijcnn")
KERNEL = PolynomialKernel(10, 1.0)
N_KERNEL_TRAIN = 1200   # exact Gram solves are O(N^2)-O(N^3): cap like LIBSVM
D_RF = 500
D_H01 = 100


def _time(fn, *args):
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return out, time.perf_counter() - t0


def run() -> List[str]:
    rows = []
    for name in DATASETS:
        ds = make_classification_dataset(name)
        xtr, ytr = ds["x_train"], ds["y_train"]
        xte, yte = ds["x_test"], ds["y_test"]
        d = xtr.shape[1]

        # --- exact kernel (LIBSVM stand-in) -------------------------------
        xk, yk = xtr[:N_KERNEL_TRAIN], ytr[:N_KERNEL_TRAIN]
        t0 = time.perf_counter()
        gram = KERNEL.gram(xk)
        _, ksvm = train_kernel_svm(gram, yk, C=1.0, kernel_fn=KERNEL.gram,
                                   X_train=xk)
        jax.block_until_ready(gram)
        trn_k = time.perf_counter() - t0
        t0 = time.perf_counter()
        acc_k = ksvm.accuracy(xte, yte)
        tst_k = time.perf_counter() - t0

        # --- RF: random features + linear ---------------------------------
        t0 = time.perf_counter()
        fm = make_feature_map(KERNEL, d, D_RF, jax.random.PRNGKey(0))
        ztr = fm(xtr)
        lin = train_linear(ztr, ytr, lam=1e-5)
        jax.block_until_ready(ztr)
        trn_rf = time.perf_counter() - t0
        t0 = time.perf_counter()
        zte = fm(xte)
        acc_rf = lin.accuracy(zte, yte)
        tst_rf = time.perf_counter() - t0

        # --- H0/1 ----------------------------------------------------------
        t0 = time.perf_counter()
        fmh = make_feature_map(KERNEL, d, D_H01, jax.random.PRNGKey(1),
                               h01=True)
        ztrh = fmh(xtr)
        linh = train_linear(ztrh, ytr, lam=1e-5)
        jax.block_until_ready(ztrh)
        trn_h = time.perf_counter() - t0
        t0 = time.perf_counter()
        zteh = fmh(xte)
        acc_h = linh.accuracy(zteh, yte)
        tst_h = time.perf_counter() - t0

        n_te = xte.shape[0]
        rows += [
            f"table1/{name}/kernel_test,{tst_k / n_te * 1e6:.1f},{acc_k:.4f}",
            f"table1/{name}/rf_test,{tst_rf / n_te * 1e6:.1f},{acc_rf:.4f}",
            f"table1/{name}/h01_test,{tst_h / n_te * 1e6:.1f},{acc_h:.4f}",
            f"table1/{name}/kernel_train,{trn_k * 1e6:.0f},{acc_k:.4f}",
            f"table1/{name}/rf_train,{trn_rf * 1e6:.0f},{acc_rf:.4f}",
            f"table1/{name}/h01_train,{trn_h * 1e6:.0f},{acc_h:.4f}",
            f"table1/{name}/speedup_tst_rf,{tst_k / max(tst_rf, 1e-9):.1f},0",
            f"table1/{name}/speedup_tst_h01,{tst_k / max(tst_h, 1e-9):.1f},0",
        ]
    return rows
