"""Thin CLI over ``repro.bench``: fused featurize+attention vs two-launch.

Runs the unified bench grid restricted to the RM family — the feature-map
cells plus the ``fused_attention`` section (fused featurize+attention
Pallas kernel vs the two-launch featurize-then-attend composition, with
the analytic HBM-bytes columns showing the removed Z(x) round-trip,
DESIGN.md §13). The grid, timing discipline, metrics and JSON schema all
come from ``repro.bench``; this script only picks the spec and the output
name.

Writes ``BENCH_rm_attention.json`` at the repo root in the canonical
schema (``repro.bench.schema``), so the fused-vs-two-launch speedup rows
have a trajectory next to BENCH_core.json's.

Usage: python benchmarks/rm_attention_bench.py [--interpret] [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

_OUT = Path(__file__).resolve().parent.parent / "BENCH_rm_attention.json"


def run(interpret: bool = False, quick: bool = False, repeats: int = 5):
    """Generator of CSV rows (benchmarks/run.py contract); writes the JSON."""
    from repro.bench import default_spec, quick_spec, run_spec

    spec = (quick_spec(interpret=interpret) if quick
            else default_spec(interpret=interpret, repeats=repeats))
    spec = dataclasses.replace(spec, estimators=("rm",))
    rows = []
    payload = run_spec(spec, emit=rows.append)
    yield from rows
    _OUT.write_text(json.dumps(payload, indent=2) + "\n")
    yield f"wrote {_OUT}"


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--interpret", action="store_true",
                    help="run the Pallas paths in interpret mode (CPU CI)")
    ap.add_argument("--quick", action="store_true",
                    help="small configs / fewer repeats")
    args = ap.parse_args()
    for row in run(interpret=args.interpret, quick=args.quick,
                   repeats=2 if args.quick else 5):
        print(row)
