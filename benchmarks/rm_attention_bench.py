"""Benchmark: RM linear attention (the paper's technique applied to the
softmax kernel) vs exact attention — wall time and approximation quality on
CPU at small scale, plus the asymptotic op-count ratio.

Row: ``rm_attn/<T>/<impl>,us_per_call,derived`` where derived is the mean
absolute error vs exact softmax attention (for rm rows) or 0 (exact rows).
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ExponentialDotProductKernel, make_feature_map
from repro.kernels.rm_attention.ops import rm_attention_causal


def _exact(q, k, v):
    t = q.shape[2]
    s = jnp.einsum("bhtd,bhsd->bhts", q, k)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    s = jnp.where(mask, s, -1e30)
    return jnp.einsum("bhts,bhsd->bhtd", jax.nn.softmax(s, -1), v)


def run() -> List[str]:
    rows = []
    b, h, dh, dv = 1, 4, 32, 32
    kern = ExponentialDotProductKernel(1.0)
    fm = make_feature_map(kern, dh, 192, jax.random.PRNGKey(0),
                          measure="proportional", stratified=True)
    for t in (256, 1024):
        key = jax.random.PRNGKey(t)
        kq, kk, kv = jax.random.split(key, 3)
        q = jax.random.normal(kq, (b, h, t, dh))
        k = jax.random.normal(kk, (b, h, t, dh))
        q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
        k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
        v = jax.random.normal(kv, (b, h, t, dv))

        exact_fn = jax.jit(_exact)
        want = exact_fn(q, k, v)
        t0 = time.perf_counter()
        for _ in range(3):
            exact_fn(q, k, v).block_until_ready()
        us_exact = (time.perf_counter() - t0) / 3 * 1e6

        def rm_fn(q, k, v):
            zq = fm(q)
            zk = fm(k)
            return rm_attention_causal(zq, zk, v, chunk=128,
                                       use_pallas=False)

        rm_jit = jax.jit(rm_fn)
        got = rm_jit(q, k, v)
        err = float(jnp.mean(jnp.abs(got - want)))
        t0 = time.perf_counter()
        for _ in range(3):
            rm_jit(q, k, v).block_until_ready()
        us_rm = (time.perf_counter() - t0) / 3 * 1e6

        rows.append(f"rm_attn/T{t}/exact,{us_exact:.0f},0")
        rows.append(f"rm_attn/T{t}/rm_D192,{us_rm:.0f},{err:.4f}")
    return rows
