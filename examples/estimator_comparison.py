"""Compare the registry estimators on one kernel-approximation task.

Builds a feature map per requested estimator ("rm", "tensor_sketch",
"ctr", ...) at the SAME feature budget from the estimator registry, then
reports Gram RMSE against the exact kernel and the accuracy of a linear
classifier trained on each feature set — the paper's Table-1 pipeline,
estimator-swapped with one string.

Run: PYTHONPATH=src python examples/estimator_comparison.py

``--estimators a,b,...`` restricts the comparison; the default is EVERY
registry entry, so a newly registered estimator appears in this comparison
(and in docs/estimators.md regenerated from it) with zero edits here.

``--devices N`` forces N host devices and ALSO runs every estimator through
the sharded execution path (features over the "rm_features" mesh axis,
Gram via one psum — repro.distributed.estimator), printing the sharded
Gram RMSE next to the single-device one. On CPU this exercises the same
code path an accelerator mesh runs.
"""
import argparse
import os


def main(devices: int = 0, estimators: str = ""):
    # heavy imports happen AFTER the XLA device-count flag is set
    import jax
    import numpy as np

    from repro.core import (
        ExponentialDotProductKernel,
        make_feature_map,
        registry,
        train_featurized_linear,
    )
    from repro.data.toy import make_classification_dataset

    kern = ExponentialDotProductKernel(1.0)
    data = make_classification_dataset("adult", seed=0)
    Xtr, ytr = data["x_train"][:2000], data["y_train"][:2000]
    Xte, yte = data["x_test"][:1000], data["y_test"][:1000]
    d = Xtr.shape[1]
    F = 512

    mesh = None
    if devices > 1:
        if F % devices != 0:
            raise SystemExit(
                f"--devices must divide the F={F} feature budget evenly "
                f"(got {devices}); try 2, 4, 8, ..."
            )
        from repro.launch.mesh import make_feature_mesh

        mesh = make_feature_mesh(devices)

    names = ([s.strip() for s in estimators.split(",") if s.strip()]
             if estimators else list(registry.list_estimators()))
    for name in names:
        registry.get(name)  # validate early, with the available-name list

    K_exact = np.asarray(kern.gram(Xte[:256]))
    print(f"kernel={kern.name}  d={d}  F={F}  devices={len(jax.devices())}")
    print(f"available estimators: {registry.list_estimators()}")

    for name in names:
        fm = make_feature_map(kern, d, F, jax.random.PRNGKey(0),
                              estimator=name, measure="proportional")
        est = np.asarray(fm.estimate_gram(Xte[:256]))
        rmse = float(np.sqrt(np.mean((est - K_exact) ** 2)))
        clf = train_featurized_linear(fm, Xtr, ytr, lam=1e-4, n_iters=15)
        acc = clf.accuracy(Xte, yte)
        line = (f"  {name:>14}: output_dim={fm.output_dim:4d}  "
                f"gram_rmse={rmse:.4f}  test_acc={acc:.3f}  "
                f"trunc_bias={fm.truncation_bias(1.0):.2e}")
        if mesh is not None:
            sfm = make_feature_map(kern, d, F, jax.random.PRNGKey(0),
                                   estimator=name, measure="proportional",
                                   mesh=mesh)
            sh = np.asarray(sfm.estimate_gram(Xte[:256]))
            srmse = float(np.sqrt(np.mean((sh - K_exact) ** 2)))
            line += (f"  sharded[{sfm.num_shards}x"
                     f"{sfm.shard_output_dim}]_rmse={srmse:.4f}")
        print(line)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices and add the sharded-execution "
                         "comparison (set BEFORE jax initializes)")
    ap.add_argument("--estimators", type=str, default="",
                    help="comma-separated registry names to compare "
                         "(default: every registry entry)")
    args = ap.parse_args()
    if args.devices > 1:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    main(args.devices, args.estimators)
