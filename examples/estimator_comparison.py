"""Compare the two registry estimators on one kernel-approximation task.

Builds a Random Maclaurin map and a TensorSketch map at the SAME feature
budget from the estimator registry, then reports Gram RMSE against the exact
kernel and the accuracy of a linear classifier trained on each feature set —
the paper's Table-1 pipeline, estimator-swapped with one string.

Run: PYTHONPATH=src python examples/estimator_comparison.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ExponentialDotProductKernel,
    make_feature_map,
    registry,
    train_featurized_linear,
)
from repro.data.toy import make_classification_dataset


def main():
    kern = ExponentialDotProductKernel(1.0)
    data = make_classification_dataset("adult", seed=0)
    Xtr, ytr = data["x_train"][:2000], data["y_train"][:2000]
    Xte, yte = data["x_test"][:1000], data["y_test"][:1000]
    d = Xtr.shape[1]
    F = 512

    K_exact = np.asarray(kern.gram(Xte[:256]))
    print(f"kernel={kern.name}  d={d}  F={F}")
    print(f"available estimators: {registry.available()}")

    for name in registry.available():
        fm = make_feature_map(kern, d, F, jax.random.PRNGKey(0),
                              estimator=name, measure="proportional")
        est = np.asarray(fm.estimate_gram(Xte[:256]))
        rmse = float(np.sqrt(np.mean((est - K_exact) ** 2)))
        clf = train_featurized_linear(fm, Xtr, ytr, lam=1e-4, n_iters=15)
        acc = clf.accuracy(Xte, yte)
        print(f"  {name:>14}: output_dim={fm.output_dim:4d}  "
              f"gram_rmse={rmse:.4f}  test_acc={acc:.3f}  "
              f"trunc_bias={fm.truncation_bias(1.0):.2e}")


if __name__ == "__main__":
    main()
