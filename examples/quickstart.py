"""Quickstart: the paper in 60 seconds.

Builds Random Maclaurin feature maps (Algorithm 1) for three dot product
kernels, checks the kernel approximation, trains a LINEAR classifier on the
features that matches an exact-kernel classifier (the paper's headline
claim), and shows the H0/1 heuristic (§6.1).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    constants_for,
    make_feature_map,
    train_kernel_svm,
    train_linear,
)
from repro.data.toy import make_classification_dataset


def main():
    key = jax.random.PRNGKey(0)

    # --- 1. approximate three kernels ------------------------------------
    print("=== kernel approximation (paper Fig. 1 setting) ===")
    x = jax.random.normal(key, (100, 20))
    x = x / (jnp.linalg.norm(x, axis=1, keepdims=True) * 1.01)
    for kern in (HomogeneousPolynomialKernel(10), PolynomialKernel(10, 1.0),
                 ExponentialDotProductKernel(1.0)):
        exact = np.asarray(kern.gram(x))
        for D in (100, 1000, 5000):
            fm = make_feature_map(kern, 20, D, key)
            err = np.abs(np.asarray(fm.estimate_gram(x)) - exact).mean()
            print(f"  {kern.name:22s} D={D:5d} mean |err| = {err:8.4f}")

    # --- 2. linear model on RM features == kernel machine ----------------
    print("\n=== RM features + linear model vs exact kernel SVM ===")
    ds = make_classification_dataset("spambase")
    kern = PolynomialKernel(10, 1.0)
    gram = kern.gram(ds["x_train"][:1500])
    _, ksvm = train_kernel_svm(gram, ds["y_train"][:1500], C=1.0,
                               kernel_fn=kern.gram,
                               X_train=ds["x_train"][:1500])
    acc_k = ksvm.accuracy(ds["x_test"], ds["y_test"])

    fm = make_feature_map(kern, ds["x_train"].shape[1], 500,
                          jax.random.PRNGKey(1))
    z_train, z_test = fm(ds["x_train"]), fm(ds["x_test"])
    lin = train_linear(z_train, ds["y_train"], lam=1e-5)
    acc_rf = lin.accuracy(z_test, ds["y_test"])
    print(f"  exact kernel SVM acc = {acc_k:.3f}   "
          f"RM(D=500) + linear acc = {acc_rf:.3f}")

    # --- 3. H0/1 heuristic ------------------------------------------------
    fm_h = make_feature_map(kern, ds["x_train"].shape[1], 100,
                            jax.random.PRNGKey(2), h01=True)
    lin_h = train_linear(fm_h(ds["x_train"]), ds["y_train"], lam=1e-5)
    acc_h = lin_h.accuracy(fm_h(ds["x_test"]), ds["y_test"])
    print(f"  H0/1 (D=100 + raw features) acc = {acc_h:.3f}")

    # --- 4. Theorem 12: how many features for eps-uniform error? ----------
    print("\n=== Theorem 12 required D (eps=0.2, delta=0.1, d=20) ===")
    c = constants_for(ExponentialDotProductKernel(1.0), radius=1.0, dim=20)
    print(f"  paper geometric measure : D >= {c.required_d(0.2, 0.1):,}")
    print(f"  proportional measure    : D >= "
          f"{c.required_d(0.2, 0.1, 'proportional'):,} (beyond-paper)")


if __name__ == "__main__":
    main()
