"""Serving example: continuous batching over decode slots, exact-KV vs the
paper's RM O(1)-state attention.

    PYTHONPATH=src python examples/serve_lm.py --attention-mode rm

Reports aggregate tokens/s and per-request TTFT; with --attention-mode rm
the per-lane state is constant-size (no KV growth), which is what makes the
long_500k dry-run cell feasible at scale.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_model
from repro.serve import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--attention-mode", default="exact",
                    choices=["exact", "rm"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True,
                     attention_mode=args.attention_mode)
    params = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, num_slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        n = int(rng.integers(4, 20))
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab_size, size=n),
                              max_new_tokens=args.max_new,
                              temperature=0.8 if i % 2 else 0.0))
    done = engine.run()
    wall = time.time() - t0

    toks = sum(len(s.generated) for s in done.values())
    print(f"[serve_lm] mode={args.attention_mode}: {len(done)} requests, "
          f"{toks} tokens, {wall:.1f}s, {toks / wall:.1f} tok/s aggregate")
    for rid in sorted(done):
        s = done[rid]
        print(f"  req {rid}: prompt={len(s.request.prompt):3d} tokens -> "
              f"{s.generated[:8]}{'...' if len(s.generated) > 8 else ''}")


if __name__ == "__main__":
    main()
