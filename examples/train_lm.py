"""End-to-end training driver: train an LM on the synthetic Markov corpus
with checkpointing + crash recovery, in EXACT or RM (paper) attention mode.

Quick CPU run (a ~1M-param model, loss visibly dropping in ~50 steps):

    PYTHONPATH=src python examples/train_lm.py --preset quick

The ~100M-parameter configuration (same code path; takes hours on 1 CPU
core, minutes on real accelerators):

    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse

import jax

from repro.data.synthetic import SyntheticLMDataset
from repro.models.config import ModelConfig, RMAttentionConfig
from repro.train.steps import TrainHyper
from repro.train.trainer import Trainer

PRESETS = {
    # ~1.1M params: runs in ~1 min on this CPU container
    "quick": dict(num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
                  d_ff=256, vocab_size=512, seq=128, batch=8),
    # ~10M params
    "10m": dict(num_layers=4, d_model=384, num_heads=6, num_kv_heads=6,
                d_ff=1024, vocab_size=2048, seq=256, batch=8),
    # ~100M params (GPT-2-small-ish)
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 d_ff=3072, vocab_size=8192, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="quick", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--attention-mode", default="exact",
                    choices=["exact", "rm"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}-{args.attention_mode}",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        attention_mode=args.attention_mode,
        rm=RMAttentionConfig(num_features=128, n_max=6),
        tie_embeddings=True,
    ).validate()
    data = SyntheticLMDataset(vocab_size=p["vocab_size"], seq_len=p["seq"],
                              global_batch=p["batch"])
    hyper = TrainHyper(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                       total_steps=args.steps)
    trainer = Trainer(cfg, hyper, data, ckpt_dir=args.ckpt_dir,
                      log_every=max(args.steps // 20, 1))
    state = trainer.train(args.steps)

    first = trainer.metrics_log[0]["ce"]
    last = trainer.metrics_log[-1]["ce"]
    import math
    uniform = math.log(p["vocab_size"])
    print(f"\n[train_lm] ce: {first:.3f} -> {last:.3f} "
          f"(uniform baseline {uniform:.3f}); "
          f"{'LEARNED' if last < first - 0.3 else 'check hyperparams'}")
    return state


if __name__ == "__main__":
    main()
