"""Paper Figure 1 reproduction: approximation error vs number of random
features D, for the homogeneous polynomial, polynomial and exponential dot
product kernels; with/without H0/1; paper-faithful iid sampling vs the
beyond-paper proportional measure.

    PYTHONPATH=src python examples/kernel_approximation.py [--full]

Writes a CSV table to results/fig1_approx_error.csv.
"""
import argparse
import csv
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    make_feature_map,
)

OUT = Path(__file__).resolve().parents[1] / "results"


def run(full: bool = False):
    dims = (10, 50, 200) if full else (10, 50)
    ds = (10, 50, 100, 500, 1000, 5000) if full else (10, 100, 1000)
    reps = 5 if full else 3
    kernels = {
        "homogeneous": HomogeneousPolynomialKernel(10),
        "polynomial": PolynomialKernel(10, 1.0),
        "exponential": ExponentialDotProductKernel(1.0),
    }
    rows = []
    for d in dims:
        key = jax.random.PRNGKey(d)
        x = jax.random.normal(key, (100, d))
        x = x / (jnp.linalg.norm(x, axis=1, keepdims=True) * 1.01)
        for kname, kern in kernels.items():
            exact = np.asarray(kern.gram(x))
            for D in ds:
                for variant in ("rf", "h01", "proportional"):
                    if variant == "h01" and kname == "homogeneous":
                        continue  # a_0 = a_1 = 0 (paper §6.2)
                    errs = []
                    for r in range(reps):
                        fm = make_feature_map(
                            kern, d, D, jax.random.PRNGKey(1000 * r + D + d),
                            h01=(variant == "h01"),
                            measure=("proportional"
                                     if variant == "proportional"
                                     else "geometric"),
                            stratified=(variant == "proportional"),
                        )
                        approx = np.asarray(fm.estimate_gram(x))
                        errs.append(np.abs(approx - exact).mean())
                    rows.append({
                        "kernel": kname, "d": d, "D": D, "variant": variant,
                        "mean_abs_err": float(np.mean(errs)),
                        "std": float(np.std(errs)),
                    })
                    print(f"  {kname:12s} d={d:3d} D={D:5d} {variant:13s} "
                          f"err={np.mean(errs):.4f}")
    OUT.mkdir(exist_ok=True, parents=True)
    with open(OUT / "fig1_approx_error.csv", "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
    print(f"wrote {OUT / 'fig1_approx_error.csv'}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)
