#!/usr/bin/env python
"""Validate a repro.obs JSONL trace: schema, record shape, lifecycle coverage.

    PYTHONPATH=src python tools/check_trace.py trace.jsonl \
        [--require-spans prefill,decode/step] [--require-events ...]

Checks (the CI ``obs-smoke`` job gates on these):

* first record is a ``meta`` header with ``schema == repro.obs.trace/v1``
  and a provenance stamp (backend/device_kind/interpret/jax_version);
* every record parses as JSON and has the right fields for its type
  (spans: name/ts_us/dur_us, events: name/ts_us, both: dict attrs);
* span durations are non-negative and timestamps non-decreasing per type
  is NOT required (spans are emitted at close, so starts interleave) —
  but every ts_us must be a finite number;
* the required lifecycle names are present. Defaults cover a serve run:
  ``request/submit -> request/admit -> prefill -> decode/step ->
  request/finish``;
* per-request lifecycles are WELL-FORMED (``check_request_lifecycles``):
  one submit before anything else, admits and evicts alternate, at most
  one finish and it is terminal, and no two in-flight requests ever hold
  the same slot (the scheduler's no-double-assignment invariant, replayed
  from the event stream — events are recorded in call order, so the
  interleaving is faithful even though spans close out of order);
* the trace converts to a Chrome ``traceEvents`` dict (what Perfetto
  loads) without error.

``check_records`` / ``check_request_lifecycles`` are importable for
in-process use — the obs concurrency tests validate live ``Tracer.records``
without touching disk.

Exit code 0 = valid, 1 = failures (each printed on its own line).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

REQUIRED_EVENTS = ("request/submit", "request/admit", "request/finish")
REQUIRED_SPANS = ("prefill", "decode/step")
PROVENANCE_KEYS = ("backend", "device_kind", "interpret", "jax_version")


def check_request_lifecycles(records):
    """Lifecycle errors over the ``request/*`` EVENT stream (empty = valid).

    Replays the per-request state machine
    ``submit -> (admit -> evict)* -> admit -> finish`` and the global
    slot-occupancy map: an ``admit`` into a slot another request currently
    holds, an ``evict``/``finish`` without a live admission, a second
    ``submit``, or activity after ``finish`` are all violations. Requests
    still queued or in-flight at the end of the trace are fine (truncated
    runs are legal) — only ORDER is policed here.
    """
    errors = []
    phase = {}          # rid -> "queued" | "running" | "done"
    slot_of = {}        # rid -> slot currently held
    occupant = {}       # slot -> rid
    for i, rec in enumerate(records):
        if rec.get("type") != "event":
            continue
        name = rec.get("name", "")
        if not name.startswith("request/"):
            continue
        attrs = rec.get("attrs") or {}
        rid = attrs.get("request_id")
        if rid is None:
            errors.append(f"record {i + 1} ({name}): no request_id attr")
            continue
        where = f"record {i + 1} (request {rid})"
        if name == "request/submit":
            if rid in phase:
                errors.append(f"{where}: duplicate submit "
                              f"(phase was {phase[rid]!r})")
            phase[rid] = "queued"
        elif name == "request/admit":
            if phase.get(rid) != "queued":
                errors.append(f"{where}: admit while "
                              f"{phase.get(rid, 'never submitted')!r}")
            slot = attrs.get("slot")
            if slot is None:
                errors.append(f"{where}: admit has no slot attr")
            else:
                holder = occupant.get(slot)
                if holder is not None and holder != rid:
                    errors.append(
                        f"{where}: admitted into slot {slot} already "
                        f"held by request {holder} (double-assignment)")
                occupant[slot] = rid
                slot_of[rid] = slot
            phase[rid] = "running"
        elif name == "request/evict":
            if phase.get(rid) != "running":
                errors.append(f"{where}: evict while "
                              f"{phase.get(rid, 'never submitted')!r}")
            else:
                occupant.pop(slot_of.pop(rid, None), None)
                phase[rid] = "queued"
        elif name == "request/finish":
            if phase.get(rid) != "running":
                errors.append(f"{where}: finish while "
                              f"{phase.get(rid, 'never submitted')!r}")
            else:
                occupant.pop(slot_of.pop(rid, None), None)
                phase[rid] = "done"
    return errors


def check_records(records, require_events=REQUIRED_EVENTS,
                  require_spans=REQUIRED_SPANS, lifecycles=True):
    """Validate an in-memory record list (e.g. a live ``Tracer.records``).

    The record-shape, required-name, lifecycle and Chrome-conversion
    checks of :func:`check_trace`, minus the file/meta-header handling —
    the in-process entry point for tests that interleave requests and
    want the trace policed without a round-trip through disk.
    """
    errors = []
    names = {"span": set(), "event": set()}
    body = [r for r in records if r.get("type") != "meta"]
    for i, rec in enumerate(body, start=1):
        kind = rec.get("type")
        if kind not in ("span", "event"):
            errors.append(f"record {i}: unknown type {kind!r}")
            continue
        if not isinstance(rec.get("name"), str) or not rec["name"]:
            errors.append(f"record {i}: missing name")
            continue
        ts = rec.get("ts_us")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            errors.append(f"record {i} ({rec['name']}): bad ts_us {ts!r}")
        if not isinstance(rec.get("attrs", {}), dict):
            errors.append(f"record {i} ({rec['name']}): attrs not a dict")
        if kind == "span":
            dur = rec.get("dur_us")
            if not isinstance(dur, (int, float)) or not math.isfinite(dur) \
                    or dur < 0:
                errors.append(
                    f"record {i} ({rec['name']}): bad dur_us {dur!r}")
        names[kind].add(rec["name"])

    for name in require_events:
        if name not in names["event"]:
            errors.append(f"required event {name!r} never recorded "
                          f"(saw: {sorted(names['event'])})")
    for name in require_spans:
        if name not in names["span"]:
            errors.append(f"required span {name!r} never recorded "
                          f"(saw: {sorted(names['span'])})")

    if lifecycles:
        errors += check_request_lifecycles(body)

    try:
        from repro.obs import chrome_trace

        chrome = chrome_trace(records)
        if not chrome.get("traceEvents"):
            errors.append("chrome conversion produced no traceEvents")
    except Exception as e:  # noqa: BLE001 - report, don't crash the gate
        errors.append(f"chrome conversion failed: {e}")
    return errors


def check_trace(path, require_events=REQUIRED_EVENTS,
                require_spans=REQUIRED_SPANS):
    """Return a list of human-readable failure strings (empty = valid)."""
    errors = []
    try:
        lines = Path(path).read_text().splitlines()
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    records = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            records.append(json.loads(line))
        except ValueError as e:
            errors.append(f"line {i + 1}: not valid JSON ({e})")
    if not records:
        return errors + ["trace is empty"]

    meta = records[0]
    if meta.get("type") != "meta":
        errors.append("first record must be the meta header, got "
                      f"type={meta.get('type')!r}")
    else:
        from repro.obs import TRACE_SCHEMA

        if meta.get("schema") != TRACE_SCHEMA:
            errors.append(f"meta.schema is {meta.get('schema')!r}, "
                          f"expected {TRACE_SCHEMA!r}")
        prov = meta.get("provenance")
        if not isinstance(prov, dict):
            errors.append("meta.provenance missing or not a dict")
        else:
            for key in PROVENANCE_KEYS:
                if key not in prov:
                    errors.append(f"meta.provenance missing {key!r}")
    for i, rec in enumerate(records[1:], start=2):
        if rec.get("type") == "meta":
            errors.append(f"record {i}: duplicate meta header")
    return errors + check_records(
        [r for r in records[1:] if r.get("type") != "meta"],
        require_events=require_events, require_spans=require_spans)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace file to validate")
    ap.add_argument("--require-events",
                    default=",".join(REQUIRED_EVENTS),
                    help="comma-separated event names that must appear")
    ap.add_argument("--require-spans",
                    default=",".join(REQUIRED_SPANS),
                    help="comma-separated span names that must appear")
    args = ap.parse_args(argv)
    split = lambda s: tuple(x for x in s.split(",") if x)
    errors = check_trace(args.trace,
                         require_events=split(args.require_events),
                         require_spans=split(args.require_spans))
    if errors:
        print(f"TRACE CHECK FAILURES ({args.trace}):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"trace OK: {args.trace}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
