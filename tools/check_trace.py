#!/usr/bin/env python
"""Validate a repro.obs JSONL trace: schema, record shape, lifecycle coverage.

    PYTHONPATH=src python tools/check_trace.py trace.jsonl \
        [--require-spans prefill,decode/step] [--require-events ...]

Checks (the CI ``obs-smoke`` job gates on these):

* first record is a ``meta`` header with ``schema == repro.obs.trace/v1``
  and a provenance stamp (backend/device_kind/interpret/jax_version);
* every record parses as JSON and has the right fields for its type
  (spans: name/ts_us/dur_us, events: name/ts_us, both: dict attrs);
* span durations are non-negative and timestamps non-decreasing per type
  is NOT required (spans are emitted at close, so starts interleave) —
  but every ts_us must be a finite number;
* the required lifecycle names are present. Defaults cover a serve run:
  ``request/submit -> request/admit -> prefill -> decode/step ->
  request/finish``;
* the trace converts to a Chrome ``traceEvents`` dict (what Perfetto
  loads) without error.

Exit code 0 = valid, 1 = failures (each printed on its own line).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

REQUIRED_EVENTS = ("request/submit", "request/admit", "request/finish")
REQUIRED_SPANS = ("prefill", "decode/step")
PROVENANCE_KEYS = ("backend", "device_kind", "interpret", "jax_version")


def check_trace(path, require_events=REQUIRED_EVENTS,
                require_spans=REQUIRED_SPANS):
    """Return a list of human-readable failure strings (empty = valid)."""
    errors = []
    try:
        lines = Path(path).read_text().splitlines()
    except OSError as e:
        return [f"cannot read {path}: {e}"]
    records = []
    for i, line in enumerate(lines):
        line = line.strip()
        if not line:
            continue
        try:
            records.append(json.loads(line))
        except ValueError as e:
            errors.append(f"line {i + 1}: not valid JSON ({e})")
    if not records:
        return errors + ["trace is empty"]

    meta = records[0]
    if meta.get("type") != "meta":
        errors.append("first record must be the meta header, got "
                      f"type={meta.get('type')!r}")
    else:
        from repro.obs import TRACE_SCHEMA

        if meta.get("schema") != TRACE_SCHEMA:
            errors.append(f"meta.schema is {meta.get('schema')!r}, "
                          f"expected {TRACE_SCHEMA!r}")
        prov = meta.get("provenance")
        if not isinstance(prov, dict):
            errors.append("meta.provenance missing or not a dict")
        else:
            for key in PROVENANCE_KEYS:
                if key not in prov:
                    errors.append(f"meta.provenance missing {key!r}")

    names = {"span": set(), "event": set()}
    for i, rec in enumerate(records[1:], start=2):
        kind = rec.get("type")
        if kind not in ("span", "event", "meta"):
            errors.append(f"record {i}: unknown type {kind!r}")
            continue
        if kind == "meta":
            errors.append(f"record {i}: duplicate meta header")
            continue
        if not isinstance(rec.get("name"), str) or not rec["name"]:
            errors.append(f"record {i}: missing name")
            continue
        ts = rec.get("ts_us")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            errors.append(f"record {i} ({rec['name']}): bad ts_us {ts!r}")
        if not isinstance(rec.get("attrs", {}), dict):
            errors.append(f"record {i} ({rec['name']}): attrs not a dict")
        if kind == "span":
            dur = rec.get("dur_us")
            if not isinstance(dur, (int, float)) or not math.isfinite(dur) \
                    or dur < 0:
                errors.append(
                    f"record {i} ({rec['name']}): bad dur_us {dur!r}")
        names[kind].add(rec["name"])

    for name in require_events:
        if name not in names["event"]:
            errors.append(f"required event {name!r} never recorded "
                          f"(saw: {sorted(names['event'])})")
    for name in require_spans:
        if name not in names["span"]:
            errors.append(f"required span {name!r} never recorded "
                          f"(saw: {sorted(names['span'])})")

    try:
        from repro.obs import chrome_trace

        chrome = chrome_trace(records)
        if not chrome.get("traceEvents"):
            errors.append("chrome conversion produced no traceEvents")
    except Exception as e:  # noqa: BLE001 - report, don't crash the gate
        errors.append(f"chrome conversion failed: {e}")
    return errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="JSONL trace file to validate")
    ap.add_argument("--require-events",
                    default=",".join(REQUIRED_EVENTS),
                    help="comma-separated event names that must appear")
    ap.add_argument("--require-spans",
                    default=",".join(REQUIRED_SPANS),
                    help="comma-separated span names that must appear")
    args = ap.parse_args(argv)
    split = lambda s: tuple(x for x in s.split(",") if x)
    errors = check_trace(args.trace,
                         require_events=split(args.require_events),
                         require_spans=split(args.require_spans))
    if errors:
        print(f"TRACE CHECK FAILURES ({args.trace}):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"trace OK: {args.trace}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
