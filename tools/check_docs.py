"""Docs gate for CI: markdown link check + quickstart execution.

Two checks (ISSUE 4 satellite — the CI ``docs`` job runs this):

1. **Link check** — every relative markdown link in ``README.md``,
   ``docs/*.md`` and ``DESIGN.md`` must resolve to an existing file or
   directory (anchors are stripped; ``http(s)``/``mailto`` links are
   skipped — CI has no network).
2. **Quickstart smoke** — every fenced ``python`` block in
   ``docs/quickstart.md`` is executed (in one shared namespace, in order).
   The quickstart IS the product's first impression; if it drifts from the
   code, this turns CI red.

Usage: PYTHONPATH=src python tools/check_docs.py
Exits non-zero on the first category of failure, listing every offender.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets must exist too.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _doc_files() -> list[Path]:
    files = [ROOT / "README.md", ROOT / "DESIGN.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check_links() -> list[str]:
    """Return a list of 'file: broken-target' strings."""
    broken = []
    for md in _doc_files():
        for target in _LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists():
                broken.append(f"{md.relative_to(ROOT)}: {target}")
    return broken


def run_quickstart() -> None:
    """Execute every python fence of docs/quickstart.md in one namespace."""
    qs = ROOT / "docs" / "quickstart.md"
    blocks = _FENCE_RE.findall(qs.read_text())
    if not blocks:
        raise SystemExit("docs/quickstart.md has no ```python blocks")
    ns: dict = {"__name__": "__quickstart__"}
    for i, block in enumerate(blocks):
        print(f"-- executing quickstart block {i + 1}/{len(blocks)} "
              f"({len(block.splitlines())} lines)")
        exec(compile(block, f"{qs}:block{i + 1}", "exec"), ns)


def main() -> int:
    broken = check_links()
    if broken:
        print("BROKEN MARKDOWN LINKS:")
        for b in broken:
            print(f"  {b}")
        return 1
    print(f"link check OK over {len(_doc_files())} files")
    run_quickstart()
    print("quickstart OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
