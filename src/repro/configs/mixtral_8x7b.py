"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, MoE 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig, MoEConfig, RMAttentionConfig

FULL = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    max_seq_len=524288,
    block_pattern=("attn_moe",),
    sliding_window=4096,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=14336,
                  capacity_factor=1.25),
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="mixtral-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=("attn_moe",),
    sliding_window=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
