"""deepseek-v2-lite-16b [moe] — 27L d_model=2048 16H (kv=16) d_ff=1408
(expert) vocab=102400 — MLA kv_lora=512, 2 shared + 64 routed top-6,
first layer dense (d_ff=10944). [arXiv:2405.04434; hf]"""
from repro.models.config import (
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RMAttentionConfig,
)

FULL = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,                    # the single dense layer's FFN
    vocab_size=102400,
    max_seq_len=524288,
    attention_kind="mla",
    block_pattern=("mla_moe",),
    first_k_dense=1,
    rope_theta=10000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                  num_shared_experts=2, capacity_factor=1.25),
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="deepseek-v2-lite-smoke",
    family="moe",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=256,
    attention_kind="mla",
    block_pattern=("mla_moe",),
    first_k_dense=1,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                  num_shared_experts=2),
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
