"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (3:1 interleave; blocks carry their own projections, no separate FFN).
[arXiv:2405.04517; unverified]

Attention-free: the paper's RM attention mode is N/A for this arch
(DESIGN.md §6 Arch-applicability); `long_500k` runs natively (O(1) decode
state).
"""
from repro.models.config import ModelConfig, XLSTMConfig

_PATTERN = ("mlstm", "mlstm", "mlstm", "slstm")

FULL = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=524288,
    block_pattern=_PATTERN,
    pos_embedding="none",
    norm_kind="layernorm",
    mlp_kind="gelu",              # unused (no ffn blocks) but must be valid
    xlstm=XLSTMConfig(proj_factor=2.0, conv_kernel=4),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=0,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=_PATTERN,
    pos_embedding="none",
    norm_kind="layernorm",
    mlp_kind="gelu",
    xlstm=XLSTMConfig(proj_factor=2.0, conv_kernel=4),
    tie_embeddings=True,
)
