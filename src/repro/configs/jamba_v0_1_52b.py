"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 — Mamba+attention 1:7 interleave, MoE every
other layer. [arXiv:2403.19887; hf]

Pattern period 8 = 1 attention + 7 mamba mixers; MoE on alternating layers
(4 of 8), dense SwiGLU on the rest.
"""
from repro.models.config import (
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RMAttentionConfig,
)

_PATTERN = (
    "attn_moe",
    "mamba_mlp",
    "mamba_moe",
    "mamba_mlp",
    "mamba_moe",
    "mamba_mlp",
    "mamba_moe",
    "mamba_mlp",
)

FULL = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    max_seq_len=524288,
    block_pattern=_PATTERN,
    rope_theta=10000.0,
    pos_embedding="none",          # Jamba uses no positional encoding
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336,
                  capacity_factor=1.25),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, scan_chunk=64),
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=_PATTERN,
    pos_embedding="none",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2, scan_chunk=16),
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
