"""hubert-xlarge [audio] — 48L d_model=1280 16H (MHA) d_ff=5120 vocab=504 —
encoder-only (same arch as wav2vec2); conv frontend is a STUB (input_specs
supplies precomputed frame embeddings). [arXiv:2106.07447; unverified]

Encoder-only: no decode step; decode-family shapes are skipped (DESIGN.md §6).
"""
from repro.models.config import ModelConfig, RMAttentionConfig

FULL = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    max_seq_len=32768,
    block_pattern=("attn_mlp",),
    causal=False,                  # bidirectional encoder
    pos_embedding="sinusoidal",
    norm_kind="layernorm",
    mlp_kind="gelu",
    frontend="audio_stub",
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="hubert-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=64,
    max_seq_len=256,
    block_pattern=("attn_mlp",),
    causal=False,
    pos_embedding="sinusoidal",
    norm_kind="layernorm",
    mlp_kind="gelu",
    frontend="audio_stub",
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
