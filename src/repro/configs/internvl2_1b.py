"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT frontend (STUB: precomputed patch embeddings) +
Qwen2-0.5B-family backbone. [arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig, RMAttentionConfig

FULL = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    max_seq_len=524288,
    block_pattern=("attn_mlp",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    tie_embeddings=True,
    frontend="vision_stub",        # input_specs supplies patch embeddings
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    num_layers=2,
    d_model=56,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=("attn_mlp",),
    qkv_bias=True,
    tie_embeddings=True,
    frontend="vision_stub",
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
