"""qwen3-1.7b [dense] — 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B family; hf]"""
from repro.models.config import ModelConfig, RMAttentionConfig

FULL = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    max_seq_len=524288,
    block_pattern=("attn_mlp",),
    qk_norm=True,
    qkv_bias=False,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    tie_embeddings=True,
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=("attn_mlp",),
    qk_norm=True,
    tie_embeddings=True,
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
