"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000 — llama+mistral mix, SWA. [arXiv:2401.16818; unverified]"""
from repro.models.config import ModelConfig, RMAttentionConfig

FULL = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    num_heads=32,
    num_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    max_seq_len=524288,
    block_pattern=("attn_mlp",),
    sliding_window=4096,          # mistral-style SWA
    rope_theta=10000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="danube3-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=("attn_mlp",),
    sliding_window=16,
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
