"""qwen2-7b [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — GQA, QKV bias. [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig, RMAttentionConfig

FULL = ModelConfig(
    name="qwen2-7b",
    family="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    max_seq_len=524288,
    block_pattern=("attn_mlp",),
    qkv_bias=True,
    rope_theta=1_000_000.0,
    norm_kind="rmsnorm",
    mlp_kind="swiglu",
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="qwen2-smoke",
    family="dense",
    num_layers=2,
    d_model=56,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=("attn_mlp",),
    qkv_bias=True,
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
