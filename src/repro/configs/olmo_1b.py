"""olmo-1b [dense] — 16L d_model=2048 16H (GQA kv=16, i.e. MHA) d_ff=8192
vocab=50304 — non-parametric LN. [arXiv:2402.00838; hf]"""
from repro.models.config import ModelConfig, RMAttentionConfig

FULL = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    max_seq_len=524288,
    block_pattern=("attn_mlp",),
    norm_kind="nonparametric_ln",   # OLMo: LN without learnable params
    mlp_kind="swiglu",
    tie_embeddings=True,
    rope_theta=10000.0,
    rm=RMAttentionConfig(num_features=256),
)

SMOKE = ModelConfig(
    name="olmo-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    max_seq_len=256,
    block_pattern=("attn_mlp",),
    norm_kind="nonparametric_ln",
    tie_embeddings=True,
    rm=RMAttentionConfig(num_features=64, n_max=6),
)
