"""Architecture registry: ``--arch <id>`` lookup for all 10 assigned archs.

Each ``<id>.py`` module defines ``FULL`` (the exact published config) and
``SMOKE`` (a reduced same-family config for CPU tests). ``get_config``
resolves ids; ``variants`` applies attention-mode overrides (the paper's RM
linear attention) used by the dry-run and the long-context cells.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_ARCH_MODULES: Dict[str, str] = {
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube_3_4b",
    "olmo-1b": "repro.configs.olmo_1b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "internvl2-1b": "repro.configs.internvl2_1b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "xlstm-350m": "repro.configs.xlstm_350m",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str, smoke: bool = False,
               attention_mode: str | None = None,
               estimator: str | None = None) -> ModelConfig:
    """Resolve an arch id, with optional attention-mode / estimator overrides.

    ``estimator`` picks the linear-attention feature family by registry name
    ("rm" / "tensor_sketch" / "ctr"); it only applies to ``attention_mode="rm"``
    models and is validated against the estimator registry.
    """
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(_ARCH_MODULES[arch])
    cfg: ModelConfig = mod.SMOKE if smoke else mod.FULL
    if attention_mode is not None and attention_mode != cfg.attention_mode:
        if not _supports_rm(cfg) and attention_mode == "rm":
            raise ValueError(
                f"{arch} is attention-free; the paper's RM attention mode "
                "does not apply (DESIGN.md §6)."
            )
        cfg = dataclasses.replace(cfg, attention_mode=attention_mode)
    if estimator is not None:
        if cfg.attention_mode != "rm":
            raise ValueError(
                f"estimator={estimator!r} requested but {arch} resolves to "
                f"attention_mode={cfg.attention_mode!r}; estimators only "
                "apply to the paper's RM linear attention (pass "
                "attention_mode='rm')."
            )
        from repro.core import registry

        registry.get(estimator)  # raises with the available-name list
        if estimator != cfg.rm.estimator:
            cfg = dataclasses.replace(
                cfg, rm=dataclasses.replace(cfg.rm, estimator=estimator)
            )
    return cfg.validate()


def _supports_rm(cfg: ModelConfig) -> bool:
    return any(
        b.split("_")[0] in ("attn", "mla") for b in cfg.block_pattern
    ) or cfg.first_k_dense > 0
