from repro.distributed.sharding import (
    constrain,
    logical_rules_context,
    set_default_rules,
    params_partition_specs,
    batch_partition_specs,
    estimator_param_specs,
    shard_map,
    DEFAULT_RULES,
)
from repro.distributed.estimator import (
    FEATURE_AXIS,
    ShardedFeatureMap,
    make_sharded_feature_map,
    shard_init_params,
    sharded_apply,
    sharded_estimate_gram,
)

__all__ = [
    "constrain",
    "logical_rules_context",
    "set_default_rules",
    "params_partition_specs",
    "batch_partition_specs",
    "estimator_param_specs",
    "shard_map",
    "DEFAULT_RULES",
    "FEATURE_AXIS",
    "ShardedFeatureMap",
    "make_sharded_feature_map",
    "shard_init_params",
    "sharded_apply",
    "sharded_estimate_gram",
]
