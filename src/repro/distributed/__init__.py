from repro.distributed.sharding import (
    constrain,
    logical_rules_context,
    set_default_rules,
    params_partition_specs,
    batch_partition_specs,
    DEFAULT_RULES,
)

__all__ = [
    "constrain",
    "logical_rules_context",
    "set_default_rules",
    "params_partition_specs",
    "batch_partition_specs",
    "DEFAULT_RULES",
]
