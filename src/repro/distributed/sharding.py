"""Logical-axis sharding rules (MaxText-style) for DP/FSDP/TP/EP/SP.

Two pieces:

1. **Activation constraints** — models call ``constrain(x, logical_axes)``
   at block boundaries; inside a ``logical_rules_context`` (set by the
   launcher) this lowers to ``with_sharding_constraint`` with the active
   mesh; outside any context it is a no-op, so models run unmodified on a
   single device.

2. **Parameter specs** — ``params_partition_specs`` maps every param leaf to
   a PartitionSpec from a name-based rule table:
     * TP   — head/ffn-hidden/expert dims over "model";
     * FSDP — the d_model-ish dim over "data" (ZeRO-3 style weight shard);
     * DP   — batch over ("pod", "data") [pod folds into data-parallelism];
     * SP   — sequence over "data" for long-context activations;
     * EP   — expert dim of MoE stacks over "model".

Logical axis names used by the models:
  "batch", "seq", "embed", "heads", "kv_heads", "ffn", "vocab", "experts",
  "rm_features", "state", None (replicated).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # newer jax: public jax.shard_map with check_vma
    _jax_shard_map = jax.shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _jax_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=False)
except AttributeError:  # pinned jax: experimental module, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map(f, mesh, in_specs, out_specs):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


shard_map.__doc__ = """Version-portable ``shard_map`` (replication checks off).

Every shard_map in the repo (MoE expert parallelism, compressed psum, the
sharded estimator path) goes through this wrapper so the jax-pin difference
(``jax.shard_map``/``check_vma`` vs ``jax.experimental.shard_map``/
``check_rep``) lives in exactly one place."""

# THE feature axis: random-feature columns (and the stacked per-shard
# estimator params backing them) shard over this name — used as both the
# logical axis in the rule table below and the mesh axis name of
# launch.mesh.make_feature_mesh / distributed.estimator.
FEATURE_AXIS = "rm_features"

# logical axis -> mesh axis (or tuple of mesh axes, or None)
DEFAULT_RULES: Dict[str, object] = {
    "batch": ("pod", "data"),
    "seq": None,           # flipped to ("pod", "data") for SP-long-context
    # Megatron-style sequence parallelism: the residual stream between
    # blocks is sharded over the TP axis on the sequence dim, so saved
    # activations (remat carriers) are 1/tp the size; XLA inserts the
    # all-gather before QKV/FFN and the reduce-scatter after the output
    # projections. Falls back to replicated when T % tp != 0 (decode).
    "act_seq": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ffn": None,
    "fsdp": "data",        # weight dim sharded for ZeRO-style FSDP
    FEATURE_AXIS: None,    # in-model estimator params replicate (§10)
    "state": "model",
    "layers": None,
    # decode KV-cache sequence dim: None = replicated over model (classic);
    # "model" = FlashDecoding-style split-K decode (scores gathered instead
    # of values — evaluated in §Perf).
    "kv_seq": None,
}

_local = threading.local()


def _active() -> Optional[Tuple[Mesh, Dict[str, object]]]:
    return getattr(_local, "ctx", None)


def set_default_rules(rules: Dict[str, object]) -> None:
    DEFAULT_RULES.update(rules)


@contextlib.contextmanager
def logical_rules_context(mesh: Mesh, rules: Optional[Dict[str, object]] = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    # drop mesh axes that don't exist in this mesh (e.g. no "pod" single-pod)
    def _filter(axis):
        if axis is None:
            return None
        if isinstance(axis, tuple):
            kept = tuple(a for a in axis if a in mesh.axis_names)
            return kept if kept else None
        return axis if axis in mesh.axis_names else None

    merged = {k: _filter(v) for k, v in merged.items()}
    prev = _active()
    _local.ctx = (mesh, merged)
    try:
        yield merged
    finally:
        _local.ctx = prev


def spec_for(logical_axes: Tuple[Optional[str], ...],
             rules: Optional[Dict[str, object]] = None) -> P:
    if rules is None:
        ctx = _active()
        if ctx is None:
            return P()
        rules = ctx[1]
    return P(*(rules.get(a) if a is not None else None for a in logical_axes))


def constrain(x: jax.Array, logical_axes: Tuple[Optional[str], ...]):
    ctx = _active()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical_axes) != x.ndim:
        raise ValueError(f"rank mismatch: {logical_axes} vs {x.shape}")
    spec = spec_for(logical_axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter rules (matched on the final path component; scanned stacks get a
# leading "layers" axis automatically when leaf rank exceeds the rule).
# ---------------------------------------------------------------------------
_PARAM_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "embedding": ("vocab", "embed"),
    "unembed": ("fsdp", "vocab"),
    # attention (2D fused-head weights)
    "wq": ("fsdp", "heads"),
    "wk": ("fsdp", "kv_heads"),
    "wv": ("fsdp", "kv_heads"),
    "wo": ("heads", "fsdp"),
    "bq": ("heads",),
    "bk": ("kv_heads",),
    "bv": ("kv_heads",),
    # MLA
    "w_q": ("fsdp", "heads"),
    "w_dkv": ("fsdp", None),
    "w_ukv": (None, "heads"),
    "w_o": ("heads", "fsdp"),
    "kv_norm_scale": (None,),
    # MLP
    "w_gate": ("fsdp", "ffn"),
    "w_up": ("fsdp", "ffn"),
    "w_down": ("ffn", "fsdp"),
    "b_up": ("ffn",),
    "b_down": (None,),
    # MoE (expert-stacked 3D) — matched by rank below
    "router": (None, None),
    "shared_gate": ("fsdp", "ffn"),
    "shared_up": ("fsdp", "ffn"),
    "shared_down": ("ffn", "fsdp"),
    # mamba
    "w_in": ("fsdp", "state"),
    "conv_w": (None, "state"),
    "conv_b": ("state",),
    "x_proj": ("state", None),
    "dt_proj": (None, "state"),
    "dt_bias": ("state",),
    "a_log": ("state", None),
    "d_skip": ("state",),
    "w_out": ("state", "fsdp"),
    # xlstm
    "w_if": ("fsdp", None),
    "b_if": (None,),
    "r_rec": (None, None, None, None),
    "gn_scale": (None,),
    "ff_up": ("fsdp", "ffn"),
    "ff_down": ("ffn", "fsdp"),
    # estimator params ("rm_est" subtree): replicated (small, frozen).
    # "omegas" = RM Rademacher rows; "h"/"s" = TensorSketch hash tables;
    # "wr"/"wi" = CTR complex Rademacher real/imag parts.
    "rm_omegas": (None, None),
    "omegas": (None, None),
    "h": (None, None),
    "s": (None, None),
    "wr": (None, None),
    "wi": (None, None),
    "rm_scale": (),
    # norms
    "scale": (None,),
    "bias": (None,),
    "pos_embedding": (None, "embed"),
}

# MoE expert-stacked weights share names with dense MLP ("w_gate" etc.) but
# have an extra leading expert dim; scanned stacks additionally prepend a
# "layers" dim. The pad order depends on whether the leaf lives under a MoE
# module (path component "moe"), which ``_leaf_spec`` receives.
def _leaf_spec(path: Tuple[str, ...], ndim: int,
               rules: Dict[str, object]) -> P:
    name = path[-1]
    base = _PARAM_RULES.get(name)
    if base is None:
        base = tuple(None for _ in range(ndim))
    logical = list(base)
    in_moe = any(p == "moe" for p in path)
    pad_order = ("experts", "layers") if in_moe else ("layers",)
    pad_i = 0
    while len(logical) < ndim and pad_i < len(pad_order):
        logical.insert(0, pad_order[pad_i])
        pad_i += 1
    while len(logical) < ndim:
        logical.insert(0, None)
    logical = logical[-ndim:] if len(logical) > ndim else logical
    return P(*(rules.get(a) if a is not None else None for a in logical))


def _dedupe_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop mesh axes that appear twice or don't divide the dim."""
    used = set()
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axis is None:
            out.append(None)
            continue
        axes = axis if isinstance(axis, tuple) else (axis,)
        kept = []
        size = 1
        for a in axes:
            if a in used or a not in mesh.axis_names:
                continue
            size *= mesh.shape[a]
            kept.append(a)
        if not kept or dim % np.prod([mesh.shape[a] for a in kept]) != 0:
            out.append(None)
            continue
        used.update(kept)
        out.append(tuple(kept) if len(kept) > 1 else kept[0])
    return P(*out)


def params_partition_specs(params_tree, mesh: Mesh,
                           rules: Optional[Dict[str, object]] = None):
    """Pytree of PartitionSpecs matching ``params_tree`` (arrays or
    ShapeDtypeStructs)."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)

    def _walk(path, node):
        if isinstance(node, dict):
            return {k: _walk(path + (k,), v) for k, v in node.items()}
        spec = _leaf_spec(path, len(node.shape), merged)
        return _dedupe_spec(spec, tuple(node.shape), mesh)

    return _walk((), params_tree)


# ---------------------------------------------------------------------------
# estimator param subtrees
# ---------------------------------------------------------------------------
# Two distinct layouts, one per serving regime (DESIGN.md §10):
#
#   * REPLICATED — the in-model ``rm_est`` subtree (RM omegas / CountSketch
#     "h"/"s" hash tensors) during data-parallel decode: small, frozen,
#     needed in full by every shard. Covered by the name rules above
#     ("omegas"/"h"/"s" -> replicated).
#   * FEATURE-SHARDED — the stacked per-shard params of the sharded
#     estimator construction (repro.distributed.estimator): leaves carry a
#     leading shard dim that lives on the "rm_features" mesh axis; shard s
#     owns the s-th sub-map's draws and feature columns.
def estimator_param_specs(params_stacked, mesh: Mesh,
                          axis: str = FEATURE_AXIS):
    """PartitionSpecs for stacked per-shard estimator params.

    Every leaf of ``params_stacked`` has shape ``[num_shards, ...]``; the
    leading dim is sharded over ``axis`` and everything else is replicated.
    Leading dims that don't divide the axis size fall back to replicated via
    ``_dedupe_spec`` (e.g. a host-built stack inspected on one device).
    """

    def _one(leaf):
        spec = P(axis, *(None for _ in range(leaf.ndim - 1)))
        return _dedupe_spec(spec, tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map(_one, params_stacked)


# decode-cache leaves, matched by name (rank WITHOUT the scanned-groups dim;
# leaves under "groups" carry one extra leading layer axis).
_CACHE_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "k": ("batch", "kv_seq", "kv_heads", None),
    "v": ("batch", "kv_seq", "kv_heads", None),
    "c_kv": ("batch", "kv_seq", None),
    "k_pe": ("batch", "kv_seq", None),
    "rm_s": ("batch", "heads", None, None),
    "rm_n": ("batch", "heads", None),
    "conv": ("batch", None, "state"),
    "ssm": ("batch", "state", None),
    "c": ("batch", "heads", None, None),
    "n": ("batch", "heads", None),
    "m": ("batch", "heads", None),   # slstm rank-3; mlstm rank-2 handled below
    "h": ("batch", "heads", None),
}


def cache_partition_specs(cache_tree, mesh: Mesh,
                          rules: Optional[Dict[str, object]] = None):
    """PartitionSpecs for decode caches: batch over DP axes, heads/state over
    "model". Indivisible dims (e.g. batch=1 in long_500k) fall back to
    replicated via _dedupe_spec."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)

    def _walk(path, node):
        if isinstance(node, dict):
            return {k: _walk(path + (k,), v) for k, v in node.items()}
        name = path[-1]
        base = _CACHE_RULES.get(name)
        nd = len(node.shape)
        stacked = "groups" in path          # scanned stacks: leading layer dim
        if base is None:
            logical = ([None] if stacked else []) + ["batch"]
            logical += [None] * (nd - len(logical))
        else:
            logical = ([None] if stacked else []) + list(base)
            logical = logical[:nd]
            while len(logical) < nd:
                logical.append(None)
        spec = P(*(merged.get(a) if a is not None else None
                   for a in logical))
        return _dedupe_spec(spec, tuple(node.shape), mesh)

    return _walk((), cache_tree)


def batch_partition_specs(batch_tree, mesh: Mesh,
                          rules: Optional[Dict[str, object]] = None,
                          seq_sharded: bool = False):
    """Input batch specs: batch dim over ("pod","data"); optionally SP."""
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    batch_axes = merged.get("batch")

    def _one(node):
        nd = len(node.shape)
        if nd == 0:
            return P()
        axes = [batch_axes]
        if seq_sharded and nd >= 2:
            axes.append(merged.get("seq"))
        while len(axes) < nd:
            axes.append(None)
        return _dedupe_spec(P(*axes), tuple(node.shape), mesh)

    return jax.tree_util.tree_map(_one, batch_tree)
