"""Sharded estimator execution over a device mesh.

The paper's embedding makes dot-product kernels *linear*: after featurizing,
``K(x, y) ~= <Z(x), Z(y)>``, and an inner product is embarrassingly shardable.
This module partitions the random-feature budget over the ``"rm_features"``
mesh axis, uniformly for EVERY entry of the estimator registry:

    * a global budget of D features over S shards becomes S independent
      sub-maps of D/S features each, built from ONE per-shard plan (the same
      hashable plan on every shard, so shard_map traces once) and per-shard
      params drawn with ``jax.random.fold_in(key, shard)`` — shard s's draws
      depend only on (key, s), never on which device holds them;
    * ``Z(x) = concat_s Z_s(x) / sqrt(S)`` — each sub-map is an unbiased
      estimator of the kernel, so their concatenation at 1/sqrt(S) scale is
      the unbiased S-fold average (deterministic prefix columns are exact
      under the same scaling: S copies of ``sqrt(a_0)/sqrt(S)`` contribute
      exactly a_0 to the Gram);
    * ``estimate_gram`` never materializes the concatenation: each shard
      computes its partial Gram ``Z_s(X) Z_s(Y)^T / S`` and ONE ``psum``
      over the feature axis reduces them.

Bit-identity contract: the mesh path and the single-device reference run the
SAME per-shard computation from the SAME folded keys in the SAME concat
order, so ``sharded=True`` vs ``sharded=False`` apply is bit-identical;
only the Gram psum may reassociate the cross-shard sum (parity to ~1e-5 in
float32 — tests/test_distributed_estimators.py locks both down).

The registry is the only coupling point: any estimator satisfying the
five-function protocol (``make_plan``/``init_params``/``apply``/
``output_dim``/``truncation_bias``) shards with no family-specific code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import registry
from repro.distributed.sharding import (
    FEATURE_AXIS,
    estimator_param_specs,
    shard_map,
)

__all__ = [
    "FEATURE_AXIS",
    "shard_init_params",
    "sharded_apply",
    "sharded_estimate_gram",
    "ShardedFeatureMap",
    "make_sharded_feature_map",
]


def _unstack(params: Any) -> Any:
    """Strip the leading size-1 shard dim of a shard-local param tree."""
    return jax.tree_util.tree_map(lambda a: a[0], params)


def _take(params: Any, s: int) -> Any:
    return jax.tree_util.tree_map(lambda a: a[s], params)


def _num_shards(params: Any) -> int:
    return int(jax.tree_util.tree_leaves(params)[0].shape[0])


# ---------------------------------------------------------------------------
# init — per-shard RNG via fold_in on the mesh coordinate
# ---------------------------------------------------------------------------
def shard_init_params(
    name: str,
    plan: Any,
    key: jax.Array,
    num_shards: int,
    *,
    dtype=jnp.float32,
    mesh: Optional[Mesh] = None,
    axis: str = FEATURE_AXIS,
) -> Any:
    """Stacked per-shard estimator params: leaves are ``[num_shards, ...]``.

    Shard s's params are ``init_params(plan, fold_in(key, s))``. With a
    ``mesh``, each shard draws ITS OWN params inside a shard_map using
    ``fold_in(key, axis_index(axis))`` — no host materialization, no
    broadcast — and the result is bit-identical to the host loop, because
    the fold-in coordinate is the shard index either way.
    """
    est = registry.get(name)
    if mesh is None:
        chunks = [
            est.init_params(plan, jax.random.fold_in(key, s), dtype)
            for s in range(num_shards)
        ]
        return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *chunks)

    if mesh.shape[axis] != num_shards:
        raise ValueError(
            f"mesh axis {axis!r} has size {mesh.shape[axis]}, "
            f"expected num_shards={num_shards}"
        )

    def local():
        sub = jax.random.fold_in(key, jax.lax.axis_index(axis))
        p = est.init_params(plan, sub, dtype)
        return jax.tree_util.tree_map(lambda a: a[None], p)

    shapes = jax.eval_shape(lambda k: est.init_params(plan, k, dtype), key)
    out_specs = jax.tree_util.tree_map(
        lambda s: P(axis, *(None for _ in s.shape)), shapes
    )
    return shard_map(local, mesh, in_specs=(), out_specs=out_specs)()


# ---------------------------------------------------------------------------
# apply — features partitioned on the "rm_features" axis
# ---------------------------------------------------------------------------
def _reference_apply(est, plan, params, x, *, accum_dtype, use_pallas,
                     interpret, precision=None):
    """Single-device reference: loop shards on host, concat in shard order."""
    s = _num_shards(params)
    scale = jnp.asarray(1.0 / np.sqrt(s), accum_dtype)
    zs = [
        est.apply(plan, _take(params, i), x, accum_dtype=accum_dtype,
                  use_pallas=use_pallas, interpret=interpret,
                  precision=precision) * scale
        for i in range(s)
    ]
    return jnp.concatenate(zs, axis=-1)


def sharded_apply(
    name: str,
    plan: Any,
    params: Any,
    x: jax.Array,
    mesh: Optional[Mesh],
    *,
    axis: str = FEATURE_AXIS,
    accum_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    precision=None,
) -> jax.Array:
    """Featurize ``x [..., d] -> [..., S * output_dim(plan)]`` over the mesh.

    ``x`` is replicated into every shard; shard s computes its sub-map's
    columns and the out-spec concatenates them along the feature axis in
    shard order — the exact layout ``_reference_apply`` produces on one
    device. ``mesh=None`` runs the reference path.
    """
    est = registry.get(name)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if mesh is None:
        return _reference_apply(est, plan, params, x,
                                accum_dtype=accum_dtype,
                                use_pallas=use_pallas, interpret=interpret,
                                precision=precision)
    s = mesh.shape[axis]
    scale = jnp.asarray(1.0 / np.sqrt(s), accum_dtype)

    def local(p, xl):
        z = est.apply(plan, _unstack(p), xl, accum_dtype=accum_dtype,
                      use_pallas=use_pallas, interpret=interpret,
                      precision=precision)
        return z * scale

    in_specs = (
        jax.tree_util.tree_map(
            lambda a: P(axis, *(None for _ in range(a.ndim - 1))), params),
        P(*(None for _ in range(x.ndim))),
    )
    out_specs = P(*(None for _ in range(x.ndim - 1)), axis)
    return shard_map(local, mesh, in_specs, out_specs)(params, x)


# ---------------------------------------------------------------------------
# Gram — partial per-shard Grams, ONE psum over the feature axis
# ---------------------------------------------------------------------------
def sharded_estimate_gram(
    name: str,
    plan: Any,
    params: Any,
    X: jax.Array,
    Y: Optional[jax.Array] = None,
    *,
    mesh: Optional[Mesh] = None,
    axis: str = FEATURE_AXIS,
    row_chunk: int = 4096,
    accum_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    precision=None,
) -> jax.Array:
    """Kernel-matrix estimate ``Z(X) Z(Y)^T`` without gathering features.

    Each shard featurizes the (replicated) rows through its own sub-map —
    row-chunked exactly like the single-device path — and contributes the
    partial Gram ``Z_s(X) Z_s(Y)^T / S``; the single ``psum`` over ``axis``
    is the only cross-device communication. ``mesh=None`` computes the same
    sum serially (the conformance reference).
    """
    est = registry.get(name)
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    s = _num_shards(params)
    inv_s = 1.0 / s

    def _apply_fn(p_shard):
        return lambda Z: est.apply(
            plan, p_shard, Z, accum_dtype=accum_dtype,
            use_pallas=use_pallas, interpret=interpret,
            precision=precision)

    if mesh is None:
        parts = [
            registry.estimate_gram(_apply_fn(_take(params, i)), X, Y,
                                   row_chunk=row_chunk) * inv_s
            for i in range(s)
        ]
        return sum(parts[1:], parts[0])

    if mesh.shape[axis] != s:
        raise ValueError(
            f"mesh axis {axis!r} has size {mesh.shape[axis]}, but params "
            f"carry {s} shards"
        )

    def local(p, Xl, *rest):
        # the shared registry helper supplies the ONE psum of the partials
        return registry.estimate_gram(
            _apply_fn(_unstack(p)), Xl, rest[0] if rest else None,
            row_chunk=row_chunk, axis_name=axis) * inv_s

    pspecs = jax.tree_util.tree_map(
        lambda a: P(axis, *(None for _ in range(a.ndim - 1))), params)
    rep2 = P(None, None)
    if Y is None:
        fn = shard_map(local, mesh, (pspecs, rep2), rep2)
        return fn(params, X)
    fn = shard_map(local, mesh, (pspecs, rep2, rep2), rep2)
    return fn(params, X, Y)


# ---------------------------------------------------------------------------
# the sharded map object
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ShardedFeatureMap:
    """A feature map whose columns live on the ``"rm_features"`` mesh axis.

    Thin carrier of (estimator name, per-shard plan, stacked params, mesh).
    Duck-types the single-device maps (``apply`` / ``__call__`` /
    ``output_dim`` / ``estimate_gram`` / ``truncation_bias``) so offline
    consumers take any of the three interchangeably; ``sharded=False`` (or
    ``mesh=None``) runs the bit-identical single-device reference.
    """

    estimator: str
    plan: Any
    params: Any                       # stacked [S, ...] leaves
    num_shards: int
    mesh: Optional[Mesh] = None
    axis: str = FEATURE_AXIS

    # -- metadata ------------------------------------------------------------
    @property
    def input_dim(self) -> int:
        return self.plan.input_dim

    @property
    def shard_output_dim(self) -> int:
        return registry.get(self.estimator).output_dim(self.plan)

    @property
    def output_dim(self) -> int:
        return self.num_shards * self.shard_output_dim

    def truncation_bias(self, radius: float) -> float:
        """Per-shard plans share one allocation, so the dropped-degree mass
        of the concatenation equals any single shard's."""
        return registry.get(self.estimator).truncation_bias(self.plan, radius)

    # -- application ---------------------------------------------------------
    def apply(
        self,
        x: jax.Array,
        *,
        sharded: Optional[bool] = None,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        accum_dtype=jnp.float32,
        precision=None,
    ) -> jax.Array:
        """Featurize ``x [..., d] -> [..., output_dim]`` (all shards'
        columns, concatenated in shard order at ``1/sqrt(S)`` scale).

        ``sharded`` defaults to "mesh present": True runs the one-launch-
        per-shard ``shard_map`` path, False the bit-identical host loop.
        """
        if sharded is None:
            sharded = self.mesh is not None
        return sharded_apply(
            self.estimator, self.plan, self.params, x,
            self.mesh if sharded else None, axis=self.axis,
            accum_dtype=accum_dtype, use_pallas=use_pallas,
            interpret=interpret, precision=precision,
        )

    def __call__(self, x: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
        """Single-device reference path (mirrors RMFeatureMap.__call__)."""
        return self.apply(x, sharded=False, use_pallas=False,
                          accum_dtype=accum_dtype)

    def estimate_gram(
        self,
        X: jax.Array,
        Y: Optional[jax.Array] = None,
        *,
        sharded: Optional[bool] = None,
        row_chunk: int = 4096,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        precision=None,
    ) -> jax.Array:
        """Kernel-matrix estimate ``Z(X) Z(Y)^T`` without gathering the
        feature columns: per-shard partial Grams, ONE psum (DESIGN.md §10).
        """
        if sharded is None:
            sharded = self.mesh is not None
        return sharded_estimate_gram(
            self.estimator, self.plan, self.params, X, Y,
            mesh=self.mesh if sharded else None, axis=self.axis,
            row_chunk=row_chunk, use_pallas=use_pallas, interpret=interpret,
            precision=precision,
        )


def make_sharded_feature_map(
    kernel,
    input_dim: int,
    num_features: int,
    key: jax.Array,
    *,
    mesh: Optional[Mesh] = None,
    num_shards: Optional[int] = None,
    estimator: str = "rm",
    axis: str = FEATURE_AXIS,
    omega_dtype=jnp.float32,
    device_init: Optional[bool] = None,
    **plan_kwargs,
) -> ShardedFeatureMap:
    """Build a mesh-sharded feature map from any registry estimator.

    The D-feature budget splits into ``num_shards`` (default: the mesh's
    ``axis`` size) sub-maps of D/S features; D must divide evenly so every
    shard traces the same plan. ``device_init=True`` (default when a mesh is
    given) draws each shard's params on its own device via the fold-in rule;
    the resulting stacked tree is already laid out with
    ``distributed.sharding.estimator_param_specs``.
    """
    if num_shards is None:
        if mesh is None:
            raise ValueError("pass mesh= and/or num_shards=")
        num_shards = mesh.shape[axis]
    if num_features % num_shards != 0:
        raise ValueError(
            f"num_features={num_features} must divide evenly over "
            f"{num_shards} feature shards"
        )
    est = registry.get(estimator)
    if not plan_kwargs.get("stratified", True) and "seed" not in plan_kwargs:
        # paper-faithful iid mode draws the degree allocation from the
        # measure — mirror make_feature_map and derive the allocation seed
        # from the key (a fixed seed=0 would freeze the draw across keys,
        # leaving a conditional bias no re-keying or shard-averaging
        # removes). The param key is split off BEFORE the shard fold-ins so
        # host and mesh construction stay bit-identical.
        key, key_deg = jax.random.split(key)
        plan_kwargs["seed"] = int(
            jax.random.randint(key_deg, (), 0, 2**31 - 1))
    plan = est.make_plan(kernel, input_dim, num_features // num_shards,
                         **plan_kwargs)
    if device_init is None:
        device_init = mesh is not None
    params = shard_init_params(
        estimator, plan, key, num_shards, dtype=omega_dtype,
        mesh=mesh if device_init else None, axis=axis,
    )
    if mesh is not None and not device_init:
        specs = estimator_param_specs(params, mesh, axis)
        params = jax.device_put(
            params,
            jax.tree_util.tree_map(
                lambda sp: NamedSharding(mesh, sp), specs,
                is_leaf=lambda sp: isinstance(sp, P)),
        )
    return ShardedFeatureMap(
        estimator=estimator, plan=plan, params=params,
        num_shards=num_shards, mesh=mesh, axis=axis,
    )
