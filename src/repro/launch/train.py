"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --batch 4 --seq 128

On hardware, the same entrypoint builds the production mesh and shards the
run; on this CPU container use --smoke (reduced config) for real execution,
or the dry-run for full-scale lowering.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_archs
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.steps import TrainHyper
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--attention-mode", default=None,
                    choices=[None, "exact", "rm"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "single", "multi"])
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="TP size for --mesh host")
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="stream a JSONL train/step + kernel-span trace "
                         "(inspect with python -m repro.obs)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the metrics snapshot (step-time histogram, "
                         "loss gauge) as JSON")
    ap.add_argument("--drift-every", type=int, default=0, metavar="N",
                    help="run the online (eps, delta) Gram-drift check "
                         "every N train steps (0 = off; rm attention only)")
    from repro.launch.budget import add_budget_args, apply_budget_selection

    add_budget_args(ap)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke,
                     attention_mode=args.attention_mode)
    cfg, _decision = apply_budget_selection(cfg, args, tag="train")
    if cfg.frontend != "none":
        raise SystemExit(
            f"{args.arch} needs modality inputs; use examples/train_lm.py "
            "with an LM arch, or the dry-run for full-scale lowering.")
    data = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch)
    mesh = {
        "none": None,
        "host": lambda: make_host_mesh(args.model_parallel),
        "single": lambda: make_production_mesh(),
        "multi": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]
    mesh = mesh() if callable(mesh) else mesh
    hyper = TrainHyper(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                       total_steps=args.steps, grad_accum=args.grad_accum)

    obs = None
    if args.trace_out or args.metrics_out or args.drift_every:
        from repro import obs as obs_mod

        drift = None
        if args.drift_every and cfg.attention_mode == "rm":
            from repro.core import ExponentialDotProductKernel

            rm = cfg.rm
            drift = obs_mod.DriftMonitor.for_estimator(
                ExponentialDotProductKernel(sigma2=rm.sigma2),
                cfg.resolved_head_dim, rm.num_features,
                estimator=rm.estimator, measure=rm.measure,
                # hold the monitored map to the SELECTED delta
                **({"delta": args.delta}
                   if args.delta is not None else {}))
        elif args.drift_every:
            print("[train] --drift-every ignored: attention mode is not "
                  "rm-family")
        obs = obs_mod.Obs(trace_path=args.trace_out, drift=drift,
                          drift_every=args.drift_every,
                          install_kernel_tracing=True)

    trainer = Trainer(cfg, hyper, data, ckpt_dir=args.ckpt_dir, mesh=mesh,
                      obs=obs)
    trainer.train(args.steps)

    if obs is not None:
        if obs.drift is not None and obs.drift.last is not None:
            rep = obs.drift.last
            print(f"[train] drift: sup_err={rep.sup_err:.4f} vs "
                  f"eps({rep.num_features}, delta)={rep.eps_bound:.4f} "
                  f"[{'OK' if rep.ok else 'VIOLATION'}]")
        if args.metrics_out:
            obs.write_metrics(args.metrics_out)
            print(f"[train] wrote metrics -> {args.metrics_out}")
        obs.close()
        if args.trace_out:
            print(f"[train] wrote trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
