"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --batch 4 --seq 128

On hardware, the same entrypoint builds the production mesh and shards the
run; on this CPU container use --smoke (reduced config) for real execution,
or the dry-run for full-scale lowering.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_archs
from repro.data.synthetic import SyntheticLMDataset
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.train.steps import TrainHyper
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--attention-mode", default=None,
                    choices=[None, "exact", "rm"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "host", "single", "multi"])
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="TP size for --mesh host")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke,
                     attention_mode=args.attention_mode)
    if cfg.frontend != "none":
        raise SystemExit(
            f"{args.arch} needs modality inputs; use examples/train_lm.py "
            "with an LM arch, or the dry-run for full-scale lowering.")
    data = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                              global_batch=args.batch)
    mesh = {
        "none": None,
        "host": lambda: make_host_mesh(args.model_parallel),
        "single": lambda: make_production_mesh(),
        "multi": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]
    mesh = mesh() if callable(mesh) else mesh
    hyper = TrainHyper(peak_lr=args.lr, warmup_steps=max(args.steps // 10, 5),
                       total_steps=args.steps, grad_accum=args.grad_accum)
    trainer = Trainer(cfg, hyper, data, ckpt_dir=args.ckpt_dir, mesh=mesh)
    trainer.train(args.steps)


if __name__ == "__main__":
    main()
