import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ before any jax import (see dryrun.py)

"""§Perf hillclimbing: re-lower chosen cells under candidate changes and
record hypothesis -> change -> before -> after.

Each experiment is a named override of (sharding rules | model config |
train hyper) applied to one (arch, shape) cell; results append to
results/perf/<cell>__<exp>.json. The EXPERIMENTS.md §Perf log is generated
from these records.

    PYTHONPATH=src python -m repro.launch.hillclimb --cell qwen2-7b:prefill_32k
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402

from repro.launch.dryrun import lower_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.train.steps import TrainHyper  # noqa: E402

PERF_DIR = Path(__file__).resolve().parents[3] / "results" / "perf"


# ---------------------------------------------------------------------------
# experiment definitions: name -> (hypothesis, overrides)
# ---------------------------------------------------------------------------
def _rm_features(n):
    def f(cfg):
        return dataclasses.replace(
            cfg, rm=dataclasses.replace(cfg.rm, num_features=n))
    return f


def _rm_chunk(c):
    def f(cfg):
        return dataclasses.replace(
            cfg, rm=dataclasses.replace(cfg.rm, chunk=c))
    return f


def _moe_dispatch(kind):
    def f(cfg):
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=kind))
    return f


EXPERIMENTS = {
    # paper-technique cell: exact -> rm and RM plan tuning
    "rm_mode": dict(
        hypothesis="RM linear attention removes the O(T^2) term; prefill "
                   "compute and score-matmul memory drop, collectives "
                   "unchanged",
        attention_mode="rm",
    ),
    "rm_mode_D512": dict(
        hypothesis="doubling RM features doubles feature-matmul flops but "
                   "stays far below exact attention at 32k",
        attention_mode="rm", cfg_override=_rm_features(512),
    ),
    "rm_mode_D128": dict(
        hypothesis="halving RM features halves the linear-attention state "
                   "cost; approximation error grows ~sqrt(2)x (bench)",
        attention_mode="rm", cfg_override=_rm_features(128),
    ),
    "rm_chunk256": dict(
        hypothesis="larger rm chunks amortize state I/O; intra-chunk "
                   "[C,C] grows 2x but stays MXU-bound",
        attention_mode="rm", cfg_override=_rm_chunk(256),
    ),
    # sharding levers
    "no_sp": dict(
        hypothesis="dropping Megatron-SP on residuals removes per-layer "
                   "all-gathers but grows saved activations 16x",
        rules_override={"act_seq": None},
    ),
    "sp_data": dict(
        hypothesis="sharding long-context activations over data axis "
                   "(batch=1 long_500k) rebalances memory",
        rules_override={"act_seq": ("data",)},
    ),
    "kv_seq_shard": dict(
        hypothesis="FlashDecoding-style split-K: shard the KV cache's "
                   "sequence dim over 'model' — XLA gathers [B,H,S] scores "
                   "(small) instead of [B,S,H,dh] values (the 75GB/step "
                   "all-gather measured in the decode baseline)",
        rules_override={"kv_seq": "model", "kv_heads": None},
    ),
    "vocab_unsharded": dict(
        hypothesis="replicating the embedding removes the logits "
                   "all-reduce at the cost of vocab memory",
        rules_override={"vocab": None},
    ),
    "no_fsdp": dict(
        hypothesis="inference has no optimizer state: shard weights over "
                   "'model' only (pure TP) — the per-layer FSDP weight "
                   "all-gathers disappear and weights still fit "
                   "(7B bf16 / 16 = 0.9GB/device)",
        rules_override={"fsdp": None},
    ),
    "rm_no_fsdp": dict(
        hypothesis="combine the paper's linear attention with pure-TP "
                   "inference sharding: both the quadratic compute term "
                   "and the weight-gather collective term drop",
        attention_mode="rm", rules_override={"fsdp": None},
    ),
    "rm_no_sp": dict(
        hypothesis="combine winners: RM linear attention (compute term) + "
                   "dropping SP's per-layer activation gathers (collective "
                   "term) — inference prefill has no remat-memory pressure "
                   "so SP's memory saving is not needed",
        attention_mode="rm", rules_override={"act_seq": None},
    ),
    # MoE levers
    "moe_einsum": dict(
        hypothesis="GShard einsum dispatch pays O(G*E*C*d) dispatch flops "
                   "(the classic formulation; expect flops blow-up)",
        cfg_override=_moe_dispatch("einsum"),
    ),
    # train levers
    "accum4": dict(
        hypothesis="4 microbatches: peak activations /4, collective bytes "
                   "~const (per-microbatch reduce)",
        hyper=TrainHyper(grad_accum=4),
    ),
    "no_remat": dict(
        hypothesis="dropping remat removes the recompute fwd (-25% flops) "
                   "but multiplies saved activations",
        cfg_override=lambda cfg: dataclasses.replace(cfg, remat=False),
    ),
}


def run_experiment(arch, shape, exp_name, mesh=None, unroll=True):
    mesh = mesh or make_production_mesh()
    exp = EXPERIMENTS[exp_name]
    rec = lower_cell(
        arch, shape, mesh, "single",
        attention_mode=exp.get("attention_mode", "exact"),
        rules_override=exp.get("rules_override"),
        hyper=exp.get("hyper"),
        unroll=unroll,
        cfg_override=exp.get("cfg_override"),
    )
    rec["experiment"] = exp_name
    rec["hypothesis"] = exp["hypothesis"]
    PERF_DIR.mkdir(parents=True, exist_ok=True)
    out = PERF_DIR / f"{arch}__{shape}__{exp_name}.json"
    out.write_text(json.dumps(rec, indent=2))
    print(f"[perf] {arch} {shape} {exp_name}: "
          f"comp={rec['compute_s_corrected']:.4f}s mem={rec['memory_s']:.4f}s "
          f"coll={rec['collective_s']:.4f}s "
          f"ratio={rec['useful_flops_ratio']:.3f} "
          f"compile={rec['compile_s']:.0f}s", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--exp", nargs="+", required=True,
                    choices=list(EXPERIMENTS))
    ap.add_argument("--scanned", action="store_true",
                    help="scanned compile (memory-focused experiments)")
    args = ap.parse_args()
    arch, shape = args.cell.split(":")
    mesh = make_production_mesh()
    for e in args.exp:
        run_experiment(arch, shape, e, mesh, unroll=not args.scanned)


if __name__ == "__main__":
    main()
