"""Serving launcher: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --slots 4

``--estimator`` picks the linear-attention feature family by registry name
(forwarded to ``get_config``, validated at engine construction);
``--data-parallel`` builds a host mesh and runs data-parallel decode with
replicated estimator params (DESIGN.md §10) — pair with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import init_model
from repro.serve import Request, ServingEngine


def make_engine(
    arch: str,
    *,
    smoke: bool = True,
    attention_mode: str | None = None,
    estimator: str | None = None,
    num_slots: int = 4,
    max_len: int = 128,
    mesh=None,
    seed: int = 0,
) -> ServingEngine:
    """Config -> params -> engine, with every override forwarded.

    The regression this guards (tests/test_serve_engine.py): ``estimator``
    must reach ``get_config`` so the engine's up-front registry validation
    sees the requested family — silently serving the default "rm" estimator
    under a ``--estimator tensor_sketch`` launch is exactly the conformance
    drift the registry exists to prevent.
    """
    cfg = get_config(arch, smoke=smoke, attention_mode=attention_mode,
                     estimator=estimator)
    if not cfg.causal:
        raise ValueError(f"{arch} is encoder-only; nothing to serve")
    params = init_model(cfg, jax.random.PRNGKey(seed))
    return ServingEngine(cfg, params, num_slots=num_slots, max_len=max_len,
                         mesh=mesh)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attention-mode", default=None,
                    choices=[None, "exact", "rm"])
    ap.add_argument("--estimator", default=None,
                    help="feature-estimator registry name "
                         "(rm/tensor_sketch/ctr)")
    ap.add_argument("--data-parallel", action="store_true",
                    help="decode over a host mesh (DP slots, replicated "
                         "params)")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="pin the jax platform before backend init "
                         "(repro.common.env.set_platform)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="expose N host CPU devices via XLA_FLAGS (for "
                         "--data-parallel on one machine)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args(argv)

    # platform knobs must land before the first device query initializes
    # the backend (repro.common.env docstring)
    from repro.common import env

    if args.host_devices:
        env.set_host_device_count(args.host_devices)
    if args.platform:
        env.set_platform(args.platform)

    mesh = None
    if args.data_parallel:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        print(f"[serve] mesh {dict(mesh.shape)} over {len(jax.devices())} "
              "devices")
    engine = make_engine(
        args.arch, smoke=args.smoke, attention_mode=args.attention_mode,
        estimator=args.estimator, num_slots=args.slots, max_len=args.max_len,
        mesh=mesh,
    )
    cfg = engine.cfg
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 24)))
        engine.submit(Request(request_id=i, prompt=prompt,
                              max_new_tokens=args.max_new))
    done = engine.run()
    wall = time.time() - t0
    toks = sum(len(s.generated) for s in done.values())
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.1f}s "
          f"({toks / wall:.1f} tok/s aggregate)")
    for rid in sorted(done):
        s = done[rid]
        ttft = (s.t_first_token - s.t_enqueue) if s.t_first_token else None
        print(f"  req {rid}: {len(s.generated)} tokens, "
              f"ttft={ttft:.2f}s" if ttft else f"  req {rid}")


if __name__ == "__main__":
    main()
