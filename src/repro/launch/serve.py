"""Serving launcher: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import init_model
from repro.serve import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attention-mode", default=None,
                    choices=[None, "exact", "rm"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke,
                     attention_mode=args.attention_mode)
    if not cfg.causal:
        raise SystemExit(f"{args.arch} is encoder-only; nothing to serve")
    params = init_model(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, num_slots=args.slots,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 24)))
        engine.submit(Request(request_id=i, prompt=prompt,
                              max_new_tokens=args.max_new))
    done = engine.run()
    wall = time.time() - t0
    toks = sum(len(s.generated) for s in done.values())
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.1f}s "
          f"({toks / wall:.1f} tok/s aggregate)")
    for rid in sorted(done):
        s = done[rid]
        ttft = (s.t_first_token - s.t_enqueue) if s.t_first_token else None
        print(f"  req {rid}: {len(s.generated)} tokens, "
              f"ttft={ttft:.2f}s" if ttft else f"  req {rid}")


if __name__ == "__main__":
    main()
