"""Serving launcher: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --requests 8 --slots 4

``--scheduler`` picks the frontend — ``continuous`` (default) is the
continuous-batching Scheduler with per-step admission/eviction and
priority queues, ``bucketed`` the deprecated batch-synchronous engine;
``--arrival-trace`` replays a JSONL arrival trace (see
``repro.bench.loadgen``) open-loop through the continuous scheduler.
``--estimator`` picks the linear-attention feature family by registry name
(forwarded to ``get_config``, validated at engine construction);
``--data-parallel`` builds a host mesh and runs data-parallel decode with
replicated estimator params (DESIGN.md §10) — pair with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU.

Observability (docs/observability.md): ``--trace-out trace.jsonl`` streams
the request lifecycle + ``kernel/*`` spans as JSONL (summarize or convert
with ``python -m repro.obs``), ``--metrics-out metrics.json`` snapshots the
TTFT / token-latency / tokens-per-sec histograms, and ``--drift-every N``
runs the online (eps, delta) Gram-drift check every N decode iterations.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import init_model
from repro.serve import Request, Scheduler, ServingEngine


def make_engine(
    arch: str,
    *,
    smoke: bool = True,
    attention_mode: str | None = None,
    estimator: str | None = None,
    num_slots: int = 4,
    max_len: int = 128,
    mesh=None,
    seed: int = 0,
    obs=None,
    scheduler: str = "continuous",
    buckets=None,
    cfg=None,
    accuracy_tiers=None,
):
    """Config -> params -> serving frontend, with every override forwarded.

    ``scheduler`` picks the frontend: ``"continuous"`` (default) builds the
    continuous-batching :class:`~repro.serve.scheduler.Scheduler`;
    ``"bucketed"`` the legacy batch-synchronous ``ServingEngine``
    (deprecated, docs/serving.md). Both expose the same submit/run surface.

    ``cfg`` short-circuits the ``get_config`` resolution with an already-
    resolved config (the launcher uses this after budget selection rewrites
    ``cfg.rm``); ``accuracy_tiers`` maps tier names to feature-generation
    counts (continuous scheduler only, docs/adaptive.md).

    The regression this guards (tests/test_serve_engine.py): ``estimator``
    must reach ``get_config`` so the engine's up-front registry validation
    sees the requested family — silently serving the default "rm" estimator
    under a ``--estimator tensor_sketch`` launch is exactly the conformance
    drift the registry exists to prevent.
    """
    if cfg is None:
        cfg = get_config(arch, smoke=smoke, attention_mode=attention_mode,
                         estimator=estimator)
    if not cfg.causal:
        raise ValueError(f"{arch} is encoder-only; nothing to serve")
    params = init_model(cfg, jax.random.PRNGKey(seed))
    if scheduler == "continuous":
        return Scheduler(cfg, params, num_slots=num_slots, max_len=max_len,
                         rng_seed=seed, buckets=buckets, mesh=mesh, obs=obs,
                         accuracy_tiers=accuracy_tiers)
    if scheduler == "bucketed":
        if accuracy_tiers is not None:
            raise ValueError("accuracy tiers need the continuous "
                             "scheduler; the bucketed engine has no "
                             "per-request admission surface")
        return ServingEngine(cfg, params, num_slots=num_slots,
                             max_len=max_len, rng_seed=seed, buckets=buckets,
                             mesh=mesh, obs=obs)
    raise ValueError(f"unknown scheduler {scheduler!r}: expected "
                     "'continuous' or 'bucketed'")


def parse_tiers(spec: str):
    """``"low:1,standard:2,high:4"`` -> ``{"low": 1, ...}`` (CLI format)."""
    tiers = {}
    for part in spec.split(","):
        name, _, gens = part.partition(":")
        name = name.strip()
        if not name or not gens.strip().isdigit():
            raise SystemExit(
                f"[serve] bad --accuracy-tiers entry {part!r}: expected "
                "name:generations pairs like 'low:1,standard:2,high:4'")
        tiers[name] = int(gens)
    return tiers


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--attention-mode", default=None,
                    choices=[None, "exact", "rm"])
    ap.add_argument("--estimator", default=None,
                    help="feature-estimator registry name "
                         "(rm/tensor_sketch/ctr)")
    ap.add_argument("--data-parallel", action="store_true",
                    help="decode over a host mesh (DP slots, replicated "
                         "params)")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="pin the jax platform before backend init "
                         "(repro.common.env.set_platform)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="expose N host CPU devices via XLA_FLAGS (for "
                         "--data-parallel on one machine)")
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "bucketed"],
                    help="serving frontend: the continuous-batching "
                         "Scheduler (default) or the deprecated "
                         "batch-synchronous bucketed engine")
    ap.add_argument("--arrival-trace", default=None, metavar="FILE",
                    help="replay a JSONL arrival trace (repro.bench."
                         "loadgen format) open-loop instead of submitting "
                         "everything up front (continuous scheduler only)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--trace-out", default=None, metavar="FILE",
                    help="stream a JSONL lifecycle + kernel-span trace "
                         "(inspect with python -m repro.obs)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE",
                    help="write the metrics snapshot (TTFT/latency/tok-s "
                         "histograms) as JSON")
    ap.add_argument("--drift-every", type=int, default=0, metavar="N",
                    help="run the online (eps, delta) Gram-drift check "
                         "every N decode iterations (0 = off; needs an "
                         "rm-family --attention-mode)")
    ap.add_argument("--accuracy-tiers", default=None, metavar="SPEC",
                    help="per-request accuracy tiers as name:generations "
                         "pairs, e.g. 'low:1,standard:2,high:4' "
                         "(continuous scheduler + rm attention; synthetic "
                         "requests cycle through the tiers)")
    from repro.launch.budget import add_budget_args, apply_budget_selection

    add_budget_args(ap)
    args = ap.parse_args(argv)

    # platform knobs must land before the first device query initializes
    # the backend (repro.common.env docstring)
    from repro.common import env

    if args.host_devices:
        env.set_host_device_count(args.host_devices)
    if args.platform:
        env.set_platform(args.platform)

    mesh = None
    if args.data_parallel:
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
        print(f"[serve] mesh {dict(mesh.shape)} over {len(jax.devices())} "
              "devices")

    # resolve the config ONCE: the budget selection (when requested)
    # rewrites cfg.rm, and the drift monitor + engine must both see the
    # selected budget, not the arch default
    cfg = get_config(args.arch, smoke=args.smoke,
                     attention_mode=args.attention_mode,
                     estimator=args.estimator)
    cfg, _decision = apply_budget_selection(cfg, args, tag="serve")

    tiers = parse_tiers(args.accuracy_tiers) if args.accuracy_tiers \
        else None
    if tiers and _decision is not None:
        # tiers split the budget into max(generations) equal blocks; round
        # the selected D UP to the next multiple (eps_at only tightens)
        import dataclasses

        gmax = max(tiers.values())
        d = cfg.rm.num_features
        if d % gmax:
            d += gmax - d % gmax
            cfg = dataclasses.replace(cfg, rm=dataclasses.replace(
                cfg.rm, num_features=d)).validate()
            print(f"[serve] rounded D up to {d} (multiple of {gmax} "
                  "tier generations)")

    obs = None
    if args.trace_out or args.metrics_out or args.drift_every:
        from repro import obs as obs_mod

        drift = None
        if args.drift_every:
            # watch a map drawn exactly like the deployed attention
            # featurizer: same estimator family, measure and budget D
            if cfg.attention_mode == "rm":
                from repro.core import ExponentialDotProductKernel

                rm = cfg.rm
                drift = obs_mod.DriftMonitor.for_estimator(
                    ExponentialDotProductKernel(sigma2=rm.sigma2),
                    cfg.resolved_head_dim, rm.num_features,
                    estimator=rm.estimator, measure=rm.measure,
                    # the monitor holds the map to the SELECTED delta
                    **({"delta": args.delta}
                       if args.delta is not None else {}))
            else:
                print("[serve] --drift-every ignored: attention mode is "
                      "not rm-family")
        obs = obs_mod.Obs(trace_path=args.trace_out, drift=drift,
                          drift_every=args.drift_every,
                          install_kernel_tracing=True)

    engine = make_engine(
        args.arch, num_slots=args.slots, max_len=args.max_len,
        mesh=mesh, obs=obs, scheduler=args.scheduler, cfg=cfg,
        accuracy_tiers=tiers,
    )
    t0 = time.time()
    if args.arrival_trace:
        if args.scheduler != "continuous":
            raise SystemExit("--arrival-trace needs --scheduler continuous")
        from repro.bench import loadgen

        arrivals = loadgen.load_trace(args.arrival_trace)
        raw = loadgen.run_load(engine, arrivals)
        done = raw["finished"]
        print(f"[serve] replayed {len(arrivals)} arrivals from "
              f"{args.arrival_trace} ({raw['truncated']} truncated)")
    else:
        rng = np.random.default_rng(0)
        tier_names = sorted(tiers) if tiers else None
        for i in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  size=int(rng.integers(4, 24)))
            # synthetic load cycles through the configured tiers so every
            # tier's admission path (and tier_features certification) runs
            tier = tier_names[i % len(tier_names)] if tier_names else None
            engine.submit(Request(request_id=i, prompt=prompt,
                                  max_new_tokens=args.max_new,
                                  accuracy_tier=tier))
        done = engine.run()
        if tier_names:
            for rid in sorted(done):
                s = done[rid]
                if s.tier_features is not None:
                    print(f"  req {rid}: tier="
                          f"{s.request.accuracy_tier} certified at "
                          f"D={s.tier_features}")
    wall = time.time() - t0
    toks = sum(len(s.generated) for s in done.values())
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.1f}s "
          f"({toks / wall:.1f} tok/s aggregate)")
    for rid in sorted(done):
        s = done[rid]
        ttft = (s.t_first_token - s.t_enqueue) if s.t_first_token else None
        print(f"  req {rid}: {len(s.generated)} tokens, "
              f"ttft={ttft:.2f}s" if ttft else f"  req {rid}")

    if obs is not None:
        snap = obs.metrics.snapshot()
        hists = snap.get("histograms", {})

        def _h(name):
            return hists.get(name, {})

        ttft_s, tok_s = _h("serve/ttft_s"), _h("serve/tokens_per_s")
        if ttft_s:
            print(f"[serve] ttft p50={ttft_s['p50']:.3f}s "
                  f"p99={ttft_s['p99']:.3f}s | per-request tok/s "
                  f"p50={tok_s.get('p50', float('nan')):.1f}")
        if obs.drift is not None and obs.drift.last is not None:
            rep = obs.drift.last
            print(f"[serve] drift: sup_err={rep.sup_err:.4f} vs "
                  f"eps({rep.num_features}, delta)={rep.eps_bound:.4f} "
                  f"[{'OK' if rep.ok else 'VIOLATION'}] "
                  f"({obs.drift.checks} checks, "
                  f"{obs.drift.violations} violations)")
        if args.metrics_out:
            obs.write_metrics(args.metrics_out)
            print(f"[serve] wrote metrics -> {args.metrics_out}")
        obs.close()
        if args.trace_out:
            print(f"[serve] wrote trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
