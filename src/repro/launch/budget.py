"""Shared ``--eps/--delta/--latency-budget`` plumbing for the launch CLIs.

Both launchers (``repro.launch.serve``, ``repro.launch.train``) grow the
same three flags: an accuracy target ``(--eps, --delta)`` and an optional
``--latency-budget``. When given, the launcher stops trusting the arch
config's hand-picked feature budget and instead asks
:func:`repro.core.select.select_budget` for the (estimator, D, precision)
that certifies the target at the lowest predicted featurization cost —
priced from the committed ``BENCH_core.json`` cost model when present
(docs/adaptive.md).

The selection is applied to the resolved config via ``dataclasses.replace``
on the ``rm`` sub-config, then re-validated, so the served/trained model
runs at exactly the certified budget and the drift monitor watches the
same (eps, delta) envelope the selection promised.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

__all__ = ["add_budget_args", "apply_budget_selection"]


def add_budget_args(ap) -> None:
    """Install the adaptive-accuracy flags on a launcher's argparser."""
    ap.add_argument("--eps", type=float, default=None, metavar="EPS",
                    help="target sup Gram error: size the RM feature "
                         "budget from the Theorem 12 bound instead of the "
                         "arch config (requires --delta; rm attention "
                         "only, docs/adaptive.md)")
    ap.add_argument("--delta", type=float, default=None, metavar="DELTA",
                    help="failure probability for --eps; also tightens "
                         "the --drift-every monitor to the same delta")
    ap.add_argument("--latency-budget", type=float, default=None,
                    metavar="SECONDS",
                    help="prefer the fastest (estimator, precision) whose "
                         "predicted featurization time fits (advisory: "
                         "accuracy is a guarantee, latency a preference)")
    ap.add_argument("--bench", default="BENCH_core.json", metavar="FILE",
                    help="bench artifact the selection cost model is "
                         "fitted from (skipped silently if absent)")


def apply_budget_selection(cfg, args, *, tag: str = "launch",
                           ) -> Tuple[object, Optional[object]]:
    """Resolve ``--eps/--delta/--latency-budget`` against a config.

    Returns ``(cfg, decision)`` — the config with the selected
    (estimator, num_features, precision) spliced into ``cfg.rm`` and
    re-validated, plus the full :class:`~repro.core.select.BudgetDecision`
    (``None`` when no accuracy target was requested). Exits with a usage
    error on half-specified targets or non-RM attention modes.
    """
    if args.eps is None and args.delta is None:
        return cfg, None
    if args.eps is None or args.delta is None:
        raise SystemExit(
            f"[{tag}] --eps and --delta must be given together "
            "(the Theorem 12 bound prices an (eps, delta) pair)")
    if cfg.attention_mode != "rm":
        raise SystemExit(
            f"[{tag}] --eps/--delta size the RM feature budget; "
            f"attention_mode={cfg.attention_mode!r} has none "
            "(pass --attention-mode rm)")

    from repro.core import CostModel, ExponentialDotProductKernel
    from repro.core.select import select_budget

    rm = cfg.rm
    cost = None
    if args.bench and os.path.exists(args.bench):
        cost = CostModel.from_file(args.bench)
    else:
        print(f"[{tag}] bench artifact {args.bench!r} not found; "
              "selection runs without a cost model (no latency ranking)")
    # The bound constants only exist for the measures core.bounds knows;
    # the config's proportional default maps through, anything exotic
    # falls back to the geometric constants (same rule as
    # make_feature_map's accuracy-target mode).
    measure = "proportional" if rm.measure == "proportional" else "geometric"
    decision = select_budget(
        ExponentialDotProductKernel(sigma2=rm.sigma2),
        cfg.resolved_head_dim, args.eps, args.delta,
        latency_budget_s=args.latency_budget,
        # pin the family only when the user pinned it on the CLI
        estimator=getattr(args, "estimator", None),
        cost_model=cost, measure=measure, radius=0.9,
    )
    line = (f"[{tag}] selection: {decision.estimator}/{decision.precision} "
            f"D={decision.num_features} certifies "
            f"eps={decision.eps_certified:.4g} <= {decision.eps:.4g} "
            f"at delta={decision.delta:g}")
    if decision.predicted_latency_s is not None:
        line += (f" (predicted featurize "
                 f"{decision.predicted_latency_s * 1e3:.2f} ms/batch"
                 f"{'' if decision.meets_latency_budget in (None, True) else ', OVER the latency budget'})")
    print(line)
    cfg = dataclasses.replace(
        cfg, rm=dataclasses.replace(
            rm, estimator=decision.estimator,
            precision=decision.precision,
            num_features=decision.num_features)).validate()
    return cfg, decision
