import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached as JSON under results/dryrun/<mesh>/<arch>__<shape>.json
(one file per cell; re-runs skip existing files unless --force). A compile
SUCCESS for a cell proves the sharding config is coherent: no sharding
mismatches, no unsupported collectives, memory analysis available for
§Dry-run / §Roofline.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import (
    HW_V5E,
    analytic_inner_loop_flops,
    collective_bytes_from_hlo,
    count_params,
    model_flops,
    roofline_from_compiled,
)
from repro.configs import get_config, list_archs
from repro.distributed.sharding import (
    batch_partition_specs,
    cache_partition_specs,
    logical_rules_context,
    params_partition_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, enumerate_cells, input_specs
from repro.train.steps import (
    TrainHyper,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _tree_sds(tree):
    """Concrete-free ShapeDtypeStruct mirror of an abstract init."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _sharding_tree(spec_tree, mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P),
    )


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               attention_mode: str, rules_override=None,
               hyper: TrainHyper | None = None, unroll: bool = True,
               cfg_override=None):
    """Lower + compile one cell; returns the record dict.

    ``unroll=True`` (single-pod/roofline runs) fully unrolls the layer scan
    so cost_analysis sees every layer; multi-pod sharding-proof runs use the
    scanned form (fast compiles — the collective schedule per layer is
    identical across layers).
    """
    cfg = get_config(arch, attention_mode=attention_mode)
    if unroll:
        cfg = dataclasses.replace(cfg, scan_unroll=True)
    if cfg_override is not None:
        cfg = cfg_override(cfg)
    spec = SHAPES[shape_name]
    hyper = hyper or TrainHyper()
    t0 = time.time()

    with logical_rules_context(mesh, rules_override) as rules:
        specs = input_specs(cfg, shape_name)
        batch_sds = specs["batch"]
        batch_spec = batch_partition_specs(batch_sds, mesh, rules)
        batch_shard = _sharding_tree(batch_spec, mesh)

        if spec.kind == "train":
            state_sds = jax.eval_shape(
                lambda: init_train_state(cfg, jax.random.PRNGKey(0), hyper)
            )
            state_spec = _state_specs(state_sds, mesh, rules)
            state_shard = _sharding_tree(state_spec, mesh)
            step = make_train_step(cfg, hyper)
            jitted = jax.jit(
                step,
                in_shardings=(state_shard, batch_shard),
                out_shardings=(state_shard, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_sds, batch_sds)
        elif spec.kind == "prefill":
            params_sds = jax.eval_shape(
                lambda: _abstract_params(cfg))
            params_spec = params_partition_specs(params_sds, mesh, rules)
            params_shard = _sharding_tree(params_spec, mesh)
            step = make_prefill_step(cfg, max_len=spec.seq_len)
            jitted = jax.jit(step, in_shardings=(params_shard, batch_shard))
            lowered = jitted.lower(params_sds, batch_sds)
        else:  # decode
            params_sds = jax.eval_shape(lambda: _abstract_params(cfg))
            params_spec = params_partition_specs(params_sds, mesh, rules)
            params_shard = _sharding_tree(params_spec, mesh)
            cache_sds = specs["cache"]
            cache_spec = cache_partition_specs(cache_sds, mesh, rules)
            cache_shard = _sharding_tree(cache_spec, mesh)
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(params_shard, cache_shard, batch_shard),
                out_shardings=(None, cache_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_sds, cache_sds, batch_sds)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    roof = roofline_from_compiled(compiled, mesh.size, HW_V5E, hlo_text=hlo)

    # MODEL_FLOPS reference
    params_sds = jax.eval_shape(lambda: _abstract_params(cfg))
    moe_frac = None
    if cfg.moe is not None:
        moe_frac = cfg.moe.top_k / cfg.moe.num_experts
    n_total, n_active = count_params(params_sds, moe_frac)
    tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    mf = model_flops("train" if spec.kind == "train" else "serve",
                     n_active, tokens)
    global_hlo_flops = roof["per_device_flops"] * mesh.size
    # analytic correction for within-layer loops counted once by XLA
    inner_fix = analytic_inner_loop_flops(cfg, spec.seq_len,
                                          spec.global_batch, spec.kind)
    corrected = global_hlo_flops + inner_fix
    compute_s_corr = corrected / mesh.size / HW_V5E.peak_flops
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": spec.kind,
        "mesh": mesh_name,
        "mesh_shape": dict(zip(mesh.axis_names,
                               [int(mesh.shape[a]) for a in mesh.axis_names])),
        "attention_mode": attention_mode,
        "seq_len": spec.seq_len,
        "global_batch": spec.global_batch,
        "tokens_per_step": tokens,
        "params_total": n_total,
        "params_active": n_active,
        "model_flops": mf,
        "hlo_flops_global": global_hlo_flops,
        "inner_loop_flops_correction": inner_fix,
        "hlo_flops_corrected": corrected,
        "compute_s_corrected": compute_s_corr,
        "useful_flops_ratio": (mf / corrected if corrected else None),
        "unrolled": unroll,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        **roof,
    }
    return record


def _abstract_params(cfg):
    from repro.models.transformer import init_model

    return init_model(cfg, jax.random.PRNGKey(0))


def _state_specs(state_sds, mesh, rules):
    """PartitionSpecs for the full TrainState (params + adamw mirrors)."""
    pspec = params_partition_specs(state_sds["params"], mesh, rules)
    out = {
        "params": pspec,
        "opt": {
            "mu": pspec,
            "nu": pspec,
            "step": P(),
        },
        "step": P(),
    }
    if "residuals" in state_sds:
        out["residuals"] = pspec
    return out


HBM_BYTES = 16e9  # TPU v5e per-chip HBM


def _fits(mem: dict) -> Optional[bool]:
    if not mem:
        return None
    total = (mem.get("temp_size_in_bytes") or 0) + \
        (mem.get("argument_size_in_bytes") or 0)
    return bool(total < 0.95 * HBM_BYTES)


def _accum_start(arch: str) -> int:
    from repro.models.transformer import init_model

    cfg = get_config(arch)
    sds = jax.eval_shape(lambda: init_model(cfg, jax.random.PRNGKey(0)))
    n, _ = count_params(sds)
    return 1 if n < 4e9 else (4 if n < 2e10 else 8)


def measure_cell(cell, mesh, mesh_name):
    """Full per-cell protocol.

    single-pod: (a) scanned compiles pick the smallest grad_accum whose
    temp+args memory fits HBM (train shapes) and give the realistic
    memory_analysis (while-loop buffer reuse); (b) an unrolled compile gives
    exact per-layer flops + collective bytes for the roofline.
    multi-pod: one scanned compile proves the pod-axis sharding.
    """
    if mesh_name != "single":
        rec = lower_cell(cell.arch, cell.shape, mesh, mesh_name,
                         cell.attention_mode, unroll=False)
        rec["fits_hbm"] = _fits(rec.get("memory_analysis"))
        return rec

    spec = SHAPES[cell.shape]
    # fast mode: scanned compile + multiply per-group loop counts by the trip
    # count (approximation, flagged in the record — used when unrolled
    # compiles of the largest archs exceed the CPU-container budget).
    if os.environ.get("REPRO_DRYRUN_FAST"):
        cfg = get_config(cell.arch, attention_mode=cell.attention_mode)
        g = cfg.num_scanned_groups
        rec = lower_cell(cell.arch, cell.shape, mesh, mesh_name,
                         cell.attention_mode, unroll=False)
        for key in ("per_device_flops", "per_device_collective_bytes",
                    "per_device_bytes"):
            rec[key] = rec[key] * g
        rec["hlo_flops_global"] = rec["per_device_flops"] * mesh.size
        rec["hlo_flops_corrected"] = (rec["hlo_flops_global"]
                                      + rec["inner_loop_flops_correction"])
        rec["compute_s"] = rec["per_device_flops"] / HW_V5E.peak_flops
        rec["compute_s_corrected"] = (rec["hlo_flops_corrected"] / mesh.size
                                      / HW_V5E.peak_flops)
        rec["memory_s"] = rec["per_device_bytes"] / HW_V5E.hbm_bw
        rec["collective_s"] = (rec["per_device_collective_bytes"]
                               / HW_V5E.link_bw)
        rec["useful_flops_ratio"] = (rec["model_flops"]
                                     / rec["hlo_flops_corrected"])
        for c in rec["collectives"].values():
            c["bytes"] *= g
            c["count"] *= g
        rec["approx_scaled_by_groups"] = g
        terms = {"compute": rec["compute_s_corrected"],
                 "memory": rec["memory_s"],
                 "collective": rec["collective_s"]}
        rec["dominant"] = max(terms, key=terms.get)
        rec["fits_hbm"] = _fits(rec.get("memory_analysis"))
        rec["grad_accum"] = 1
        return rec

    mem_rec = None
    grad_accum = 1
    if spec.kind == "train":
        accum = _accum_start(cell.arch)
        while True:
            mem_rec = lower_cell(cell.arch, cell.shape, mesh, mesh_name,
                                 cell.attention_mode, unroll=False,
                                 hyper=TrainHyper(grad_accum=accum))
            if _fits(mem_rec.get("memory_analysis")) or accum >= 16:
                break
            accum *= 2
        grad_accum = accum
    else:
        mem_rec = lower_cell(cell.arch, cell.shape, mesh, mesh_name,
                             cell.attention_mode, unroll=False)

    rec = lower_cell(cell.arch, cell.shape, mesh, mesh_name,
                     cell.attention_mode, unroll=True)
    rec["grad_accum"] = grad_accum
    rec["memory_analysis_scanned"] = mem_rec.get("memory_analysis")
    rec["fits_hbm"] = _fits(mem_rec.get("memory_analysis"))
    rec["compile_s_scanned"] = mem_rec.get("compile_s")
    return rec


def run_cells(mesh_names, archs, shapes, force=False, fail_fast=False):
    arch_cfgs = {a: get_config(a) for a in archs}
    cells = enumerate_cells(archs, arch_cfgs, shapes)
    summary = []
    for mesh_name in mesh_names:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        out_dir = RESULTS_DIR / mesh_name
        out_dir.mkdir(parents=True, exist_ok=True)
        for cell in cells:
            out_path = out_dir / f"{cell.arch}__{cell.shape}.json"
            if cell.skipped:
                rec = {
                    "arch": cell.arch, "shape": cell.shape,
                    "mesh": mesh_name, "skipped": True,
                    "skip_reason": cell.skip_reason,
                }
                out_path.write_text(json.dumps(rec, indent=2))
                summary.append((cell.arch, cell.shape, mesh_name, "SKIP"))
                print(f"[dryrun] SKIP  {cell.arch:22s} {cell.shape:12s} "
                      f"{mesh_name}: {cell.skip_reason}", flush=True)
                continue
            if out_path.exists() and not force:
                summary.append((cell.arch, cell.shape, mesh_name, "CACHED"))
                print(f"[dryrun] CACHE {cell.arch:22s} {cell.shape:12s} "
                      f"{mesh_name}", flush=True)
                continue
            try:
                rec = measure_cell(cell, mesh, mesh_name)
                rec["skipped"] = False
                out_path.write_text(json.dumps(rec, indent=2))
                summary.append((cell.arch, cell.shape, mesh_name, "OK"))
                print(
                    f"[dryrun] OK    {cell.arch:22s} {cell.shape:12s} "
                    f"{mesh_name} compile={rec['compile_s']:.1f}s "
                    f"dom={rec['dominant']} "
                    f"comp={rec['compute_s']:.4f}s "
                    f"mem={rec['memory_s']:.4f}s "
                    f"coll={rec['collective_s']:.4f}s",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 - record failures
                summary.append((cell.arch, cell.shape, mesh_name, "FAIL"))
                err = {"arch": cell.arch, "shape": cell.shape,
                       "mesh": mesh_name, "error": str(e),
                       "traceback": traceback.format_exc()}
                (out_dir / f"{cell.arch}__{cell.shape}.FAILED.json"
                 ).write_text(json.dumps(err, indent=2))
                print(f"[dryrun] FAIL  {cell.arch:22s} {cell.shape:12s} "
                      f"{mesh_name}: {e}", flush=True)
                if fail_fast:
                    raise
    return summary


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (see configs)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="all archs x all shapes")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--fail-fast", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        f"dry-run requires 512 host devices, got {len(jax.devices())} "
        "(XLA_FLAGS must be set before jax import)"
    )
    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    mesh_names = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    summary = run_cells(mesh_names, archs, shapes, force=args.force,
                        fail_fast=args.fail_fast)
    n_ok = sum(1 for s in summary if s[3] in ("OK", "CACHED"))
    n_skip = sum(1 for s in summary if s[3] == "SKIP")
    n_fail = sum(1 for s in summary if s[3] == "FAIL")
    print(f"\n[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
