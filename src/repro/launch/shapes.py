"""Assigned input shapes and per-(arch x shape) cell enumeration.

LM shapes (assignment):
  train_4k     seq_len=4096,   global_batch=256  -> train_step
  prefill_32k  seq_len=32768,  global_batch=32   -> prefill (serve)
  decode_32k   seq_len=32768,  global_batch=128  -> serve_step (1 new token,
                                                   cache of seq_len)
  long_500k    seq_len=524288, global_batch=1    -> long-context serve_step

Skip rules (DESIGN.md §6):
  * encoder-only archs (hubert) have no decode -> decode_32k & long_500k skip;
  * long_500k needs sub-quadratic attention -> exact softmax archs run it in
    the paper's RM linear-attention mode ("rm"); SSM/hybrid archs run
    natively. The attention mode used is recorded per cell.

``input_specs`` returns ShapeDtypeStruct stand-ins only — no allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

VLM_PATCHES = 256  # vision_stub prefix length carved out of seq_len


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    attention_mode: str            # mode this cell runs under
    skipped: bool = False
    skip_reason: str = ""


def enumerate_cells(archs: List[str], arch_cfgs: Dict[str, ModelConfig],
                    shapes: Optional[List[str]] = None) -> List[Cell]:
    cells = []
    for arch in archs:
        cfg = arch_cfgs[arch]
        attention_free = not any(
            b.split("_")[0] in ("attn", "mla") for b in cfg.block_pattern
        )
        for sname in shapes or SHAPES:
            spec = SHAPES[sname]
            if spec.kind == "decode" and not cfg.causal:
                cells.append(Cell(arch, sname, cfg.attention_mode, True,
                                  "encoder-only: no decode step"))
                continue
            mode = cfg.attention_mode
            if sname == "long_500k":
                # sub-quadratic requirement: exact-attention archs switch to
                # the paper's RM linear attention; SSM archs run natively.
                if not attention_free:
                    mode = "rm"
            cells.append(Cell(arch, sname, mode))
    return cells


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int):
    b, t = global_batch, seq_len
    if cfg.frontend == "audio_stub":
        return {
            "embeds": _sds((b, t, cfg.d_model), jnp.bfloat16),
            "targets": _sds((b, t), jnp.int32),
        }
    if cfg.frontend == "vision_stub":
        t_text = t - VLM_PATCHES
        return {
            "embeds": _sds((b, VLM_PATCHES, cfg.d_model), jnp.bfloat16),
            "tokens": _sds((b, t_text), jnp.int32),
            "targets": _sds((b, t_text), jnp.int32),
        }
    return {
        "tokens": _sds((b, t), jnp.int32),
        "targets": _sds((b, t), jnp.int32),
    }


def prefill_batch_specs(cfg: ModelConfig, seq_len: int, global_batch: int):
    specs = train_batch_specs(cfg, seq_len, global_batch)
    specs.pop("targets", None)
    return specs


def decode_batch_specs(cfg: ModelConfig, global_batch: int):
    return {
        "tokens": _sds((global_batch, 1), jnp.int32),
        "positions": _sds((global_batch,), jnp.int32),
    }


def decode_cache_specs(cfg: ModelConfig, global_batch: int, max_len: int):
    """Abstract cache pytree via eval_shape (no allocation)."""
    from repro.models.transformer import init_decode_cache

    return jax.eval_shape(
        lambda: init_decode_cache(cfg, global_batch, max_len)
    )


def input_specs(cfg: ModelConfig, shape_name: str):
    """The full abstract input set for a cell, keyed by step kind."""
    spec = SHAPES[shape_name]
    if spec.kind == "train":
        return {"batch": train_batch_specs(cfg, spec.seq_len, spec.global_batch)}
    if spec.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, spec.seq_len,
                                             spec.global_batch)}
    if spec.kind == "decode":
        return {
            "batch": decode_batch_specs(cfg, spec.global_batch),
            "cache": decode_cache_specs(cfg, spec.global_batch, spec.seq_len),
        }
    raise ValueError(shape_name)
