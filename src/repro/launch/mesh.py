"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run forces 512 host devices via XLA_FLAGS before any jax import, while
tests/benches must keep seeing 1 device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType


def _auto(n: int):
    return (AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for tests/elastic reconfiguration."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (CPU tests: 1..8 devices)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), axis_types=_auto(2))
