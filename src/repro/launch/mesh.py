"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run forces 512 host devices via XLA_FLAGS before any jax import, while
tests/benches must keep seeing 1 device.

Pin compatibility: ``jax.sharding.AxisType`` (explicit/auto axis types) only
exists on newer jax releases. On pins without it every mesh axis is plain
(implicitly Auto), which is exactly what ``shard_map``/``pjit`` expect here —
so the kwarg is dropped rather than emulated.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

try:  # jax >= 0.5-era explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older pins: meshes are implicitly Auto
    AxisType = None


def _auto(n: int) -> dict:
    """axis_types kwargs for ``jax.make_mesh`` (empty on pins without them)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for tests/elastic reconfiguration."""
    return jax.make_mesh(shape, axes, **_auto(len(axes)))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist locally (CPU tests: 1..8 devices)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"), **_auto(2))


def make_feature_mesh(num_shards: Optional[int] = None):
    """1-axis mesh whose axis IS the logical feature axis ``"rm_features"``.

    The sharded estimator path (``repro.distributed.estimator``) partitions
    random-feature columns over this axis: each device owns one shard's
    params and feature columns, and Gram estimation reduces with a single
    ``psum``. Defaults to all local devices (8 under
    ``--xla_force_host_platform_device_count=8``).
    """
    from repro.distributed.sharding import FEATURE_AXIS

    n = len(jax.devices()) if num_shards is None else num_shards
    return jax.make_mesh((n,), (FEATURE_AXIS,), **_auto(1))
