from repro.train.steps import (
    TrainState,
    init_train_state,
    make_train_step,
    make_eval_step,
    make_prefill_step,
    make_decode_step,
)
from repro.train.checkpoint import CheckpointManager

__all__ = [
    "TrainState",
    "init_train_state",
    "make_train_step",
    "make_eval_step",
    "make_prefill_step",
    "make_decode_step",
    "CheckpointManager",
]
