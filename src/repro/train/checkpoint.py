"""Fault-tolerant checkpointing: atomic writes, keep-last-k, resharding
restore for elastic reconfiguration.

Format: one ``.npz`` per checkpoint (flattened param paths -> arrays) plus a
``meta.json``. Writes go to ``<dir>/tmp.<step>`` then ``os.replace`` into
place — a crash mid-save can never corrupt the latest checkpoint (restart
safety). ``restore(..., shardings=...)`` device_puts each leaf with the
CURRENT mesh's sharding, so a run restarted on a different topology (elastic
downscale after node failure, upscale after repair) reshards transparently.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.common.tree import flatten_dict, unflatten_dict


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def available_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and (p / "state.npz").exists():
                steps.append(int(p.name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state: Any, extra_meta: Optional[Dict] = None):
        flat = flatten_dict(_to_host(state))
        tmp = self.dir / f"tmp.{step}.{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "state.npz", **{k: np.asarray(v)
                                       for k, v in flat.items()})
        meta = {
            "step": step,
            "time": time.time(),
            "leaves": {k: [list(np.shape(v)), str(np.asarray(v).dtype)]
                       for k, v in flat.items()},
        }
        if extra_meta:
            meta.update(extra_meta)
        (tmp / "meta.json").write_text(json.dumps(meta, indent=2))
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)             # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = self.available_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step: Optional[int] = None, shardings: Any = None,
                template: Any = None) -> Dict[str, Any]:
        """Load a checkpoint; optionally reshard onto the current mesh.

        ``shardings``: pytree of NamedSharding matching the state —
        device_put reshards each leaf (elastic restarts). ``template``:
        optional pytree to validate structure against.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = self._step_dir(step)
        with np.load(path / "state.npz") as data:
            flat = {k: data[k] for k in data.files}
        state = unflatten_dict(flat)
        state = _fix_scalars(state)
        if template is not None:
            t_flat = set(flatten_dict(template).keys())
            s_flat = set(flat.keys())
            if t_flat != s_flat:
                missing = t_flat - s_flat
                extra = s_flat - t_flat
                raise ValueError(
                    f"checkpoint structure mismatch: missing={sorted(missing)[:5]} "
                    f"extra={sorted(extra)[:5]}"
                )
        if shardings is not None:
            from repro.common.tree import EMPTY_SENTINEL

            flat_state = flatten_dict(state)
            flat_shard = flatten_dict(shardings)
            state = unflatten_dict({
                k: (v if k.endswith(EMPTY_SENTINEL)
                    else jax.device_put(v, flat_shard[k]))
                for k, v in flat_state.items()
            })
        return state


def _to_host(tree: Any) -> Any:
    return jax.tree_util.tree_map(lambda x: np.asarray(x), tree)


def _fix_scalars(tree: Any) -> Any:
    import jax.numpy as jnp

    return jax.tree_util.tree_map(
        lambda x: jnp.asarray(x) if np.ndim(x) == 0 else x, tree
    )
