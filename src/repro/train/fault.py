"""Fault tolerance & straggler mitigation for long-running multi-pod jobs.

On real clusters, failures surface as (a) whole-process death — handled by
checkpoint/auto-resume; (b) stragglers — individual hosts running slow; and
(c) topology changes — restart with fewer/more healthy pods. This module
provides the host-side machinery for all three, simulated/CPU-testable:

  * ``StragglerMonitor`` — per-step wall-time EWMA + deadline; flags steps
    exceeding ``threshold x`` the running mean (on real deployments this
    feeds the controller that preempts or cordons the slow host; here the
    hook records and optionally invokes a callback).
  * ``run_with_restarts`` — crash-restart harness: run a step loop, on
    exception restore the latest checkpoint and continue (bounded retries).
  * ``elastic_remesh`` — rebuild mesh + shardings for the surviving device
    count and reshard the state through ``CheckpointManager.restore`` — the
    multi-pod story for losing a pod (2x16x16 -> 16x16).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional

import jax


class StragglerMonitor:
    def __init__(self, threshold: float = 2.0, ewma: float = 0.9,
                 warmup_steps: int = 3,
                 on_straggler: Optional[Callable[[int, float, float], None]] = None):
        self.threshold = threshold
        self.ewma_coef = ewma
        self.warmup = warmup_steps
        self.mean: Optional[float] = None
        self.events: List[Dict[str, float]] = []
        self.on_straggler = on_straggler
        self._seen = 0

    def record(self, step: int, duration_s: float) -> bool:
        """Returns True if this step was flagged as a straggler."""
        self._seen += 1
        flagged = False
        if self.mean is not None and self._seen > self.warmup:
            if duration_s > self.threshold * self.mean:
                flagged = True
                self.events.append(
                    {"step": step, "duration": duration_s, "mean": self.mean}
                )
                if self.on_straggler:
                    self.on_straggler(step, duration_s, self.mean)
        if self.mean is None:
            self.mean = duration_s
        else:
            self.mean = self.ewma_coef * self.mean + \
                (1 - self.ewma_coef) * duration_s
        return flagged


def run_with_restarts(
    step_fn: Callable[[Any, int], Any],
    init_state: Any,
    num_steps: int,
    ckpt_manager,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    monitor: Optional[StragglerMonitor] = None,
    state_shardings: Any = None,
) -> Any:
    """Crash-tolerant loop: checkpoint every k steps; on exception, restore
    the latest checkpoint and resume (up to ``max_restarts`` times).

    ``step_fn(state, step) -> state`` may raise (simulated node failure in
    tests; real XLA/runtime errors in production).
    """
    state = init_state
    start = 0
    latest = ckpt_manager.latest_step()
    if latest is not None:
        state = ckpt_manager.restore(latest, shardings=state_shardings)
        start = latest
    restarts = 0
    step = start
    while step < num_steps:
        try:
            t0 = time.time()
            state = step_fn(state, step)
            if monitor is not None:
                monitor.record(step, time.time() - t0)
            step += 1
            if step % checkpoint_every == 0 or step == num_steps:
                ckpt_manager.save(step, state)
        except Exception:  # noqa: BLE001 - restart semantics
            restarts += 1
            if restarts > max_restarts:
                raise
            latest = ckpt_manager.latest_step()
            if latest is None:
                state = init_state
                step = 0
            else:
                state = ckpt_manager.restore(latest,
                                             shardings=state_shardings)
                step = latest
    return state


def elastic_remesh(
    ckpt_manager,
    make_mesh_fn: Callable[[], Any],
    make_shardings_fn: Callable[[Any], Any],
    step: Optional[int] = None,
):
    """Restore the latest checkpoint onto a NEW mesh (different device
    count/topology). Returns (mesh, resharded_state).

    The checkpoint format is topology-free (host numpy), so any mesh whose
    axis sizes divide the weight dims can pick the run up — e.g. dropping
    from 2 pods to 1 after a pod failure, or onto 8 CPU devices in tests.
    """
    mesh = make_mesh_fn()
    shardings = make_shardings_fn(mesh)
    state = ckpt_manager.restore(step, shardings=shardings)
    return mesh, state
