"""The training loop: sharded step, metrics, checkpointing, fault hooks.

``Trainer`` wires together: the data pipeline (step-indexed, resumable),
jitted train step with pjit shardings (when a mesh is given), the
CheckpointManager (atomic, keep-k), and the StragglerMonitor. CPU-runnable
end-to-end (examples/train_lm.py); the same class drives the production mesh
— only the mesh/shardings arguments change.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    batch_partition_specs,
    logical_rules_context,
    params_partition_specs,
)
from repro.models.config import ModelConfig
from repro.obs import resolve as _obs_resolve
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import StragglerMonitor
from repro.train.steps import TrainHyper, init_train_state, make_train_step


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        hyper: TrainHyper,
        dataset,
        ckpt_dir: Optional[str] = None,
        mesh=None,
        seed: int = 0,
        log_every: int = 10,
        checkpoint_every: int = 100,
        obs: Any = None,
    ):
        self.cfg = cfg
        self.hyper = hyper
        self.dataset = dataset
        self.mesh = mesh
        self.log_every = log_every
        self.checkpoint_every = checkpoint_every
        self.obs = _obs_resolve(obs)
        self.monitor = StragglerMonitor()
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.metrics_log: List[Dict[str, float]] = []

        step_fn = make_train_step(cfg, hyper)
        if mesh is not None:
            with logical_rules_context(mesh) as rules:
                state_sds = jax.eval_shape(
                    lambda: init_train_state(cfg, jax.random.PRNGKey(seed),
                                             hyper))
                pspec = params_partition_specs(state_sds["params"], mesh,
                                               rules)
                state_spec = {
                    "params": pspec,
                    "opt": {"mu": pspec, "nu": pspec, "step": P()},
                    "step": P(),
                }
                if "residuals" in state_sds:
                    state_spec["residuals"] = pspec
                batch_sds = dataset.batch_at(0)
                batch_spec = batch_partition_specs(batch_sds, mesh, rules)
                to_shard = lambda spec: jax.tree_util.tree_map(
                    lambda s: NamedSharding(mesh, s), spec,
                    is_leaf=lambda s: isinstance(s, P))
                self._state_sharding = to_shard(state_spec)
                self._batch_sharding = to_shard(batch_spec)
                self._step = jax.jit(
                    step_fn,
                    in_shardings=(self._state_sharding, self._batch_sharding),
                    out_shardings=(self._state_sharding, None),
                    donate_argnums=(0,),
                )
                self._rules_ctx = lambda: logical_rules_context(mesh)
        else:
            self._state_sharding = None
            self._step = jax.jit(step_fn, donate_argnums=(0,))
            self._rules_ctx = None
        self._seed = seed

    # -- lifecycle -------------------------------------------------------------
    def init_or_restore(self) -> Dict[str, Any]:
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            state = self.ckpt.restore(shardings=self._state_sharding)
            return state
        state = init_train_state(self.cfg, jax.random.PRNGKey(self._seed),
                                 self.hyper)
        if self._state_sharding is not None:
            state = jax.device_put(state, self._state_sharding)
        return state

    def train(self, num_steps: int, state: Optional[Dict] = None):
        state = state if state is not None else self.init_or_restore()
        start = int(state["step"])
        for step in range(start, num_steps):
            batch = self.dataset.batch_at(step)
            t0 = self.obs.now()
            with self.obs.span("train/step", step=step):
                if self._rules_ctx is not None:
                    with self._rules_ctx():
                        state, metrics = self._step(state, batch)
                else:
                    state, metrics = self._step(state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = self.obs.now() - t0
            self.monitor.record(step, dt)
            self.obs.histogram("train/step_s", dt)
            if self.obs.enabled:
                # the float() host-read is free here (loss is already
                # ready) but stays off the disabled path entirely
                self.obs.gauge("train/loss", float(metrics["loss"]))
                self.obs.counter("train/steps")
            self.obs.tick_drift()
            if step % self.log_every == 0 or step == num_steps - 1:
                row = {k: float(v) for k, v in metrics.items()}
                row.update(step=step, sec_per_step=dt)
                self.metrics_log.append(row)
                print(f"[train] step={step:5d} loss={row['loss']:.4f} "
                      f"ce={row['ce']:.4f} gnorm={row['grad_norm']:.3f} "
                      f"{dt*1000:.0f}ms", flush=True)
            if (self.ckpt is not None and step > start
                    and step % self.checkpoint_every == 0):
                self.ckpt.save(step, state)
        if self.ckpt is not None:
            self.ckpt.save(num_steps, state)
        return state
