"""jit-able step functions: train (with grad accumulation and optional
cross-pod int8 gradient compression), eval, prefill, decode.

``make_train_step`` returns a pure ``step(state, batch) -> (state, metrics)``
suitable for jax.jit/pjit with sharded state/batch. Gradient accumulation
reshapes the batch to [accum, B/accum, ...] and lax.scans the microbatches —
peak activation memory divides by ``accum`` while arithmetic stays identical.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step as model_decode_step
from repro.models.transformer import forward, loss_fn, prefill
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compressed_psum_with_feedback

TrainState = Dict[str, Any]  # {"params", "opt", "step"} (+"residuals" opt.)


@dataclasses.dataclass(frozen=True)
class TrainHyper:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    grad_accum: int = 1
    adamw: AdamWConfig = AdamWConfig()
    # "none" | "int8_pod": compress the cross-pod gradient all-reduce
    grad_compression: str = "none"


def init_train_state(cfg: ModelConfig, key: jax.Array,
                     hyper: TrainHyper = TrainHyper()) -> TrainState:
    from repro.models.transformer import init_model

    params = init_model(cfg, key)
    state: TrainState = {
        "params": params,
        "opt": adamw_init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if hyper.grad_compression == "int8_pod":
        state["residuals"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
        )
    return state


def _lr_at(hyper: TrainHyper, step):
    from repro.optim.schedule import warmup_cosine

    return warmup_cosine(step, hyper.peak_lr, hyper.warmup_steps,
                         hyper.total_steps)


def make_train_step(
    cfg: ModelConfig, hyper: TrainHyper = TrainHyper(),
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, Dict]]:
    """Build the train step. jit/pjit it at the call site."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch), has_aux=True
        )(params)
        return grads, metrics

    def accumulate(params, batch):
        if hyper.grad_accum <= 1:
            return grads_of(params, batch)
        accum = hyper.grad_accum

        def micro(batch_tree, i):
            return jax.tree_util.tree_map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:])[i],
                batch_tree,
            )

        def body(carry, i):
            g_acc, m_acc = carry
            g, m = grads_of(params, micro(batch, i))
            g_acc = jax.tree_util.tree_map(lambda a, b: a + b, g_acc, g)
            m_acc = jax.tree_util.tree_map(lambda a, b: a + b, m_acc, m)
            return (g_acc, m_acc), None

        g0, m0 = grads_of(params, micro(batch, 0))
        (g, m), _ = jax.lax.scan(body, (g0, m0), jnp.arange(1, accum))
        scale = 1.0 / accum
        g = jax.tree_util.tree_map(lambda x: x * scale, g)
        m = jax.tree_util.tree_map(lambda x: x * scale, m)
        return g, m

    def step_fn(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        grads, metrics = accumulate(state["params"], batch)
        new_state = dict(state)
        if hyper.grad_compression == "int8_pod":
            # the caller wraps this step in shard_map over the "pod" axis;
            # here we only see the compressed reduction.
            grads, new_state["residuals"] = compressed_psum_with_feedback(
                grads, state["residuals"], axis_name="pod"
            )
        lr = _lr_at(hyper, state["step"])
        params, opt, opt_metrics = adamw_update(
            state["params"], grads, state["opt"], lr, hyper.adamw
        )
        new_state.update(
            params=params, opt=opt, step=state["step"] + 1
        )
        metrics = {**metrics, **opt_metrics}
        return new_state, metrics

    return step_fn


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, cfg, batch)
        return metrics

    return eval_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        if not cfg.causal:
            # encoder: "prefill" is a full (bidirectional) encode
            logits, _ = forward(params, cfg, batch)
            return logits, None
        return prefill(params, cfg, batch, max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def step(params, cache, batch):
        return model_decode_step(params, cfg, cache, batch["tokens"],
                                 batch["positions"])

    return step
