"""Algorithm 1 — Random Maclaurin (RM) feature maps for dot product kernels.

Paper construction (Kar & Karnick, AISTATS 2012): for each output feature i,
sample a degree ``N ~ P[N=n] = p^-(n+1)`` and ``N`` Rademacher vectors
``w_1..w_N in {-1,+1}^d``, and emit

    Z_i(x) = sqrt(a_N * p^(N+1)) * prod_j <w_j, x>.

``Z = (Z_1..Z_D)/sqrt(D)`` is an unbiased, uniformly-convergent estimator of
``K(x,y) = f(<x,y>)`` (paper Lemmas 6-8, Theorem 12).

TPU adaptation (see DESIGN.md §3): degrees are sampled ONCE at construction
("static degree draws") and features are *bucketed by degree* so the whole map
is a single ``[B,d] x [d, M]`` matmul followed by a segmented product over
degree-length runs of columns — MXU-friendly, no per-feature control flow.

Generalized external measure: the paper uses ``q_n = p^-(n+1)`` with the
estimator scale ``sqrt(a_n / q_n) = sqrt(a_n p^(n+1))``. Any normalized
measure q with support covering {n : a_n > 0} keeps the estimator unbiased
(importance sampling). We provide:

  * ``geometric``      — the paper's measure (faithful baseline),
  * ``geometric_ge2``  — conditioned on N>=2, used by the H0/1 heuristic,
  * ``proportional``   — beyond-paper: q_n ∝ a_n R^(2n). This is the
    variance/bound-optimal choice: the per-feature estimator bound drops from
    the paper's ``C = p f(pR^2)`` to ``f(R^2)`` (see bounds.py), reducing the
    required D by the square of the ratio.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maclaurin import DotProductKernel

__all__ = ["RMFeatureMap", "make_feature_map", "degree_measure"]


# ---------------------------------------------------------------------------
# External measures over degrees
# ---------------------------------------------------------------------------
def degree_measure(
    kernel: DotProductKernel,
    n_max: int,
    p: float = 2.0,
    kind: str = "geometric",
    min_degree: int = 0,
    radius: float = 1.0,
) -> np.ndarray:
    """Normalized measure q over degrees [0, n_max], zero where a_n == 0.

    Degrees with ``a_n == 0`` never need to be sampled (their feature would be
    identically zero) so we drop them from the support and renormalize — this
    is itself a small variance improvement over literal Algorithm 1 and keeps
    the estimator exactly unbiased.
    """
    coefs = kernel.coefs(n_max)
    if kind == "geometric":
        q = np.asarray([p ** -(n + 1) for n in range(n_max + 1)])
    elif kind == "geometric_ge2":
        q = np.asarray(
            [p ** -(n + 1) if n >= 2 else 0.0 for n in range(n_max + 1)]
        )
    elif kind == "proportional":
        q = coefs * (radius**2) ** np.arange(n_max + 1)
    else:
        raise ValueError(f"unknown degree measure {kind!r}")
    q = np.where(coefs > 0.0, q, 0.0)
    q = np.where(np.arange(n_max + 1) >= min_degree, q, 0.0)
    total = q.sum()
    if total <= 0:
        raise ValueError(
            f"measure {kind!r} has empty support for kernel {kernel.name} "
            f"with n_max={n_max}, min_degree={min_degree}"
        )
    return q / total


# ---------------------------------------------------------------------------
# The feature map
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RMFeatureMap:
    """A materialized Random Maclaurin feature map (degree-bucketed).

    Attributes
    ----------
    degrees:  sorted unique degrees with at least one feature, EXCLUDING 0.
    counts:   #features per entry of ``degrees``.
    omegas:   one array per entry of ``degrees``: ``[c_n * n, d]`` Rademacher
              rows (consecutive runs of n rows belong to one feature).
    scales:   per-degree feature scale ``sqrt(a_n / q_n) / sqrt(D)``.
    const:    value contributed by all degree-0 features combined (a scalar;
              ``sqrt(a_0/q_0)/sqrt(D)`` repeated c_0 times -> represented as a
              single column of value sqrt(c_0) * scale_0 for compactness).
    h01:      if True the map is the H0/1 variant: output is
              ``[sqrt(a_0), sqrt(a_1) * x, Z_{>=2}(x)]`` (paper §6.1).
    """

    degrees: Tuple[int, ...]
    counts: Tuple[int, ...]
    omegas: List[jax.Array]
    scales: List[jax.Array]
    const: Optional[jax.Array]
    h01: bool
    h01_coefs: Optional[jax.Array]  # [2] = (a_0, a_1) when h01
    input_dim: int
    num_random: int  # D
    coefs_host: Tuple[float, ...] = ()  # a_0..a_{n_max} (host copies, for diag)

    # -- pytree plumbing (lets the map ride inside jit/pjit closures) -------
    def tree_flatten(self):
        children = (self.omegas, self.scales, self.const, self.h01_coefs)
        aux = (
            self.degrees,
            self.counts,
            self.h01,
            self.input_dim,
            self.num_random,
            self.coefs_host,
        )
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        omegas, scales, const, h01_coefs = children
        degrees, counts, h01, input_dim, num_random, coefs_host = aux
        return cls(
            degrees=degrees,
            counts=counts,
            omegas=omegas,
            scales=scales,
            const=const,
            h01=h01,
            h01_coefs=h01_coefs,
            input_dim=input_dim,
            num_random=num_random,
            coefs_host=coefs_host,
        )

    # -- metadata ------------------------------------------------------------
    @property
    def output_dim(self) -> int:
        dim = sum(self.counts)
        if self.const is not None:
            dim += 1
        if self.h01:
            dim += 1 + self.input_dim
        return dim

    def truncation_bias(self, radius: float) -> float:
        """sup_{|<x,y>| <= radius^2} of the dropped-degree mass.

        Degrees of the series with a_n > 0 but no allocated features
        contribute ``sum a_n radius^{2n}`` worst-case bias (zero for the
        paper-faithful iid mode only in expectation — there every degree has
        sampling support; for stratified mode this is the §4.2-style
        truncation error).
        """
        present = set(self.degrees)
        if self.const is not None:
            present.add(0)
        if self.h01:
            present.update((0, 1))
        bias = 0.0
        for n, a_n in enumerate(self.coefs_host):
            if a_n > 0.0 and n not in present:
                bias += a_n * radius ** (2 * n)
        return bias

    # -- application ----------------------------------------------------------
    def __call__(self, x: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
        """Apply the map to ``x`` of shape ``[..., d]`` -> ``[..., output_dim]``.

        Pure-jnp path (the Pallas fused kernel lives in
        ``repro.kernels.rm_feature`` and is numerically checked against this).
        """
        if x.shape[-1] != self.input_dim:
            raise ValueError(
                f"expected trailing dim {self.input_dim}, got {x.shape}"
            )
        batch_shape = x.shape[:-1]
        xf = x.reshape(-1, self.input_dim).astype(accum_dtype)
        feats = []
        if self.h01:
            a0, a1 = self.h01_coefs[0], self.h01_coefs[1]
            feats.append(
                jnp.full((xf.shape[0], 1), jnp.sqrt(a0), dtype=accum_dtype)
            )
            feats.append(jnp.sqrt(a1) * xf)
        if self.const is not None:
            feats.append(
                jnp.broadcast_to(self.const, (xf.shape[0], 1)).astype(accum_dtype)
            )
        for deg, cnt, omega, scale in zip(
            self.degrees, self.counts, self.omegas, self.scales
        ):
            proj = xf @ omega.astype(accum_dtype).T  # [B, cnt*deg]
            proj = proj.reshape(xf.shape[0], cnt, deg)
            feats.append(jnp.prod(proj, axis=-1) * scale.astype(accum_dtype))
        z = jnp.concatenate(feats, axis=-1)
        return z.reshape(*batch_shape, z.shape[-1])

    # Convenience: the linear-kernel estimate of K.
    def estimate_gram(self, X: jax.Array, Y: Optional[jax.Array] = None):
        zx = self(X)
        zy = zx if Y is None else self(Y)
        return zx @ zy.T


def make_feature_map(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    key: jax.Array,
    *,
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    omega_dtype=jnp.float32,
    stratified: bool = True,
) -> RMFeatureMap:
    """Build an ``RMFeatureMap`` (Algorithm 1 / §6.1 H0/1 / beyond-paper measures).

    Two allocation modes:

    * ``stratified=False`` — **paper-faithful Algorithm 1**: iid degree draws
      from q, per-feature scale ``sqrt(a_n / q_n) / sqrt(D)``. Exactly
      unbiased for the full kernel.
    * ``stratified=True`` (default) — counts ``c_n = round(D * q_n)`` with
      exact per-degree weights ``sqrt(a_n / c_n)``. Conditioned on the counts
      this estimates the kernel *restricted to the allocated degrees* with no
      degree-sampling variance at all (it coincides with the paper's §4.2
      truncated construction when q is the ``proportional`` measure). The
      dropped-degree mass is reported by ``RMFeatureMap.truncation_bias``.
    """
    kernel.validate_positive_definite(n_max)
    if h01 and measure == "geometric":
        measure = "geometric_ge2"
    q = degree_measure(kernel, n_max, p=p, kind=measure, radius=radius,
                       min_degree=2 if h01 else 0)
    coefs = kernel.coefs(n_max)

    # --- draw / allocate per-degree counts ---------------------------------
    key_deg, key_omega = jax.random.split(key)
    if stratified:
        raw = q * num_features
        counts_all = np.floor(raw).astype(np.int64)
        # distribute the remainder to the largest fractional parts
        deficit = num_features - int(counts_all.sum())
        if deficit > 0:
            order = np.argsort(-(raw - counts_all))
            counts_all[order[:deficit]] += 1
    else:
        seed = int(jax.random.randint(key_deg, (), 0, 2**31 - 1))
        rng = np.random.Generator(np.random.Philox(seed))
        draws = rng.choice(len(q), size=num_features, p=q)
        counts_all = np.bincount(draws, minlength=len(q)).astype(np.int64)

    def bucket_scale(n: int, cnt: int) -> float:
        if stratified:
            return float(np.sqrt(coefs[n] / cnt))
        return float(np.sqrt(coefs[n] / q[n]) / np.sqrt(num_features))

    degrees: List[int] = []
    counts: List[int] = []
    omegas: List[jax.Array] = []
    scales: List[jax.Array] = []
    const = None

    # degree-0 bucket: c_0 identical constant features collapse into a single
    # column of value sqrt(c_0) * scale_0.
    if counts_all[0] > 0:
        c0 = int(counts_all[0])
        const = jnp.asarray(
            np.sqrt(c0) * bucket_scale(0, c0), dtype=jnp.float32
        )

    subkeys = jax.random.split(key_omega, int((counts_all[1:] > 0).sum()) + 1)
    ki = 0
    for n in range(1, n_max + 1):
        cnt = int(counts_all[n])
        if cnt == 0:
            continue
        rows = cnt * n
        bern = jax.random.bernoulli(subkeys[ki], 0.5, (rows, input_dim))
        ki += 1
        omega = (2.0 * bern.astype(omega_dtype) - 1.0).astype(omega_dtype)
        degrees.append(n)
        counts.append(cnt)
        omegas.append(omega)
        scales.append(jnp.asarray(bucket_scale(n, cnt), dtype=jnp.float32))

    h01_coefs = None
    if h01:
        a0 = float(kernel.coef(0))
        a1 = float(kernel.coef(1))
        if a0 == 0.0 and a1 == 0.0:
            raise ValueError(
                f"H0/1 is a no-op for kernel {kernel.name}: a_0 = a_1 = 0 "
                "(e.g. homogeneous polynomial kernels — paper §6.2)."
            )
        h01_coefs = jnp.asarray([a0, a1], dtype=jnp.float32)

    return RMFeatureMap(
        degrees=tuple(degrees),
        counts=tuple(counts),
        omegas=omegas,
        scales=scales,
        const=const,
        h01=h01,
        h01_coefs=h01_coefs,
        input_dim=input_dim,
        num_random=num_features,
        coefs_host=tuple(float(c) for c in coefs),
    )
