"""Algorithm 1 — Random Maclaurin (RM) feature maps for dot product kernels.

Paper construction (Kar & Karnick, AISTATS 2012): for each output feature i,
sample a degree ``N ~ P[N=n] = p^-(n+1)`` and ``N`` Rademacher vectors
``w_1..w_N in {-1,+1}^d``, and emit

    Z_i(x) = sqrt(a_N * p^(N+1)) * prod_j <w_j, x>.

``Z = (Z_1..Z_D)/sqrt(D)`` is an unbiased, uniformly-convergent estimator of
``K(x,y) = f(<x,y>)`` (paper Lemmas 6-8, Theorem 12).

TPU adaptation (see DESIGN.md §3): degrees are sampled ONCE at construction
("static degree draws") and the whole map is lowered to the ``FeaturePlan``
packed layout (repro.core.plan) — a single ``[max_degree, F, d]`` omega
tensor with per-column (degree, scale) metadata, applied as one fused masked
product (one Pallas launch on TPU; ``__call__`` is the jnp parity path).

Generalized external measure: the paper uses ``q_n = p^-(n+1)`` with the
estimator scale ``sqrt(a_n / q_n) = sqrt(a_n p^(n+1))``. Any normalized
measure q with support covering {n : a_n > 0} keeps the estimator unbiased
(importance sampling). We provide:

  * ``geometric``      — the paper's measure (faithful baseline),
  * ``geometric_ge2``  — conditioned on N>=2, used by the H0/1 heuristic,
  * ``proportional``   — beyond-paper: q_n ∝ a_n R^(2n). This is the
    variance/bound-optimal choice: the per-feature estimator bound drops from
    the paper's ``C = p f(pR^2)`` to ``f(R^2)`` (see bounds.py), reducing the
    required D by the square of the ratio.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maclaurin import DotProductKernel
from repro.core.plan import FeaturePlan, apply_plan, init_omegas, make_feature_plan

__all__ = ["RMFeatureMap", "make_feature_map", "degree_measure"]


# ---------------------------------------------------------------------------
# External measures over degrees
# ---------------------------------------------------------------------------
def degree_measure(
    kernel: DotProductKernel,
    n_max: int,
    p: float = 2.0,
    kind: str = "geometric",
    min_degree: int = 0,
    radius: float = 1.0,
) -> np.ndarray:
    """Normalized measure q over degrees [0, n_max], zero where a_n == 0.

    Args:
        kernel: the dot-product kernel supplying Maclaurin coefficients.
        n_max: last degree in the support.
        p: geometric decay base for the ``geometric*`` kinds.
        kind: ``"geometric"`` (paper), ``"geometric_ge2"`` (H0/1),
            ``"proportional"`` (variance-optimal ``q_n ∝ a_n R^{2n}``).
        min_degree: zero out degrees below this before renormalizing.
        radius: data radius R for the proportional measure.
    Returns:
        float64 ``[n_max + 1]`` array summing to 1.

    Degrees with ``a_n == 0`` never need to be sampled (their feature would be
    identically zero) so we drop them from the support and renormalize — this
    is itself a small variance improvement over literal Algorithm 1 and keeps
    the estimator exactly unbiased.
    """
    coefs = kernel.coefs(n_max)
    if kind == "geometric":
        q = np.asarray([p ** -(n + 1) for n in range(n_max + 1)])
    elif kind == "geometric_ge2":
        q = np.asarray(
            [p ** -(n + 1) if n >= 2 else 0.0 for n in range(n_max + 1)]
        )
    elif kind == "proportional":
        q = coefs * (radius**2) ** np.arange(n_max + 1)
    else:
        raise ValueError(f"unknown degree measure {kind!r}")
    q = np.where(coefs > 0.0, q, 0.0)
    q = np.where(np.arange(n_max + 1) >= min_degree, q, 0.0)
    total = q.sum()
    if total <= 0:
        raise ValueError(
            f"measure {kind!r} has empty support for kernel {kernel.name} "
            f"with n_max={n_max}, min_degree={min_degree}"
        )
    return q / total


# ---------------------------------------------------------------------------
# The feature map
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class RMFeatureMap:
    """A materialized Random Maclaurin feature map.

    Thin carrier of (``plan``, ``omegas``): the hashable ``FeaturePlan``
    (degrees, counts, scales, const, H0/1 block — see repro.core.plan) plus
    the flat ``[plan.total_rows, d]`` Rademacher draws that instantiate it.
    Legacy per-bucket views (``degrees``/``counts``/``scales``/``const``)
    are exposed as properties for diagnostics and older call sites.
    """

    plan: FeaturePlan
    omegas: jax.Array

    # -- pytree plumbing (lets the map ride inside jit/pjit closures) -------
    def tree_flatten(self):
        return (self.omegas,), (self.plan,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (omegas,) = children
        (plan,) = aux
        return cls(plan=plan, omegas=omegas)

    # -- metadata ------------------------------------------------------------
    @property
    def degrees(self) -> Tuple[int, ...]:
        return self.plan.degrees

    @property
    def counts(self) -> Tuple[int, ...]:
        return self.plan.counts

    @property
    def scales(self) -> Tuple[float, ...]:
        return self.plan.scales

    @property
    def const(self) -> Optional[float]:
        return self.plan.const if self.plan.const != 0.0 else None

    @property
    def h01(self) -> bool:
        return self.plan.h01

    @property
    def h01_coefs(self) -> Optional[Tuple[float, float]]:
        if not self.plan.h01:
            return None
        return (self.plan.h01_a0, self.plan.h01_a1)

    @property
    def input_dim(self) -> int:
        return self.plan.input_dim

    @property
    def num_random(self) -> int:
        return self.plan.num_random

    @property
    def coefs_host(self) -> Tuple[float, ...]:
        return self.plan.coefs_host

    @property
    def output_dim(self) -> int:
        return self.plan.output_dim

    def bucket_omegas(self) -> List[jax.Array]:
        """Per-degree views into the flat draws: one [c_n * n, d] block each."""
        out, off = [], 0
        for n, c in zip(self.plan.degrees, self.plan.counts):
            out.append(self.omegas[off : off + c * n])
            off += c * n
        return out

    def truncation_bias(self, radius: float) -> float:
        """sup_{|<x,y>| <= radius^2} of the dropped-degree mass.

        Degrees of the series with a_n > 0 but no allocated features
        contribute ``sum a_n radius^{2n}`` worst-case bias (zero for the
        paper-faithful iid mode only in expectation — there every degree has
        sampling support; for stratified mode this is the §4.2-style
        truncation error).
        """
        return self.plan.truncation_bias(radius)

    # -- application ----------------------------------------------------------
    def __call__(self, x: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
        """Apply the map to ``x`` of shape ``[..., d]`` -> ``[..., output_dim]``.

        Pure-jnp fused path (the Pallas launch lives in
        ``repro.kernels.rm_feature`` and is numerically checked against this).
        """
        return apply_plan(
            self.plan, self.omegas, x, accum_dtype=accum_dtype,
            use_pallas=False,
        )

    def apply(
        self,
        x: jax.Array,
        *,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        accum_dtype=jnp.float32,
        precision=None,
    ) -> jax.Array:
        """Backend-routed fused path (ONE Pallas launch on TPU).

        ``precision`` ("fp32" | "bf16") is the feature-kernel input dtype
        policy — bf16 inputs/packed weights, fp32 accumulation either way.
        """
        return apply_plan(
            self.plan, self.omegas, x, accum_dtype=accum_dtype,
            use_pallas=use_pallas, interpret=interpret, precision=precision,
        )

    # Convenience: the linear-kernel estimate of K.
    def estimate_gram(
        self,
        X: jax.Array,
        Y: Optional[jax.Array] = None,
        *,
        row_chunk: int = 4096,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        axis_name: Optional[str] = None,
        precision=None,
    ) -> jax.Array:
        """Kernel-matrix estimate through the fused ``apply_plan`` path.

        Featurization is chunked over rows so the fused launch's padded
        tiles (and the flat [rows, total_rows] projection on the jnp path)
        stay bounded — Gram estimation on 50k-point datasets runs in
        ``row_chunk``-row slices instead of one giant intermediate.

        ``axis_name`` is the sharded-execution hook (DESIGN.md §10): when
        this map is one feature shard inside a ``shard_map``, the partial
        Gram is reduced over that mesh axis with a single ``psum``.
        ``precision`` applies the feature-kernel dtype policy to the
        featurization; the Gram matmul itself stays fp32.
        """
        from repro.core.registry import estimate_gram

        return estimate_gram(
            lambda Z: self.apply(Z, use_pallas=use_pallas,
                                 interpret=interpret, precision=precision),
            X, Y, row_chunk=row_chunk, axis_name=axis_name,
        )


def make_feature_map(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: Optional[int] = None,
    key: Optional[jax.Array] = None,
    *,
    eps: Optional[float] = None,
    delta: Optional[float] = None,
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    omega_dtype=None,
    stratified: bool = True,
    estimator: str = "rm",
    mesh=None,
    num_shards: Optional[int] = None,
    precision=None,
):
    """Build a feature map (Algorithm 1 / §6.1 H0/1 / beyond-paper measures).

    ``estimator`` selects the random-feature family from the estimator
    registry (``repro.core.registry``): ``"rm"`` (default) returns an
    ``RMFeatureMap``; any other name (``"tensor_sketch"``, ``"ctr"``, or a
    third-party registration) delegates to that entry's ``make_map`` with
    the same kwargs — all families share the degree-measure machinery, so
    downstream code is estimator-agnostic (docs/estimators.md is the
    choosing guide).

    ``mesh`` / ``num_shards`` switch to the SHARDED construction
    (``repro.distributed.estimator``): the budget splits over the
    ``"rm_features"`` mesh axis into per-shard sub-maps whose params are
    drawn with ``fold_in(key, shard)``; the returned ``ShardedFeatureMap``
    duck-types this function's output for any registry estimator.

    Two allocation modes (see ``core.plan.allocate_features``):

    * ``stratified=False`` — **paper-faithful Algorithm 1**: iid degree draws
      from q, per-feature scale ``sqrt(a_n / q_n) / sqrt(D)``. Exactly
      unbiased for the full kernel.
    * ``stratified=True`` (default) — counts ``c_n = round(D * q_n)`` with
      exact per-degree weights ``sqrt(a_n / c_n)``. Conditioned on the counts
      this estimates the kernel *restricted to the allocated degrees* with no
      degree-sampling variance at all (it coincides with the paper's §4.2
      truncated construction when q is the ``proportional`` measure). The
      dropped-degree mass is reported by ``RMFeatureMap.truncation_bias``.

    ``precision`` ("fp32" | "bf16") sets the STORAGE dtype of the drawn
    parameters to the policy's compute dtype (lossless for every family —
    the draws take values in {0, +-1}); pass the same policy to
    ``map.apply(precision=...)`` to run the kernels on bf16 operands.
    Explicit ``omega_dtype`` wins when both are given (``None`` — the
    default — means "derive from precision, else fp32").

    Accuracy-target mode (ROADMAP open item 3, docs/adaptive.md): instead
    of ``num_features``, pass ``eps=``/``delta=`` and the budget is
    ``required_num_features(kernel, radius, input_dim, eps, delta)`` —
    Theorem 12's smallest D certifying sup error <= eps w.p. >= 1 - delta
    (the ``proportional`` measure uses its tighter beyond-paper constant).
    Exactly one of ``num_features`` or the (eps, delta) pair is required.
    """
    if key is None:
        raise TypeError("make_feature_map requires key=")
    if (eps is None) != (delta is None):
        raise ValueError("pass BOTH eps and delta (or neither); got "
                         f"eps={eps!r}, delta={delta!r}")
    if eps is not None:
        if num_features is not None:
            raise ValueError(
                "pass either num_features or (eps, delta), not both")
        from repro.core.bounds import required_num_features

        bound_measure = ("proportional" if measure == "proportional"
                         else "geometric")
        num_features = required_num_features(
            kernel, radius, input_dim, eps, delta, p=p,
            measure=bound_measure)
    elif num_features is None:
        raise ValueError("pass num_features or accuracy targets "
                         "(eps=..., delta=...)")
    if omega_dtype is None:
        if precision is not None:
            from repro.common.dtypes import resolve_precision

            omega_dtype = resolve_precision(precision).compute_dtype
        else:
            omega_dtype = jnp.float32
    if mesh is not None or num_shards is not None:
        from repro.distributed.estimator import make_sharded_feature_map

        return make_sharded_feature_map(
            kernel, input_dim, num_features, key,
            mesh=mesh, num_shards=num_shards, estimator=estimator,
            omega_dtype=omega_dtype,
            p=p, measure=measure, h01=h01, n_max=n_max, radius=radius,
            stratified=stratified,
        )
    if estimator != "rm":
        from repro.core import registry

        return registry.get(estimator).make_map(
            kernel, input_dim, num_features, key,
            p=p, measure=measure, h01=h01, n_max=n_max, radius=radius,
            omega_dtype=omega_dtype, stratified=stratified,
        )
    key_deg, key_omega = jax.random.split(key)
    seed = 0
    if not stratified:
        seed = int(jax.random.randint(key_deg, (), 0, 2**31 - 1))
    plan = make_feature_plan(
        kernel,
        input_dim,
        num_features,
        p=p,
        measure=measure,
        h01=h01,
        n_max=n_max,
        radius=radius,
        stratified=stratified,
        seed=seed,
    )
    return RMFeatureMap(plan=plan, omegas=init_omegas(plan, key_omega, omega_dtype))
