"""Static (hashable) RM feature-map plans for use inside jitted models.

The transformer stack scans over layers, so every layer must share the SAME
plan *structure* (degrees/counts/scales) while carrying its OWN Rademacher
draws as (non-trainable) parameters. This module splits the RMFeatureMap into

  * ``PlanMeta``   — a hashable tuple of (degree, count, scale) triples plus
                     the constant column, computed host-side from the kernel,
  * ``init_omegas``— per-layer parameter initialization ([sum_n c_n * n, d]),
  * ``apply_plan`` — the jit-friendly application given (meta, omegas, x).

``apply_plan`` matches ``RMFeatureMap.__call__`` numerically (same bucketing)
and has a Pallas-backed variant in repro.kernels.rm_feature.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.feature_map import degree_measure
from repro.core.maclaurin import DotProductKernel

__all__ = ["PlanMeta", "make_plan_meta", "init_omegas", "apply_plan",
           "plan_output_dim"]


class PlanMeta(NamedTuple):
    """Hashable plan: static through jit/scan. Scales baked as floats."""

    degrees: Tuple[int, ...]     # ascending, degree >= 1 buckets
    counts: Tuple[int, ...]
    scales: Tuple[float, ...]
    const: float                 # 0.0 when absent; else the degree-0 column
    input_dim: int

    @property
    def total_rows(self) -> int:
        return int(sum(c * n for c, n in zip(self.counts, self.degrees)))

    @property
    def output_dim(self) -> int:
        return int(sum(self.counts)) + (1 if self.const != 0.0 else 0)


def make_plan_meta(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    *,
    p: float = 2.0,
    measure: str = "proportional",
    stratified: bool = True,
    n_max: int = 16,
    radius: float = 1.0,
    seed: int = 0,
) -> PlanMeta:
    """Host-side plan construction (mirrors core.feature_map.make_feature_map)."""
    kernel.validate_positive_definite(n_max)
    q = degree_measure(kernel, n_max, p=p, kind=measure, radius=radius)
    coefs = kernel.coefs(n_max)

    if stratified:
        raw = q * num_features
        counts_all = np.floor(raw).astype(np.int64)
        deficit = num_features - int(counts_all.sum())
        if deficit > 0:
            order = np.argsort(-(raw - counts_all))
            counts_all[order[:deficit]] += 1
    else:
        rng = np.random.Generator(np.random.Philox(seed))
        draws = rng.choice(len(q), size=num_features, p=q)
        counts_all = np.bincount(draws, minlength=len(q)).astype(np.int64)

    def bucket_scale(n: int, cnt: int) -> float:
        if stratified:
            return float(np.sqrt(coefs[n] / cnt))
        return float(np.sqrt(coefs[n] / q[n]) / np.sqrt(num_features))

    degrees, counts, scales = [], [], []
    const = 0.0
    if counts_all[0] > 0:
        c0 = int(counts_all[0])
        const = float(np.sqrt(c0) * bucket_scale(0, c0))
    for n in range(1, n_max + 1):
        cnt = int(counts_all[n])
        if cnt:
            degrees.append(n)
            counts.append(cnt)
            scales.append(bucket_scale(n, cnt))
    return PlanMeta(
        degrees=tuple(degrees),
        counts=tuple(counts),
        scales=tuple(scales),
        const=const,
        input_dim=input_dim,
    )


def init_omegas(meta: PlanMeta, key: jax.Array, dtype=jnp.float32) -> jax.Array:
    """All Rademacher rows for one plan instance, concatenated: [rows, d]."""
    bern = jax.random.bernoulli(key, 0.5, (meta.total_rows, meta.input_dim))
    return (2.0 * bern.astype(dtype) - 1.0).astype(dtype)


def apply_plan(
    meta: PlanMeta,
    omegas: jax.Array,
    x: jax.Array,
    accum_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """Featurize ``x [..., d] -> [..., meta.output_dim]``.

    XLA path: one fused projection ``x @ omegas.T`` then per-bucket
    segmented products. On TPU (``use_pallas`` defaults to the backend) each
    bucket routes to the fused Pallas kernel instead
    (repro.kernels.rm_feature) — same layout, VMEM-tiled.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    batch_shape = x.shape[:-1]
    xf = x.reshape(-1, meta.input_dim).astype(accum_dtype)
    feats = []
    if meta.const != 0.0:
        feats.append(jnp.full((xf.shape[0], 1), meta.const, dtype=accum_dtype))
    if use_pallas:
        from repro.kernels.rm_feature.ops import rm_feature_bucket

        off = 0
        for deg, cnt, scale in zip(meta.degrees, meta.counts, meta.scales):
            rows = cnt * deg
            feats.append(
                rm_feature_bucket(xf, omegas[off : off + rows], deg,
                                  float(scale))
            )
            off += rows
    else:
        proj = xf @ omegas.astype(accum_dtype).T  # [B, total_rows]
        off = 0
        for deg, cnt, scale in zip(meta.degrees, meta.counts, meta.scales):
            rows = cnt * deg
            block = proj[:, off : off + rows].reshape(-1, cnt, deg)
            feats.append(jnp.prod(block, axis=-1) * scale)
            off += rows
    z = jnp.concatenate(feats, axis=-1)
    return z.reshape(*batch_shape, z.shape[-1])


def plan_output_dim(meta: PlanMeta) -> int:
    return meta.output_dim
