"""Static (hashable) RM feature-map plans for use inside jitted models.

Compatibility shim: the plan subsystem moved to ``repro.core.plan``
(``FeaturePlan`` is the single source of truth for allocation, scales, and
the fused packed layout). The transformer stack scans over layers, so every
layer shares the SAME plan *structure* while carrying its OWN Rademacher
draws as (non-trainable) parameters:

  * ``PlanMeta``   — alias of ``FeaturePlan`` (hashable, static through jit),
  * ``init_omegas``— per-layer parameter initialization ([total_rows, d]),
  * ``apply_plan`` — the jit-friendly fused application (ONE Pallas launch on
                     TPU, its jnp mirror elsewhere).
"""
from __future__ import annotations

from repro.core.maclaurin import DotProductKernel
from repro.core.plan import (
    FeaturePlan,
    apply_plan,
    init_omegas,
    make_feature_plan,
    plan_output_dim,
)

__all__ = ["PlanMeta", "make_plan_meta", "init_omegas", "apply_plan",
           "plan_output_dim"]

PlanMeta = FeaturePlan


def make_plan_meta(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    *,
    p: float = 2.0,
    measure: str = "proportional",
    stratified: bool = True,
    n_max: int = 16,
    radius: float = 1.0,
    seed: int = 0,
) -> FeaturePlan:
    """Host-side plan construction (thin wrapper over core.plan)."""
    return make_feature_plan(
        kernel,
        input_dim,
        num_features,
        p=p,
        measure=measure,
        h01=False,
        n_max=n_max,
        radius=radius,
        stratified=stratified,
        seed=seed,
    )
