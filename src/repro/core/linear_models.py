"""Linear & kernel classifiers for the paper's Table 1 / Figure 2 experiments.

The paper trains LIBLINEAR on random features and LIBSVM on exact kernels.
Offline equivalents, all pure JAX:

  * ``train_linear`` — L2-regularized {logistic | squared-hinge} linear
    classifier by full-batch Newton-CG (hessian-vector products via jvp∘grad).
    This is the same problem class LIBLINEAR solves (primal L2R-L2LOSS/LR).
  * ``train_kernel_ridge`` — exact-kernel baseline: (K + lam N I) alpha = y
    in host fp64 (Cholesky + jitter fallback), plus a squared-hinge Newton
    active-set refinement for ±1 labels (primal L2-SVM, Chapelle 2007).
  * ``train_kernel_svm`` — dual L2-SVM via projected coordinate ascent on the
    exact Gram matrix (small N; the LIBSVM stand-in).

All training functions return a ``Classifier`` with ``decision`` /
``predict`` / ``accuracy``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Classifier",
    "train_linear",
    "train_featurized_linear",
    "train_kernel_ridge",
    "train_kernel_svm",
]


@dataclasses.dataclass
class Classifier:
    decision_fn: Callable[[jax.Array], jax.Array]

    def decision(self, X: jax.Array) -> jax.Array:
        return self.decision_fn(X)

    def predict(self, X: jax.Array) -> jax.Array:
        return jnp.sign(self.decision(X))

    def accuracy(self, X: jax.Array, y: jax.Array) -> float:
        return float(jnp.mean(self.predict(X) == jnp.sign(y)))


# ---------------------------------------------------------------------------
# Primal linear models (LIBLINEAR stand-in)
# ---------------------------------------------------------------------------
def _logistic_loss(wb, X, y, lam):
    w, b = wb
    margins = y * (X @ w + b)
    # log(1 + exp(-m)) stably
    loss = jnp.mean(jnp.logaddexp(0.0, -margins))
    return loss + 0.5 * lam * jnp.sum(w * w)


def _squared_hinge_loss(wb, X, y, lam):
    w, b = wb
    margins = y * (X @ w + b)
    loss = jnp.mean(jnp.maximum(0.0, 1.0 - margins) ** 2)
    return loss + 0.5 * lam * jnp.sum(w * w)


def _newton_cg(loss_fn, wb0, n_iters: int = 20, cg_iters: int = 25, tol: float = 1e-7):
    """Inexact Newton with CG on the (PSD) Gauss-Newton/Hessian."""

    grad_fn = jax.grad(loss_fn)

    def hvp(wb, v):
        return jax.jvp(grad_fn, (wb,), (v,))[1]

    def cg_solve(wb, g):
        # solve H dx = g approximately
        def body(state, _):
            x, r, pdir, rs = state
            hp = hvp(wb, pdir)
            denom = _tree_dot(pdir, hp)
            alpha = rs / jnp.maximum(denom, 1e-12)
            x = jax.tree_util.tree_map(lambda a, b: a + alpha * b, x, pdir)
            r = jax.tree_util.tree_map(lambda a, b: a - alpha * b, r, hp)
            rs_new = _tree_dot(r, r)
            beta = rs_new / jnp.maximum(rs, 1e-30)
            pdir = jax.tree_util.tree_map(lambda a, b: a + beta * b, r, pdir)
            return (x, r, pdir, rs_new), None

        x0 = jax.tree_util.tree_map(jnp.zeros_like, g)
        state0 = (x0, g, g, _tree_dot(g, g))
        (x, _, _, _), _ = jax.lax.scan(body, state0, None, length=cg_iters)
        return x

    def newton_step(wb, _):
        g = grad_fn(wb)
        dx = cg_solve(wb, g)
        # backtracking-free damped step (loss_fn is convex & smooth here)
        wb = jax.tree_util.tree_map(lambda a, b: a - b, wb, dx)
        return wb, _tree_dot(g, g)

    wb, gnorms = jax.lax.scan(newton_step, wb0, None, length=n_iters)
    return wb, gnorms


def _tree_dot(a, b):
    leaves = jax.tree_util.tree_map(lambda x, y: jnp.sum(x * y), a, b)
    return jax.tree_util.tree_reduce(lambda x, y: x + y, leaves)


@partial(jax.jit, static_argnames=("loss", "n_iters"))
def _fit_linear(X, y, lam, loss: str = "squared_hinge", n_iters: int = 20):
    loss_fn = {
        "logistic": _logistic_loss,
        "squared_hinge": _squared_hinge_loss,
    }[loss]
    wb0 = (jnp.zeros(X.shape[1], dtype=jnp.float32), jnp.zeros((), jnp.float32))
    wb, gnorms = _newton_cg(lambda wb: loss_fn(wb, X, y, lam), wb0, n_iters)
    return wb, gnorms


def train_linear(
    X: jax.Array,
    y: jax.Array,
    lam: float = 1e-4,
    loss: str = "squared_hinge",
    n_iters: int = 20,
) -> Classifier:
    """Train an L2-regularized linear classifier; y in {-1, +1}."""
    X = jnp.asarray(X, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    (w, b), _ = _fit_linear(X, y, jnp.float32(lam), loss, n_iters)
    return Classifier(decision_fn=lambda Z: jnp.asarray(Z, jnp.float32) @ w + b)


def train_featurized_linear(
    fmap,
    X: jax.Array,
    y: jax.Array,
    lam: float = 1e-4,
    loss: str = "squared_hinge",
    n_iters: int = 20,
    use_pallas: Optional[bool] = None,
) -> Classifier:
    """Paper pipeline in one call: featurize with a feature map, fit linear.

    ``fmap`` is any registry estimator's map object (``RMFeatureMap``,
    ``SketchFeatureMap``, or anything exposing ``apply``; legacy
    ``plan``/``omegas`` carriers still work); train-time and decision-time
    featurization both run through the fused single-launch path, so the
    returned ``Classifier.decision`` accepts RAW inputs, not features.
    """
    if hasattr(fmap, "apply"):
        def featurize(Z):
            return fmap.apply(jnp.asarray(Z, jnp.float32),
                              use_pallas=use_pallas)
    else:
        from repro.core.plan import apply_plan

        def featurize(Z):
            return apply_plan(fmap.plan, fmap.omegas,
                              jnp.asarray(Z, jnp.float32),
                              use_pallas=use_pallas)

    base = train_linear(featurize(X), y, lam=lam, loss=loss, n_iters=n_iters)
    return Classifier(decision_fn=lambda Z: base.decision(featurize(Z)))


# ---------------------------------------------------------------------------
# Exact-kernel baselines (LIBSVM stand-ins)
# ---------------------------------------------------------------------------
def _chol_solve(system: np.ndarray, rhs: np.ndarray) -> np.ndarray:
    """Stabilized host-side fp64 SPD solve: Cholesky with an escalating
    jitter retry, general least-squares as the last resort."""
    n = system.shape[0]
    jitter = 0.0
    for _ in range(4):
        try:
            chol = np.linalg.cholesky(system + jitter * np.eye(n))
            return np.linalg.solve(chol.T, np.linalg.solve(chol, rhs))
        except np.linalg.LinAlgError:
            jitter = max(jitter * 10.0,
                         1e-10 * max(np.trace(system) / n, 1.0))
    return np.linalg.lstsq(system, rhs, rcond=None)[0]


def train_kernel_ridge(
    gram: jax.Array, y: jax.Array, lam: float = 1e-3,
    kernel_fn: Optional[Callable] = None, X_train: Optional[jax.Array] = None,
    refine: str | bool = "auto", max_newton_iters: int = 50,
) -> Tuple[jax.Array, Classifier]:
    """Solve (K + lam N I) alpha = y. Returns (alpha, clf using kernel_fn).

    The solve runs host-side in float64 via Cholesky with a jitter
    fallback — at small ``lam`` the regularized Gram matrix is
    ill-conditioned and an fp32 on-device solve loses precision near the
    margin.

    As the LIBSVM stand-in baseline, binary ``±1`` labels additionally get
    a Newton active-set refinement on the primal squared-hinge objective
    (Chapelle 2007): each step re-solves the ridge system restricted to
    current margin violators ``y_i f(x_i) < 1``, so correctly-classified
    points stop dragging the fit (a plain least-squares fit of ``sign``
    labels is biased by its easy points — on low-rank polynomial Grams the
    LS optimum can misclassify near the decision boundary at ANY
    precision or ``lam``).  ``refine`` is ``"auto"`` (refine iff labels
    are all ±1), ``True``, or ``False`` (plain ridge regression).
    """
    n = gram.shape[0]
    gram_host = np.asarray(gram, np.float64)
    rhs = np.asarray(y, np.float64)
    ridge = lam * n * np.eye(n)
    alpha_host = _chol_solve(gram_host + ridge, rhs)

    is_binary = bool(np.all(np.abs(np.abs(rhs) - 1.0) < 1e-12))
    if refine is True or (refine == "auto" and is_binary):
        prev_sv = None
        for _ in range(max_newton_iters):
            margin_violation = rhs * (gram_host @ alpha_host) < 1.0
            idx = np.where(margin_violation)[0]
            if prev_sv is not None and np.array_equal(idx, prev_sv):
                break
            prev_sv = idx
            if idx.size == 0:
                break
            sub = _chol_solve(
                gram_host[np.ix_(idx, idx)] + lam * n * np.eye(idx.size),
                rhs[idx])
            alpha_host = np.zeros(n)
            alpha_host[idx] = sub

    alpha = jnp.asarray(alpha_host, gram.dtype)

    def decision(Xt):
        if kernel_fn is None or X_train is None:
            raise ValueError("provide kernel_fn and X_train for prediction")
        return kernel_fn(Xt, X_train) @ alpha

    return alpha, Classifier(decision_fn=decision)


def train_kernel_svm(
    gram: jax.Array,
    y: jax.Array,
    C: float = 1.0,
    n_epochs: int = 40,
    kernel_fn: Optional[Callable] = None,
    X_train: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Classifier]:
    """Dual L2-loss SVM by coordinate ascent over the exact Gram matrix.

    Solves max_a  sum a_i - 1/2 sum a_i a_j y_i y_j Q_ij, 0 <= a_i,
    with Q = K + I/(2C)  (L2-loss SVM dual — unbounded above, diagonal shift).
    """
    y = jnp.asarray(y, gram.dtype)
    n = gram.shape[0]
    q_diag = jnp.diagonal(gram) + 1.0 / (2.0 * C)

    def epoch(carry, _):
        alpha, grad_cache = carry  # grad_cache = Q_y @ (alpha*y) per i handled below

        def one_coord(carry_in, i):
            alpha, = carry_in
            # G_i = y_i * (K @ (alpha*y))_i + alpha_i/(2C) - 1
            ky = gram[i] @ (alpha * y)
            g = y[i] * ky + alpha[i] / (2.0 * C) - 1.0
            new_ai = jnp.maximum(alpha[i] - g / q_diag[i], 0.0)
            alpha = alpha.at[i].set(new_ai)
            return (alpha,), None

        (alpha,), _ = jax.lax.scan(one_coord, (alpha,), jnp.arange(n))
        return (alpha, grad_cache), None

    alpha0 = jnp.zeros(n, gram.dtype)
    (alpha, _), _ = jax.lax.scan(epoch, (alpha0, alpha0), None, length=n_epochs)

    coef = alpha * y

    def decision(Xt):
        if kernel_fn is None or X_train is None:
            raise ValueError("provide kernel_fn and X_train for prediction")
        return kernel_fn(Xt, X_train) @ coef

    return alpha, Classifier(decision_fn=decision)
