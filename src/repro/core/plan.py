"""FeaturePlan — the single source of truth for RM feature-map plans.

Every Random-Maclaurin path in the repo (SVM featurization via
``RMFeatureMap``, the static per-layer plans inside jitted models, and the
Pallas-accelerated ``repro.kernels.rm_feature`` ops) is driven by one host-side
object built here:

    degree measure  ->  stratified / iid allocation  ->  per-degree scales
                    ->  packed fused layout (DESIGN.md §3)

A ``FeaturePlan`` is a hashable NamedTuple, so it passes through
``jax.jit``/``lax.scan`` as a static constant, and it fully determines the
*column layout* of the feature vector:

    [ h01 const | h01 identity block | degree-0 const | degree buckets asc ]

For the fused kernel, every output column f is expressed uniformly as

    z_f(x) = col_scale[f] * prod_{j < col_degree[f]} <W[j, f, :], x>

with ``W`` a single ``[max_degree, F, d]`` tensor (``pack_omegas``): const
columns have degree 0 (empty product), the H0/1 identity block is degree 1
with one-hot rows, and degree-n bucket columns carry n Rademacher rows. This
lets the WHOLE map run as ONE Pallas launch (a masked running product over
degree slots) instead of one launch per degree bucket.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maclaurin import DotProductKernel

__all__ = [
    "FeaturePlan",
    "BIAS_TAIL_DEGREES",
    "allocate_features",
    "make_feature_plan",
    "init_omegas",
    "pack_omegas",
    "apply_plan",
    "plan_output_dim",
]

# ``coefs_host`` carries this many Taylor coefficients BEYOND n_max so
# ``truncation_bias`` accounts for the series tail the plan can never
# allocate (paper §4.2's truncation error), not just in-range degrees that
# happened to get zero features. With the window fixed, the reported bias is
# monotonically non-increasing in n_max for decaying-coefficient kernels —
# the conformance contract tests/test_estimator_conformance.py enforces.
BIAS_TAIL_DEGREES = 8


# ---------------------------------------------------------------------------
# plan serialization (shared with SketchPlan — repro.sketch.plan)
# ---------------------------------------------------------------------------
_PLAN_TUPLE_FIELDS = ("degrees", "counts", "scales", "coefs_host")


def plan_to_json(plan) -> str:
    """Any plan NamedTuple -> JSON carrying every field (cross-host repro)."""
    import json

    return json.dumps({f: getattr(plan, f) for f in plan._fields})


def plan_from_json(cls, s: str):
    import json

    d = json.loads(s)
    for f in _PLAN_TUPLE_FIELDS:
        if f in d:
            d[f] = tuple(d[f])
    return cls(**d)


# ---------------------------------------------------------------------------
# allocation (shared by Algorithm 1, static plans, and Algorithm 2)
# ---------------------------------------------------------------------------
def allocate_features(
    coefs: np.ndarray,
    q: np.ndarray,
    num_features: int,
    *,
    stratified: bool,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Split a budget of ``num_features`` across degrees of measure ``q``.

    Returns ``(counts, scales)`` over degrees ``0..len(q)-1``:

    * ``stratified=True`` — deterministic counts ``c_n = round(D q_n)``
      (largest-remainder rounding) with exact weights ``sqrt(a_n / c_n)``;
      no degree-sampling variance, coincides with the paper's §4.2 truncated
      construction under the proportional measure.
    * ``stratified=False`` — paper-faithful Algorithm 1: iid draws ``N ~ q``
      with importance weights ``sqrt(a_n / q_n) / sqrt(D)``; exactly unbiased.
      The draws come from a fresh ``Philox(seed)`` generator each call, so
      identical seeds give identical allocations; ``make_feature_plan``
      records both the seed and the realized counts on the ``FeaturePlan``.

    ``scales[n]`` is 0 where ``counts[n] == 0``.
    """
    if stratified:
        raw = q * num_features
        counts = np.floor(raw).astype(np.int64)
        deficit = num_features - int(counts.sum())
        if deficit > 0:
            order = np.argsort(-(raw - counts))
            counts[order[:deficit]] += 1
    else:
        rng = np.random.Generator(np.random.Philox(seed))
        draws = rng.choice(len(q), size=num_features, p=q)
        counts = np.bincount(draws, minlength=len(q)).astype(np.int64)

    scales = np.zeros(len(q), dtype=np.float64)
    nz = counts > 0
    if stratified:
        scales[nz] = np.sqrt(coefs[nz] / counts[nz])
    else:
        scales[nz] = np.sqrt(coefs[nz] / q[nz]) / np.sqrt(num_features)
    return counts, scales


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------
class FeaturePlan(NamedTuple):
    """Hashable RM feature-map plan: static through jit/scan.

    ``degrees``/``counts``/``scales`` describe the degree >= 1 random buckets
    (ascending). ``const`` is the collapsed degree-0 column value (0.0 when
    absent). The H0/1 variant (paper §6.1) prepends an exact
    ``[sqrt(a_0), sqrt(a_1) x]`` block. ``seed`` records the
    ``allocate_features`` seed alongside the realized allocation (counts), so
    iid-mode plans are reproducible across hosts: the plan's repr and
    ``to_json`` carry everything needed to rebuild identical column layouts.
    """

    degrees: Tuple[int, ...]
    counts: Tuple[int, ...]
    scales: Tuple[float, ...]
    const: float
    h01: bool
    h01_a0: float
    h01_a1: float
    input_dim: int
    num_random: int                   # D, the random-feature budget
    # a_0..a_{n_max + BIAS_TAIL_DEGREES}: allocation sees a_0..a_{n_max};
    # the extra tail window feeds truncation_bias diagnostics only.
    coefs_host: Tuple[float, ...]
    seed: int                         # degree-allocation seed (reproducibility)

    # -- sizes ---------------------------------------------------------------
    @property
    def total_rows(self) -> int:
        """Rademacher rows backing the random buckets: sum_n c_n * n."""
        return int(sum(c * n for c, n in zip(self.counts, self.degrees)))

    @property
    def max_degree(self) -> int:
        """Product depth of the packed layout (0 for a const-only plan)."""
        deg = max(self.degrees) if self.degrees else 0
        if self.h01:
            deg = max(deg, 1)
        return deg

    @property
    def num_prefix_columns(self) -> int:
        """Deterministic columns ahead of the random buckets."""
        pre = 0
        if self.h01:
            pre += 1 + self.input_dim
        if self.const != 0.0:
            pre += 1
        return pre

    @property
    def output_dim(self) -> int:
        return self.num_prefix_columns + int(sum(self.counts))

    # -- fused column layout (host-side, static) -----------------------------
    def column_degrees(self) -> np.ndarray:
        """Per-column product depth, int32 ``[output_dim]``."""
        deg = []
        if self.h01:
            deg.append(0)                      # sqrt(a_0) column
            deg.extend([1] * self.input_dim)   # identity block
        if self.const != 0.0:
            deg.append(0)
        for n, c in zip(self.degrees, self.counts):
            deg.extend([n] * c)
        return np.asarray(deg, dtype=np.int32)

    def column_scales(self) -> np.ndarray:
        """Per-column scale, float32 ``[output_dim]``."""
        sc = []
        if self.h01:
            sc.append(float(np.sqrt(self.h01_a0)))
            sc.extend([float(np.sqrt(self.h01_a1))] * self.input_dim)
        if self.const != 0.0:
            sc.append(float(self.const))
        for s, c in zip(self.scales, self.counts):
            sc.extend([float(s)] * c)
        return np.asarray(sc, dtype=np.float32)

    # -- diagnostics ---------------------------------------------------------
    def truncation_bias(self, radius: float) -> float:
        """Worst-case dropped-degree mass ``sum a_n R^{2n}`` over degrees with
        ``a_n > 0`` but no allocated features (paper §4.2), including the
        ``BIAS_TAIL_DEGREES``-wide coefficient window beyond n_max that the
        plan can never allocate."""
        present = set(self.degrees)
        if self.const != 0.0:
            present.add(0)
        if self.h01:
            present.update((0, 1))
        bias = 0.0
        for n, a_n in enumerate(self.coefs_host):
            if a_n > 0.0 and n not in present:
                bias += a_n * radius ** (2 * n)
        return bias

    # -- serialization -------------------------------------------------------
    def to_json(self) -> str:
        """Full plan state (seed + realized allocation included) as JSON."""
        return plan_to_json(self)

    @classmethod
    def from_json(cls, s: str) -> "FeaturePlan":
        return plan_from_json(cls, s)


def make_feature_plan(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    *,
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    stratified: bool = True,
    seed: int = 0,
) -> FeaturePlan:
    """Construct the plan (Algorithm 1 / §6.1 H0/1 / beyond-paper measures).

    This is the ONLY place degree allocation happens; ``make_feature_map``
    (core.feature_map) and ``make_plan_meta`` (core.static_plan) are thin
    wrappers.
    """
    from repro.core.feature_map import degree_measure

    kernel.validate_positive_definite(n_max)
    if h01 and measure == "geometric":
        measure = "geometric_ge2"
    q = degree_measure(kernel, n_max, p=p, kind=measure, radius=radius,
                       min_degree=2 if h01 else 0)
    coefs = kernel.coefs(n_max)
    coefs_diag = kernel.coefs(n_max + BIAS_TAIL_DEGREES)

    counts_all, scales_all = allocate_features(
        coefs, q, num_features, stratified=stratified, seed=seed
    )

    const = 0.0
    if counts_all[0] > 0:
        # c_0 identical constant features collapse into one column of value
        # sqrt(c_0) * scale_0 (same second moment, fewer columns).
        const = float(np.sqrt(counts_all[0]) * scales_all[0])

    degrees, counts, scales = [], [], []
    for n in range(1, n_max + 1):
        if counts_all[n]:
            degrees.append(n)
            counts.append(int(counts_all[n]))
            scales.append(float(scales_all[n]))

    h01_a0 = h01_a1 = 0.0
    if h01:
        h01_a0 = float(kernel.coef(0))
        h01_a1 = float(kernel.coef(1))
        if h01_a0 == 0.0 and h01_a1 == 0.0:
            raise ValueError(
                f"H0/1 is a no-op for kernel {kernel.name}: a_0 = a_1 = 0 "
                "(e.g. homogeneous polynomial kernels — paper §6.2)."
            )

    return FeaturePlan(
        degrees=tuple(degrees),
        counts=tuple(counts),
        scales=tuple(scales),
        const=const,
        h01=h01,
        h01_a0=h01_a0,
        h01_a1=h01_a1,
        input_dim=input_dim,
        num_random=num_features,
        coefs_host=tuple(float(c) for c in coefs_diag),
        seed=seed,
    )


# ---------------------------------------------------------------------------
# parameters and packing
# ---------------------------------------------------------------------------
def init_omegas(plan: FeaturePlan, key: jax.Array, dtype=jnp.float32) -> jax.Array:
    """All Rademacher rows for one plan instance, flat ``[total_rows, d]``.

    Row layout is bucket-major then feature-major: rows
    ``[off_n + i*n, off_n + (i+1)*n)`` belong to feature i of degree bucket n.
    """
    bern = jax.random.bernoulli(key, 0.5, (plan.total_rows, plan.input_dim))
    return (2.0 * bern.astype(dtype) - 1.0).astype(dtype)


def pack_omegas(plan: FeaturePlan, omegas: jax.Array) -> jax.Array:
    """Flat rows ``[total_rows, d]`` -> fused tensor ``[max_degree, F, d]``.

    Column f's product slots are ``W[0:col_degree[f], f, :]``; unused slots
    are zero (they are masked inside the kernel, never multiplied). The H0/1
    identity block occupies slot 0 with one-hot rows; const columns use no
    slots at all. Pure reshape/pad/concat, O(max_degree * F * d) bytes.

    Callers applying one plan repeatedly outside a layer scan should pack
    once and pass ``packed=`` to ``apply_plan``. Inside a scanned layer stack
    the per-layer omegas are scan carries, so the pack re-runs each layer
    step — same traffic the per-bucket path paid in its per-launch
    pad/transpose; storing pre-packed parameters is the remaining headroom.
    """
    d = plan.input_dim
    k = plan.max_degree
    dtype = omegas.dtype
    parts = []
    if plan.h01:
        pre = jnp.zeros((1 + d, k, d), dtype)
        if k > 0:
            eye = jnp.eye(d, dtype=dtype)[:, None, :]          # [d, 1, d]
            pre = pre.at[1:, :1, :].set(eye)
        parts.append(pre)
    if plan.const != 0.0:
        parts.append(jnp.zeros((1, k, d), dtype))
    off = 0
    for n, c in zip(plan.degrees, plan.counts):
        rows = omegas[off : off + c * n].reshape(c, n, d)
        off += c * n
        parts.append(jnp.pad(rows, ((0, 0), (0, k - n), (0, 0))))
    if not parts:
        return jnp.zeros((k, 0, d), dtype)
    packed = jnp.concatenate(parts, axis=0)                     # [F, k, d]
    return jnp.transpose(packed, (1, 0, 2))                     # [k, F, d]


# ---------------------------------------------------------------------------
# application — ONE fused launch (or its jnp mirror)
# ---------------------------------------------------------------------------
def _apply_plan_flat(
    plan: FeaturePlan, omegas: jax.Array, xf: jax.Array, compute_dtype,
    accum_dtype
) -> jax.Array:
    """jnp parity path: one flat ``x @ omegas.T`` + segmented products.

    Emits the exact fused column order (h01 const, identity block, const,
    buckets ascending) without materializing the ``[max_degree, F]`` masked
    product — XLA-friendly and does only ``sum c_n n`` projection columns.

    Mirrors the Pallas precision contract: the projection operands are cast
    to ``compute_dtype`` (bf16 under the mixed policy) while the dot itself
    carries ``preferred_element_type=accum_dtype`` and the segmented
    products run in ``accum_dtype`` — fp32 accumulation either way.
    """
    xc = xf.astype(compute_dtype)
    feats = []
    if plan.h01:
        feats.append(jnp.full((xf.shape[0], 1), np.sqrt(plan.h01_a0),
                              dtype=accum_dtype))
        feats.append(jnp.asarray(np.sqrt(plan.h01_a1), accum_dtype)
                     * xc.astype(accum_dtype))
    if plan.const != 0.0:
        feats.append(jnp.full((xf.shape[0], 1), plan.const, dtype=accum_dtype))
    if plan.total_rows:
        proj = jax.lax.dot_general(
            xc, omegas.astype(compute_dtype),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=accum_dtype,
        )                                               # [B, total_rows]
        off = 0
        for deg, cnt, scale in zip(plan.degrees, plan.counts, plan.scales):
            rows = cnt * deg
            block = proj[:, off : off + rows].reshape(-1, cnt, deg)
            feats.append(jnp.prod(block, axis=-1) * jnp.asarray(scale,
                                                                accum_dtype))
            off += rows
    return jnp.concatenate(feats, axis=-1)


def apply_plan(
    plan: FeaturePlan,
    omegas: jax.Array,
    x: jax.Array,
    accum_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    packed: Optional[jax.Array] = None,
    precision=None,
) -> jax.Array:
    """Featurize ``x [..., d] -> [..., plan.output_dim]``.

    The whole map — const column, H0/1 block, every degree bucket — is a
    single fused application (``repro.kernels.rm_feature.rm_feature_fused``):
    one Pallas launch on TPU, a flat matmul + segmented products on the jnp
    path. ``use_pallas`` defaults to the backend (True on TPU). ``packed``
    short-circuits ``pack_omegas`` for callers that cache the packed tensor.

    ``precision`` (``None``/``"fp32"``/``"bf16"`` or a
    ``repro.common.dtypes.Precision``) selects the INPUT dtype policy: under
    ``"bf16"`` x and the packed omega tensor enter the kernel in bf16 (the
    Rademacher values +-1 are exact in bf16, so only x is rounded) while
    accumulation stays fp32 on both paths.
    """
    # Lazy import: core.plan is imported by kernels-level code at call sites.
    from repro.common.dtypes import resolve_precision
    from repro.kernels.rm_feature.ops import rm_feature_fused

    if x.shape[-1] != plan.input_dim:
        raise ValueError(
            f"expected trailing dim {plan.input_dim}, got {x.shape}"
        )
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    prec = resolve_precision(precision)
    compute_dtype = prec.compute_dtype
    batch_shape = x.shape[:-1]
    xf = x.reshape(-1, plan.input_dim)
    if use_pallas:
        w = (packed if packed is not None
             else pack_omegas(plan, omegas)).astype(compute_dtype)
        col_deg = jnp.asarray(plan.column_degrees())
        col_scale = jnp.asarray(plan.column_scales())
        z = rm_feature_fused(
            xf.astype(compute_dtype), w, col_deg, col_scale,
            use_pallas=True, interpret=interpret,
        )
        z = z.astype(accum_dtype)
    else:
        z = _apply_plan_flat(plan, omegas, xf.astype(accum_dtype),
                             compute_dtype, accum_dtype)
    return z.reshape(*batch_shape, z.shape[-1])


def plan_output_dim(plan: FeaturePlan) -> int:
    """Real output columns of ``apply_plan`` for this plan (prefix columns
    plus one column per allocated random feature)."""
    return plan.output_dim
