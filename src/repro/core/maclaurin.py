"""Dot product kernel zoo with Maclaurin coefficient access.

A dot product kernel is ``K(x, y) = f(<x, y>)``. By Schoenberg's theorem
(paper Theorem 1), ``f`` yields a positive definite kernel on the unit ball of
a Hilbert space iff its Maclaurin expansion ``f(x) = sum_n a_n x^n`` has
``a_n >= 0`` for all n. Every kernel here exposes:

  * ``coefs(n_max)`` — the coefficients ``a_0 .. a_{n_max}`` (float64, host),
  * ``f(x)`` / ``fprime(x)`` — closed forms (work on numpy or jax arrays),
  * ``gram(X, Y)`` — the exact kernel matrix,
  * ``radius`` — radius of convergence of the series (np.inf if entire).

Coefficients are computed in log-space where factorials/binomials are
involved, so large degrees / small sigmas stay finite.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

__all__ = [
    "DotProductKernel",
    "HomogeneousPolynomialKernel",
    "PolynomialKernel",
    "ExponentialDotProductKernel",
    "VovkRealKernel",
    "VovkInfiniteKernel",
    "MaclaurinKernel",
    "kernel_from_name",
]


class DotProductKernel:
    """Base class. Subclasses must set ``name`` and implement ``coef``/``f``."""

    name: str = "abstract"
    #: radius of convergence of the Maclaurin series (np.inf when entire)
    radius: float = np.inf

    # -- series ------------------------------------------------------------
    def coef(self, n: int) -> float:
        raise NotImplementedError

    def coefs(self, n_max: int) -> np.ndarray:
        return np.asarray([self.coef(n) for n in range(n_max + 1)], dtype=np.float64)

    def validate_positive_definite(self, n_max: int = 64) -> None:
        """Theorem 1: all Maclaurin coefficients must be non-negative."""
        cs = self.coefs(n_max)
        if np.any(cs < -1e-300):
            bad = int(np.argmax(cs < 0))
            raise ValueError(
                f"kernel {self.name!r} has negative Maclaurin coefficient "
                f"a_{bad}={cs[bad]:.3e}; not positive definite (Schoenberg)."
            )

    # -- closed forms --------------------------------------------------------
    def f(self, x):
        raise NotImplementedError

    def fprime(self, x):
        raise NotImplementedError

    def series_eval(self, x, n_max: int = 64) -> np.ndarray:
        """Evaluate via the truncated series (float64). For tests/oracles."""
        x = np.asarray(x, dtype=np.float64)
        cs = self.coefs(n_max)
        out = np.zeros_like(x)
        for n in range(n_max, -1, -1):  # Horner
            out = out * x + cs[n]
        return out

    # -- batched kernels -----------------------------------------------------
    def gram(self, X, Y=None):
        """Exact kernel matrix ``K[i, j] = f(<X_i, Y_j>)`` (jax arrays ok)."""
        Y = X if Y is None else Y
        return self.f(X @ Y.T)

    def __repr__(self) -> str:  # pragma: no cover - debugging sugar
        return f"{type(self).__name__}({self.name})"


@dataclasses.dataclass(frozen=True)
class HomogeneousPolynomialKernel(DotProductKernel):
    """``K(x, y) = <x, y>^p`` — a_p = 1, all other coefficients zero."""

    degree: int = 10

    def __post_init__(self):
        if self.degree < 1:
            raise ValueError("degree must be >= 1")

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"homogeneous_poly{self.degree}"

    def coef(self, n: int) -> float:
        return 1.0 if n == self.degree else 0.0

    def f(self, x):
        return x**self.degree

    def fprime(self, x):
        return self.degree * x ** (self.degree - 1)


@dataclasses.dataclass(frozen=True)
class PolynomialKernel(DotProductKernel):
    """``K(x, y) = (<x, y> + r)^p`` — a_n = C(p, n) r^(p-n) for n <= p."""

    degree: int = 10
    r: float = 1.0

    def __post_init__(self):
        if self.degree < 1:
            raise ValueError("degree must be >= 1")
        if self.r < 0:
            raise ValueError("offset r must be >= 0 for positive definiteness")

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"poly{self.degree}_r{self.r:g}"

    def coef(self, n: int) -> float:
        if n > self.degree:
            return 0.0
        return float(math.comb(self.degree, n)) * self.r ** (self.degree - n)

    def f(self, x):
        return (x + self.r) ** self.degree

    def fprime(self, x):
        return self.degree * (x + self.r) ** (self.degree - 1)


@dataclasses.dataclass(frozen=True)
class ExponentialDotProductKernel(DotProductKernel):
    """``K(x, y) = exp(<x, y> / sigma^2)`` — a_n = sigma^{-2n} / n!.

    The softmax-attention kernel: with ``sigma^2 = sqrt(d_head)`` this is the
    unnormalized attention weight ``exp(q.k / sqrt(d_head))``.
    """

    sigma2: float = 1.0

    def __post_init__(self):
        if self.sigma2 <= 0:
            raise ValueError("sigma2 must be > 0")

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"exp_dot_s{self.sigma2:g}"

    def coef(self, n: int) -> float:
        # exp(log) for stability at large n / small sigma2.
        return math.exp(-n * math.log(self.sigma2) - math.lgamma(n + 1))

    def f(self, x):
        if isinstance(x, (np.ndarray, float, int)):
            return np.exp(np.asarray(x, dtype=np.float64) / self.sigma2)
        return jnp.exp(x / self.sigma2)

    def fprime(self, x):
        return self.f(x) / self.sigma2


@dataclasses.dataclass(frozen=True)
class VovkRealKernel(DotProductKernel):
    """Vovk's real polynomial kernel ``(1 - x^p) / (1 - x) = sum_{n<p} x^n``."""

    degree: int = 10

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"vovk_real{self.degree}"

    def coef(self, n: int) -> float:
        return 1.0 if n < self.degree else 0.0

    def f(self, x):
        # Stable at x == 1 via the series form.
        if isinstance(x, (np.ndarray, float, int)):
            x = np.asarray(x, dtype=np.float64)
            out = np.zeros_like(x)
            for _ in range(self.degree):
                out = out * x + 1.0
            return out
        out = jnp.zeros_like(x)
        for _ in range(self.degree):
            out = out * x + 1.0
        return out

    def fprime(self, x):
        if isinstance(x, (np.ndarray, float, int)):
            x = np.asarray(x, dtype=np.float64)
            out = np.zeros_like(x)
            for n in range(self.degree - 1, 0, -1):
                out = out * x + float(n)
            return out
        out = jnp.zeros_like(x)
        for n in range(self.degree - 1, 0, -1):
            out = out * x + float(n)
        return out


@dataclasses.dataclass(frozen=True)
class VovkInfiniteKernel(DotProductKernel):
    """Vovk's infinite polynomial kernel ``1 / (1 - x)`` (radius 1)."""

    radius: float = 1.0

    @property
    def name(self) -> str:  # type: ignore[override]
        return "vovk_infinite"

    def coef(self, n: int) -> float:
        return 1.0

    def f(self, x):
        return 1.0 / (1.0 - x)

    def fprime(self, x):
        return 1.0 / (1.0 - x) ** 2


@dataclasses.dataclass(frozen=True)
class MaclaurinKernel(DotProductKernel):
    """Generic kernel from a user-supplied coefficient function.

    ``f``/``fprime`` fall back to (slow, float64) series evaluation when no
    closed form is given.
    """

    coef_fn: Callable[[int], float] = lambda n: 0.0
    f_fn: Optional[Callable] = None
    fprime_fn: Optional[Callable] = None
    label: str = "custom"
    radius: float = np.inf
    series_terms: int = 64

    @property
    def name(self) -> str:  # type: ignore[override]
        return f"maclaurin_{self.label}"

    def coef(self, n: int) -> float:
        return float(self.coef_fn(n))

    def f(self, x):
        if self.f_fn is not None:
            return self.f_fn(x)
        return self.series_eval(x, self.series_terms)

    def fprime(self, x):
        if self.fprime_fn is not None:
            return self.fprime_fn(x)
        x = np.asarray(x, dtype=np.float64)
        cs = self.coefs(self.series_terms)
        out = np.zeros_like(x)
        for n in range(self.series_terms, 0, -1):
            out = out * x + n * cs[n]
        return out


def kernel_from_name(name: str, **kwargs) -> DotProductKernel:
    """Config-friendly constructor: 'exp', 'poly', 'homogeneous', 'vovk_real',
    'vovk_infinite'."""
    name = name.lower()
    if name in ("exp", "exponential", "exp_dot"):
        return ExponentialDotProductKernel(**kwargs)
    if name in ("poly", "polynomial"):
        return PolynomialKernel(**kwargs)
    if name in ("homogeneous", "homogeneous_poly", "hpoly"):
        return HomogeneousPolynomialKernel(**kwargs)
    if name == "vovk_real":
        return VovkRealKernel(**kwargs)
    if name == "vovk_infinite":
        return VovkInfiniteKernel(**kwargs)
    raise ValueError(f"unknown dot product kernel {name!r}")
