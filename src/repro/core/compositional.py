"""Algorithm 2 — Random Maclaurin feature maps for compositional kernels.

``K_co(x, y) = K_dp(K(x, y)) = f(K(x, y))`` for an arbitrary PD kernel K,
given black-box access to a routine A that returns *one-dimensional* unbiased
feature maps W for K: ``E[W(x) W(y)] = K(x, y)``, ``|W(x)| <= sqrt(C_W)``.

Per output feature: draw ``N ~ q``, get N independent instantiations
``W_1..W_N`` from A, and emit ``Z(x) = sqrt(a_N / q_N) * prod_j W_j(x)``.

Inner maps provided:

  * ``RademacherInnerMap`` — W(x) = w.x with Rademacher w. Recovers
    Algorithm 1 exactly (the dot product composed into K_dp).
  * ``RFFInnerMap`` — Rahimi-Recht random Fourier features for the Gaussian
    kernel: W(x) = sqrt(2) cos(w.x + b), w ~ N(0, 1/sigma^2 I),
    b ~ U[0, 2pi). Bounded by sqrt(2), unbiased for exp(-|x-y|^2/2sigma^2).
    Composing: f(K_rbf) e.g. exp(K_rbf(x,y)) — kernels outside every prior
    feature-map family (paper §5).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.feature_map import degree_measure
from repro.core.maclaurin import DotProductKernel
from repro.core.plan import allocate_features

__all__ = [
    "RademacherInnerMap",
    "RFFInnerMap",
    "CompositionalFeatureMap",
    "make_compositional_feature_map",
]


class InnerMap:
    """A batch of M independent 1-d feature maps W for the inner kernel K.

    ``apply(x)`` returns ``[..., M]``: column j is W_j evaluated at x.
    """

    bound: float  # sqrt(C_W)

    def apply(self, x: jax.Array) -> jax.Array:
        raise NotImplementedError

    def exact_kernel(self, X: jax.Array, Y: jax.Array) -> jax.Array:
        raise NotImplementedError


@dataclasses.dataclass
class RademacherInnerMap(InnerMap):
    """W_j(x) = <w_j, x>, w Rademacher — the dot product inner kernel."""

    omega: jax.Array  # [M, d]
    bound: float = np.inf  # bounded by R in B_1(0,R) only

    @staticmethod
    def create(key: jax.Array, num: int, dim: int) -> "RademacherInnerMap":
        bern = jax.random.bernoulli(key, 0.5, (num, dim))
        return RademacherInnerMap(omega=2.0 * bern.astype(jnp.float32) - 1.0)

    def apply(self, x: jax.Array) -> jax.Array:
        return x @ self.omega.T

    def exact_kernel(self, X, Y):
        return X @ Y.T


@dataclasses.dataclass
class RFFInnerMap(InnerMap):
    """Rahimi-Recht random Fourier features for the Gaussian RBF kernel."""

    w: jax.Array  # [M, d]
    b: jax.Array  # [M]
    sigma: float = 1.0
    bound: float = float(np.sqrt(2.0))

    @staticmethod
    def create(key: jax.Array, num: int, dim: int, sigma: float = 1.0) -> "RFFInnerMap":
        kw, kb = jax.random.split(key)
        w = jax.random.normal(kw, (num, dim)) / sigma
        b = jax.random.uniform(kb, (num,), minval=0.0, maxval=2.0 * np.pi)
        return RFFInnerMap(w=w, b=b, sigma=sigma)

    def apply(self, x: jax.Array) -> jax.Array:
        return jnp.sqrt(2.0) * jnp.cos(x @ self.w.T + self.b)

    def exact_kernel(self, X, Y):
        sq = (
            jnp.sum(X**2, -1)[:, None]
            + jnp.sum(Y**2, -1)[None, :]
            - 2.0 * X @ Y.T
        )
        return jnp.exp(-sq / (2.0 * self.sigma**2))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompositionalFeatureMap:
    """Degree-bucketed Algorithm 2 map.

    For each allocated degree n there is an inner map batch with ``c_n * n``
    independent W's; feature i of the bucket is the product of its n columns.
    """

    degrees: Tuple[int, ...]
    counts: Tuple[int, ...]
    inner_maps: List[InnerMap]
    scales: List[jax.Array]
    const: Optional[jax.Array]
    input_dim: int

    def tree_flatten(self):
        return (self.inner_maps, self.scales, self.const), (
            self.degrees,
            self.counts,
            self.input_dim,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        inner_maps, scales, const = children
        degrees, counts, input_dim = aux
        return cls(degrees, counts, inner_maps, scales, const, input_dim)

    @property
    def output_dim(self) -> int:
        return sum(self.counts) + (1 if self.const is not None else 0)

    def __call__(self, x: jax.Array) -> jax.Array:
        batch_shape = x.shape[:-1]
        xf = x.reshape(-1, self.input_dim)
        feats = []
        if self.const is not None:
            feats.append(jnp.broadcast_to(self.const, (xf.shape[0], 1)))
        for deg, cnt, inner, scale in zip(
            self.degrees, self.counts, self.inner_maps, self.scales
        ):
            w = inner.apply(xf)  # [B, cnt*deg]
            w = w.reshape(xf.shape[0], cnt, deg)
            feats.append(jnp.prod(w, axis=-1) * scale)
        z = jnp.concatenate(feats, axis=-1)
        return z.reshape(*batch_shape, z.shape[-1])

    def estimate_gram(self, X, Y=None):
        zx = self(X)
        zy = zx if Y is None else self(Y)
        return zx @ zy.T


def make_compositional_feature_map(
    dp_kernel: DotProductKernel,
    inner_factory,
    input_dim: int,
    num_features: int,
    key: jax.Array,
    *,
    p: float = 2.0,
    measure: str = "geometric",
    n_max: int = 24,
    inner_bound: float = 1.0,
    stratified: bool = True,
) -> CompositionalFeatureMap:
    """Build Algorithm 2's map.

    ``inner_factory(key, num) -> InnerMap`` returns a batch of ``num``
    independent inner maps (black-box A of the paper). ``inner_bound`` is
    ``C_W`` and feeds the proportional measure (q_n ∝ a_n C_W^n).
    """
    dp_kernel.validate_positive_definite(n_max)
    q = degree_measure(dp_kernel, n_max, p=p, kind=measure, radius=np.sqrt(inner_bound))
    coefs = dp_kernel.coefs(n_max)

    key_deg, key_inner = jax.random.split(key)
    seed = 0
    if not stratified:
        seed = int(jax.random.randint(key_deg, (), 0, 2**31 - 1))
    counts_all, scales_all = allocate_features(
        coefs, q, num_features, stratified=stratified, seed=seed
    )

    degrees: List[int] = []
    counts: List[int] = []
    inner_maps: List[InnerMap] = []
    scales: List[jax.Array] = []
    const = None
    if counts_all[0] > 0:
        const = jnp.asarray(
            np.sqrt(counts_all[0]) * scales_all[0], dtype=jnp.float32
        )

    subkeys = jax.random.split(key_inner, int((counts_all[1:] > 0).sum()) + 1)
    ki = 0
    for n in range(1, n_max + 1):
        cnt = int(counts_all[n])
        if cnt == 0:
            continue
        inner_maps.append(inner_factory(subkeys[ki], cnt * n))
        ki += 1
        degrees.append(n)
        counts.append(cnt)
        scales.append(jnp.asarray(scales_all[n], dtype=jnp.float32))

    return CompositionalFeatureMap(
        degrees=tuple(degrees),
        counts=tuple(counts),
        inner_maps=inner_maps,
        scales=scales,
        const=const,
        input_dim=input_dim,
    )
