"""Progressive feature doubling — grow D online without redrawing.

The adaptive-accuracy subsystem (ROADMAP open item 3, docs/adaptive.md)
needs the feature budget to be a DIAL, not a constructor constant: when the
drift monitor reports an (eps, delta) violation, the serving/training loop
must buy more accuracy without invalidating the features it already
computed.  The construction is the ``fold_in``-keyed shard draw that
``distributed/estimator.py`` already pins for mesh shards, reused over a
*generation* index instead of a device coordinate:

    * one per-generation plan of ``base_features`` columns (the same
      hashable plan for every generation, so growth never retraces);
    * generation g's params are ``init_params(plan, fold_in(key, g))`` —
      they depend only on (key, g), never on when g was materialized, so
      growing from G to 2G generations APPENDS draws and leaves
      generations [0, G) bit-identical;
    * ``Z(x) = concat_g Z_g(x) / sqrt(G)`` — each generation is an unbiased
      estimator of the kernel, so the concatenation at ``1/sqrt(G)`` is the
      unbiased G-fold average.  The *raw* (unscaled) feature prefix is
      bit-identical across growth; the scaled output differs from the old
      one only by the single global ``sqrt(G_old / G_new)`` factor.

Because the fold-in coordinate doubles as the shard index, a
``GrowableFeatureMap`` at G generations computes the same raw feature
layout as ``ShardedFeatureMap`` with S = G shards of the same plan and key
— growth and sharding are one contract.

``eps_at`` tightens monotonically in the generation count (Theorem 12's
certified error at the current total budget), which is what lets
``obs.DriftMonitor.recommend()`` → ``grow()`` form a control loop: every
doubling multiplies the certified eps by ``~1/sqrt(2)``.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import registry
from repro.core.bounds import HoeffdingConstants, constants_for
from repro.core.maclaurin import DotProductKernel

__all__ = ["GrowableFeatureMap", "make_growable_feature_map"]


def _stack_params(est, plan, key_data: np.ndarray, start: int, stop: int,
                  dtype) -> Any:
    """Stacked params for generations [start, stop): leaf g is drawn with
    ``fold_in(key, g)`` — the exact rule ``shard_init_params`` pins for
    mesh shards, so a generation's draw depends only on (key, g)."""
    key = jnp.asarray(key_data, jnp.uint32)
    chunks = [
        est.init_params(plan, jax.random.fold_in(key, g), dtype)
        for g in range(start, stop)
    ]
    return jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *chunks)


def _concat_stacked(old: Any, new: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda a, b: jnp.concatenate([a, b], axis=0), old, new)


@dataclasses.dataclass
class GrowableFeatureMap:
    """A feature map whose budget doubles in place, prefix-preserving.

    Thin carrier of (estimator name, one per-generation plan, stacked
    ``[G, ...]`` params, the base PRNG key all generations fold from, and
    the bound context).  Duck-types the other map objects (``apply`` /
    ``__call__`` / ``output_dim`` / ``estimate_gram`` /
    ``truncation_bias``) so offline consumers take it interchangeably.
    """

    estimator: str
    plan: Any
    params: Any                        # stacked [n_generations, ...] leaves
    n_generations: int
    key_data: np.ndarray               # uint32 key the generations fold from
    kernel: Optional[DotProductKernel] = None
    radius: float = 1.0
    measure: str = "geometric"
    p: float = 2.0
    omega_dtype: Any = jnp.float32

    # -- metadata ------------------------------------------------------------
    @property
    def input_dim(self) -> int:
        return self.plan.input_dim

    @property
    def generation_output_dim(self) -> int:
        return registry.get(self.estimator).output_dim(self.plan)

    @property
    def output_dim(self) -> int:
        return self.n_generations * self.generation_output_dim

    def truncation_bias(self, radius: float) -> float:
        """Generations share one plan, so the dropped-degree mass of the
        concatenation equals any single generation's."""
        return registry.get(self.estimator).truncation_bias(
            self.plan, radius)

    # -- bound side ----------------------------------------------------------
    def constants(self) -> HoeffdingConstants:
        if self.kernel is None:
            raise ValueError(
                "this GrowableFeatureMap carries no kernel (e.g. it was "
                "rebuilt via from_json without one); pass kernel= to "
                "from_json to restore eps_at/required_generations")
        return constants_for(self.kernel, self.radius, self.input_dim,
                             self.p)

    def eps_at(self, delta: float,
               num_features: Optional[int] = None) -> float:
        """Theorem 12's certified uniform error at ``num_features``
        (default: the CURRENT total budget).  Monotone non-increasing in
        the generation count — the conformance suite pins this."""
        d = self.output_dim if num_features is None else num_features
        return self.constants().eps_at(d, delta, self.measure)

    def required_generations(self, eps: float, delta: float) -> int:
        """Smallest generation count whose total budget certifies eps."""
        d_req = self.constants().required_d(eps, delta, self.measure)
        per_gen = self.generation_output_dim
        return max(-(-d_req // per_gen), 1)

    # -- growth --------------------------------------------------------------
    def grow(self, factor: int = 2) -> "GrowableFeatureMap":
        """Multiply the generation count by ``factor`` WITHOUT redrawing.

        Returns a new map whose generations ``[0, n_generations)`` carry
        the exact same params (the stacked prefix is untouched); only
        generations ``[n_generations, factor * n_generations)`` are new
        draws, keyed by their generation index alone.
        """
        if factor < 2:
            raise ValueError(f"growth factor must be >= 2, got {factor}")
        return self.grow_to_generations(self.n_generations * factor)

    def grow_to_generations(self, n_generations: int) -> "GrowableFeatureMap":
        if n_generations < self.n_generations:
            raise ValueError(
                f"cannot shrink: have {self.n_generations} generations, "
                f"asked for {n_generations}")
        if n_generations == self.n_generations:
            return self
        est = registry.get(self.estimator)
        new = _stack_params(est, self.plan, self.key_data,
                            self.n_generations, n_generations,
                            self.omega_dtype)
        return dataclasses.replace(
            self,
            params=_concat_stacked(self.params, new),
            n_generations=n_generations,
        )

    def grow_to(self, num_features: int) -> "GrowableFeatureMap":
        """Grow until ``output_dim >= num_features`` (whole generations)."""
        per_gen = self.generation_output_dim
        return self.grow_to_generations(
            max(-(-num_features // per_gen), self.n_generations))

    # -- application ---------------------------------------------------------
    def apply(
        self,
        x: jax.Array,
        *,
        rescale: bool = True,
        accum_dtype=jnp.float32,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        precision=None,
    ) -> jax.Array:
        """Featurize ``x [..., d] -> [..., output_dim]``.

        Generation g's columns occupy the contiguous block
        ``[g * generation_output_dim, (g+1) * generation_output_dim)``.
        ``rescale=False`` returns the RAW concatenation (no ``1/sqrt(G)``)
        — the quantity that is bit-identical across ``grow()``; the scaled
        output is exactly ``raw * (1/sqrt(G))``, one global multiply.
        """
        est = registry.get(self.estimator)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        zs = [
            est.apply(self.plan,
                      jax.tree_util.tree_map(lambda a: a[g], self.params),
                      x, accum_dtype=accum_dtype, use_pallas=use_pallas,
                      interpret=interpret, precision=precision)
            for g in range(self.n_generations)
        ]
        raw = jnp.concatenate(zs, axis=-1)
        if not rescale:
            return raw
        return raw * jnp.asarray(1.0 / np.sqrt(self.n_generations),
                                 accum_dtype)

    def __call__(self, x: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
        return self.apply(x, use_pallas=False, accum_dtype=accum_dtype)

    def estimate_gram(
        self,
        X: jax.Array,
        Y: Optional[jax.Array] = None,
        *,
        row_chunk: int = 4096,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        precision=None,
    ) -> jax.Array:
        """Kernel-matrix estimate without materializing the concatenation:
        per-generation partial Grams summed at ``1/G`` (the serial twin of
        the sharded psum reduction)."""
        est = registry.get(self.estimator)
        if use_pallas is None:
            use_pallas = jax.default_backend() == "tpu"
        inv_g = 1.0 / self.n_generations

        def _apply_fn(g):
            p = jax.tree_util.tree_map(lambda a: a[g], self.params)
            return lambda Z: est.apply(
                self.plan, p, Z, use_pallas=use_pallas,
                interpret=interpret, precision=precision)

        parts = [
            registry.estimate_gram(_apply_fn(g), X, Y,
                                   row_chunk=row_chunk) * inv_g
            for g in range(self.n_generations)
        ]
        return sum(parts[1:], parts[0])

    # -- serialization -------------------------------------------------------
    def to_json(self) -> str:
        """Growth state as JSON: the per-generation plan (via the shared
        plan serialization), the base key, and the generation count — the
        params are NOT stored; they are a pure function of (plan, key, G)
        and are redrawn bit-identically by ``from_json``."""
        ptype = type(self.plan)
        return json.dumps({
            "estimator": self.estimator,
            "plan_type": [ptype.__module__, ptype.__qualname__],
            "plan": json.loads(self.plan.to_json()),
            "n_generations": self.n_generations,
            "key_data": np.asarray(self.key_data).tolist(),
            "radius": self.radius,
            "measure": self.measure,
            "p": self.p,
        })

    @classmethod
    def from_json(cls, s: str,
                  kernel: Optional[DotProductKernel] = None,
                  omega_dtype=jnp.float32) -> "GrowableFeatureMap":
        d = json.loads(s)
        mod, qual = d["plan_type"]
        plan_cls = getattr(importlib.import_module(mod), qual)
        plan = plan_cls.from_json(json.dumps(d["plan"]))
        key_data = np.asarray(d["key_data"], np.uint32)
        est = registry.get(d["estimator"])
        params = _stack_params(est, plan, key_data, 0, d["n_generations"],
                               omega_dtype)
        return cls(
            estimator=d["estimator"], plan=plan, params=params,
            n_generations=d["n_generations"], key_data=key_data,
            kernel=kernel, radius=d["radius"], measure=d["measure"],
            p=d["p"], omega_dtype=omega_dtype,
        )


def make_growable_feature_map(
    kernel: DotProductKernel,
    input_dim: int,
    key: jax.Array,
    *,
    base_features: int = 64,
    n_generations: int = 1,
    eps: Optional[float] = None,
    delta: Optional[float] = None,
    estimator: str = "rm",
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    omega_dtype=jnp.float32,
    stratified: bool = True,
    precision=None,
) -> GrowableFeatureMap:
    """Build a growable map from any registry estimator.

    Either start from an explicit ``n_generations`` of ``base_features``
    each, or pass accuracy targets ``eps``/``delta`` and get the smallest
    generation count whose total budget Theorem 12 certifies at
    (eps, delta) — the same inversion ``select_budget`` uses.
    """
    if omega_dtype is None or precision is not None:
        if precision is not None:
            from repro.common.dtypes import resolve_precision

            omega_dtype = resolve_precision(precision).compute_dtype
        elif omega_dtype is None:
            omega_dtype = jnp.float32
    est = registry.get(estimator)
    plan = est.make_plan(
        kernel, input_dim, base_features,
        p=p, measure=measure, h01=h01, n_max=n_max, radius=radius,
        stratified=stratified,
    )
    key_data = np.asarray(key, np.uint32)
    fm = GrowableFeatureMap(
        estimator=estimator, plan=plan,
        params=_stack_params(est, plan, key_data, 0, 1, omega_dtype),
        n_generations=1, key_data=key_data, kernel=kernel, radius=radius,
        measure=measure, p=p, omega_dtype=omega_dtype,
    )
    if eps is not None or delta is not None:
        if eps is None or delta is None:
            raise ValueError("pass BOTH eps and delta (or neither)")
        n_generations = fm.required_generations(eps, delta)
    return fm.grow_to_generations(max(n_generations, 1))
