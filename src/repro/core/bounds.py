"""Theorem 12 / Theorem 16 constants and required-D calculators.

All quantities follow the paper's notation:

  * domain ``Omega ⊆ B_1(0, R)`` in R^d,
  * estimator bound   ``C_Omega = p * f(p R^2)``                (Lemma 8)
  * kernel Lipschitz  ``R f'(R^2)``                             (Lemma 10)
  * estimator Lip.    ``p^2 R sqrt(d) f'(p R^2)``               (Lemma 11)
  * L = sum of the two                                           (§4.1)
  * failure prob     ``2 (32 R L / eps)^{2d} exp(-D eps^2 / (8 C^2))``

plus the beyond-paper constant for the ``proportional`` degree measure
(q_n ∝ a_n R^{2n}): there every feature satisfies
``|Z(x)Z(y)| <= sum_n a_n R^{2n} = f(R^2)`` — strictly smaller than the
paper's ``p f(p R^2)``, shrinking required D by the squared ratio.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.maclaurin import DotProductKernel

__all__ = ["HoeffdingConstants", "constants_for", "required_num_features",
           "pointwise_failure_prob", "uniform_failure_prob"]


@dataclasses.dataclass(frozen=True)
class HoeffdingConstants:
    """All the constants entering Theorem 12 for one (kernel, domain) pair."""

    radius: float
    dim: int
    p: float
    c_omega: float          # paper estimator bound  p f(pR^2)
    c_proportional: float   # beyond-paper bound     f(R^2)
    lipschitz: float        # L of §4.1

    def required_d(self, eps: float, delta: float, measure: str = "geometric") -> int:
        c = self.c_omega if measure == "geometric" else self.c_proportional
        log_cover = 2.0 * self.dim * math.log(max(32.0 * self.radius * self.lipschitz / eps, 2.0))
        d_req = 8.0 * c**2 / eps**2 * (log_cover + math.log(2.0 / delta))
        return int(math.ceil(d_req))


def constants_for(
    kernel: DotProductKernel, radius: float, dim: int, p: float = 2.0
) -> HoeffdingConstants:
    r2 = radius**2
    if np.isfinite(kernel.radius) and p * r2 >= kernel.radius:
        raise ValueError(
            f"p*R^2 = {p * r2:g} exceeds the series radius {kernel.radius:g} "
            f"of {kernel.name}; rescale the data (paper §3, choose c > I/gamma)."
        )
    f_pr2 = float(kernel.f(p * r2))
    fp_r2 = float(kernel.fprime(r2))
    fp_pr2 = float(kernel.fprime(p * r2))
    c_omega = p * f_pr2
    c_prop = float(kernel.f(r2))
    lipschitz = radius * fp_r2 + p**2 * radius * math.sqrt(dim) * fp_pr2
    return HoeffdingConstants(
        radius=radius,
        dim=dim,
        p=p,
        c_omega=c_omega,
        c_proportional=c_prop,
        lipschitz=lipschitz,
    )


def pointwise_failure_prob(
    consts: HoeffdingConstants, num_features: int, eps: float,
    measure: str = "geometric",
) -> float:
    """Hoeffding bound for a single pair (x, y)."""
    c = consts.c_omega if measure == "geometric" else consts.c_proportional
    return 2.0 * math.exp(-num_features * eps**2 / (8.0 * c**2))


def uniform_failure_prob(
    consts: HoeffdingConstants, num_features: int, eps: float,
    measure: str = "geometric",
) -> float:
    """Theorem 12's uniform bound over the whole domain (can exceed 1)."""
    c = consts.c_omega if measure == "geometric" else consts.c_proportional
    log_p = (
        math.log(2.0)
        + 2.0 * consts.dim * math.log(max(32.0 * consts.radius * consts.lipschitz / eps, 1e-9))
        - num_features * eps**2 / (8.0 * c**2)
    )
    return math.exp(min(log_p, 50.0))


def required_num_features(
    kernel: DotProductKernel,
    radius: float,
    dim: int,
    eps: float,
    delta: float,
    p: float = 2.0,
    measure: str = "geometric",
) -> int:
    """D such that Theorem 12 guarantees sup error <= eps w.p. >= 1 - delta."""
    return constants_for(kernel, radius, dim, p).required_d(eps, delta, measure)
