"""Theorem 12 / Theorem 16 constants and required-D calculators.

All quantities follow the paper's notation:

  * domain ``Omega ⊆ B_1(0, R)`` in R^d,
  * estimator bound   ``C_Omega = p * f(p R^2)``                (Lemma 8)
  * kernel Lipschitz  ``R f'(R^2)``                             (Lemma 10)
  * estimator Lip.    ``p^2 R sqrt(d) f'(p R^2)``               (Lemma 11)
  * L = sum of the two                                           (§4.1)
  * failure prob     ``2 (32 R L / eps)^{2d} exp(-D eps^2 / (8 C^2))``

plus the beyond-paper constant for the ``proportional`` degree measure
(q_n ∝ a_n R^{2n}): there every feature satisfies
``|Z(x)Z(y)| <= sum_n a_n R^{2n} = f(R^2)`` — strictly smaller than the
paper's ``p f(p R^2)``, shrinking required D by the squared ratio.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.maclaurin import DotProductKernel

__all__ = ["HoeffdingConstants", "constants_for", "required_num_features",
           "pointwise_failure_prob", "uniform_failure_prob",
           "pairwise_eps", "required_features_for_pairs"]

# Shared floor for the covering ratio 32 R L / eps.  Both directions of the
# Theorem 12 bound (required_d forward, uniform_failure_prob backward) MUST
# floor identically, otherwise the round trip
# ``uniform_failure_prob(consts, required_d(eps, delta), eps) <= delta``
# breaks for large eps where the ratio drops below 1 (one side would use a
# positive log-cover, the other a hugely negative one).
_COVER_RATIO_FLOOR = 2.0


def _require_positive(name: str, value: float) -> None:
    if not value > 0.0:
        raise ValueError(f"{name} must be > 0, got {name}={value!r}")


def _require_delta(delta: float) -> None:
    if not 0.0 < delta < 1.0:
        raise ValueError(
            f"delta must be a failure probability in (0, 1), got "
            f"delta={delta!r}")


def _require_n_pairs(n_pairs: int) -> None:
    if n_pairs < 1:
        raise ValueError(
            f"n_pairs must be >= 1 (a union bound over zero pairs is "
            f"vacuous), got n_pairs={n_pairs!r}")


@dataclasses.dataclass(frozen=True)
class HoeffdingConstants:
    """All the constants entering Theorem 12 for one (kernel, domain) pair."""

    radius: float
    dim: int
    p: float
    c_omega: float          # paper estimator bound  p f(pR^2)
    c_proportional: float   # beyond-paper bound     f(R^2)
    lipschitz: float        # L of §4.1

    def _c(self, measure: str) -> float:
        return self.c_omega if measure == "geometric" else self.c_proportional

    def _log_cover(self, eps: float) -> float:
        """Log of the Theorem 12 covering term, floored consistently for
        BOTH directions of the bound (see ``_COVER_RATIO_FLOOR``)."""
        ratio = 32.0 * self.radius * self.lipschitz / eps
        return 2.0 * self.dim * math.log(max(ratio, _COVER_RATIO_FLOOR))

    def _log_uniform_failure(self, num_features: int, eps: float,
                             measure: str) -> float:
        c = self._c(measure)
        return (math.log(2.0) + self._log_cover(eps)
                - num_features * eps**2 / (8.0 * c**2))

    def required_d(self, eps: float, delta: float, measure: str = "geometric") -> int:
        _require_positive("eps", eps)
        _require_delta(delta)
        c = self._c(measure)
        d_req = 8.0 * c**2 / eps**2 * (self._log_cover(eps) + math.log(2.0 / delta))
        d = max(int(math.ceil(d_req)), 1)
        # The ceil can land within float slop of the boundary (observed at
        # D ~ 1e15: failure prob = delta * (1 + 3e-13)); bump until the
        # round trip uniform_failure_prob(required_d(...)) <= delta holds
        # exactly rather than approximately.  The guard must exponentiate
        # the same way uniform_failure_prob does — comparing in log space
        # admits one-ulp regressions after exp().
        while math.exp(
                min(self._log_uniform_failure(d, eps, measure), 50.0)
        ) > delta:
            d = int(math.ceil(d * (1.0 + 1e-12))) + 1
        return d

    def eps_at(self, num_features: int, delta: float,
               measure: str = "geometric", *, tol: float = 1e-12) -> float:
        """Invert :meth:`required_d`: the smallest uniform error ``eps``
        Theorem 12 certifies at budget ``num_features``.

        ``required_d`` is strictly decreasing in eps (the Hoeffding
        exponent dominates the log-covering term), so the inverse is a
        bisection; the defining round-trip property — pinned by
        tests/test_bounds_roundtrip.py — is::

            required_d(eps, delta) <= D  =>  eps_at(D, delta) <= eps

        i.e. asking for the budget the bound demands always buys back an
        error guarantee at least as tight as requested.
        """
        if num_features <= 0:
            raise ValueError(f"num_features must be positive, "
                             f"got {num_features}")
        _require_delta(delta)

        def _ok(eps: float) -> bool:
            return self.required_d(eps, delta, measure) <= num_features

        lo, hi = tol, 1.0
        while not _ok(hi):            # error certs can exceed 1 at tiny D
            hi *= 2.0
            if hi > 1e12:
                raise ValueError(
                    f"no meaningful eps at D={num_features} "
                    f"(delta={delta}): bound exceeds 1e12")
        for _ in range(200):
            mid = 0.5 * (lo + hi)
            if _ok(mid):
                hi = mid
            else:
                lo = mid
            if hi - lo <= tol * max(1.0, hi):
                break
        return hi

    def pairwise_eps(self, num_features: int, n_pairs: int, delta: float,
                     measure: str = "geometric") -> float:
        """Hoeffding + union error bound over a FIXED set of ``n_pairs``
        pairs at budget D (no epsilon-net): the exact inversion of
        ``pointwise_failure_prob`` with ``delta / n_pairs`` per pair::

            eps(D, delta) = sqrt(8 C^2 log(2 n_pairs / delta) / D)

        This is the monitor-facing bound — ``obs.DriftMonitor`` watches
        specific sentinel pairs, not the whole domain, so it delegates
        here rather than to the Theorem 12 covering bound.
        """
        if num_features <= 0:
            raise ValueError(f"num_features must be positive, "
                             f"got {num_features}")
        _require_n_pairs(n_pairs)
        _require_delta(delta)
        c = self._c(measure)
        return math.sqrt(
            8.0 * c * c * math.log(2.0 * n_pairs / delta) / num_features)

    def required_features_for_pairs(self, eps: float, n_pairs: int,
                                    delta: float,
                                    measure: str = "geometric") -> int:
        """Inverse of :meth:`pairwise_eps`: D such that the fixed-pair
        union bound certifies error <= eps w.p. >= 1 - delta.

        The returned D is clamped to >= 1: for huge eps the raw formula
        rounds to 0, which is invalid downstream as a feature budget.
        """
        _require_positive("eps", eps)
        _require_n_pairs(n_pairs)
        _require_delta(delta)
        c = self._c(measure)
        return max(int(math.ceil(
            8.0 * c * c * math.log(2.0 * n_pairs / delta) / eps**2)), 1)


def constants_for(
    kernel: DotProductKernel, radius: float, dim: int, p: float = 2.0
) -> HoeffdingConstants:
    r2 = radius**2
    if np.isfinite(kernel.radius) and p * r2 >= kernel.radius:
        raise ValueError(
            f"p*R^2 = {p * r2:g} exceeds the series radius {kernel.radius:g} "
            f"of {kernel.name}; rescale the data (paper §3, choose c > I/gamma)."
        )
    f_pr2 = float(kernel.f(p * r2))
    fp_r2 = float(kernel.fprime(r2))
    fp_pr2 = float(kernel.fprime(p * r2))
    c_omega = p * f_pr2
    c_prop = float(kernel.f(r2))
    lipschitz = radius * fp_r2 + p**2 * radius * math.sqrt(dim) * fp_pr2
    return HoeffdingConstants(
        radius=radius,
        dim=dim,
        p=p,
        c_omega=c_omega,
        c_proportional=c_prop,
        lipschitz=lipschitz,
    )


def pairwise_eps(
    kernel: DotProductKernel, radius: float, dim: int, num_features: int,
    n_pairs: int, delta: float, p: float = 2.0,
    measure: str = "geometric",
) -> float:
    """Module-level convenience for ``constants_for(...).pairwise_eps``."""
    return constants_for(kernel, radius, dim, p).pairwise_eps(
        num_features, n_pairs, delta, measure)


def required_features_for_pairs(
    kernel: DotProductKernel, radius: float, dim: int, eps: float,
    n_pairs: int, delta: float, p: float = 2.0,
    measure: str = "geometric",
) -> int:
    """Module-level convenience for
    ``constants_for(...).required_features_for_pairs``."""
    return constants_for(kernel, radius, dim, p).required_features_for_pairs(
        eps, n_pairs, delta, measure)


def pointwise_failure_prob(
    consts: HoeffdingConstants, num_features: int, eps: float,
    measure: str = "geometric",
) -> float:
    """Hoeffding bound for a single pair (x, y)."""
    c = consts.c_omega if measure == "geometric" else consts.c_proportional
    return 2.0 * math.exp(-num_features * eps**2 / (8.0 * c**2))


def uniform_failure_prob(
    consts: HoeffdingConstants, num_features: int, eps: float,
    measure: str = "geometric",
) -> float:
    """Theorem 12's uniform bound over the whole domain (can exceed 1).

    Shares the covering-ratio floor with :meth:`HoeffdingConstants.required_d`
    (``_COVER_RATIO_FLOOR``), so the round trip
    ``uniform_failure_prob(consts, required_d(eps, delta), eps) <= delta``
    holds for every eps, including large eps where the ratio drops below 1.
    """
    log_p = consts._log_uniform_failure(num_features, eps, measure)
    return math.exp(min(log_p, 50.0))


def required_num_features(
    kernel: DotProductKernel,
    radius: float,
    dim: int,
    eps: float,
    delta: float,
    p: float = 2.0,
    measure: str = "geometric",
) -> int:
    """D such that Theorem 12 guarantees sup error <= eps w.p. >= 1 - delta."""
    return constants_for(kernel, radius, dim, p).required_d(eps, delta, measure)
