"""§4.2 — the alternative feature map via Maclaurin series truncation.

The paper: choose ``k = k(eps, R)`` such that the tail mass
``sum_{n>k} a_n R^{2n} <= eps_trunc`` and build feature maps for the truncated
kernel ``K~(x,y) = sum_{n<=k} a_n <x,y>^n``; those maps are
``(eps_trunc + eps_rf)``-accurate for K.

We realize the truncated map as a *stratified, proportional-measure*
``RMFeatureMap`` restricted to degrees ``<= k``: every allocated degree is
estimated with exact weight a_n (no degree-sampling variance) and the feature
budget D is split across degrees proportionally to their worst-case mass
``a_n R^{2n}`` — the allocation that equalizes per-degree contribution to the
uniform error bound.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np

from repro.core.feature_map import RMFeatureMap, make_feature_map
from repro.core.maclaurin import DotProductKernel

__all__ = ["truncation_degree", "make_truncated_feature_map"]


def truncation_degree(
    kernel: DotProductKernel,
    radius: float,
    eps_trunc: float,
    n_max: int = 64,
) -> Tuple[int, float]:
    """Smallest k with tail mass ``sum_{n>k} a_n R^{2n} <= eps_trunc``.

    Returns ``(k, achieved_tail_mass)``; raises if even n_max is not enough.
    """
    coefs = kernel.coefs(n_max)
    mass = coefs * (radius**2) ** np.arange(n_max + 1)
    total = kernel.f(radius**2)
    # tail after degree k = total - cumulative_{<=k}
    cum = np.cumsum(mass)
    tails = np.asarray(total - cum, dtype=np.float64)
    ok = np.nonzero(tails <= eps_trunc)[0]
    if len(ok) == 0:
        raise ValueError(
            f"kernel {kernel.name}: tail mass at n_max={n_max} is "
            f"{tails[-1]:.3e} > eps_trunc={eps_trunc:.3e}; increase n_max "
            "or rescale the data (paper §3: scale by c > I/gamma)."
        )
    k = int(ok[0])
    return k, float(max(tails[k], 0.0))


def make_truncated_feature_map(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    key: jax.Array,
    *,
    radius: float = 1.0,
    eps_trunc: float = 1e-4,
    n_max: int = 64,
    omega_dtype=None,
) -> RMFeatureMap:
    """Build the §4.2 truncated feature map for ``kernel``."""
    import jax.numpy as jnp

    k, _ = truncation_degree(kernel, radius, eps_trunc, n_max)
    kwargs = {}
    if omega_dtype is not None:
        kwargs["omega_dtype"] = omega_dtype
    return make_feature_map(
        kernel,
        input_dim,
        num_features,
        key,
        measure="proportional",
        stratified=True,
        n_max=max(k, 1),
        radius=radius,
        **kwargs,
    )
