"""The paper's contribution: Random Maclaurin feature maps for dot product
kernels (Kar & Karnick, AISTATS 2012), as composable JAX modules.

``repro.core.registry`` holds the pluggable estimator registry ("rm",
"tensor_sketch", ...); every entry shares the Taylor-coefficient degree
measure pipeline defined here."""
from repro.core import registry
from repro.core.maclaurin import (
    DotProductKernel,
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    MaclaurinKernel,
    PolynomialKernel,
    VovkInfiniteKernel,
    VovkRealKernel,
    kernel_from_name,
)
from repro.core.plan import (
    FeaturePlan,
    allocate_features,
    apply_plan,
    init_omegas,
    make_feature_plan,
    pack_omegas,
    plan_output_dim,
)
from repro.core.feature_map import RMFeatureMap, degree_measure, make_feature_map
from repro.core.truncated import make_truncated_feature_map, truncation_degree
from repro.core.compositional import (
    CompositionalFeatureMap,
    RademacherInnerMap,
    RFFInnerMap,
    make_compositional_feature_map,
)
from repro.core.bounds import (
    HoeffdingConstants,
    constants_for,
    pairwise_eps,
    pointwise_failure_prob,
    required_features_for_pairs,
    required_num_features,
    uniform_failure_prob,
)
from repro.core.doubling import GrowableFeatureMap, make_growable_feature_map
from repro.core.select import (
    BudgetDecision,
    CostModel,
    relative_to_additive_eps,
    select_budget,
)
from repro.core.linear_models import (
    Classifier,
    train_featurized_linear,
    train_kernel_ridge,
    train_kernel_svm,
    train_linear,
)

__all__ = [
    "registry",
    "FeaturePlan",
    "allocate_features",
    "apply_plan",
    "init_omegas",
    "make_feature_plan",
    "pack_omegas",
    "plan_output_dim",
    "train_featurized_linear",
    "DotProductKernel",
    "ExponentialDotProductKernel",
    "HomogeneousPolynomialKernel",
    "MaclaurinKernel",
    "PolynomialKernel",
    "VovkInfiniteKernel",
    "VovkRealKernel",
    "kernel_from_name",
    "RMFeatureMap",
    "degree_measure",
    "make_feature_map",
    "make_truncated_feature_map",
    "truncation_degree",
    "CompositionalFeatureMap",
    "RademacherInnerMap",
    "RFFInnerMap",
    "make_compositional_feature_map",
    "GrowableFeatureMap",
    "make_growable_feature_map",
    "BudgetDecision",
    "CostModel",
    "relative_to_additive_eps",
    "select_budget",
    "HoeffdingConstants",
    "constants_for",
    "pointwise_failure_prob",
    "required_num_features",
    "pairwise_eps",
    "required_features_for_pairs",
    "uniform_failure_prob",
    "Classifier",
    "train_kernel_ridge",
    "train_kernel_svm",
    "train_linear",
]
