"""Adaptive (eps, delta)-driven budget selection (ROADMAP open item 3).

Theorem 12 prices accuracy in features: ``required_d(eps, delta)`` is the
budget the bound demands.  The committed ``BENCH_core.json`` trajectory
prices features in seconds: every (estimator x precision) cell carries a
measured featurization throughput.  ``select_budget`` combines the two —
given (kernel, eps, delta, optional latency budget) it returns the
(estimator, D, precision) that certifies the accuracy target at the lowest
predicted latency.

The latency side is a ``CostModel`` fitted from bench rows: per
(estimator, precision) the measured features/second at each benched F,
linearly interpolated in log-F (clamped at the ends — throughput curves
are flat-ish in F, so the interpolation is a mild correction, not an
extrapolation engine).  The committed artifact is interpret-mode CPU until
ROADMAP item 1 lands real-hardware rows; the decision structure is
identical either way, only the numbers move.

Relative-error mode (Chen & Phillips, PAPERS.md): for small kernel values
an additive eps is the wrong target — ``relative=True`` converts a
relative target into the additive eps that guarantees it at the smallest
kernel magnitude on the data ball.

Run as a CLI: ``python -m repro.core.select --kernel exp --dim 64
--eps 0.1 --delta 0.05 --bench BENCH_core.json`` (the CI adaptive-smoke
job drives this against the committed artifact with ``--check-coverage``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bounds import constants_for
from repro.core.maclaurin import DotProductKernel

__all__ = ["CostModel", "BudgetDecision", "select_budget",
           "relative_to_additive_eps", "selection_section", "main"]

# The throughput column the cost model reads. ``fused_feats_per_s`` is the
# single-launch Pallas path — the one serving actually runs.
THROUGHPUT_KEY = "fused_feats_per_s"


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Featurization throughput per (estimator, precision), from bench rows.

    ``rows`` maps ``(estimator, precision)`` to a sorted tuple of
    ``(F, feats_per_s)`` measurements.
    """

    backend: str
    interpret: bool
    rows: Dict[Tuple[str, str], Tuple[Tuple[int, float], ...]]

    @classmethod
    def from_payload(cls, payload: Dict[str, Any],
                     throughput_key: str = THROUGHPUT_KEY) -> "CostModel":
        rows: Dict[Tuple[str, str], List[Tuple[int, float]]] = {}
        for shape in payload.get("results", {}).values():
            F = int(shape["F"])
            for cell_key, cell in shape.get("cells", {}).items():
                est, prec = cell_key.split("/", 1)
                tput = float(cell[throughput_key])
                if tput > 0.0:
                    rows.setdefault((est, prec), []).append((F, tput))
        return cls(
            backend=str(payload.get("backend", "unknown")),
            interpret=bool(payload.get("interpret", False)),
            rows={k: tuple(sorted(v)) for k, v in rows.items()},
        )

    @classmethod
    def from_file(cls, path, throughput_key: str = THROUGHPUT_KEY
                  ) -> "CostModel":
        with open(path) as f:
            return cls.from_payload(json.load(f), throughput_key)

    def covers(self, estimator: str, precision: str) -> bool:
        return (estimator, precision) in self.rows

    def missing_cells(self, estimators: Sequence[str],
                      precisions: Sequence[str]) -> List[str]:
        """Grid cells with no usable throughput row — the CI coverage gate."""
        return [f"{e}/{p}" for e in estimators for p in precisions
                if not self.covers(e, p)]

    def throughput(self, estimator: str, precision: str,
                   num_features: int) -> float:
        """Features/second at budget F: log-F linear interpolation over the
        benched points, clamped to the measured range at the ends."""
        pts = self.rows.get((estimator, precision))
        if not pts:
            raise KeyError(
                f"cost model has no rows for {estimator}/{precision} "
                f"(backend={self.backend}); benched cells: "
                f"{sorted('/'.join(k) for k in self.rows)}")
        fs = np.log([p[0] for p in pts])
        ts = np.asarray([p[1] for p in pts])
        return float(np.interp(math.log(max(num_features, 1)), fs, ts))

    def predict_latency_s(self, estimator: str, precision: str,
                          num_features: int, batch: int) -> float:
        """Time to featurize ``batch`` rows at budget ``num_features``."""
        return batch * num_features / self.throughput(
            estimator, precision, num_features)


def relative_to_additive_eps(kernel: DotProductKernel, radius: float,
                             eps_rel: float, grid: int = 512) -> float:
    """Additive eps guaranteeing relative error ``eps_rel`` on the ball.

    On ``B(0, R)`` the kernel value is ``f(t)`` for ``t in [-R^2, R^2]``;
    an additive error of ``eps_rel * min |f|`` is a relative error of at
    most ``eps_rel`` everywhere on the ball (Chen & Phillips' regime is
    exactly the one where this min is small and additive targets go
    blind).  Raises if the kernel crosses zero on the ball — no additive
    budget can certify a relative target there.
    """
    if not eps_rel > 0.0:
        raise ValueError(f"eps_rel must be > 0, got eps_rel={eps_rel!r}")
    r2 = radius * radius
    lo = -r2 if kernel.radius > r2 or not np.isfinite(kernel.radius) else -r2
    ts = np.linspace(lo, r2, grid)
    raw = np.asarray([float(kernel.f(t)) for t in ts])
    min_val = float(np.abs(raw).min())
    # a sign change between grid points means f hits zero somewhere on the
    # ball even if no sample lands exactly on it
    if min_val <= 0.0 or (raw.min() < 0.0 < raw.max()):
        raise ValueError(
            f"kernel {kernel.name} attains 0 on the radius-{radius} ball; "
            "a relative-error target is not certifiable by an additive "
            "bound there")
    return eps_rel * min_val


@dataclasses.dataclass(frozen=True)
class BudgetDecision:
    """The selection outcome plus the full candidate table behind it."""

    estimator: str
    precision: str
    num_features: int
    eps: float                          # the (additive) target
    delta: float
    measure: str
    eps_certified: float                # eps_at(num_features, delta)
    predicted_latency_s: Optional[float]
    latency_budget_s: Optional[float]
    meets_latency_budget: Optional[bool]
    kernel: str
    input_dim: int
    radius: float
    batch: int
    backend: Optional[str]
    candidates: Tuple[Dict[str, Any], ...]

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["candidates"] = list(d["candidates"])
        return d


def select_budget(
    kernel: DotProductKernel,
    input_dim: int,
    eps: float,
    delta: float,
    *,
    latency_budget_s: Optional[float] = None,
    estimator: Optional[str] = None,
    platform: Optional[str] = None,
    precision: Optional[str] = None,
    cost_model: Optional[CostModel] = None,
    bench_path: Optional[str] = None,
    measure: str = "proportional",
    radius: float = 1.0,
    p: float = 2.0,
    batch: int = 1024,
    relative: bool = False,
) -> BudgetDecision:
    """Pick (estimator, D, precision) certifying (eps, delta) at least cost.

    The accuracy side is exact: ``D = required_d(eps, delta)`` from the
    Theorem 12 constants, so ``eps_at(D, delta) <= eps`` by the round-trip
    property ``tests/test_bounds_roundtrip.py`` pins.  The latency side
    ranks candidates by the cost model's predicted featurization time;
    with no cost model (or for unbenched cells) selection falls back to
    the fixed preference order and reports ``predicted_latency_s=None``.

    ``latency_budget_s`` filters candidates by predicted latency.  When NO
    candidate fits, the fastest one is still returned with
    ``meets_latency_budget=False`` — accuracy is a guarantee, latency a
    preference; callers that want hard latency floors check the flag.

    ``relative=True`` reinterprets ``eps`` as a relative target (Chen &
    Phillips) and converts via :func:`relative_to_additive_eps`.

    ``platform`` is advisory: it is recorded and checked against the cost
    model's backend, a mismatch raises (a GPU decision priced from CPU
    interpret rows would be fiction).
    """
    from repro.core import registry

    if relative:
        eps = relative_to_additive_eps(kernel, radius, eps)
    if cost_model is None and bench_path is not None:
        cost_model = CostModel.from_file(bench_path)
    if (platform is not None and cost_model is not None
            and cost_model.backend not in (platform, "unknown")):
        raise ValueError(
            f"platform={platform!r} but the cost model was measured on "
            f"backend={cost_model.backend!r}; re-bench on the target "
            "platform or drop the platform pin")

    consts = constants_for(kernel, radius, input_dim, p)
    d_req = consts.required_d(eps, delta, measure)
    eps_certified = consts.eps_at(d_req, delta, measure)

    estimators = [estimator] if estimator else list(
        registry.list_estimators())
    precisions = [precision] if precision else ["fp32", "bf16"]

    candidates: List[Dict[str, Any]] = []
    for est in estimators:
        registry.get(est)  # raises with the available-name list
        for prec in precisions:
            cand: Dict[str, Any] = {
                "estimator": est, "precision": prec,
                "num_features": d_req,
                "predicted_latency_s": None,
                "meets_latency_budget": None,
            }
            if cost_model is not None and cost_model.covers(est, prec):
                lat = cost_model.predict_latency_s(est, prec, d_req, batch)
                cand["predicted_latency_s"] = lat
                if latency_budget_s is not None:
                    cand["meets_latency_budget"] = lat <= latency_budget_s
            candidates.append(cand)

    priced = [c for c in candidates
              if c["predicted_latency_s"] is not None]
    in_budget = [c for c in priced if c["meets_latency_budget"]]
    if in_budget:
        best = min(in_budget, key=lambda c: c["predicted_latency_s"])
    elif priced:
        best = min(priced, key=lambda c: c["predicted_latency_s"])
    else:
        best = candidates[0]  # no cost model: fixed preference order

    return BudgetDecision(
        estimator=best["estimator"],
        precision=best["precision"],
        num_features=d_req,
        eps=eps,
        delta=delta,
        measure=measure,
        eps_certified=eps_certified,
        predicted_latency_s=best["predicted_latency_s"],
        latency_budget_s=latency_budget_s,
        meets_latency_budget=best["meets_latency_budget"],
        kernel=kernel.name,
        input_dim=input_dim,
        radius=radius,
        batch=batch,
        backend=cost_model.backend if cost_model is not None else None,
        candidates=tuple(candidates),
    )


def selection_section(payload: Dict[str, Any],
                      targets: Optional[Sequence[Tuple[float, float]]] = None
                      ) -> Dict[str, Any]:
    """The ``selection`` section of a bench payload: the decision table
    ``select_budget`` produces for each benched shape at a small (eps,
    delta) target grid, priced from the payload's OWN rows.  Committed
    next to the timings, it makes every bench artifact double as a
    worked example of the adaptive-accuracy control loop."""
    from repro.bench.spec import make_kernel

    cost = CostModel.from_payload(payload)
    targets = list(targets or [(0.25, 0.05), (0.1, 0.01)])
    decisions: Dict[str, Any] = {}
    for shape_name, shape in payload.get("results", {}).items():
        kernel = make_kernel(shape["kernel"])
        per_shape = []
        for eps, delta in targets:
            dec = select_budget(
                kernel, int(shape["d"]), eps, delta,
                cost_model=cost, measure="proportional", radius=0.7,
                batch=int(shape["batch"]),
            )
            per_shape.append(dec.to_dict())
        decisions[shape_name] = per_shape
    return {
        "targets": [list(t) for t in targets],
        "measure": "proportional",
        "radius": 0.7,
        "decisions": decisions,
    }


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Selection CLI — the CI adaptive-smoke entry point."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.core.select",
        description="(eps, delta) -> (estimator, D, precision) via "
                    "Theorem 12 + the BENCH_core.json cost model")
    ap.add_argument("--kernel", default="exp",
                    help="exp | polyN (e.g. poly7)")
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--delta", type=float, default=0.05)
    ap.add_argument("--latency-budget", type=float, default=None,
                    help="seconds; filters candidates by predicted latency")
    ap.add_argument("--estimator", default=None)
    ap.add_argument("--precision", default=None)
    ap.add_argument("--measure", default="proportional")
    ap.add_argument("--radius", type=float, default=0.7)
    ap.add_argument("--batch", type=int, default=1024)
    ap.add_argument("--relative", action="store_true",
                    help="treat --eps as a relative target "
                         "(Chen & Phillips)")
    ap.add_argument("--bench", default="BENCH_core.json",
                    help="bench artifact to fit the cost model from")
    ap.add_argument("--check-coverage", action="store_true",
                    help="exit 1 if the cost model misses any "
                         "estimator x precision cell")
    args = ap.parse_args(argv)

    from repro.bench.spec import make_kernel
    from repro.core import registry

    cost = None
    if args.bench and Path(args.bench).exists():
        cost = CostModel.from_file(args.bench)
    elif args.check_coverage:
        print(f"selection: bench artifact {args.bench!r} not found")
        return 1

    if args.check_coverage:
        missing = cost.missing_cells(registry.list_estimators(),
                                     ["fp32", "bf16"])
        if missing:
            print(f"selection: cost model from {args.bench} is missing "
                  f"cells: {missing}")
            return 1
        print(f"selection: cost model covers the full "
              f"{len(registry.list_estimators())} x 2 grid "
              f"(backend={cost.backend}, interpret={cost.interpret})")

    decision = select_budget(
        make_kernel(args.kernel), args.dim, args.eps, args.delta,
        latency_budget_s=args.latency_budget, estimator=args.estimator,
        precision=args.precision, cost_model=cost, measure=args.measure,
        radius=args.radius, batch=args.batch, relative=args.relative,
    )
    print(json.dumps(decision.to_dict(), indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
