"""Estimator registry — pluggable random-feature estimators behind one name.

Every estimator family in the repo (Random Maclaurin, TensorSketch,
complex-to-real, future entries) is a set of five functions sharing one
protocol, keyed by name:

    make_plan(kernel, input_dim, num_features, *, p, measure, h01, n_max,
              radius, stratified, seed)        -> plan   (hashable, jit-static)
    init_params(plan, key, dtype=float32)      -> Dict[str, jax.Array]
    apply(plan, params, x, *, accum_dtype, use_pallas, interpret,
          precision)                           -> features
    output_dim(plan)                           -> int
    truncation_bias(plan, radius)              -> float

``precision`` (None | "fp32" | "bf16" | repro.common.dtypes.Precision) is
the feature-kernel mixed-precision policy: it fixes the dtype x and the
packed weight tensors enter the fused kernels in, while accumulation stays
fp32 in every family (bf16-in / fp32-accum — see repro.common.dtypes).

Consumers — ``make_feature_map``, RM attention (``models/attention.py`` /
``models/mla.py``), the serving engine, benchmarks — resolve
``registry.get(name)`` and never special-case on the estimator: the same
Taylor-coefficient degree measure drives either family, params are an opaque
pytree the consumer stores, and ``plan.output_dim`` fixes downstream shapes.

Built-in entries are registered lazily: ``get(name)`` calls that entry's
factory on first use, and each factory imports only its own family's
modules — ``repro.core`` never imports the sketch subsystem unless
"tensor_sketch" is actually requested.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "Estimator",
    "register",
    "get",
    "available",
    "list_estimators",
    "featurize_chunked",
    "estimate_gram",
]


@dataclasses.dataclass(frozen=True)
class Estimator:
    """One estimator family. ``make_map`` builds the convenience map object
    (``RMFeatureMap`` / ``SketchFeatureMap``) used by offline consumers.

    ``fused_attention_supported`` is the capability flag for the fused
    featurize+attention kernels (kernels/rm_attention/fused.py): families
    that can express their feature map as the packed masked-running-product
    layout set it True and provide ``pack_fused(plan, params) ->
    (w [max_degree, F, d], col_deg [F] np.int32, col_scale [F] np.float32)``
    — the attention/MLA/serving layers featurize inside the attention
    kernel's VMEM tiles. Families that can't (tensor_sketch's FFT
    convolution, ctr's complex pair) leave the default False and the model
    layers transparently fall back to the two-launch composition.
    """

    name: str
    make_plan: Callable[..., Any]
    init_params: Callable[..., Dict[str, jax.Array]]
    apply: Callable[..., jax.Array]
    make_map: Callable[..., Any]
    output_dim: Callable[[Any], int]
    truncation_bias: Callable[[Any, float], float]
    fused_attention_supported: bool = False
    pack_fused: Optional[Callable[..., Any]] = None


_REGISTRY: Dict[str, Estimator] = {}

# name -> factory building the entry on first get(); each factory imports
# only its own family's modules, so RM-only consumers never pay the sketch
# subsystem import (and vice versa).
_BUILTIN_FACTORIES: Dict[str, Callable[[], Estimator]] = {}


def register(entry: Estimator) -> Estimator:
    """Add (or replace) a registry entry under ``entry.name``.

    Args:
        entry: a fully-populated ``Estimator`` record.
    Returns:
        The same entry, so third-party families can register at import time
        with a decorator-ish one-liner.
    """
    _REGISTRY[entry.name] = entry
    return entry


def get(name: str) -> Estimator:
    """Resolve an estimator family by name (building lazily if builtin).

    Args:
        name: registry key — one of ``list_estimators()``.
    Returns:
        The ``Estimator`` record.
    Raises:
        KeyError: unknown name; the message carries the available names so
            consumer-side validation errors (e.g. the serving engine's
            constructor check) are self-explanatory.
    """
    if name not in _REGISTRY and name in _BUILTIN_FACTORIES:
        register(_BUILTIN_FACTORIES[name]())
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown estimator {name!r}; available: {available()}"
        )
    return _REGISTRY[name]


def list_estimators() -> Tuple[str, ...]:
    """Every registered estimator name (builtin factories included).

    The conformance suite (tests/test_estimator_conformance.py) and the
    sharded execution layer (repro.distributed.estimator) iterate this list:
    a new registry entry is automatically picked up by both — the conformance
    contract and the mesh path are part of the protocol, not per-family code.
    """
    return tuple(sorted(set(_REGISTRY) | set(_BUILTIN_FACTORIES)))


# back-compat alias (pre-PR-3 name); list_estimators is canonical
available = list_estimators


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------
def featurize_chunked(
    apply_fn: Callable[[jax.Array], jax.Array],
    X: jax.Array,
    row_chunk: int = 4096,
) -> jax.Array:
    """Apply a feature map over row chunks of ``X [N, d]``.

    Bounds the live intermediate (the fused launch's padded tiles / the flat
    projection) to ``row_chunk`` rows, so Gram estimation on 50k-point
    datasets never materializes an [N, total_rows] scratch. Chunk boundaries
    are static python slices — shapes stay jit-friendly.
    """
    X = jnp.asarray(X)
    n = X.shape[0]
    if n <= row_chunk:
        return apply_fn(X)
    parts = [apply_fn(X[i : i + row_chunk]) for i in range(0, n, row_chunk)]
    return jnp.concatenate(parts, axis=0)


def estimate_gram(
    apply_fn: Callable[[jax.Array], jax.Array],
    X: jax.Array,
    Y=None,
    row_chunk: int = 4096,
    axis_name: Optional[str] = None,
) -> jax.Array:
    """Kernel-matrix estimate ``Z(X) Z(Y)^T`` via chunked featurization.

    The shared body behind ``RMFeatureMap.estimate_gram``,
    ``SketchFeatureMap.estimate_gram`` and the sharded execution path
    (``repro.distributed.estimator``). The embedding makes the kernel
    LINEAR, so feature-sharded execution needs exactly one collective:
    when called inside a ``shard_map`` whose shards each hold a slice of
    the feature columns, pass ``axis_name`` and the partial Gram
    ``Z_s(X) Z_s(Y)^T`` is reduced with a single ``psum``.
    """
    zx = featurize_chunked(apply_fn, X, row_chunk=row_chunk)
    zy = zx if Y is None else featurize_chunked(apply_fn, Y,
                                                row_chunk=row_chunk)
    gram = zx @ zy.T
    if axis_name is not None:
        gram = jax.lax.psum(gram, axis_name)
    return gram


# ---------------------------------------------------------------------------
# built-in entries
# ---------------------------------------------------------------------------
def _plan_output_dim(plan) -> int:
    """Protocol ``output_dim``: real output columns of ``apply`` for this
    plan (every built-in plan type exposes it as a property)."""
    return plan.output_dim


def _plan_truncation_bias(plan, radius: float) -> float:
    """Protocol ``truncation_bias``: worst-case dropped-degree kernel mass
    ``sum a_n radius^{2n}`` over unallocated degrees (paper §4.2), including
    the ``BIAS_TAIL_DEGREES`` coefficient window beyond n_max."""
    return plan.truncation_bias(radius)


def _rm_init_params(plan, key, dtype=jnp.float32):
    """Protocol ``init_params`` for "rm": ``{"omegas": [total_rows, d]}``
    flat Rademacher draws (``core.plan.init_omegas``)."""
    from repro.core.plan import init_omegas

    return {"omegas": init_omegas(plan, key, dtype)}


def _rm_apply(plan, params, x, *, accum_dtype=jnp.float32, use_pallas=None,
              interpret=None, precision=None):
    """Protocol ``apply`` for "rm": ``x [..., d] -> [..., plan.output_dim]``
    through the fused ``core.plan.apply_plan`` path (one Pallas launch on
    TPU, flat matmul + segmented products off)."""
    from repro.core.plan import apply_plan

    return apply_plan(plan, params["omegas"], x, accum_dtype=accum_dtype,
                      use_pallas=use_pallas, interpret=interpret,
                      precision=precision)


def _ts_apply(plan, params, x, *, accum_dtype=jnp.float32, use_pallas=None,
              interpret=None, precision=None):
    """Protocol ``apply`` for "tensor_sketch": ``x [..., d] ->
    [..., plan.output_dim]`` via ``sketch.plan.apply_sketch_plan``.

    Like the RM path's per-scan-step pack_omegas, the frequency-domain
    pack re-runs per call here (hash tables are the stored params — exact
    in any dtype, where pre-packed cos/sin tensors would be degraded by
    the bf16 compute cast). Callers outside a layer scan can cache via
    apply_sketch_plan(packed=...); storing pre-packed params is the same
    remaining headroom DESIGN.md §3 notes for RM.
    """
    from repro.sketch.plan import apply_sketch_plan

    return apply_sketch_plan(plan, params, x, accum_dtype=accum_dtype,
                             use_pallas=use_pallas, interpret=interpret,
                             precision=precision)


def _ctr_apply(plan, params, x, *, accum_dtype=jnp.float32, use_pallas=None,
               interpret=None, precision=None):
    """Protocol ``apply`` for "ctr": ``x [..., d] ->
    [..., plan.output_dim]`` via ``ctr.plan.apply_ctr_plan`` (stacked
    real/imag halves of the complex products; pack_ctr re-runs per call —
    same caching note as the other families)."""
    from repro.ctr.plan import apply_ctr_plan

    return apply_ctr_plan(plan, params, x, accum_dtype=accum_dtype,
                          use_pallas=use_pallas, interpret=interpret,
                          precision=precision)


def _structured_apply(plan, params, x, *, accum_dtype=jnp.float32,
                      use_pallas=None, interpret=None, precision=None):
    """Protocol ``apply`` for "structured": ``x [..., d] ->
    [..., plan.output_dim]`` via ``structured.plan.apply_structured_plan``
    (butterfly-WHT Hadamard stacks; pack_structured re-runs per call —
    same caching note as the other families)."""
    from repro.structured.plan import apply_structured_plan

    return apply_structured_plan(plan, params, x, accum_dtype=accum_dtype,
                                 use_pallas=use_pallas, interpret=interpret,
                                 precision=precision)


def _rm_pack_fused(plan, params):
    """Protocol ``pack_fused`` for "rm": the packed ``[max_degree, F, d]``
    omega tensor plus the per-column degree/scale vectors (host numpy —
    they ride through the fused ops as jit-static tuples)."""
    from repro.core.plan import pack_omegas

    return (pack_omegas(plan, params["omegas"]), plan.column_degrees(),
            plan.column_scales())


def _make_rm_entry() -> Estimator:
    """Factory for the "rm" (Random Maclaurin, Kar & Karnick) entry."""
    from repro.core.feature_map import make_feature_map
    from repro.core.plan import make_feature_plan

    return Estimator(
        name="rm",
        make_plan=make_feature_plan,
        init_params=_rm_init_params,
        apply=_rm_apply,
        make_map=make_feature_map,
        output_dim=_plan_output_dim,
        truncation_bias=_plan_truncation_bias,
        fused_attention_supported=True,
        pack_fused=_rm_pack_fused,
    )


def _make_ts_entry() -> Estimator:
    """Factory for the "tensor_sketch" (Pham & Pagh) entry."""
    from repro.sketch.feature_map import make_sketch_feature_map
    from repro.sketch.plan import init_sketch_params, make_sketch_plan

    return Estimator(
        name="tensor_sketch",
        make_plan=make_sketch_plan,
        init_params=init_sketch_params,
        apply=_ts_apply,
        make_map=make_sketch_feature_map,
        output_dim=_plan_output_dim,
        truncation_bias=_plan_truncation_bias,
    )


def _make_ctr_entry() -> Estimator:
    """Factory for the "ctr" (complex-to-real, Wacker et al. 2022) entry."""
    from repro.ctr.feature_map import make_ctr_feature_map
    from repro.ctr.plan import init_ctr_params, make_ctr_plan

    return Estimator(
        name="ctr",
        make_plan=make_ctr_plan,
        init_params=init_ctr_params,
        apply=_ctr_apply,
        make_map=make_ctr_feature_map,
        output_dim=_plan_output_dim,
        truncation_bias=_plan_truncation_bias,
    )


def _make_structured_entry() -> Estimator:
    """Factory for the "structured" (Hadamard, Choromanski & Sindhwani
    2016) entry. ``fused_attention_supported`` stays False: the family's
    whole point is NOT materializing dense ``[max_degree, F, d]`` rows, so
    it has no ``pack_fused`` layout — the attention/MLA/serving layers
    take the two-launch composition."""
    from repro.structured.feature_map import make_structured_feature_map
    from repro.structured.plan import (
        init_structured_params,
        make_structured_plan,
    )

    return Estimator(
        name="structured",
        make_plan=make_structured_plan,
        init_params=init_structured_params,
        apply=_structured_apply,
        make_map=make_structured_feature_map,
        output_dim=_plan_output_dim,
        truncation_bias=_plan_truncation_bias,
    )


_BUILTIN_FACTORIES["rm"] = _make_rm_entry
_BUILTIN_FACTORIES["tensor_sketch"] = _make_ts_entry
_BUILTIN_FACTORIES["ctr"] = _make_ctr_entry
_BUILTIN_FACTORIES["structured"] = _make_structured_entry
