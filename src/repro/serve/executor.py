"""The step executor: compiled model functions + the batched decode cache.

Both serving frontends — the legacy bucketed :class:`~repro.serve.engine.
ServingEngine` and the continuous-batching :class:`~repro.serve.scheduler.
Scheduler` — drive the SAME compute object. The executor owns everything
that touches jax:

  * construction-time config validation (causal, estimator registry name,
    precision policy, fusion mode) so a bad config fails here with the
    valid names, not deep inside the first jitted prefill;
  * the prefill bucket ladder (``buckets=``, validated sorted/positive and
    clipped to ``max_len`` so every compiled shape is REACHABLE — a custom
    ``max_len`` below the largest default bucket no longer leaves dead
    entries in the ladder);
  * the batched decode cache (``num_slots`` lanes, spliced per admission)
    and its optional DP-mesh shardings;
  * the jitted prefill/decode calls themselves. Both are MODULE-LEVEL
    jitted functions with the (hashable, frozen) ``ModelConfig`` as a
    static argument, so compilations are shared across executor instances
    — the invariant suite builds hundreds of schedulers per run and pays
    for each (cfg, shape) exactly once per process.

The executor is observability-free: spans/events belong to the frontends,
pure jax belongs here.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import (
    _split_kind,
    decode_step,
    init_decode_cache,
    prefill,
)

__all__ = ["DEFAULT_BUCKETS", "StepExecutor", "effective_buckets"]

DEFAULT_BUCKETS = (32, 64, 128, 256, 512, 1024, 2048)


def effective_buckets(buckets: Sequence[int], max_len: int) -> Tuple[int, ...]:
    """Clip a bucket ladder to the lengths ``max_len`` can actually serve.

    Ladder entries >= ``max_len`` are unreachable (``submit`` rejects
    prompts of length >= ``max_len``), so the effective ladder is every
    bucket strictly below ``max_len`` plus ``max_len`` itself as the final
    rung — the number of compiled prefill shapes is exactly
    ``len(effective_buckets(...))`` in the worst case.
    """
    ladder = tuple(int(b) for b in buckets)
    if not ladder:
        raise ValueError("buckets must be a non-empty sequence of ints")
    if any(b <= 0 for b in ladder):
        raise ValueError(f"buckets must all be positive, got {ladder}")
    if any(b >= nxt for b, nxt in zip(ladder, ladder[1:])):
        raise ValueError(
            f"buckets must be strictly increasing, got {ladder}")
    return tuple(b for b in ladder if b < max_len) + (int(max_len),)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _decode_compiled(params, cfg: ModelConfig, cache, tokens, positions):
    return decode_step(params, cfg, cache, tokens, positions)


@functools.partial(jax.jit, static_argnames=("cfg", "max_len"))
def _prefill_compiled(params, cfg: ModelConfig, tokens, positions,
                      max_len: int):
    return prefill(params, cfg, {"tokens": tokens, "positions": positions},
                   max_len)


class StepExecutor:
    """Owns params, the batched decode cache and the compiled step fns.

    Args:
        cfg: frozen model config (validated here).
        params: model params pytree.
        num_slots: decode lanes in the batched cache.
        max_len: per-lane cache length; position ``max_len - 1`` is the
            scratch slot idle lanes park on.
        buckets: prefill bucket ladder (default :data:`DEFAULT_BUCKETS`);
            validated strictly-increasing/positive and clipped to
            ``max_len`` (see :func:`effective_buckets`).
        mesh: optional device mesh for DP decode (slot axis sharded,
            params replicated per the name-rule table, DESIGN.md §10).
    """

    def __init__(self, cfg: ModelConfig, params: Any, num_slots: int,
                 max_len: int, *, buckets: Optional[Sequence[int]] = None,
                 mesh: Any = None, feature_generations: int = 1):
        if not cfg.causal:
            raise ValueError("encoder-only models cannot be served "
                             "autoregressively")
        # Fail-early config validation: estimator registry name, precision
        # policy and fusion mode all raise here with the valid options.
        self.estimator: Optional[str] = None
        self.fused_attention = False
        feature_generations = int(feature_generations)
        if feature_generations < 1:
            raise ValueError(
                f"feature_generations must be >= 1, got "
                f"{feature_generations}")
        self.feature_generations = feature_generations
        self.generation_features: Optional[int] = None
        if cfg.attention_mode == "rm":
            from repro.common.dtypes import resolve_precision
            from repro.core import registry
            from repro.models.attention import rm_fuse_enabled

            self.estimator = registry.get(cfg.rm.estimator).name
            resolve_precision(cfg.rm.precision)
            self.fused_attention = rm_fuse_enabled(cfg)
            # Accuracy tiers (docs/adaptive.md): the feature budget splits
            # into fold_in-keyed generations; a tier certifies the prefix
            # of g generations.  The split must be exact so every tier's
            # budget is a whole number of generations.
            if cfg.rm.num_features % feature_generations != 0:
                raise ValueError(
                    f"cfg.rm.num_features={cfg.rm.num_features} must "
                    f"divide evenly into feature_generations="
                    f"{feature_generations} (per-tier budgets are whole "
                    "generations — see docs/adaptive.md)")
            self.generation_features = (cfg.rm.num_features
                                        // feature_generations)
        elif feature_generations != 1:
            raise ValueError(
                f"feature_generations={feature_generations} requires the "
                f"RM attention mode; {cfg.attention_mode!r} has no "
                "feature budget to tier")
        self.cfg = cfg
        self.params = params
        self.num_slots = int(num_slots)
        self.max_len = int(max_len)
        self.mesh = mesh
        self.buckets = effective_buckets(
            DEFAULT_BUCKETS if buckets is None else buckets, self.max_len)
        # Prompt-length bucketing applies to attention-family mixers only:
        # they tolerate right-padded prompts at sentinel positions (< 0).
        # SSM mixers carry recurrent state through every position and keep
        # exact lengths (one compile per distinct prompt length).
        mixers = {_split_kind(kind)[0] for kind in cfg.block_pattern}
        self.bucketed = mixers <= {"attn", "mla"}
        self._cache_shardings = None
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.distributed.sharding import (
                cache_partition_specs,
                params_partition_specs,
            )

            def _shardings(specs):
                return jax.tree_util.tree_map(
                    lambda sp: NamedSharding(mesh, sp), specs,
                    is_leaf=lambda sp: isinstance(sp, P))

            self.params = jax.device_put(
                params, _shardings(params_partition_specs(params, mesh)))
            probe = init_decode_cache(cfg, self.num_slots, self.max_len)
            self._cache_shardings = _shardings(
                cache_partition_specs(probe, mesh))
        self.cache = None
        self.reset_cache()

    # -- accuracy tiers -------------------------------------------------------
    def tier_features(self, generations: int) -> int:
        """Feature budget a tier of ``generations`` generations certifies.

        The RM budget splits into ``feature_generations`` equal fold_in-
        keyed blocks (the ``GrowableFeatureMap`` layout); a request at
        tier g is certified against the first ``g * generation_features``
        columns' (eps, delta) bound (docs/adaptive.md).
        """
        if self.generation_features is None:
            raise ValueError(
                "accuracy tiers require the RM attention mode "
                f"(attention_mode={self.cfg.attention_mode!r})")
        g = int(generations)
        if not 1 <= g <= self.feature_generations:
            raise ValueError(
                f"tier generations={generations} out of range [1, "
                f"{self.feature_generations}]")
        return g * self.generation_features

    # -- cache lifecycle ------------------------------------------------------
    @property
    def scratch_position(self) -> int:
        """The cache position idle lanes decode into (output discarded)."""
        return self.max_len - 1

    def reset_cache(self) -> None:
        """(Re)initialize the batched decode cache — fresh lanes, no state.

        The fault-recovery path calls this to respawn after a failed step:
        in-flight decode state is discarded and affected requests replay
        from their prompts (docs/serving.md, recovery contract).
        """
        self.cache = init_decode_cache(self.cfg, self.num_slots, self.max_len)
        if self._cache_shardings is not None:
            self.cache = jax.device_put(self.cache, self._cache_shardings)

    # -- prefill --------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest effective-ladder bucket holding an ``n``-token prompt."""
        if not self.bucketed:
            return int(n)
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(
            f"prompt length {n} exceeds the largest prefill bucket "
            f"({self.buckets[-1]} tokens); shorten the prompt or raise "
            "max_len / extend the bucket ladder")

    def prefill(self, prompt: np.ndarray) -> Tuple[jax.Array, Any, int]:
        """Run one request's prefill; return ``(logits, cache1, bucket)``.

        The prompt is right-padded to its bucket with tokens at sentinel
        position -1, so no real query attends to padding and no decode
        state accumulates it (pinned exactly by
        tests/test_serve_engine.py::test_bucketed_prefill_rm_state_matches_unpadded).
        ``logits`` is the full ``[1, bucket, V]`` array — callers sample
        from the last REAL position ``len(prompt) - 1``.
        """
        t = len(prompt)
        tb = self.bucket_for(t)
        tokens = np.zeros((1, tb), np.int32)
        tokens[0, :t] = np.asarray(prompt, np.int32)
        positions = np.full((1, tb), -1, np.int32)
        positions[0, :t] = np.arange(t, dtype=np.int32)
        logits, cache1 = _prefill_compiled(
            self.params, self.cfg, jnp.asarray(tokens),
            jnp.asarray(positions), self.max_len)
        return logits, cache1, tb

    def splice(self, slot: int, cache1: Any) -> None:
        """Write a request's (batch=1) prefill cache into lane ``slot``."""

        def _walk(big, small, path):
            if isinstance(big, dict):
                return {k: _walk(big[k], small[k], path + (k,))
                        for k in big}
            axis = 1 if "groups" in path else 0
            return jax.lax.dynamic_update_index_in_dim(
                big, jnp.take(small, 0, axis=axis).astype(big.dtype), slot,
                axis=axis,
            )

        self.cache = _walk(self.cache, cache1, ())
        if self._cache_shardings is not None:
            # keep the DP layout sticky: the host-level splice loses the
            # slot-axis sharding of the updated leaves
            self.cache = jax.device_put(self.cache, self._cache_shardings)

    # -- decode ---------------------------------------------------------------
    def decode(self, tokens: jax.Array, positions: jax.Array) -> jax.Array:
        """One batched decode step over ALL lanes; updates the cache.

        ``tokens`` is ``[num_slots, 1]`` int32, ``positions``
        ``[num_slots]`` int32 (idle lanes at :attr:`scratch_position`).
        Returns logits ``[num_slots, 1, V]``.
        """
        logits, self.cache = _decode_compiled(
            self.params, self.cfg, self.cache, tokens, positions)
        return logits
