"""Continuous-batching scheduler: per-step slot admission/eviction over the
shared :class:`~repro.serve.executor.StepExecutor`.

This is the serving frontend ROADMAP item 2 asks for — the O(1)-state
decode lanes the paper's feature maps buy us, driven by a scheduler whose
correctness contract is property-tested (tests/test_scheduler_invariants.py)
rather than assumed:

  * **per-step admission** — every :meth:`step` first admits queued
    requests into freed slots (prefill) while other slots keep decoding;
    no batch-synchronous barriers. Compiled shapes stay bounded: one
    decode shape per (num_slots, max_len) and one prefill shape per
    effective bucket.
  * **FIFO + priority queues with backpressure** — requests carry a
    ``priority`` (higher admits first; FIFO within a priority class via a
    monotone submission sequence number). A full engine NEVER drops work:
    requests wait in the queue until a slot frees (``cache_full`` is a
    per-request finish reason, not an admission failure).
  * **per-request deterministic sampling** — request ``r``'s ``t``-th
    token is sampled with ``fold_in(fold_in(key(seed), r), t)``, so every
    request's output is a pure function of ``(rng_seed, request)`` —
    independent of slot count, admission order, co-batched requests,
    evictions and restarts. This is the bit-identical-to-sequential-oracle
    invariant the test suite pins, and what makes the recovery contract
    below possible. (The legacy ``ServingEngine`` splits one engine-global
    key instead, so its temperature>0 streams depend on scheduling.)
  * **eviction + restart-from-scratch recovery** — :meth:`evict` preempts
    a slot and re-queues its request at its ORIGINAL queue position
    (sequence number preserved → no starvation); the request replays from
    its prompt and, by the key discipline above, regenerates the exact
    same tokens. A failed prefill/decode step (when ``max_restarts > 0``)
    triggers the same path for every in-flight slot plus a fresh decode
    cache — at-least-once token delivery with bit-identical replay
    (docs/serving.md).

Observability: the full request lifecycle (``request/submit`` →
``request/admit``/``admit`` span → ``prefill`` span → ``decode/step``
spans → ``request/finish``, plus ``request/evict``/``evict`` spans and
``serve/restart`` events), the ``serve/queue_age_s`` gauge (age of the
oldest queued request) and the TTFT / inter-token / tokens-per-sec
histograms, all on the injectable ``repro.obs`` clock — the whole
scheduler runs deterministically under ``FakeClock``.
"""
from __future__ import annotations

import dataclasses
import heapq
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import resolve as _obs_resolve
from repro.serve.engine import Request, RequestState
from repro.serve.executor import StepExecutor
from repro.serve.sampler import sample_token

__all__ = ["Scheduler", "StepInfo"]


@dataclasses.dataclass
class StepInfo:
    """What one scheduler tick did — the loadgen's accounting unit."""

    admitted: List[int] = dataclasses.field(default_factory=list)
    finished: List[int] = dataclasses.field(default_factory=list)
    evicted: List[int] = dataclasses.field(default_factory=list)
    active: int = 0                 # slots that ran the decode this tick
    new_tokens: int = 0             # tokens emitted (prefill + decode)
    restarted: bool = False         # a fault-recovery respawn happened
    t_start: float = 0.0
    t_end: float = 0.0


class Scheduler:
    """Continuous-batching serving scheduler (see module docstring).

    Args:
        cfg: frozen model config (validated by the executor).
        params: model params pytree.
        num_slots: decode lanes.
        max_len: per-lane cache length (scratch position is the last).
        rng_seed: base PRNG seed; request ``r``'s stream is
            ``fold_in(PRNGKey(rng_seed), r)``.
        buckets: prefill bucket ladder override (validated
            sorted/positive, clipped to ``max_len``).
        max_admits_per_step: cap on admissions (prefills) per tick —
            bounds per-step latency contributed by prefill work; ``None``
            admits into every free slot.
        max_restarts: fault-recovery budget. 0 (default) disables
            recovery: executor exceptions propagate. With N > 0, up to N
            failed steps re-queue all in-flight requests onto a fresh
            decode cache and continue; the N+1-th failure re-raises.
        straggler_monitor: optional ``repro.train.fault.StragglerMonitor``
            — decode-step wall times are ``record``-ed on it, reusing the
            training stack's straggler detection for serving.
        mesh: optional DP mesh (slot axis sharded; DESIGN.md §10).
        obs: optional ``repro.obs.Obs``; ``None`` is a strict no-op.
    """

    def __init__(self, cfg: Any, params: Any, *, num_slots: int = 4,
                 max_len: int = 1024, rng_seed: int = 0,
                 buckets: Optional[Sequence[int]] = None,
                 max_admits_per_step: Optional[int] = None,
                 max_restarts: int = 0, straggler_monitor: Any = None,
                 mesh: Any = None, obs: Any = None,
                 accuracy_tiers: Optional[Dict[str, int]] = None):
        self.obs = _obs_resolve(obs)
        # Per-request accuracy tiers (docs/adaptive.md): tier name ->
        # feature generation count. The executor splits the RM budget into
        # max(tiers) equal fold_in-keyed generations; a request at tier g
        # is certified against the g-generation feature prefix's (eps,
        # delta) bound. Validation (rm mode, even split, range) lives in
        # the executor so a bad tier map fails at construction.
        self.accuracy_tiers: Optional[Dict[str, int]] = None
        feature_generations = 1
        if accuracy_tiers:
            for name, gens in accuracy_tiers.items():
                if int(gens) < 1:
                    raise ValueError(
                        f"accuracy tier {name!r} must map to >= 1 "
                        f"generations, got {gens}")
            self.accuracy_tiers = {k: int(v)
                                   for k, v in accuracy_tiers.items()}
            feature_generations = max(self.accuracy_tiers.values())
        self.executor = StepExecutor(cfg, params, num_slots, max_len,
                                     buckets=buckets, mesh=mesh,
                                     feature_generations=feature_generations)
        self.estimator = self.executor.estimator
        self.fused_attention = self.executor.fused_attention
        self.cfg = cfg
        self.num_slots = int(num_slots)
        self.max_len = int(max_len)
        self.mesh = mesh
        self.max_admits_per_step = max_admits_per_step
        self.max_restarts = int(max_restarts)
        self.straggler_monitor = straggler_monitor
        self.restarts = 0
        self.slots: List[Optional[RequestState]] = [None] * self.num_slots
        self.finished: Dict[int, RequestState] = {}
        self._heap: List[Tuple[int, int, Request]] = []  # (-prio, seq, req)
        self._seq = 0
        self._seq_of: Dict[int, int] = {}
        self._t_submit: Dict[int, float] = {}
        self._attempts: Dict[int, int] = {}
        self._base_key = jax.random.PRNGKey(rng_seed)
        self._tokens = np.zeros((self.num_slots, 1), np.int32)
        self._positions = np.full((self.num_slots,),
                                  self.executor.scratch_position, np.int32)
        self._step_idx = 0

    # -- public API -----------------------------------------------------------
    @property
    def params(self):
        return self.executor.params

    @property
    def cache(self):
        return self.executor.cache

    @property
    def queue_depth(self) -> int:
        return len(self._heap)

    def pending(self) -> bool:
        """Any work left — queued or mid-decode?"""
        return bool(self._heap) or any(s is not None for s in self.slots)

    def submit(self, request: Request) -> None:
        """Enqueue a request (backpressure: never drops, never blocks).

        Request ids must be unique across the scheduler's lifetime — the
        per-request PRNG stream and the finished map are keyed on them.
        """
        rid = request.request_id
        if rid in self._seq_of or rid in self.finished or any(
                s is not None and s.request.request_id == rid
                for s in self.slots):
            raise ValueError(f"duplicate request_id {rid}: ids key the "
                             "per-request PRNG stream and result map")
        if len(request.prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(request.prompt)} exceeds engine "
                f"max_len {self.max_len}: the decode cache has no room "
                "for generated tokens; raise max_len or truncate")
        if request.accuracy_tier is not None:
            if not self.accuracy_tiers:
                raise ValueError(
                    f"request {rid} asks for accuracy_tier="
                    f"{request.accuracy_tier!r} but the scheduler was "
                    "built without accuracy_tiers=")
            if request.accuracy_tier not in self.accuracy_tiers:
                raise ValueError(
                    f"unknown accuracy_tier {request.accuracy_tier!r} "
                    f"for request {rid}; configured tiers: "
                    f"{sorted(self.accuracy_tiers)}")
        seq = self._seq
        self._seq += 1
        self._seq_of[rid] = seq
        self._t_submit[rid] = self.obs.now()
        heapq.heappush(self._heap, (-int(request.priority), seq, request))
        self.obs.event("request/submit", request_id=rid,
                       prompt_len=len(request.prompt),
                       priority=int(request.priority),
                       accuracy_tier=request.accuracy_tier)
        self.obs.counter("serve/requests_submitted")
        self.obs.gauge("serve/queue_depth", len(self._heap))

    def step(self) -> StepInfo:
        """One scheduler tick: admit into free slots, then decode the batch.

        Returns a :class:`StepInfo` describing what happened. With
        ``max_restarts > 0``, an executor failure inside the tick re-queues
        every in-flight request onto a fresh decode cache (restart-from-
        scratch recovery) instead of propagating, up to the budget.
        """
        self._step_idx += 1
        info = StepInfo(t_start=self.obs.now())
        try:
            self._admit_phase(info)
            self._decode_phase(info)
        except Exception as e:  # noqa: BLE001 - bounded restart semantics
            if self.restarts >= self.max_restarts:
                raise
            self.restarts += 1
            self._recover(info, repr(e))
        info.t_end = self.obs.now()
        return info

    def evict(self, slot: int, reason: str = "preempted") -> Request:
        """Preempt ``slot``: discard its decode state, re-queue its request.

        The request keeps its ORIGINAL submission sequence number, so it
        re-enters the queue at its old position (no starvation) and — by
        the per-request key discipline — will regenerate the exact same
        tokens from scratch on re-admission (the recovery contract,
        docs/serving.md).
        """
        state = self.slots[slot]
        if state is None:
            raise ValueError(f"slot {slot} is not occupied")
        req = state.request
        with self.obs.span("evict", request_id=req.request_id, slot=slot,
                           reason=reason):
            self.obs.event("request/evict", request_id=req.request_id,
                           slot=slot, reason=reason,
                           tokens_discarded=len(state.generated))
            self.obs.counter("serve/evictions")
            self.slots[slot] = None
            self._positions[slot] = self.executor.scratch_position
            self._requeue(req)
        return req

    def run(self, max_iters: int = 100_000) -> Dict[int, RequestState]:
        """Step until drained (or ``max_iters``) — same truncation contract
        as ``ServingEngine.run``: a cap expiry warns, bumps
        ``serve/truncated`` by the pending count, and leaves unfinished
        requests queued/in-flight for a later ``run()``/``step()``."""
        it = 0
        while self.pending() and it < max_iters:
            self.step()
            it += 1
        pendings = len(self._heap) + sum(s is not None for s in self.slots)
        if pendings:
            warnings.warn(
                f"Scheduler.run hit max_iters={max_iters} with "
                f"{pendings} request(s) still pending; returned results "
                "are truncated", RuntimeWarning, stacklevel=2)
            self.obs.counter("serve/truncated", pendings)
        return self.finished

    # -- internals ------------------------------------------------------------
    def _tier_features(self, req: Request) -> Optional[int]:
        """The feature budget certified for this request's tier (None when
        tiers are not in play)."""
        if req.accuracy_tier is None or not self.accuracy_tiers:
            return None
        return self.executor.tier_features(
            self.accuracy_tiers[req.accuracy_tier])

    def _request_key(self, rid: int, token_idx: int) -> jax.Array:
        return jax.random.fold_in(
            jax.random.fold_in(self._base_key, rid), token_idx)

    def _requeue(self, request: Request) -> None:
        rid = request.request_id
        heapq.heappush(self._heap,
                       (-int(request.priority), self._seq_of[rid], request))
        # queue-age accounting restarts from the requeue (the original
        # submit time still anchors TTFT via the state's t_enqueue)
        self._t_submit.setdefault(rid, self.obs.now())
        self.obs.gauge("serve/queue_depth", len(self._heap))

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit_phase(self, info: StepInfo) -> None:
        free = self._free_slots()
        budget = (len(free) if self.max_admits_per_step is None
                  else min(self.max_admits_per_step, len(free)))
        while free and self._heap and budget > 0:
            slot = free.pop(0)
            _, _, req = heapq.heappop(self._heap)
            budget -= 1
            try:
                finished_at_admit = self._admit_one(slot, req, info)
            except Exception:
                # a failed prefill must not lose the popped request: put it
                # back at its original queue position before the recovery
                # path (or the caller) sees the exception
                self._requeue(req)
                raise
            if finished_at_admit:
                # hand the lane back for the next queued request this
                # same admission pass (it never decoded)
                free.insert(0, slot)
        if self._heap:
            oldest = min(self._t_submit.get(r.request_id, info.t_start)
                         for _, _, r in self._heap)
            self.obs.gauge("serve/queue_age_s", self.obs.now() - oldest)
        else:
            self.obs.gauge("serve/queue_age_s", 0.0)
        self.obs.gauge("serve/slots_occupied",
                       sum(s is not None for s in self.slots))

    def _admit_one(self, slot: int, req: Request, info: StepInfo) -> bool:
        """Prefill ``req`` into ``slot``. Returns True if it finished at
        admission (EOS/max_new_tokens=1/cache-filling prompt) — the lane
        is then still free."""
        rid = req.request_id
        t = len(req.prompt)
        tb = self.executor.bucket_for(t)
        attempt = self._attempts.get(rid, 0) + 1
        self._attempts[rid] = attempt
        tier_features = self._tier_features(req)
        with self.obs.span("admit", request_id=rid, slot=slot, bucket=tb,
                           attempt=attempt):
            self.obs.event("request/admit", request_id=rid, slot=slot,
                           bucket=tb, attempt=attempt,
                           accuracy_tier=req.accuracy_tier,
                           tier_features=tier_features)
            with self.obs.span("prefill", request_id=rid, bucket=tb,
                               prompt_len=t):
                logits, cache1, _ = self.executor.prefill(req.prompt)
                self.executor.splice(slot, cache1)
        t_enqueue = self._t_submit.pop(rid, None)
        if t_enqueue is None:
            t_enqueue = self.obs.now()
        state = RequestState(request=req, slot=slot, position=t,
                             t_enqueue=t_enqueue, admissions=attempt,
                             tier_features=tier_features)
        info.admitted.append(rid)
        # first generated token from the LAST REAL prefill logit, sampled
        # on the request's own key stream (token index 0)
        tok = sample_token(logits[:, t - 1], self._request_key(rid, 0),
                           req.temperature)
        tok_i = int(tok[0])
        state.generated.append(tok_i)
        state.t_first_token = self.obs.now()
        state.t_tokens.append(state.t_first_token)
        info.new_tokens += 1
        self.obs.histogram("serve/ttft_s",
                           state.t_first_token - state.t_enqueue)
        self.obs.gauge("serve/queue_depth", len(self._heap))
        hit_eos = req.eos_token is not None and tok_i == req.eos_token
        if (hit_eos or len(state.generated) >= req.max_new_tokens
                or t >= self.max_len - 1):
            state.done = True
            state.t_done = self.obs.now()
            self._finish(state, "eos" if hit_eos else (
                "max_new_tokens"
                if len(state.generated) >= req.max_new_tokens
                else "cache_full"), info)
            return True
        self._tokens[slot, 0] = tok_i
        self._positions[slot] = t
        self.slots[slot] = state
        return False

    def _decode_phase(self, info: StepInfo) -> None:
        active = [s for s in self.slots if s is not None]
        info.active = len(active)
        if not active:
            return
        t_step = self.obs.now()
        with self.obs.span("decode/step", active=len(active)):
            logits = self.executor.decode(jnp.asarray(self._tokens),
                                          jnp.asarray(self._positions))
            for state in list(active):
                i = state.slot
                req = state.request
                tok_idx = len(state.generated)
                tok = int(sample_token(
                    logits[i:i + 1, 0],
                    self._request_key(req.request_id, tok_idx),
                    req.temperature)[0])
                state.generated.append(tok)
                t_tok = self.obs.now()
                self.obs.histogram("serve/inter_token_s",
                                   t_tok - state.t_tokens[-1])
                state.t_tokens.append(t_tok)
                state.position += 1
                info.new_tokens += 1
                self._tokens[i, 0] = tok
                self._positions[i] = state.position
                hit_eos = req.eos_token is not None and tok == req.eos_token
                if (len(state.generated) >= req.max_new_tokens or hit_eos
                        or state.position >= self.max_len - 1):
                    state.done = True
                    state.t_done = self.obs.now()
                    self._finish(state, "eos" if hit_eos else (
                        "max_new_tokens"
                        if len(state.generated) >= req.max_new_tokens
                        else "cache_full"), info)
                    self.slots[i] = None
                    self._positions[i] = self.executor.scratch_position
        dur = self.obs.now() - t_step
        if self.straggler_monitor is not None:
            self.straggler_monitor.record(self._step_idx, dur)
        self.obs.histogram("serve/token_latency_s", dur)
        self.obs.counter("serve/tokens_generated", len(active))
        self.obs.gauge("serve/slots_occupied",
                       sum(s is not None for s in self.slots))
        self.obs.tick_drift()

    def _recover(self, info: StepInfo, cause: str) -> None:
        """Respawn after a failed step: re-queue every in-flight request,
        reset the decode cache, continue. Requests replay from their
        prompts and regenerate identical tokens (per-request keys)."""
        requeued = []
        for i, state in enumerate(self.slots):
            if state is None:
                continue
            req = state.request
            requeued.append(req.request_id)
            info.evicted.append(req.request_id)
            self.slots[i] = None
            self._requeue(req)
            self.obs.event("request/evict", request_id=req.request_id,
                           slot=i, reason="restart",
                           tokens_discarded=len(state.generated))
        self.executor.reset_cache()
        self._tokens[:] = 0
        self._positions[:] = self.executor.scratch_position
        info.restarted = True
        self.obs.counter("serve/restarts")
        self.obs.event("serve/restart", cause=cause,
                       restart=self.restarts, requeued=requeued)
        self.obs.gauge("serve/slots_occupied", 0)

    def _finish(self, state: RequestState, reason: str,
                info: StepInfo) -> None:
        req = state.request
        state.finish_reason = reason
        self.finished[req.request_id] = state
        info.finished.append(req.request_id)
        n_tok = len(state.generated)
        self.obs.event("request/finish", request_id=req.request_id,
                       slot=state.slot, tokens=n_tok, reason=reason)
        wall = state.t_done - state.t_enqueue
        if wall > 0:
            self.obs.histogram("serve/tokens_per_s", n_tok / wall)
