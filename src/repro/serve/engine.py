"""Batched serving engine with bucketed prefill over fixed decode slots.

.. deprecated::
    ``ServingEngine`` is the LEGACY serving frontend. New code should use
    :class:`repro.serve.scheduler.Scheduler` — the continuous-batching
    scheduler with per-step admission/eviction, priority queues,
    per-request deterministic sampling and a property-tested invariant
    contract (tests/test_scheduler_invariants.py, docs/serving.md). The
    engine is kept for the engine-global PRNG discipline its regression
    tests pin and as the ``--scheduler bucketed`` fallback.

Design (vLLM-style, adapted to jax's static shapes):

  * the engine owns ``num_slots`` decode lanes; the decode step is ONE jitted
    call over all lanes every iteration (token + per-lane position);
  * finished/empty lanes decode into a scratch position of their cache
    (position pinned, output discarded) — no recompilation as requests churn;
  * admission: queued requests are prefills; each prefill runs (jitted,
    bucketed to power-of-two lengths to bound compile count) and its cache is
    spliced into the lane's slice of the batched cache;
  * RM/SSM archs have O(1)-size lane state, so splicing is a constant-cost
    scatter — the paper's technique removes the per-token KV growth entirely
    (DESIGN.md §2).

All jax-touching machinery (compiled prefill/decode, the bucket ladder,
cache splicing, mesh shardings) lives in
:class:`repro.serve.executor.StepExecutor`, shared with the continuous
scheduler; this module owns only queueing, sampling and observability.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.obs import resolve as _obs_resolve
from repro.serve.executor import DEFAULT_BUCKETS, StepExecutor
from repro.serve.sampler import sample_token


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                  # [T] int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: Optional[int] = None
    priority: int = 0                   # higher admits first (Scheduler only)
    accuracy_tier: Optional[str] = None  # per-request tier (Scheduler only):
    #   a key into the scheduler's accuracy_tiers map, resolved to a feature
    #   generation count (docs/adaptive.md) and certified on the request's
    #   admit event / RequestState.tier_features


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int
    generated: List[int] = dataclasses.field(default_factory=list)
    position: int = 0                   # next position to decode
    done: bool = False
    finish_reason: Optional[str] = None  # "eos"|"max_new_tokens"|"cache_full"
    t_enqueue: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None
    t_tokens: List[float] = dataclasses.field(default_factory=list)
    admissions: int = 0                 # times admitted (> 1 after eviction)
    tier_features: Optional[int] = None  # feature budget certified for this
    #   request's accuracy tier (None = full budget / tiers not configured)


def _bucket(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Legacy module-level bucket lookup (kept for its regression tests);
    engines resolve buckets through ``StepExecutor.bucket_for``, which
    additionally clips the ladder to ``max_len``."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest prefill bucket "
        f"({buckets[-1]} tokens); shorten the prompt or extend the "
        "bucket ladder in serve.engine._bucket")


class ServingEngine:
    def __init__(
        self,
        cfg: Any,
        params: Any,
        num_slots: int = 4,
        max_len: int = 1024,
        rng_seed: int = 0,
        mesh: Any = None,
        obs: Any = None,
        buckets: Optional[Sequence[int]] = None,
    ):
        # Observability is strictly opt-in: obs=None resolves to the shared
        # no-op sink (one attribute read + pass-through per hook), so the
        # decode loop stays bit-identical with instrumentation disabled
        # (tests/test_serve_obs.py pins this).
        self.obs = _obs_resolve(obs)
        # The executor validates the config up front (causal, estimator
        # registry name, precision policy, fusion mode) and owns the
        # compiled prefill/decode calls, the bucket ladder (``buckets=``,
        # validated sorted/positive and clipped to max_len) and the
        # batched decode cache + mesh shardings.
        self.executor = StepExecutor(cfg, params, num_slots, max_len,
                                     buckets=buckets, mesh=mesh)
        self.estimator = self.executor.estimator
        self.fused_attention = self.executor.fused_attention
        self.cfg = cfg
        self.num_slots = num_slots
        self.max_len = max_len
        self.mesh = mesh
        self.slots: List[Optional[RequestState]] = [None] * num_slots
        self.queue: List[Request] = []
        self.finished: Dict[int, RequestState] = {}
        self._t_submit: Dict[int, float] = {}
        self._key = jax.random.PRNGKey(rng_seed)
        self._tokens = np.zeros((num_slots, 1), np.int32)
        self._positions = np.zeros((num_slots,), np.int32)

    # Back-compat views onto executor-owned state (dist tests poke these).
    @property
    def params(self):
        return self.executor.params

    @property
    def cache(self):
        return self.executor.cache

    # -- public API -----------------------------------------------------------
    def submit(self, request: Request) -> None:
        if len(request.prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(request.prompt)} exceeds engine "
                f"max_len {self.max_len}: the decode cache has no room "
                "for generated tokens; raise max_len or truncate")
        self._t_submit[request.request_id] = self.obs.now()
        self.queue.append(request)
        self.obs.event("request/submit", request_id=request.request_id,
                       prompt_len=len(request.prompt))
        self.obs.counter("serve/requests_submitted")
        self.obs.gauge("serve/queue_depth", len(self.queue))

    def run(self, max_iters: int = 10_000) -> Dict[int, RequestState]:
        """Drive admission + decode until drained (or ``max_iters``).

        Returns the finished-request map. If ``max_iters`` expires with
        requests still queued or mid-decode, the run is TRUNCATED: those
        requests stay in ``self.queue`` / ``self.slots`` (no entry in the
        returned map), a ``RuntimeWarning`` is emitted, and the
        ``serve/truncated`` counter records how many were left behind —
        callers distinguishing a drained run from a truncated one check
        either signal (docs/serving.md).
        """
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self._admit()
            self._decode_iteration()
            it += 1
        pending = len(self.queue) + sum(s is not None for s in self.slots)
        if pending:
            warnings.warn(
                f"ServingEngine.run hit max_iters={max_iters} with "
                f"{pending} request(s) still pending; returned results "
                "are truncated", RuntimeWarning, stacklevel=2)
            self.obs.counter("serve/truncated", pending)
        return self.finished

    # -- internals --------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self) -> None:
        free = self._free_slots()
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            t = len(req.prompt)
            tb = self.executor.bucket_for(t)
            self.obs.event("request/admit", request_id=req.request_id,
                           slot=slot, bucket=tb)
            with self.obs.span("prefill", request_id=req.request_id,
                               bucket=tb, prompt_len=t):
                logits, cache1, _ = self.executor.prefill(req.prompt)
                self.executor.splice(slot, cache1)
            t_enqueue = self._t_submit.pop(req.request_id, None)
            if t_enqueue is None:
                t_enqueue = self.obs.now()
            state = RequestState(request=req, slot=slot, position=t,
                                 t_enqueue=t_enqueue, admissions=1)
            # first generated token from the LAST REAL prefill logit
            self._key, sub = jax.random.split(self._key)
            tok = sample_token(logits[:, t - 1], sub, req.temperature)
            tok_i = int(tok[0])
            state.generated.append(tok_i)
            state.t_first_token = self.obs.now()
            state.t_tokens.append(state.t_first_token)
            self.obs.histogram("serve/ttft_s",
                               state.t_first_token - state.t_enqueue)
            self.obs.gauge("serve/queue_depth", len(self.queue))
            # the prefill-sampled token can already terminate the request
            # (EOS, max_new_tokens=1, or a prompt that fills the cache):
            # finish WITHOUT occupying the decode lane, and hand the slot
            # back for the next queued request this same admission pass.
            hit_eos = req.eos_token is not None and tok_i == req.eos_token
            if (hit_eos or len(state.generated) >= req.max_new_tokens
                    or t >= self.max_len - 1):
                state.done = True
                state.t_done = self.obs.now()
                self._finish(state, "eos" if hit_eos else (
                    "max_new_tokens"
                    if len(state.generated) >= req.max_new_tokens
                    else "cache_full"))
                free.insert(0, slot)
                continue
            self._tokens[slot, 0] = tok_i
            self._positions[slot] = t
            self.slots[slot] = state
        self.obs.gauge("serve/slots_occupied",
                       sum(s is not None for s in self.slots))
        # park empty lanes on a scratch position
        for i, s in enumerate(self.slots):
            if s is None:
                self._positions[i] = self.executor.scratch_position

    def _decode_iteration(self) -> None:
        import jax.numpy as jnp

        active = [s for s in self.slots if s is not None]
        if not active:
            return
        t_step = self.obs.now()
        with self.obs.span("decode/step", active=len(active)):
            logits = self.executor.decode(jnp.asarray(self._tokens),
                                          jnp.asarray(self._positions))
            self._key, sub = jax.random.split(self._key)
            # per-slot temperature: scale each lane's logits by its
            # request's temperature, then ONE batched categorical; greedy
            # (temperature <= 0) lanes take the argmax instead. Division
            # by the 1.0 placeholder is exact, so all-default batches are
            # bit-identical to an unscaled sample.
            temps = np.ones((len(self.slots),), np.float32)
            for state in active:
                if state.request.temperature > 0:
                    temps[state.slot] = state.request.temperature
            greedy = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            sampled = sample_token(
                logits[:, 0] / jnp.asarray(temps)[:, None], sub,
                temperature=1.0)
            for state in list(active):
                i = state.slot
                req = state.request
                tok = int(sampled[i] if req.temperature > 0 else greedy[i])
                state.generated.append(tok)
                state.t_tokens.append(self.obs.now())
                state.position += 1
                self._tokens[i, 0] = tok
                self._positions[i] = state.position
                hit_eos = req.eos_token is not None and tok == req.eos_token
                if (len(state.generated) >= req.max_new_tokens or hit_eos
                        or state.position >= self.max_len - 1):
                    state.done = True
                    state.t_done = self.obs.now()
                    self._finish(state, "eos" if hit_eos else (
                        "max_new_tokens"
                        if len(state.generated) >= req.max_new_tokens
                        else "cache_full"))
                    self.slots[i] = None
        # the step latency amortizes over every lane that got a token, so
        # the histogram reads as per-token decode latency
        self.obs.histogram("serve/token_latency_s",
                           self.obs.now() - t_step)
        self.obs.counter("serve/tokens_generated", len(active))
        self.obs.gauge("serve/slots_occupied",
                       sum(s is not None for s in self.slots))
        self.obs.tick_drift()

    def _finish(self, state: RequestState, reason: str) -> None:
        """Record a finished request. ``reason`` is the ACTUAL stopping
        condition threaded from the caller — "eos" | "max_new_tokens" |
        "cache_full" — not inferred from the last token, so a length-
        stopped request whose final token coincides with EOS, or a cache
        exhaustion, are labeled truthfully."""
        req = state.request
        state.finish_reason = reason
        self.finished[req.request_id] = state
        n_tok = len(state.generated)
        self.obs.event("request/finish", request_id=req.request_id,
                       slot=state.slot, tokens=n_tok, reason=reason)
        wall = state.t_done - state.t_enqueue
        if wall > 0:
            self.obs.histogram("serve/tokens_per_s", n_tok / wall)
