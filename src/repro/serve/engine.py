"""Batched serving engine with continuous batching over fixed decode slots.

Design (vLLM-style, adapted to jax's static shapes):

  * the engine owns ``num_slots`` decode lanes; the decode step is ONE jitted
    call over all lanes every iteration (token + per-lane position);
  * finished/empty lanes decode into a scratch position of their cache
    (position pinned, output discarded) — no recompilation as requests churn;
  * admission: queued requests are prefills; each prefill runs (jitted,
    bucketed to power-of-two lengths to bound compile count) and its cache is
    spliced into the lane's slice of the batched cache;
  * RM/SSM archs have O(1)-size lane state, so splicing is a constant-cost
    scatter — the paper's technique removes the per-token KV growth entirely
    (DESIGN.md §2).

This engine is CPU-runnable (examples/serve_lm.py) and mesh-compatible: all
state updates are pure jax ops on pytrees that can carry shardings.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import (
    _split_kind,
    decode_step,
    init_decode_cache,
    prefill,
)
from repro.obs import resolve as _obs_resolve
from repro.serve.sampler import sample_token


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray                  # [T] int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_token: Optional[int] = None


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int
    generated: List[int] = dataclasses.field(default_factory=list)
    position: int = 0                   # next position to decode
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest prefill bucket "
        f"({buckets[-1]} tokens); shorten the prompt or extend the "
        "bucket ladder in serve.engine._bucket")


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        num_slots: int = 4,
        max_len: int = 1024,
        rng_seed: int = 0,
        mesh: Any = None,
        obs: Any = None,
    ):
        # Observability is strictly opt-in: obs=None resolves to the shared
        # no-op sink (one attribute read + pass-through per hook), so the
        # decode loop stays bit-identical with instrumentation disabled
        # (tests/test_serve_obs.py pins this).
        self.obs = _obs_resolve(obs)
        if not cfg.causal:
            raise ValueError("encoder-only models cannot be served "
                             "autoregressively")
        # Resolve the feature-estimator entry up front: a bad estimator name
        # should fail at engine construction with the registry's name list,
        # not deep inside the first jitted prefill. RM/sketch lane state is
        # O(1) either way (plan.output_dim fixes the state shapes).
        self.estimator = None
        self.fused_attention = False
        if cfg.attention_mode == "rm":
            from repro.common.dtypes import resolve_precision
            from repro.core import registry
            from repro.models.attention import rm_fuse_enabled

            self.estimator = registry.get(cfg.rm.estimator).name
            # Same fail-early rule for the feature-kernel precision policy:
            # a typo'd cfg.rm.precision raises here with the valid names.
            resolve_precision(cfg.rm.precision)
            # ... and for the fusion mode: rm_fuse_enabled validates
            # cfg.rm.fuse_featurize and resolves the estimator capability
            # flag. When True, prefill emits outputs + decode state from ONE
            # fused launch and each decode step runs ONE featurize launch
            # for q and k together (docs/serving.md).
            self.fused_attention = rm_fuse_enabled(cfg)
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.mesh = mesh
        self.cache = init_decode_cache(cfg, num_slots, max_len)
        if mesh is not None:
            # Data-parallel decode: the slot axis of the cache shards over
            # the DP mesh axes and the params — the frozen ``rm_est``
            # estimator subtree included — replicate per the name-rule table
            # (DESIGN.md §10). Decode inputs are committed by jit against
            # these placements every iteration; slot counts that don't
            # divide the DP axes fall back to replicated via _dedupe_spec.
            from jax.sharding import NamedSharding, PartitionSpec as P

            from repro.distributed.sharding import (
                cache_partition_specs,
                params_partition_specs,
            )

            def _shardings(specs):
                return jax.tree_util.tree_map(
                    lambda sp: NamedSharding(mesh, sp), specs,
                    is_leaf=lambda sp: isinstance(sp, P))

            self.params = jax.device_put(
                params, _shardings(params_partition_specs(params, mesh)))
            self._cache_shardings = _shardings(
                cache_partition_specs(self.cache, mesh))
            self.cache = jax.device_put(self.cache, self._cache_shardings)
        self.slots: List[Optional[RequestState]] = [None] * num_slots
        self.queue: List[Request] = []
        self.finished: Dict[int, RequestState] = {}
        self._t_submit: Dict[int, float] = {}
        self._key = jax.random.PRNGKey(rng_seed)
        self._tokens = jnp.zeros((num_slots, 1), jnp.int32)
        self._positions = jnp.zeros((num_slots,), jnp.int32)

        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos)
        )
        self._prefill_cache: Dict[int, Callable] = {}
        # Prompt-length bucketing (DESIGN.md §2): attention-family mixers
        # tolerate right-padded prompts at sentinel positions (< 0) — the
        # causal mask plus rm-state masking keep real outputs exact, so
        # prefill compiles are bounded per bucket instead of per distinct
        # prompt length. SSM mixers carry recurrent state through every
        # position and would need per-step freezing; they keep exact lengths.
        mixers = {_split_kind(kind)[0] for kind in cfg.block_pattern}
        self._bucketed = mixers <= {"attn", "mla"}

    # -- public API -----------------------------------------------------------
    def submit(self, request: Request) -> None:
        if len(request.prompt) >= self.max_len:
            raise ValueError(
                f"prompt length {len(request.prompt)} exceeds engine "
                f"max_len {self.max_len}: the decode cache has no room "
                "for generated tokens; raise max_len or truncate")
        self._t_submit[request.request_id] = self.obs.now()
        self.queue.append(request)
        self.obs.event("request/submit", request_id=request.request_id,
                       prompt_len=len(request.prompt))
        self.obs.counter("serve/requests_submitted")
        self.obs.gauge("serve/queue_depth", len(self.queue))

    def run(self, max_iters: int = 10_000) -> Dict[int, RequestState]:
        """Drive admission + decode until drained (or ``max_iters``).

        Returns the finished-request map. If ``max_iters`` expires with
        requests still queued or mid-decode, the run is TRUNCATED: those
        requests stay in ``self.queue`` / ``self.slots`` (no entry in the
        returned map), a ``RuntimeWarning`` is emitted, and the
        ``serve/truncated`` counter records how many were left behind —
        callers distinguishing a drained run from a truncated one check
        either signal (docs/serving.md).
        """
        it = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and it < max_iters:
            self._admit()
            self._decode_iteration()
            it += 1
        pending = len(self.queue) + sum(s is not None for s in self.slots)
        if pending:
            warnings.warn(
                f"ServingEngine.run hit max_iters={max_iters} with "
                f"{pending} request(s) still pending; returned results "
                "are truncated", RuntimeWarning, stacklevel=2)
            self.obs.counter("serve/truncated", pending)
        return self.finished

    # -- internals --------------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _prefill_fn(self, length: int):
        if length not in self._prefill_cache:
            cfg = self.cfg

            def fn(params, tokens, positions):
                batch = {"tokens": tokens, "positions": positions}
                return prefill(params, cfg, batch, self.max_len)

            self._prefill_cache[length] = jax.jit(fn)
        return self._prefill_cache[length]

    def _admit(self) -> None:
        free = self._free_slots()
        while free and self.queue:
            slot = free.pop(0)
            req = self.queue.pop(0)
            t = len(req.prompt)
            # right-pad to the bucketed length: one compile per bucket, not
            # per distinct prompt length. Padding tokens sit at sentinel
            # position -1 so no real query attends to them and no state
            # accumulates them.
            tb = min(_bucket(t), self.max_len) if self._bucketed else t
            self.obs.event("request/admit", request_id=req.request_id,
                           slot=slot, bucket=tb)
            with self.obs.span("prefill", request_id=req.request_id,
                               bucket=tb, prompt_len=t):
                tokens = np.zeros((1, tb), np.int32)
                tokens[0, :t] = np.asarray(req.prompt, np.int32)
                positions = np.full((1, tb), -1, np.int32)
                positions[0, :t] = np.arange(t, dtype=np.int32)
                logits, cache1 = self._prefill_fn(tb)(
                    self.params, jnp.asarray(tokens), jnp.asarray(positions)
                )
                self._splice_cache(slot, cache1)
            t_enqueue = self._t_submit.pop(req.request_id, None)
            if t_enqueue is None:
                t_enqueue = self.obs.now()
            state = RequestState(request=req, slot=slot, position=t,
                                 t_enqueue=t_enqueue)
            # first generated token from the LAST REAL prefill logit
            self._key, sub = jax.random.split(self._key)
            tok = sample_token(logits[:, t - 1], sub, req.temperature)
            tok_i = int(tok[0])
            state.generated.append(tok_i)
            state.t_first_token = self.obs.now()
            self.obs.histogram("serve/ttft_s",
                               state.t_first_token - state.t_enqueue)
            self.obs.gauge("serve/queue_depth", len(self.queue))
            # the prefill-sampled token can already terminate the request
            # (EOS, max_new_tokens=1, or a prompt that fills the cache):
            # finish WITHOUT occupying the decode lane, and hand the slot
            # back for the next queued request this same admission pass.
            hit_eos = req.eos_token is not None and tok_i == req.eos_token
            if (hit_eos or len(state.generated) >= req.max_new_tokens
                    or t >= self.max_len - 1):
                state.done = True
                state.t_done = self.obs.now()
                self._finish(state, "eos" if hit_eos else (
                    "max_new_tokens"
                    if len(state.generated) >= req.max_new_tokens
                    else "cache_full"))
                free.insert(0, slot)
                continue
            self._tokens = self._tokens.at[slot, 0].set(tok[0])
            self._positions = self._positions.at[slot].set(t)
            self.slots[slot] = state
        self.obs.gauge("serve/slots_occupied",
                       sum(s is not None for s in self.slots))
        # park empty lanes on a scratch position
        for i, s in enumerate(self.slots):
            if s is None:
                self._positions = self._positions.at[i].set(self.max_len - 1)

    def _splice_cache(self, slot: int, cache1: Any) -> None:
        """Write a request's (batch=1) cache into lane ``slot``."""

        # structural walk (dict trees with matching structure)
        def _walk(big, small, path):
            if isinstance(big, dict):
                return {k: _walk(big[k], small[k], path + (k,))
                        for k in big}
            axis = 1 if "groups" in path else 0
            return jax.lax.dynamic_update_index_in_dim(
                big, jnp.take(small, 0, axis=axis).astype(big.dtype), slot,
                axis=axis,
            )

        self.cache = _walk(self.cache, cache1, ())
        if self.mesh is not None:
            # keep the DP layout sticky: the host-level splice above loses
            # the slot-axis sharding of the updated leaves
            self.cache = jax.device_put(self.cache, self._cache_shardings)

    def _decode_iteration(self) -> None:
        active = [s for s in self.slots if s is not None]
        if not active:
            return
        t_step = self.obs.now()
        with self.obs.span("decode/step", active=len(active)):
            logits, self.cache = self._decode(
                self.params, self.cache, self._tokens, self._positions
            )
            self._key, sub = jax.random.split(self._key)
            # per-slot temperature: scale each lane's logits by its
            # request's temperature, then ONE batched categorical; greedy
            # (temperature <= 0) lanes take the argmax instead. Division
            # by the 1.0 placeholder is exact, so all-default batches are
            # bit-identical to an unscaled sample.
            temps = np.ones((len(self.slots),), np.float32)
            for state in active:
                if state.request.temperature > 0:
                    temps[state.slot] = state.request.temperature
            greedy = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            sampled = sample_token(
                logits[:, 0] / jnp.asarray(temps)[:, None], sub,
                temperature=1.0)
            for state in list(active):
                i = state.slot
                req = state.request
                tok = int(sampled[i] if req.temperature > 0 else greedy[i])
                state.generated.append(tok)
                state.position += 1
                self._tokens = self._tokens.at[i, 0].set(tok)
                self._positions = self._positions.at[i].set(state.position)
                hit_eos = req.eos_token is not None and tok == req.eos_token
                if (len(state.generated) >= req.max_new_tokens or hit_eos
                        or state.position >= self.max_len - 1):
                    state.done = True
                    state.t_done = self.obs.now()
                    self._finish(state, "eos" if hit_eos else (
                        "max_new_tokens"
                        if len(state.generated) >= req.max_new_tokens
                        else "cache_full"))
                    self.slots[i] = None
        # the step latency amortizes over every lane that got a token, so
        # the histogram reads as per-token decode latency
        self.obs.histogram("serve/token_latency_s",
                           self.obs.now() - t_step)
        self.obs.counter("serve/tokens_generated", len(active))
        self.obs.gauge("serve/slots_occupied",
                       sum(s is not None for s in self.slots))
        self.obs.tick_drift()

    def _finish(self, state: RequestState, reason: str) -> None:
        """Record a finished request. ``reason`` is the ACTUAL stopping
        condition threaded from the caller — "eos" | "max_new_tokens" |
        "cache_full" — not inferred from the last token, so a length-
        stopped request whose final token coincides with EOS, or a cache
        exhaustion, are labeled truthfully."""
        req = state.request
        self.finished[req.request_id] = state
        n_tok = len(state.generated)
        self.obs.event("request/finish", request_id=req.request_id,
                       tokens=n_tok, reason=reason)
        wall = state.t_done - state.t_enqueue
        if wall > 0:
            self.obs.histogram("serve/tokens_per_s", n_tok / wall)
