from repro.serve.engine import ServingEngine, Request, RequestState
from repro.serve.sampler import sample_token

__all__ = ["ServingEngine", "Request", "RequestState", "sample_token"]
