from repro.serve.engine import ServingEngine, Request, RequestState
from repro.serve.executor import DEFAULT_BUCKETS, StepExecutor, effective_buckets
from repro.serve.sampler import sample_token
from repro.serve.scheduler import Scheduler, StepInfo

__all__ = [
    "DEFAULT_BUCKETS",
    "Request",
    "RequestState",
    "Scheduler",
    "ServingEngine",
    "StepExecutor",
    "StepInfo",
    "effective_buckets",
    "sample_token",
]
