"""Common layers: norms, MLPs, rotary embeddings, embeddings, heads.

Functional style: ``init_*`` returns a param dict, ``apply`` functions are
pure. Param dicts use plain nested dicts so they compose with pjit sharding
rules by path (see repro.launch.sharding).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def normal_init(key, shape, std, dtype):
    return (jax.random.normal(key, shape) * std).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_norm(cfg: ModelConfig, dim: int, dtype) -> Params:
    if cfg.norm_kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    if cfg.norm_kind == "nonparametric_ln":  # OLMo: no learnable params
        return {}
    raise ValueError(cfg.norm_kind)


def apply_norm(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if cfg.norm_kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * params["scale"].astype(jnp.float32)
    else:  # layernorm / nonparametric_ln
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mean) * jax.lax.rsqrt(var + cfg.norm_eps)
        if "scale" in params:
            out = out * params["scale"].astype(jnp.float32)
        if "bias" in params:
            out = out + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


def rms_norm_headwise(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Per-head qk-norm (Qwen3): normalize the trailing head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# MLP (dense FFN)
# ---------------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key: jax.Array, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    std = cfg.init_std
    if cfg.mlp_kind == "swiglu":
        return {
            "w_gate": normal_init(k1, (d, d_ff), std, dtype),
            "w_up": normal_init(k2, (d, d_ff), std, dtype),
            "w_down": normal_init(k3, (d_ff, d), std, dtype),
        }
    if cfg.mlp_kind == "gelu":
        return {
            "w_up": normal_init(k1, (d, d_ff), std, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": normal_init(k2, (d_ff, d), std, dtype),
            "b_down": jnp.zeros((d,), dtype),
        }
    raise ValueError(cfg.mlp_kind)


def apply_mlp(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.mlp_kind == "swiglu":
        gate = x @ params["w_gate"]
        up = x @ params["w_up"]
        return (jax.nn.silu(gate) * up) @ params["w_down"]
    up = x @ params["w_up"] + params["b_up"]
    return jax.nn.gelu(up) @ params["w_down"] + params["b_down"]


# ---------------------------------------------------------------------------
# rotary position embedding (half-rotation / llama style)
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, Dh]; positions: [B, T] (int32)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)          # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,T,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, dim: int) -> jax.Array:
    """[B, T] -> [B, T, dim] classic transformer sin/cos table."""
    half = dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def init_embedding(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    params = {
        "embedding": normal_init(k1, (cfg.vocab_size, cfg.d_model),
                                 cfg.init_std, dtype)
    }
    if not cfg.tie_embeddings:
        params["unembed"] = normal_init(
            k2, (cfg.d_model, cfg.vocab_size), cfg.init_std, dtype
        )
    return params


def embed_tokens(params: Params, cfg: ModelConfig, tokens: jax.Array,
                 compute_dtype) -> jax.Array:
    return params["embedding"].astype(compute_dtype)[tokens]


def unembed(params: Params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ params["embedding"].astype(x.dtype).T
    else:
        logits = x @ params["unembed"].astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if cfg.logits_softcap > 0.0:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
