"""Model assembly: block patterns, scanned layer stacks, losses, decode.

Layer stack = ``first_k_dense`` unscanned leading blocks (DeepSeek style) +
``num_scanned_groups`` repeats of ``block_pattern`` scanned with lax.scan
(params stacked on a leading axis — small HLO, fast compiles, the standard
MaxText trick). ``cfg.remat`` wraps the scan body in jax.checkpoint.

Block kinds: "attn_mlp", "attn_moe", "mla_mlp", "mla_moe", "mamba_mlp",
"mamba_moe", "mamba", "mlstm", "slstm".
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import xlstm as xlstm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (
    apply_mlp,
    apply_norm,
    embed_tokens,
    init_embedding,
    init_mlp,
    init_norm,
    sinusoidal_positions,
    unembed,
)

Params = Dict[str, Any]

_MIXER_INIT = {
    "attn": attn_mod.init_attention,
    "mla": mla_mod.init_mla,
    "mamba": mamba_mod.init_mamba,
    "mlstm": xlstm_mod.init_mlstm,
    "slstm": xlstm_mod.init_slstm,
}
_MIXER_FWD = {
    "attn": attn_mod.attention_forward,
    "mla": mla_mod.mla_forward,
    "mamba": mamba_mod.mamba_forward,
    "mlstm": xlstm_mod.mlstm_forward,
    "slstm": xlstm_mod.slstm_forward,
}
_MIXER_DECODE = {
    "attn": attn_mod.attention_decode,
    "mla": mla_mod.mla_decode,
    "mamba": mamba_mod.mamba_decode,
    "mlstm": xlstm_mod.mlstm_decode,
    "slstm": xlstm_mod.slstm_decode,
}
_MIXER_PREFILL = {
    "attn": attn_mod.attention_prefill_cache,
    "mla": mla_mod.mla_prefill_cache,
    "mamba": mamba_mod.mamba_prefill_cache,
    "mlstm": xlstm_mod.mlstm_prefill_cache,
    "slstm": xlstm_mod.slstm_prefill_cache,
}


def _split_kind(kind: str) -> Tuple[str, Optional[str]]:
    if "_" in kind:
        mixer, ffn = kind.split("_", 1)
        return mixer, ffn
    return kind, None


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------
def init_block(cfg: ModelConfig, kind: str, key: jax.Array, dtype) -> Params:
    mixer, ffn = _split_kind(kind)
    k1, k2 = jax.random.split(key)
    params: Params = {
        "norm1": init_norm(cfg, cfg.d_model, dtype),
        mixer: _MIXER_INIT[mixer](cfg, k1, dtype),
    }
    if ffn is not None:
        params["norm2"] = init_norm(cfg, cfg.d_model, dtype)
        if ffn == "moe":
            params["moe"] = moe_mod.init_moe(cfg, k2, dtype)
        else:
            params["mlp"] = init_mlp(cfg, k2, cfg.d_ff, dtype)
    return params


def block_forward(
    params: Params, cfg: ModelConfig, kind: str, x: jax.Array,
    positions: jax.Array,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    mixer, ffn = _split_kind(kind)
    aux: Dict[str, jax.Array] = {}
    h = apply_norm(params["norm1"], cfg, x)
    x = x + _MIXER_FWD[mixer](params[mixer], cfg, h, positions)
    x = constrain(x, ("batch", "act_seq", None))
    if ffn is not None:
        h = apply_norm(params["norm2"], cfg, x)
        if ffn == "moe":
            y, aux = moe_mod.apply_moe(params["moe"], cfg, h)
        else:
            y = apply_mlp(params["mlp"], cfg, h)
        x = x + y
        x = constrain(x, ("batch", "act_seq", None))
    return x, aux


def block_decode(
    params: Params, cfg: ModelConfig, kind: str, x: jax.Array,
    cache: Params, positions: jax.Array,
) -> Tuple[jax.Array, Params]:
    mixer, ffn = _split_kind(kind)
    h = apply_norm(params["norm1"], cfg, x)
    y, new_cache = _MIXER_DECODE[mixer](params[mixer], cfg, h, cache,
                                        positions)
    x = x + y
    if ffn is not None:
        h = apply_norm(params["norm2"], cfg, x)
        if ffn == "moe":
            y, _ = moe_mod.apply_moe(params["moe"], cfg, h)
        else:
            y = apply_mlp(params["mlp"], cfg, h)
        x = x + y
    return x, new_cache


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> Params:
    mixer, _ = _split_kind(kind)
    if mixer == "attn":
        return attn_mod.init_attention_cache(cfg, batch, max_len, dtype)
    if mixer == "mla":
        return mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
    if mixer == "mamba":
        return mamba_mod.init_mamba_cache(cfg, batch, dtype)
    if mixer == "mlstm":
        return xlstm_mod.init_mlstm_cache(cfg, batch, dtype)
    if mixer == "slstm":
        return xlstm_mod.init_slstm_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------
def init_model(cfg: ModelConfig, key: jax.Array) -> Params:
    """Full parameter pytree. Scanned groups are vmapped-over-init."""
    cfg.validate()
    import numpy as np

    from repro.common.dtypes import canonical_dtype

    dtype = canonical_dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4 + cfg.first_k_dense)
    params: Params = {"embed": init_embedding(cfg, keys[0], dtype)}

    for i in range(cfg.first_k_dense):
        kind = _dense_kind_for(cfg)
        params[f"dense_{i}"] = init_block(cfg, kind, keys[2 + i], dtype)

    g = cfg.num_scanned_groups

    def init_group(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        return {
            f"b{j}_{kind}": init_block(cfg, kind, ks[j], dtype)
            for j, kind in enumerate(cfg.block_pattern)
        }

    group_keys = jax.random.split(keys[1], g)
    params["groups"] = jax.vmap(init_group)(group_keys)
    params["final_norm"] = init_norm(cfg, cfg.d_model, dtype)
    return params


def _dense_kind_for(cfg: ModelConfig) -> str:
    """The block kind used for first_k_dense leading layers."""
    mixer, _ = _split_kind(cfg.block_pattern[0])
    return f"{mixer}_mlp" if mixer in ("attn", "mla") else "attn_mlp"


def cast_params_to_compute(params: Params, cfg: ModelConfig) -> Params:
    """Mixed precision: master weights stay fp32 in the optimizer; the
    forward pass sees one bf16 copy (modules re-upcast where fp32 matters:
    norms, router logits, SSM dynamics, RM feature products)."""
    from repro.common.dtypes import canonical_dtype

    cdtype = canonical_dtype(cfg.compute_dtype)
    if cdtype == jnp.float32:
        return params

    def _cast(p):
        if p.dtype == jnp.float32:
            return p.astype(cdtype)
        return p

    return jax.tree_util.tree_map(_cast, params)


def _prepare_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, Any]):
    """tokens and/or precomputed embeds -> x [B, T, d], positions [B, T]."""
    from repro.common.dtypes import canonical_dtype

    cdtype = canonical_dtype(cfg.compute_dtype)
    parts = []
    if "embeds" in batch and batch["embeds"] is not None:
        parts.append(batch["embeds"].astype(cdtype))  # modality frontend stub
    if "tokens" in batch and batch["tokens"] is not None:
        parts.append(embed_tokens(params["embed"], cfg, batch["tokens"], cdtype))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    b, t = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if cfg.pos_embedding == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)
    return x, positions


def forward(
    params: Params, cfg: ModelConfig, batch: Dict[str, Any]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence forward -> (logits [B,T,V] fp32, aux losses)."""
    params = cast_params_to_compute(params, cfg)
    x, positions = _prepare_inputs(params, cfg, batch)
    x = constrain(x, ("batch", "act_seq", None))
    aux_total: Dict[str, jax.Array] = {}

    for i in range(cfg.first_k_dense):
        kind = _dense_kind_for(cfg)
        x, aux = block_forward(params[f"dense_{i}"], cfg, kind, x, positions)
        aux_total = _acc_aux(aux_total, aux)

    def group_body(x, group_params):
        aux_g: Dict[str, jax.Array] = {}
        for j, kind in enumerate(cfg.block_pattern):
            x, aux = block_forward(group_params[f"b{j}_{kind}"], cfg, kind, x,
                                   positions)
            aux_g = _acc_aux(aux_g, aux)
        # scan carries must be fixed-structure: always emit both keys
        out_aux = {
            "moe_load_balance": aux_g.get("moe_load_balance", jnp.float32(0)),
            "moe_router_z": aux_g.get("moe_router_z", jnp.float32(0)),
        }
        return x, out_aux

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    x, aux_stacked = jax.lax.scan(body, x, params["groups"],
                                  unroll=cfg.scan_unroll)
    for k, v in aux_stacked.items():
        if cfg.moe is not None:
            aux_total = _acc_aux(aux_total, {k: jnp.sum(v)})

    x = apply_norm(params["final_norm"], cfg, x)
    logits = unembed(params["embed"], cfg, x)
    return logits, aux_total


def _acc_aux(a: Dict[str, jax.Array], b: Dict[str, jax.Array]):
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + v
    return out


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def loss_fn(
    params: Params, cfg: ModelConfig, batch: Dict[str, Any],
    z_loss_weight: float = 1e-4,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal-LM (or framewise, for encoders) cross entropy + aux losses.

    ``batch["targets"]`` aligns with the LAST T_targets positions of the
    model input (vlm prefixes are unsupervised). Ignore index: -1.
    """
    logits, aux = forward(params, cfg, batch)
    targets = batch["targets"]
    t_tgt = targets.shape[1]
    logits = logits[:, -t_tgt:, :]

    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.maximum(targets, 0)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (targets >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / denom
    z_loss = jnp.sum((lse**2) * mask) / denom * z_loss_weight

    total = ce + z_loss
    metrics = {"ce": ce, "z_loss": z_loss, "tokens": jnp.sum(mask)}
    if cfg.moe is not None:
        lb = aux.get("moe_load_balance", jnp.float32(0.0))
        rz = aux.get("moe_router_z", jnp.float32(0.0))
        total = total + cfg.moe.router_aux_weight * lb
        total = total + cfg.moe.router_z_weight * rz
        metrics["moe_load_balance"] = lb
        metrics["moe_router_z"] = rz
    metrics["loss"] = total
    return total, metrics


def block_prefill(
    params: Params, cfg: ModelConfig, kind: str, x: jax.Array,
    positions: jax.Array, max_len: int,
) -> Tuple[jax.Array, Params]:
    """Like block_forward but also emits this block's decode cache."""
    mixer, ffn = _split_kind(kind)
    h = apply_norm(params["norm1"], cfg, x)
    y, cache = _MIXER_PREFILL[mixer](params[mixer], cfg, h, positions, max_len)
    x = x + y
    if ffn is not None:
        h = apply_norm(params["norm2"], cfg, x)
        if ffn == "moe":
            y, _ = moe_mod.apply_moe(params["moe"], cfg, h)
        else:
            y = apply_mlp(params["mlp"], cfg, h)
        x = x + y
    return x, cache


def prefill(
    params: Params, cfg: ModelConfig, batch: Dict[str, Any], max_len: int
) -> Tuple[jax.Array, Params]:
    """Consume a prompt; return (logits [B,T,V], decode cache).

    The serving engine calls this once per request batch, then switches to
    ``decode_step``.
    """
    params = cast_params_to_compute(params, cfg)
    x, positions = _prepare_inputs(params, cfg, batch)
    x = constrain(x, ("batch", "act_seq", None))
    cache: Params = {}
    for i in range(cfg.first_k_dense):
        kind = _dense_kind_for(cfg)
        x, cache[f"dense_{i}"] = block_prefill(
            params[f"dense_{i}"], cfg, kind, x, positions, max_len)

    def group_body(x, group_params):
        caches = {}
        for j, kind in enumerate(cfg.block_pattern):
            name = f"b{j}_{kind}"
            x, caches[name] = block_prefill(group_params[name], cfg, kind, x,
                                            positions, max_len)
        return x, caches

    x, cache["groups"] = jax.lax.scan(group_body, x, params["groups"],
                                      unroll=cfg.scan_unroll)
    x = apply_norm(params["final_norm"], cfg, x)
    logits = unembed(params["embed"], cfg, x)
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """Cache pytree mirroring the layer stack (scanned groups stacked)."""
    from repro.common.dtypes import canonical_dtype

    if not cfg.causal:
        raise ValueError(f"{cfg.name} is encoder-only: no decode step")
    dtype = canonical_dtype(cfg.compute_dtype)
    cache: Params = {}
    for i in range(cfg.first_k_dense):
        kind = _dense_kind_for(cfg)
        cache[f"dense_{i}"] = init_block_cache(cfg, kind, batch, max_len, dtype)

    def one_group(_):
        return {
            f"b{j}_{kind}": init_block_cache(cfg, kind, batch, max_len, dtype)
            for j, kind in enumerate(cfg.block_pattern)
        }

    g = cfg.num_scanned_groups
    cache["groups"] = jax.vmap(one_group)(jnp.arange(g))
    return cache


def decode_step(
    params: Params, cfg: ModelConfig, cache: Params,
    tokens: jax.Array,      # [B, 1] int32
    positions: jax.Array,   # [B]    int32 position of this token
) -> Tuple[jax.Array, Params]:
    """One autoregressive step -> (logits [B, 1, V], updated cache)."""
    from repro.common.dtypes import canonical_dtype

    params = cast_params_to_compute(params, cfg)
    cdtype = canonical_dtype(cfg.compute_dtype)
    x = embed_tokens(params["embed"], cfg, tokens, cdtype)
    if cfg.pos_embedding == "sinusoidal":
        x = x + sinusoidal_positions(positions[:, None], cfg.d_model).astype(
            x.dtype)

    new_cache: Params = {}
    for i in range(cfg.first_k_dense):
        kind = _dense_kind_for(cfg)
        x, new_cache[f"dense_{i}"] = block_decode(
            params[f"dense_{i}"], cfg, kind, x, cache[f"dense_{i}"], positions
        )

    def group_body(x, scanned):
        group_params, group_cache = scanned
        new_gc = {}
        for j, kind in enumerate(cfg.block_pattern):
            name = f"b{j}_{kind}"
            x, new_gc[name] = block_decode(
                group_params[name], cfg, kind, x, group_cache[name], positions
            )
        return x, new_gc

    x, new_cache["groups"] = jax.lax.scan(
        group_body, x, (params["groups"], cache["groups"]),
        unroll=cfg.scan_unroll,
    )
    x = apply_norm(params["final_norm"], cfg, x)
    logits = unembed(params["embed"], cfg, x)
    return logits, new_cache
