"""DeepSeek-V2 Multi-head Latent Attention (MLA).

Exact mode caches the compressed latent ``c_kv`` (+ the shared rope key) and
decodes with the absorbed-projection trick; rm mode featurizes the
decompressed q/k with the paper's RM plan and keeps the O(1) linear-attention
state instead of the latent cache (DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rm_attention.ops import (
    rm_attention_causal,
    rm_attention_decode_step,
    rm_attention_fused_causal,
    rm_attention_fused_decode_step,
    rm_attention_fused_prefill,
    rm_attention_prefill_final_state,
)
from repro.models.attention import (
    NEG_INF,
    rm_estimator,
    rm_fuse_enabled,
    rm_plan_for,
    rm_valid_mask,
    _rm_featurize,
    _rm_fused_operands,
)
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, normal_init

Params = Dict[str, jax.Array]


def init_mla(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    std = cfg.init_std
    params: Params = {
        "w_q": normal_init(ks[0], (d, h * qk_dim), std, dtype),
        "w_dkv": normal_init(ks[1], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                             std, dtype),
        "kv_norm_scale": jnp.ones((m.kv_lora_rank,), dtype),
        "w_ukv": normal_init(
            ks[2], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)),
            std, dtype),
        "w_o": normal_init(ks[3], (h * m.v_head_dim, d), std, dtype),
    }
    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, qk_dim)
        params["rm_est"] = rm_estimator(cfg).init_params(meta, ks[4])
        if cfg.rm.learnable_scale:
            params["rm_scale"] = jnp.asarray(
                math.log(math.expm1(cfg.rm.qk_scale)), dtype=jnp.float32
            )
    return params


def _rms(x, scale, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        x.dtype
    )


def _mla_qkv(params: Params, cfg: ModelConfig, x, positions):
    """Decompressed q, k, v: [B, T, H, *]."""
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    nope, rope, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim

    q = (x @ params["w_q"]).reshape(b, t, h, nope + rope)
    q_nope, q_pe = q[..., :nope], q[..., nope:]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)

    ckv = x @ params["w_dkv"]
    c_kv, k_pe = ckv[..., : m.kv_lora_rank], ckv[..., m.kv_lora_rank :]
    c_kv = _rms(c_kv, params["kv_norm_scale"], cfg.norm_eps)
    k_pe = apply_rope(k_pe[:, :, None, :], positions, cfg.rope_theta)

    kv = (c_kv @ params["w_ukv"]).reshape(b, t, h, nope + dv)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe, (b, t, h, rope))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    return q_full, k, v, c_kv, k_pe


def mla_forward(params: Params, cfg: ModelConfig, x, positions) -> jax.Array:
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    q, k, v, _, _ = _mla_qkv(params, cfg, x, positions)

    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, m.qk_nope_head_dim + m.qk_rope_head_dim)
        v_t = jnp.transpose(v, (0, 2, 1, 3))
        if rm_fuse_enabled(cfg):
            qs, ks, w, cd, cs = _rm_fused_operands(params, cfg, meta, q, k)
            out = rm_attention_fused_causal(qs, ks, v_t, w, cd, cs,
                                            chunk=cfg.rm.chunk,
                                            eps=cfg.rm.eps)
        else:
            zq = _rm_featurize(params, cfg, meta, q)
            zk = _rm_featurize(params, cfg, meta, k)
            out = rm_attention_causal(zq, zk, v_t, chunk=cfg.rm.chunk,
                                      eps=cfg.rm.eps)
        out = jnp.transpose(out, (0, 2, 1, 3)).astype(x.dtype)
    else:
        # blockwise online-softmax for long sequences (see attention.py)
        from repro.models.attention import _softmax_attention

        out = _softmax_attention(cfg, q, k, v, positions, positions)

    return out.reshape(b, t, h * m.v_head_dim) @ params["w_o"]


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m = cfg.mla
    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, m.qk_nope_head_dim + m.qk_rope_head_dim)
        f = meta.output_dim
        return {
            "rm_s": jnp.zeros((batch, cfg.num_heads, f, m.v_head_dim),
                              jnp.float32),
            "rm_n": jnp.zeros((batch, cfg.num_heads, f), jnp.float32),
        }
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_pe": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
    }


def mla_prefill_cache(
    params: Params, cfg: ModelConfig, x, positions, max_len: int
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill forward + build the decode cache (latent or RM state)."""
    m = cfg.mla
    b, t, _ = x.shape
    if cfg.attention_mode == "rm" and rm_fuse_enabled(cfg):
        # fused prefill: one launch yields the causal outputs AND the O(1)
        # decode state (padded positions masked via kvalid in-kernel)
        q, k, v, _, _ = _mla_qkv(params, cfg, x, positions)
        meta = rm_plan_for(cfg, m.qk_nope_head_dim + m.qk_rope_head_dim)
        qs, ks, w, cd, cs = _rm_fused_operands(params, cfg, meta, q, k)
        v_t = jnp.transpose(v, (0, 2, 1, 3))
        kvalid = (positions >= 0).astype(jnp.float32)
        out, s, n = rm_attention_fused_prefill(
            qs, ks, v_t, w, cd, cs, kvalid=kvalid, chunk=cfg.rm.chunk,
            eps=cfg.rm.eps)
        y = jnp.transpose(out, (0, 2, 1, 3)).astype(x.dtype)
        y = y.reshape(b, t, cfg.num_heads * m.v_head_dim) @ params["w_o"]
        return y, {"rm_s": s, "rm_n": n}
    y = mla_forward(params, cfg, x, positions)
    if cfg.attention_mode == "rm":
        q, k, v, _, _ = _mla_qkv(params, cfg, x, positions)
        meta = rm_plan_for(cfg, m.qk_nope_head_dim + m.qk_rope_head_dim)
        # mask features of padded (bucketed-prefill) positions out of the state
        zk = rm_valid_mask(_rm_featurize(params, cfg, meta, k), positions)
        v_t = jnp.transpose(v, (0, 2, 1, 3))
        s, n = rm_attention_prefill_final_state(zk, v_t)
        return y, {"rm_s": s, "rm_n": n}
    cache = init_mla_cache(cfg, b, max_len, x.dtype)
    _, _, _, c_kv, k_pe = _mla_qkv(params, cfg, x, positions)
    c_cache = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
    pe_cache = jax.lax.dynamic_update_slice(
        cache["k_pe"], k_pe[:, :, 0].astype(cache["k_pe"].dtype), (0, 0, 0))
    return y, {"c_kv": c_cache, "k_pe": pe_cache}


def mla_decode(
    params: Params, cfg: ModelConfig, x, cache, positions
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, 1, d]. Exact mode = absorbed-latent attention over the cache."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    nope, rope, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q, k, v, c_kv_t, k_pe_t = _mla_qkv(params, cfg, x, positions[:, None])

    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, nope + rope)
        v0 = jnp.transpose(v, (0, 2, 1, 3))[:, :, 0]
        if rm_fuse_enabled(cfg):
            # q and k share one featurize launch per decoded token
            qs, ks, w, cd, cs = _rm_fused_operands(params, cfg, meta, q, k)
            out, s_new, n_new = rm_attention_fused_decode_step(
                qs[:, :, 0], ks[:, :, 0], v0, cache["rm_s"], cache["rm_n"],
                w, cd, cs, eps=cfg.rm.eps)
        else:
            zq = _rm_featurize(params, cfg, meta, q)[:, :, 0]
            zk = _rm_featurize(params, cfg, meta, k)[:, :, 0]
            out, s_new, n_new = rm_attention_decode_step(
                zq, zk, v0, cache["rm_s"], cache["rm_n"], eps=cfg.rm.eps
            )
        y = out.reshape(b, 1, h * dv).astype(x.dtype) @ params["w_o"]
        return y, {"rm_s": s_new, "rm_n": n_new}

    size = cache["c_kv"].shape[1]
    bidx = jnp.arange(b)
    c_cache = cache["c_kv"].at[bidx, positions].set(
        c_kv_t[:, 0].astype(cache["c_kv"].dtype))
    pe_cache = cache["k_pe"].at[bidx, positions].set(
        k_pe_t[:, 0, 0].astype(cache["k_pe"].dtype))

    # absorbed scores: q_nope absorbed through w_uk into latent space
    w_ukv = params["w_ukv"].reshape(m.kv_lora_rank, h, nope + dv)
    w_uk = w_ukv[..., :nope]                   # [lora, H, nope]
    w_uv = w_ukv[..., nope:]                   # [lora, H, dv]
    q_nope, q_pe = q[:, 0, :, :nope], q[:, 0, :, nope:]
    q_lat = jnp.einsum("bhn,lhn->bhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = jnp.einsum("bhl,bsl->bhs", q_lat,
                        c_cache.astype(jnp.float32))
    scores += jnp.einsum("bhr,bsr->bhs", q_pe.astype(jnp.float32),
                         pe_cache.astype(jnp.float32))
    scores /= math.sqrt(nope + rope)
    valid = jnp.arange(size)[None, :] <= positions[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsl->bhl", probs, c_cache.astype(jnp.float32))
    out = jnp.einsum("bhl,lhv->bhv", o_lat, w_uv.astype(jnp.float32))
    y = out.reshape(b, 1, h * dv).astype(x.dtype) @ params["w_o"]
    return y, {"c_kv": c_cache, "k_pe": pe_cache}
