"""Mixture-of-Experts FFN.

Scalable path (``dispatch="local"``, default): the MoE block runs inside a
``shard_map`` over the data-parallel axes — every DP shard routes and
dispatches ITS OWN tokens with purely local scatter/gather (no global token
indices, so SPMD never materializes cross-device permutes), while expert
weights are column-sharded over the "model" axis (ff dim). Each device
computes partial expert outputs for all (local) tokens; one psum over
"model" completes the block — the same collective shape as a dense TP FFN.
This shards for ANY (num_experts, tensor-parallel) combination, including
E=8 on tp=16 (Mixtral) and E=64 (DeepSeek).

Ablation path (``dispatch="einsum"``): the classic GShard one-hot dispatch
einsum, O(G*E*C*d) FLOPs and a materialized [G,E,C] tensor — correct but
only viable at toy scale; kept for tests and the §Perf before/after story.

Outside a mesh context both paths degrade gracefully to single-device code.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shlib
from repro.models.config import ModelConfig, MoEConfig
from repro.models.layers import normal_init

Params = Dict[str, jax.Array]


def init_moe(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    moe = cfg.moe
    d, e, ff = cfg.d_model, moe.num_experts, moe.d_ff_expert
    ks = jax.random.split(key, 7)
    std = cfg.init_std
    params: Params = {
        "router": normal_init(ks[0], (d, e), std, jnp.float32),
        "w_gate": normal_init(ks[1], (e, d, ff), std, dtype),
        "w_up": normal_init(ks[2], (e, d, ff), std, dtype),
        "w_down": normal_init(ks[3], (e, ff, d), std, dtype),
    }
    if moe.num_shared_experts > 0:
        sff = moe.num_shared_experts * ff
        params["shared_gate"] = normal_init(ks[4], (d, sff), std, dtype)
        params["shared_up"] = normal_init(ks[5], (d, sff), std, dtype)
        params["shared_down"] = normal_init(ks[6], (sff, d), std, dtype)
    return params


def _capacity(moe: MoEConfig, num_tokens: int) -> int:
    cap = int(num_tokens * moe.top_k * moe.capacity_factor / moe.num_experts)
    return max(cap, moe.top_k)


def _route(params: Params, moe: MoEConfig, xf: jax.Array):
    """Router probs + normalized top-k. xf: [G, d] (local tokens)."""
    logits = xf.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(probs, moe.top_k)
    top_vals = top_vals / jnp.maximum(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9
    )
    return logits, probs, top_vals, top_idx


def _shared_expert_out(params: Params, xf: jax.Array) -> jax.Array:
    gate = xf @ params["shared_gate"]
    up = xf @ params["shared_up"]
    return (jax.nn.silu(gate) * up) @ params["shared_down"]


def _moe_core_local(params: Params, cfg: ModelConfig, xf: jax.Array):
    """Local-token dispatch -> expert FFN -> combine. xf: [G_loc, d].

    Returns (y [G_loc, d] — PARTIAL over the ff shard if weights are
    column-sharded, aux dict of local scalars). All indices are local.
    """
    moe = cfg.moe
    g, d = xf.shape
    e, k = moe.num_experts, moe.top_k
    logits, probs, top_vals, top_idx = _route(params, moe, xf)
    cap = _capacity(moe, g)

    # local sorted-rank dispatch: [G*K] pairs -> per-expert capacity buffers
    e_flat = top_idx.reshape(-1)
    w_flat = top_vals.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(g), k)
    onehot_fe = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    rank = jnp.cumsum(onehot_fe, axis=0) - onehot_fe
    my_rank = jnp.take_along_axis(rank, e_flat[:, None], axis=1)[:, 0]
    valid = my_rank < cap
    slot = jnp.where(valid, e_flat * cap + my_rank, e * cap)
    buf = jnp.zeros((e * cap + 1, d), xf.dtype)
    buf = buf.at[slot].add(xf[tok_flat] * valid[:, None].astype(xf.dtype))
    xin = buf[:-1].reshape(e, cap, d)

    gate = jnp.einsum("ecd,edf->ecf", xin, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xin, params["w_up"])
    yexp = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                      params["w_down"])

    y_flat = yexp.reshape(e * cap, d)
    picked = jnp.where(valid[:, None],
                       y_flat[jnp.minimum(slot, e * cap - 1)], 0.0)
    contrib = picked.astype(jnp.float32) * w_flat[:, None]
    y = jax.ops.segment_sum(contrib, tok_flat, num_segments=g)

    mask_ge = jnp.sum(jax.nn.one_hot(top_idx, e, dtype=jnp.float32), axis=1)
    aux = {
        "lb_fe": jnp.mean(mask_ge, axis=0) / k,          # [E]
        "lb_pe": jnp.mean(probs, axis=0),                # [E]
        "z_sq": jnp.mean(
            jax.scipy.special.logsumexp(logits, axis=-1) ** 2),
    }
    return y, aux


def _moe_core_einsum(params: Params, cfg: ModelConfig, xf: jax.Array):
    """GShard one-hot dispatch (toy scale / ablation)."""
    moe = cfg.moe
    g, d = xf.shape
    e, k = moe.num_experts, moe.top_k
    logits, probs, top_vals, top_idx = _route(params, moe, xf)
    cap = _capacity(moe, g)

    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)       # [G,K,E]
    mask_ge = jnp.sum(onehot, axis=1)
    gates_ge = jnp.einsum("gk,gke->ge", top_vals, onehot)
    rank = jnp.cumsum(mask_ge, axis=0) - mask_ge
    keep = (rank < cap) * mask_ge
    dispatch = jax.nn.one_hot(rank.astype(jnp.int32), cap,
                              dtype=jnp.float32) * keep[..., None]
    xin = jnp.einsum("gec,gd->ecd", dispatch,
                     xf.astype(jnp.float32)).astype(xf.dtype)
    gate = jnp.einsum("ecd,edf->ecf", xin, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xin, params["w_up"])
    yexp = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                      params["w_down"])
    combine = dispatch * gates_ge[..., None]
    y = jnp.einsum("gec,ecd->gd", combine, yexp.astype(jnp.float32))
    aux = {
        "lb_fe": jnp.mean(mask_ge, axis=0) / k,
        "lb_pe": jnp.mean(probs, axis=0),
        "z_sq": jnp.mean(
            jax.scipy.special.logsumexp(logits, axis=-1) ** 2),
    }
    return y, aux


def _finalize_aux(moe: MoEConfig, aux) -> Dict[str, jax.Array]:
    return {
        "moe_load_balance": moe.num_experts * jnp.sum(
            aux["lb_fe"] * aux["lb_pe"]),
        "moe_router_z": aux["z_sq"],
    }


def apply_moe(
    params: Params, cfg: ModelConfig, x: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, T, d] -> (y, aux losses)."""
    moe = cfg.moe
    b, t, d = x.shape
    core = _moe_core_einsum if moe.dispatch == "einsum" else _moe_core_local

    ctx = shlib._active()
    if ctx is None:
        # single-device path (tests, CPU examples)
        xf = x.reshape(-1, d)
        y, aux = core(params, cfg, xf)
        if moe.num_shared_experts > 0:
            y = y + _shared_expert_out(params, xf).astype(jnp.float32)
        return y.reshape(b, t, d).astype(x.dtype), _finalize_aux(moe, aux)

    mesh, rules = ctx
    dp = rules.get("batch")
    ep = rules.get("ffn")  # expert ff dim rides the tensor-parallel axis
    # tiny/odd batches (long_500k decodes with B=1) can't shard over the DP
    # axes — fall back to replicated tokens, experts still ff-sharded.
    if dp is not None:
        dp_axes = dp if isinstance(dp, tuple) else (dp,)
        dp_size = 1
        for a in dp_axes:
            dp_size *= mesh.shape[a]
        if b % dp_size != 0:
            dp = None

    in_specs = (
        {
            "router": P(),
            "w_gate": P(None, None, ep),
            "w_up": P(None, None, ep),
            "w_down": P(None, ep, None),
            **({"shared_gate": P(None, ep),
                "shared_up": P(None, ep),
                "shared_down": P(ep, None)}
               if moe.num_shared_experts > 0 else {}),
        },
        P(dp, None, None),
    )
    out_specs = (P(dp, None, None), {"lb_fe": P(), "lb_pe": P(), "z_sq": P()})

    def local_fn(p, x_loc):
        bl, tl, _ = x_loc.shape
        xf = x_loc.reshape(-1, d)
        y, aux = core(p, cfg, xf)
        if moe.num_shared_experts > 0:
            y = y + _shared_expert_out(p, xf).astype(jnp.float32)
        if ep is not None:
            y = jax.lax.psum(y, ep)          # complete the ff-shard partials
        if dp is not None:
            aux = jax.tree_util.tree_map(
                lambda a: jax.lax.pmean(a, dp), aux)
        return y.reshape(bl, tl, d).astype(x_loc.dtype), aux

    moe_params = {k: params[k] for k in in_specs[0]}
    y, aux = shlib.shard_map(
        local_fn, mesh, in_specs, out_specs,
    )(moe_params, x)
    return y, _finalize_aux(moe, aux)
