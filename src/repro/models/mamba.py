"""Mamba-1 selective SSM block (for Jamba's 7-of-8 layers).

Training/prefill uses a chunked associative scan: the sequence is split into
``scan_chunk`` slices scanned sequentially (O(T/C) steps) with a parallel
associative scan inside each chunk — the chunk size bounds the materialized
[B, C, d_in, N] state tensor (DESIGN.md §5 memory notes). Decode is the O(1)
recurrence with (conv window, ssm state) carried in the cache.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import normal_init

Params = Dict[str, jax.Array]


def _dt_rank(cfg: ModelConfig) -> int:
    return cfg.mamba.dt_rank or math.ceil(cfg.d_model / 16)


def init_mamba(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    mc = cfg.mamba
    d = cfg.d_model
    d_in = mc.expand * d
    n = mc.d_state
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    std = cfg.init_std
    params: Params = {
        "w_in": normal_init(ks[0], (d, 2 * d_in), std, dtype),
        "conv_w": normal_init(ks[1], (mc.d_conv, d_in), std, dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": normal_init(ks[2], (d_in, r + 2 * n), std, dtype),
        "dt_proj": normal_init(ks[3], (r, d_in), std, dtype),
        "dt_bias": jnp.full((d_in,), math.log(math.expm1(0.01)), dtype),
        "a_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, n + 1, dtype=jnp.float32),
                             (d_in, n))
        ).astype(jnp.float32),
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "w_out": normal_init(ks[4], (d_in, d), std, dtype),
    }
    return params


def _ssm_coeffs(params: Params, cfg: ModelConfig, xc: jax.Array):
    """xc: [B, T, d_in] (post-conv). Returns a_bar, bx, c  for the scan."""
    mc = cfg.mamba
    n = mc.d_state
    r = _dt_rank(cfg)
    proj = xc @ params["x_proj"]                                  # [B,T,r+2n]
    dt, b_in, c_in = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(
        dt @ params["dt_proj"] + params["dt_bias"].astype(dt.dtype)
    ).astype(jnp.float32)                                         # [B,T,d_in]
    a = -jnp.exp(params["a_log"].astype(jnp.float32))             # [d_in, N]
    a_bar = jnp.exp(dt[..., None] * a)                            # [B,T,d_in,N]
    # Euler-discretized input: dt * B * x
    bx = (
        dt[..., None]
        * b_in[:, :, None, :].astype(jnp.float32)
        * xc[..., None].astype(jnp.float32)
    )                                                             # [B,T,d_in,N]
    return a_bar, bx, c_in.astype(jnp.float32)


def _causal_conv(params: Params, cfg: ModelConfig, x: jax.Array,
                 init_state: jax.Array = None):
    """Depthwise causal conv over T. x: [B, T, d_in]."""
    kk = cfg.mamba.d_conv
    if init_state is None:
        pad = jnp.zeros((x.shape[0], kk - 1, x.shape[2]), x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * params["conv_w"][i].astype(x.dtype)
        for i in range(kk)
    )
    return out + params["conv_b"].astype(x.dtype), xp[:, -(kk - 1):]


def mamba_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions=None) -> jax.Array:
    """x: [B, T, d] -> [B, T, d]."""
    mc = cfg.mamba
    b, t, d = x.shape
    d_in = mc.expand * d
    xz = x @ params["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(params, cfg, xs)
    xc = jax.nn.silu(xc)
    a_bar, bx, c = _ssm_coeffs(params, cfg, xc)

    chunk = min(mc.scan_chunk, t)
    pad = (-t) % chunk
    if pad:
        a_bar = jnp.pad(a_bar, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    nch = tp // chunk
    a_c = a_bar.reshape(b, nch, chunk, d_in, mc.d_state).swapaxes(0, 1)
    b_c = bx.reshape(b, nch, chunk, d_in, mc.d_state).swapaxes(0, 1)

    def assoc(elem_a, elem_b):
        a1, u1 = elem_a
        a2, u2 = elem_b
        return a1 * a2, a2 * u1 + u2

    def chunk_step(h, ab):
        a_i, b_i = ab                                   # [B, C, d_in, N]
        cum_a, cum_u = jax.lax.associative_scan(assoc, (a_i, b_i), axis=1)
        h_t = cum_a * h[:, None] + cum_u                # [B, C, d_in, N]
        return h_t[:, -1], h_t

    h0 = jnp.zeros((b, d_in, mc.d_state), jnp.float32)
    _, hs = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    hs = hs.swapaxes(0, 1).reshape(b, tp, d_in, mc.d_state)[:, :t]

    y = jnp.einsum("btdn,btn->btd", hs, c[:, :t])
    y = y + params["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["w_out"]


def mamba_prefill_cache(
    params: Params, cfg: ModelConfig, x: jax.Array, positions, max_len: int
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Forward + final (conv window, ssm state) for decode handoff.

    Runs the same chunked scan as ``mamba_forward`` but keeps the carry.
    """
    mc = cfg.mamba
    b, t, d = x.shape
    d_in = mc.expand * d
    xz = x @ params["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, _ = _causal_conv(params, cfg, xs)
    xc = jax.nn.silu(xc)
    a_bar, bx, c = _ssm_coeffs(params, cfg, xc)

    chunk = min(mc.scan_chunk, t)
    pad = (-t) % chunk
    if pad:
        a_bar = jnp.pad(a_bar, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=1.0)
        bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    nch = tp // chunk
    a_c = a_bar.reshape(b, nch, chunk, d_in, mc.d_state).swapaxes(0, 1)
    b_c = bx.reshape(b, nch, chunk, d_in, mc.d_state).swapaxes(0, 1)

    def assoc(ea, eb):
        a1, u1 = ea
        a2, u2 = eb
        return a1 * a2, a2 * u1 + u2

    def chunk_step(h, ab):
        a_i, b_i = ab
        cum_a, cum_u = jax.lax.associative_scan(assoc, (a_i, b_i), axis=1)
        h_t = cum_a * h[:, None] + cum_u
        return h_t[:, -1], h_t

    h0 = jnp.zeros((b, d_in, mc.d_state), jnp.float32)
    h_final, hs = jax.lax.scan(chunk_step, h0, (a_c, b_c))
    hs = hs.swapaxes(0, 1).reshape(b, tp, d_in, mc.d_state)[:, :t]

    y = jnp.einsum("btdn,btn->btd", hs, c[:, :t])
    y = y + params["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = y @ params["w_out"]

    kk = mc.d_conv
    xs_pad = jnp.pad(xs, ((0, 0), (kk - 1, 0), (0, 0)))
    conv_state = xs_pad[:, -(kk - 1):] if kk > 1 else xs[:, :0]
    return y, {"conv": conv_state, "ssm": h_final}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, mc.d_conv - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, d_in, mc.d_state), jnp.float32),
    }


def mamba_decode(
    params: Params, cfg: ModelConfig, x: jax.Array, cache, positions=None
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, 1, d]; O(1) per-token recurrence."""
    mc = cfg.mamba
    xz = x @ params["w_in"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(params, cfg, xs, init_state=cache["conv"])
    xc = jax.nn.silu(xc)
    a_bar, bx, c = _ssm_coeffs(params, cfg, xc)
    h = cache["ssm"] * a_bar[:, 0] + bx[:, 0]
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0])[:, None]
    y = y + params["d_skip"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["w_out"], {"conv": conv_state, "ssm": h}
