"""Unified model configuration covering all 10 assigned architectures.

One frozen dataclass; family-specific sub-configs are optional fields. The
layer stack is ``block_pattern`` repeated ``num_layers / len(block_pattern)``
times (scanned over repeats for compile efficiency), optionally preceded by
``first_k_dense`` unscanned dense layers (DeepSeek-V2 style).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = [
    "RMAttentionConfig",
    "MoEConfig",
    "MLAConfig",
    "MambaConfig",
    "XLSTMConfig",
    "ModelConfig",
]


@dataclasses.dataclass(frozen=True)
class RMAttentionConfig:
    """The paper's technique as an attention mode (DESIGN.md §2).

    q/k are l2-normalized per head, scaled by ``qk_scale`` and mapped through
    a feature plan for exp(<q,k>/sigma2); attention becomes linear in the
    features. ``measure='proportional', stratified=True`` is the beyond-paper
    low-variance default; ``measure='geometric', stratified=False`` is the
    paper-faithful Algorithm 1 sampler. ``estimator`` names the feature
    family in the estimator registry (``repro.core.registry``): ``"rm"``
    (Random Maclaurin, default), ``"tensor_sketch"`` (CountSketch + FFT) or
    ``"ctr"`` (complex-to-real); all are driven by the same
    Taylor-coefficient measure. ``precision`` is the feature-kernel
    mixed-precision policy (``"fp32"`` | ``"bf16"``): under ``"bf16"`` the
    featurization kernels take bf16 inputs/packed weights with fp32
    accumulation (repro.common.dtypes.Precision), halving the featurize
    HBM traffic in attention/MLA prefill and decode.

    ``fuse_featurize`` selects the fused featurize+attention path
    (DESIGN.md §13), which computes Z(q)/Z(k) inside the attention
    kernel's VMEM tiles instead of materializing them to HBM between two
    launches: ``"auto"`` (default) fuses when the Pallas kernels compile
    (TPU) and keeps the two-launch path elsewhere; ``"on"`` always uses the
    fused formulation (off-TPU it runs the fused jnp composition — useful
    for parity tests); ``"off"`` always two-launch. Estimators without
    ``fused_attention_supported`` fall back to two-launch regardless.
    """

    estimator: str = "rm"
    precision: str = "fp32"
    fuse_featurize: str = "auto"
    num_features: int = 256
    sigma2: float = 1.0
    qk_scale: float = 1.0
    p: float = 2.0
    measure: str = "proportional"
    stratified: bool = True
    n_max: int = 8
    chunk: int = 128
    eps: float = 1e-4
    learnable_scale: bool = True


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 0           # per-expert hidden dim
    num_shared_experts: int = 0    # DeepSeek shared experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # "local": shard_map-local dispatch per DP shard + ff-sharded experts +
    #          one psum over "model" (default — scales to 1M tokens/step);
    # "einsum": GShard one-hot dispatch, O(G*E*C*d) — toy scale / ablation.
    dispatch: str = "local"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention dims."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = no query compression (V2-Lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0               # 0 = ceil(d_model / 16)
    scan_chunk: int = 64


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0       # mLSTM up-projection
    conv_kernel: int = 4
    slstm_ff_factor: float = 1.3333
    chunk: int = 64                # mLSTM chunkwise parallel size


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str = "model"
    family: str = "dense"          # dense | moe | vlm | audio | hybrid | ssm

    # trunk dims
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0              # 0 = d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192

    # block structure
    block_pattern: Tuple[str, ...] = ("attn_mlp",)
    first_k_dense: int = 0         # unscanned leading dense layers
    causal: bool = True            # False => encoder-only (hubert)
    frontend: str = "none"         # none | vision_stub | audio_stub

    # attention flavor
    attention_kind: str = "gqa"    # gqa | mla
    attention_mode: str = "exact"  # exact | rm  (rm = the paper's technique)
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"    # rope | sinusoidal | none

    # norms / mlp
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm | nonparametric_ln
    mlp_kind: str = "swiglu"       # swiglu | gelu
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logits_softcap: float = 0.0

    # sub-configs
    rm: RMAttentionConfig = RMAttentionConfig()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # init
    init_std: float = 0.02
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True             # activation checkpointing on scanned blocks
    # fully unroll the layer scan. False = fast compiles (tests, training);
    # True = dry-run/roofline mode, where XLA cost_analysis must see every
    # layer's ops (while-loop bodies are counted once, DESIGN.md §8).
    scan_unroll: bool = False

    # ---------------- derived ----------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def num_scanned_groups(self) -> int:
        n = self.num_layers - self.first_k_dense
        period = len(self.block_pattern)
        if n % period:
            raise ValueError(
                f"{self.name}: {n} scanned layers not divisible by pattern "
                f"period {period}"
            )
        return n // period

    def validate(self) -> "ModelConfig":
        assert self.num_heads % self.num_kv_heads == 0
        if self.attention_kind == "mla":
            assert self.mla is not None, "mla config required"
        if any("moe" in b for b in self.block_pattern):
            assert self.moe is not None, "moe config required"
        if any("mamba" in b for b in self.block_pattern):
            assert self.mamba is not None, "mamba config required"
        if any(b in ("mlstm", "slstm") for b in self.block_pattern):
            assert self.xlstm is not None, "xlstm config required"
        _ = self.num_scanned_groups
        return self
