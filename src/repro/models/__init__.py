"""Model zoo: composable JAX transformer / SSM / hybrid blocks with the
paper's RM linear attention as a first-class attention mode."""
from repro.models.config import (
    MLAConfig,
    MambaConfig,
    ModelConfig,
    MoEConfig,
    RMAttentionConfig,
    XLSTMConfig,
)
from repro.models.transformer import (
    init_model,
    forward,
    loss_fn,
    init_decode_cache,
    decode_step,
    prefill,
)

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "MLAConfig",
    "MambaConfig",
    "XLSTMConfig",
    "RMAttentionConfig",
    "init_model",
    "forward",
    "loss_fn",
    "init_decode_cache",
    "decode_step",
    "prefill",
]
