"""Attention: exact GQA (+SWA, qk-norm, QKV bias), DeepSeek MLA, and the
paper's Random-Maclaurin linear attention mode.

Modes (cfg.attention_mode):
  * "exact" — softmax attention; decode uses a ring-buffer KV cache.
  * "rm"    — q/k are per-head l2-normalized, scaled, and featurized with a
              static RM plan for the exponential dot product kernel
              (DESIGN.md §2); attention is linear in the features. Decode
              keeps an O(1) state (S [F, dv], n [F]) instead of a KV cache —
              this is what makes the `long_500k` shape feasible.

All forward paths take ``positions [B, T]`` so prefill/decode share code.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import registry
from repro.core.maclaurin import ExponentialDotProductKernel
from repro.kernels.rm_attention.ops import (
    rm_attention_causal,
    rm_attention_decode_step,
    rm_attention_fused_causal,
    rm_attention_fused_noncausal,
    rm_attention_fused_prefill,
    rm_attention_noncausal,
    rm_attention_prefill_final_state,
)
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, normal_init, rms_norm_headwise

Params = Dict[str, jax.Array]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# feature plan (shared helpers; estimator resolved from the registry)
# ---------------------------------------------------------------------------
def rm_estimator(cfg: ModelConfig) -> registry.Estimator:
    """The config's feature-estimator entry ("rm", "tensor_sketch", ...)."""
    return registry.get(cfg.rm.estimator)


def rm_plan_for(cfg: ModelConfig, input_dim: int):
    """Build the (estimator-specific, hashable) plan at trace time."""
    rm = cfg.rm
    kernel = ExponentialDotProductKernel(rm.sigma2)
    return rm_estimator(cfg).make_plan(
        kernel,
        input_dim,
        rm.num_features,
        p=rm.p,
        measure=rm.measure,
        stratified=rm.stratified,
        n_max=rm.n_max,
        radius=rm.qk_scale,
        seed=0,
    )


def rm_valid_mask(z: jax.Array, positions: jax.Array) -> jax.Array:
    """Zero featurized keys at padded positions (position < 0).

    The serving engine right-pads prompts to bucketed lengths with sentinel
    positions (DESIGN.md §2); masked features contribute nothing to the
    linear-attention prefix sums or the O(1) decode state. z: [B, H, T, F].
    """
    valid = (positions >= 0).astype(z.dtype)      # [B, T]
    return z * valid[:, None, :, None]


def _rm_featurize(
    params: Params, cfg: ModelConfig, meta, x: jax.Array
) -> jax.Array:
    """[B, T, H, dh] -> [B, H, T, F]: l2-normalize, scale, featurize.

    ``meta`` is the estimator-specific plan from ``rm_plan_for``; the actual
    application is dispatched through the registry entry named by
    ``cfg.rm.estimator``, whose params live under ``params["rm_est"]``.
    """
    xf = x.astype(jnp.float32)
    norm = jnp.linalg.norm(xf, axis=-1, keepdims=True)
    xhat = xf / jnp.maximum(norm, 1e-6)
    if cfg.rm.learnable_scale:
        scale = jax.nn.softplus(params["rm_scale"]).astype(jnp.float32)
    else:
        scale = jnp.float32(cfg.rm.qk_scale)
    z = rm_estimator(cfg).apply(meta, params["rm_est"], xhat * scale,
                                precision=cfg.rm.precision)
    return jnp.transpose(z, (0, 2, 1, 3))  # [B, H, T, F]


def rm_fuse_enabled(cfg: ModelConfig) -> bool:
    """Whether the rm attention path runs the fused featurize+attention ops.

    ``cfg.rm.fuse_featurize``: "off" -> never; "on" -> always (off-TPU the
    fused ops run their jnp composition); "auto" -> only where the Pallas
    kernels compile (TPU). Estimators without the
    ``fused_attention_supported`` capability always take the two-launch
    path — the flag is the registry-level fallback contract.
    """
    mode = cfg.rm.fuse_featurize
    if mode not in ("auto", "on", "off"):
        raise ValueError(
            f"cfg.rm.fuse_featurize must be 'auto', 'on' or 'off'; "
            f"got {mode!r}")
    if mode == "off":
        return False
    if not rm_estimator(cfg).fused_attention_supported:
        return False
    if mode == "on":
        return True
    from repro.kernels.common import default_interpret

    return not default_interpret()


def _rm_scaled_qk(params: Params, cfg: ModelConfig, x: jax.Array):
    """[B, T, H, dh] -> [B, H, T, dh]: the pre-featurize transform.

    EXACTLY the normalize+scale step of ``_rm_featurize`` — the fused
    attention kernels take these raw rows and featurize them in VMEM.
    """
    xf = x.astype(jnp.float32)
    norm = jnp.linalg.norm(xf, axis=-1, keepdims=True)
    xhat = xf / jnp.maximum(norm, 1e-6)
    if cfg.rm.learnable_scale:
        scale = jax.nn.softplus(params["rm_scale"]).astype(jnp.float32)
    else:
        scale = jnp.float32(cfg.rm.qk_scale)
    return jnp.transpose(xhat * scale, (0, 2, 1, 3))


def _rm_fused_operands(params: Params, cfg: ModelConfig, meta, q, k):
    """Packed layout + precision-cast operands for the fused ops.

    Returns ``(qs, ks, w, col_deg, col_scale)`` with q/k pre-scaled
    [B, H, T, dh] and w the packed ``[max_degree, F, dh]`` omegas, all cast
    to the precision policy's compute dtype (accumulation stays fp32 inside
    the kernels).
    """
    from repro.common.dtypes import resolve_precision

    w, col_deg, col_scale = rm_estimator(cfg).pack_fused(
        meta, params["rm_est"])
    prec = resolve_precision(cfg.rm.precision)
    dt = prec.compute_dtype
    qs = _rm_scaled_qk(params, cfg, q).astype(dt)
    ks = _rm_scaled_qk(params, cfg, k).astype(dt)
    return qs, ks, w.astype(dt), col_deg, col_scale


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    d, h, hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    dh = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    std = cfg.init_std
    params: Params = {
        "wq": normal_init(ks[0], (d, h * dh), std, dtype),
        "wk": normal_init(ks[1], (d, hkv * dh), std, dtype),
        "wv": normal_init(ks[2], (d, hkv * dh), std, dtype),
        "wo": normal_init(ks[3], (h * dh, d), std, dtype),
    }
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((h * dh,), dtype)
        params["bk"] = jnp.zeros((hkv * dh,), dtype)
        params["bv"] = jnp.zeros((hkv * dh,), dtype)
    if cfg.qk_norm:
        params["q_norm_scale"] = jnp.ones((dh,), dtype)
        params["k_norm_scale"] = jnp.ones((dh,), dtype)
    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, dh)
        params["rm_est"] = rm_estimator(cfg).init_params(meta, ks[4])
        if cfg.rm.learnable_scale:
            # softplus^-1(qk_scale)
            params["rm_scale"] = jnp.asarray(
                math.log(math.expm1(cfg.rm.qk_scale)), dtype=jnp.float32
            )
    return params


def _project_qkv(params: Params, cfg: ModelConfig, x: jax.Array):
    b, t, _ = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, t, h, dh)
    k = k.reshape(b, t, hkv, dh)
    v = v.reshape(b, t, hkv, dh)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, params["q_norm_scale"], cfg.norm_eps)
        k = rms_norm_headwise(k, params["k_norm_scale"], cfg.norm_eps)
    return q, k, v


def _apply_positional(cfg: ModelConfig, q, k, positions):
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def _repeat_kv(x: jax.Array, rep: int) -> jax.Array:
    if rep == 1:
        return x
    return jnp.repeat(x, rep, axis=2)


# Above this sequence length, exact attention switches to the blockwise
# online-softmax formulation (bounded memory; flash-attention schedule in
# XLA). Below it, the simple einsum is faster to compile and plenty small.
_BLOCKWISE_THRESHOLD = 2048
_BLOCK_Q = 1024
_BLOCK_K = 1024


def _mask_block(cfg: ModelConfig, qp, kp):
    """qp: [.., bq], kp: [.., bk] -> bool [.., bq, bk].

    Keys at negative positions are padding (bucketed prefill, DESIGN.md §2)
    and are never attended to.
    """
    m = jnp.ones(qp.shape + (kp.shape[-1],), dtype=bool)
    m &= kp[..., None, :] >= 0
    if cfg.causal:
        m &= qp[..., :, None] >= kp[..., None, :]
    if cfg.sliding_window > 0:
        m &= (qp[..., :, None] - kp[..., None, :]) < cfg.sliding_window
    return m


def _softmax_attention_small(cfg, q, k, v, q_positions, k_positions):
    dh = q.shape[-1]
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(dh)
    mask = _mask_block(cfg, q_positions, k_positions)[:, None]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def _softmax_attention_blockwise(cfg, q, k, v, q_positions, k_positions):
    """Memory-efficient exact attention: scan over KV blocks with online
    softmax (running max / sum) per Q block. Peak score memory is
    [B, H, block_q, block_k] instead of [B, H, T, T].

    Masked-out blocks are still computed then zeroed (static shapes); the
    causal/window FLOP overhead this costs is measured in EXPERIMENTS.md
    §Roofline and attacked in the §Perf hillclimb where it matters.
    """
    b, tq, h, dh = q.shape
    tk = k.shape[1]
    dv = v.shape[-1]  # may differ from dh (MLA: qk 192, v 128)
    bq, bk = min(_BLOCK_Q, tq), min(_BLOCK_K, tk)
    pad_q, pad_k = (-tq) % bq, (-tk) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qpos = jnp.pad(q_positions, ((0, 0), (0, pad_q)))
    kpos = jnp.pad(k_positions, ((0, 0), (0, pad_k)),
                   constant_values=jnp.iinfo(jnp.int32).max)
    nq, nk = (tq + pad_q) // bq, (tk + pad_k) // bk
    scale = 1.0 / math.sqrt(dh)

    q_c = qp.reshape(b, nq, bq, h, dh)
    k_c = kp.reshape(b, nk, bk, h, dh)
    v_c = vp.reshape(b, nk, bk, h, dv)
    qpos_c = qpos.reshape(b, nq, bq)
    kpos_c = kpos.reshape(b, nk, bk)

    def q_block(qi_data):
        q_i, qpos_i = qi_data            # [B,bq,H,dh], [B,bq]

        def kv_step(carry, kj_data):
            m, l, acc = carry
            k_j, v_j, kpos_j = kj_data
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i.astype(jnp.float32),
                           k_j.astype(jnp.float32)) * scale
            mask = _mask_block(cfg, qpos_i, kpos_j)[:, None]
            # padded keys carry sentinel positions -> always invalid
            mask &= (kpos_j < jnp.iinfo(jnp.int32).max)[:, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, v_j.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((b, h, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (k_c.swapaxes(0, 1), v_c.swapaxes(0, 1), kpos_c.swapaxes(0, 1)),
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.swapaxes(1, 2)        # [B,bq,H,dh]

    outs = jax.lax.map(q_block, (q_c.swapaxes(0, 1), qpos_c.swapaxes(0, 1)))
    out = outs.swapaxes(0, 1).reshape(b, nq * bq, h, dv)[:, :tq]
    return out.astype(v.dtype)


def _softmax_attention(
    cfg: ModelConfig, q, k, v, q_positions, k_positions
) -> jax.Array:
    """q: [B,Tq,H,dh]; k,v: [B,Tk,H,dh]; positions give the mask."""
    if max(q.shape[1], k.shape[1]) > _BLOCKWISE_THRESHOLD:
        return _softmax_attention_blockwise(cfg, q, k, v, q_positions,
                                            k_positions)
    return _softmax_attention_small(cfg, q, k, v, q_positions, k_positions)


def attention_forward(
    params: Params, cfg: ModelConfig, x: jax.Array, positions: jax.Array
) -> jax.Array:
    """Full-sequence attention (training / prefill). x: [B, T, d]."""
    b, t, _ = x.shape
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x)
    q, k = _apply_positional(cfg, q, k, positions)
    k = _repeat_kv(k, cfg.q_per_kv)
    v = _repeat_kv(v, cfg.q_per_kv)

    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, dh)
        v_t = jnp.transpose(v, (0, 2, 1, 3))  # [B,H,T,dv]
        if rm_fuse_enabled(cfg):
            # fused path: q/k go in RAW (pre-scaled); Z never touches HBM
            qs, ks, w, cd, cs = _rm_fused_operands(params, cfg, meta, q, k)
            fused_op = (rm_attention_fused_causal if cfg.causal
                        else rm_attention_fused_noncausal)
            out = fused_op(qs, ks, v_t, w, cd, cs, chunk=cfg.rm.chunk,
                           eps=cfg.rm.eps)
        else:
            zq = _rm_featurize(params, cfg, meta, q)
            zk = _rm_featurize(params, cfg, meta, k)
            if cfg.causal:
                out = rm_attention_causal(
                    zq, zk, v_t, chunk=cfg.rm.chunk, eps=cfg.rm.eps
                )
            else:
                out = rm_attention_noncausal(zq, zk, v_t, eps=cfg.rm.eps)
        out = jnp.transpose(out, (0, 2, 1, 3)).astype(x.dtype)
    else:
        out = _softmax_attention(cfg, q, k, v, positions, positions)

    return out.reshape(b, t, h * dh) @ params["wo"]


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------
def init_attention_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype
) -> Dict[str, jax.Array]:
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, dh)
        f = meta.output_dim
        return {
            "rm_s": jnp.zeros((batch, h, f, dh), jnp.float32),
            "rm_n": jnp.zeros((batch, h, f), jnp.float32),
        }
    window = cfg.sliding_window or max_len
    size = min(max_len, window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, size, hkv, dh), dtype),
        "v": jnp.zeros((batch, size, hkv, dh), dtype),
    }


def attention_decode(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,           # [B, 1, d]
    cache: Dict[str, jax.Array],
    positions: jax.Array,   # [B] current position of the new token
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    b = x.shape[0]
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x)          # [B,1,*,dh]
    q, k = _apply_positional(cfg, q, k, positions[:, None])

    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, dh)
        k = _repeat_kv(k, cfg.q_per_kv)
        v = _repeat_kv(v, cfg.q_per_kv)
        v0 = jnp.transpose(v, (0, 2, 1, 3))[:, :, 0]       # [B,H,dv]
        if rm_fuse_enabled(cfg):
            # one featurize launch per token (q and k ride together)
            # instead of two — see rm_attention_fused_decode_step
            from repro.kernels.rm_attention.ops import (
                rm_attention_fused_decode_step,
            )

            qs, ks, w, cd, cs = _rm_fused_operands(params, cfg, meta, q, k)
            out, s_new, n_new = rm_attention_fused_decode_step(
                qs[:, :, 0], ks[:, :, 0], v0, cache["rm_s"], cache["rm_n"],
                w, cd, cs, eps=cfg.rm.eps)
        else:
            zq = _rm_featurize(params, cfg, meta, q)[:, :, 0]  # [B,H,F]
            zk = _rm_featurize(params, cfg, meta, k)[:, :, 0]
            out, s_new, n_new = rm_attention_decode_step(
                zq, zk, v0, cache["rm_s"], cache["rm_n"], eps=cfg.rm.eps
            )
        y = out[:, None].reshape(b, 1, h * dh).astype(x.dtype) @ params["wo"]
        return y, {"rm_s": s_new, "rm_n": n_new}

    # exact: ring-buffer write at slot positions % size
    size = cache["k"].shape[1]
    slots = (positions % size).astype(jnp.int32)
    bidx = jnp.arange(b)
    k_cache = cache["k"].at[bidx, slots].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slots].set(v[:, 0].astype(cache["v"].dtype))

    # positions stored in each slot (for mask + rope-consistency)
    slot_ids = jnp.arange(size)[None, :]                    # [1, S]
    # slot s holds absolute position: the largest p <= positions with p%size==s
    abs_pos = positions[:, None] - ((positions[:, None] - slot_ids) % size)
    valid = abs_pos >= 0
    if cfg.sliding_window > 0:
        valid &= (positions[:, None] - abs_pos) < cfg.sliding_window

    kk = _repeat_kv(k_cache, cfg.q_per_kv)
    vv = _repeat_kv(v_cache, cfg.q_per_kv)
    scores = jnp.einsum(
        "bhd,bshd->bhs", q[:, 0].astype(jnp.float32), kk.astype(jnp.float32)
    ) / math.sqrt(dh)
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhs,bshd->bhd", probs.astype(vv.dtype), vv)
    y = out.reshape(b, 1, h * dh) @ params["wo"]
    return y, {"k": k_cache, "v": v_cache}


def attention_prefill_cache(
    params: Params,
    cfg: ModelConfig,
    x: jax.Array,          # [B, T, d] prompt
    positions: jax.Array,  # [B, T]
    max_len: int,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Run prefill AND build the decode cache in one pass."""
    b, t, _ = x.shape
    h, dh = cfg.num_heads, cfg.resolved_head_dim
    q, k, v = _project_qkv(params, cfg, x)
    q, k = _apply_positional(cfg, q, k, positions)

    if cfg.attention_mode == "rm":
        meta = rm_plan_for(cfg, dh)
        kr = _repeat_kv(k, cfg.q_per_kv)
        vr = _repeat_kv(v, cfg.q_per_kv)
        v_t = jnp.transpose(vr, (0, 2, 1, 3))
        if rm_fuse_enabled(cfg):
            # fused prefill: causal outputs AND the O(1) decode state from
            # ONE launch (the kernel's state scratch holds the full-prefix
            # state after the last chunk); padded prompt positions are
            # masked via kvalid instead of zeroing a materialized Z(k)
            qs, ks, w, cd, cs = _rm_fused_operands(params, cfg, meta, q, kr)
            kvalid = (positions >= 0).astype(jnp.float32)
            out, s, n = rm_attention_fused_prefill(
                qs, ks, v_t, w, cd, cs, kvalid=kvalid, chunk=cfg.rm.chunk,
                eps=cfg.rm.eps)
        else:
            zq = _rm_featurize(params, cfg, meta, q)
            # padded prompt positions (bucketed prefill) must not pollute
            # the prefix sums or the O(1) decode state
            zk = rm_valid_mask(_rm_featurize(params, cfg, meta, kr),
                               positions)
            out = rm_attention_causal(zq, zk, v_t, chunk=cfg.rm.chunk,
                                      eps=cfg.rm.eps)
            s, n = rm_attention_prefill_final_state(zk, v_t)
        y = jnp.transpose(out, (0, 2, 1, 3)).astype(x.dtype)
        y = y.reshape(b, t, h * dh) @ params["wo"]
        return y, {"rm_s": s, "rm_n": n}

    kr = _repeat_kv(k, cfg.q_per_kv)
    vr = _repeat_kv(v, cfg.q_per_kv)
    out = _softmax_attention(cfg, q, kr, vr, positions, positions)
    y = out.reshape(b, t, h * dh) @ params["wo"]

    cache = init_attention_cache(cfg, b, max_len, k.dtype)
    size = cache["k"].shape[1]
    if t <= size:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        )
    else:  # keep last `size` tokens (ring layout: slot = pos % size)
        k_tail = k[:, -size:]
        v_tail = v[:, -size:]
        tail_pos = positions[:, -size:]
        slots = (tail_pos % size).astype(jnp.int32)
        bidx = jnp.arange(b)[:, None]
        k_cache = cache["k"].at[bidx, slots].set(k_tail.astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, slots].set(v_tail.astype(cache["v"].dtype))
    return y, {"k": k_cache, "v": v_cache}
