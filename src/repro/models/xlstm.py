"""xLSTM blocks: mLSTM (matrix memory, parallel form) and sLSTM (scalar
memory, strictly sequential scan with memory mixing).

The mLSTM's exp-gated outer-product state is a *gated* cousin of the RM
linear-attention state (both keep sum_s w_s k_s v_s^T); the connection is
noted in DESIGN.md §6 — but xlstm is attention-free, so the paper's RM
technique is not applied here (assignment's arch-applicability rule).

Training uses the stabilized quadratic masked form for mLSTM (O(T^2), like
exact attention) and a lax.scan for sLSTM. Decode for both is O(1)/token.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import normal_init

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    pf = cfg.xlstm.proj_factor
    d_up = int(pf * d)
    dh = d_up // h
    ks = jax.random.split(key, 8)
    std = cfg.init_std
    return {
        "w_up": normal_init(ks[0], (d, 2 * d_up), std, dtype),
        "conv_w": normal_init(ks[1], (cfg.xlstm.conv_kernel, d_up), std, dtype),
        "conv_b": jnp.zeros((d_up,), dtype),
        "wq": normal_init(ks[2], (d_up, d_up), std, dtype),
        "wk": normal_init(ks[3], (d_up, d_up), std, dtype),
        "wv": normal_init(ks[4], (d_up, d_up), std, dtype),
        "w_if": normal_init(ks[5], (d_up, 2 * h), std, dtype),
        "b_if": jnp.concatenate(
            [jnp.zeros((h,)), jnp.full((h,), 3.0)]
        ).astype(dtype),  # forget-gate bias init high
        "gn_scale": jnp.ones((d_up,), dtype),
        "w_down": normal_init(ks[6], (d_up, d), std, dtype),
    }


def _mlstm_qkv_gates(params: Params, cfg: ModelConfig, xu: jax.Array,
                     conv_state=None):
    """xu: [B, T, d_up] -> q, k, v [B,T,H,dh]; i, f logits [B,T,H]."""
    h = cfg.num_heads
    kk = cfg.xlstm.conv_kernel
    if conv_state is None:
        pad = jnp.zeros((xu.shape[0], kk - 1, xu.shape[2]), xu.dtype)
    else:
        pad = conv_state.astype(xu.dtype)
    xp = jnp.concatenate([pad, xu], axis=1)
    xc = sum(
        xp[:, i : i + xu.shape[1]] * params["conv_w"][i].astype(xu.dtype)
        for i in range(kk)
    ) + params["conv_b"].astype(xu.dtype)
    xc = jax.nn.silu(xc)
    b, t, d_up = xu.shape
    dh = d_up // h
    q = (xc @ params["wq"]).reshape(b, t, h, dh)
    k = (xc @ params["wk"]).reshape(b, t, h, dh) / math.sqrt(dh)
    v = (xu @ params["wv"]).reshape(b, t, h, dh)
    gates = (xc @ params["w_if"] + params["b_if"].astype(xu.dtype)).astype(
        jnp.float32
    )
    i_log, f_log = gates[..., :h], gates[..., h:]
    new_conv = xp[:, -(kk - 1):]
    return q, k, v, i_log, f_log, new_conv


def _mlstm_cell_chunked(cfg: ModelConfig, q, k, v, i_log, f_log):
    """Stabilized chunkwise-parallel mLSTM.

    Sequence is cut into ``cfg.xlstm.chunk`` slices; within a chunk the
    (t, s) weight matrix is quadratic (bounded [C, C]); across chunks the
    matrix memory (C_state, n_state, m_state) recurs through a lax.scan —
    peak memory O(T*C) instead of O(T^2).

    Stabilization: every weight exp(.) is computed relative to a per-step
    max ``m_t = max(intra-chunk max, b_t + m_state)`` exactly like the
    sequential recurrence, so the chunked form is bit-comparable to
    ``mlstm_decode`` rolled T times (tested).
    """
    b, t, h, dh = q.shape
    chunk = min(cfg.xlstm.chunk, t)
    pad = (-t) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_log = jnp.pad(i_log, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)
        f_log = jnp.pad(f_log, ((0, 0), (0, pad), (0, 0)))
    tp = t + pad
    n_ch = tp // chunk

    def to_chunks(x_, extra=()):
        return x_.reshape(b, n_ch, chunk, *x_.shape[2:]).swapaxes(0, 1)

    q_c, k_c, v_c = to_chunks(q), to_chunks(k), to_chunks(v)
    i_c, f_c = to_chunks(i_log), to_chunks(f_log)

    def chunk_step(carry, inp):
        c_state, n_state, m_state = carry        # [B,H,dh,dh],[B,H,dh],[B,H]
        qq, kk, vv, ii, ff = inp                 # [B,C,H,*]
        logf = jax.nn.log_sigmoid(ff.astype(jnp.float32))   # [B,C,H]
        bcum = jnp.cumsum(logf, axis=1)                     # inclusive
        btot = bcum[:, -1]                                  # [B,H]
        ii = ii.astype(jnp.float32)

        # per-step stabilizer: intra max over s<=t of (b_t - b_s + i_s),
        # inter term b_t + m_state
        lw_intra = (bcum[:, :, None, :] - bcum[:, None, :, :]
                    + ii[:, None, :, :])                    # [B,Ct,Cs,H]
        mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))[None, :, :,
                                                              None]
        lw_intra = jnp.where(mask, lw_intra, -1e30)
        m_intra = jnp.max(lw_intra, axis=2)                 # [B,Ct,H]
        m_inter = bcum + m_state[:, None, :]                # [B,Ct,H]
        m_t = jnp.maximum(m_intra, m_inter)

        w_intra = jnp.exp(lw_intra - m_t[:, :, None, :])    # [B,Ct,Cs,H]
        scores = jnp.einsum("bqhd,bshd->bqsh", qq.astype(jnp.float32),
                            kk.astype(jnp.float32)) * w_intra
        num = jnp.einsum("bqsh,bshd->bqhd", scores, vv.astype(jnp.float32))
        den = jnp.sum(scores, axis=2)                       # [B,Ct,H]

        w_inter = jnp.exp(m_inter - m_t)                    # [B,Ct,H]
        q_eff = qq.astype(jnp.float32) * w_inter[..., None]
        num += jnp.einsum("bqhd,bhdv->bqhv", q_eff, c_state)
        den += jnp.einsum("bqhd,bhd->bqh", q_eff, n_state)

        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
        out = num / den[..., None]                          # [B,Ct,H,dh]

        # state update to end of chunk
        m_new = jnp.maximum(m_state + btot,
                            jnp.max(btot[:, None] - bcum + ii, axis=1))
        w_st = jnp.exp(btot[:, None] - bcum + ii - m_new[:, None])  # [B,C,H]
        c_new = (jnp.exp(m_state + btot - m_new)[..., None, None] * c_state
                 + jnp.einsum("bsh,bshd,bshv->bhdv", w_st,
                              kk.astype(jnp.float32),
                              vv.astype(jnp.float32)))
        n_new = (jnp.exp(m_state + btot - m_new)[..., None] * n_state
                 + jnp.einsum("bsh,bshd->bhd", w_st, kk.astype(jnp.float32)))
        return (c_new, n_new, m_new), out

    c0 = jnp.zeros((b, h, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    (_, _, _), outs = jax.lax.scan(chunk_step, (c0, n0, m0),
                                   (q_c, k_c, v_c, i_c, f_c))
    out = outs.swapaxes(0, 1).reshape(b, tp, h, dh)[:, :t]
    return out


def mlstm_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions=None) -> jax.Array:
    """Chunkwise-parallel stabilized mLSTM. x: [B, T, d]."""
    b, t, d = x.shape
    up = x @ params["w_up"]
    xu, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_log, f_log, _ = _mlstm_qkv_gates(params, cfg, xu)
    out = _mlstm_cell_chunked(cfg, q, k, v, i_log, f_log)
    out = out.reshape(b, t, -1)
    out = _group_norm(out, params["gn_scale"], cfg.num_heads, cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32))
    return out.astype(x.dtype) @ params["w_down"]


def _group_norm(x: jax.Array, scale: jax.Array, groups: int, eps: float):
    """Per-head group norm over the feature dim. x: [..., d_up] fp32."""
    shape = x.shape
    xg = x.reshape(*shape[:-1], groups, shape[-1] // groups)
    mean = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(shape) * scale.astype(x.dtype)


def mlstm_prefill_cache(params: Params, cfg: ModelConfig, x: jax.Array,
                        positions, max_len: int):
    """Forward + closed-form final (C, n, m) state for decode handoff.

    m_T = max_s (i_s + F_T - F_s) with F the cumulative log-forget sums;
    C_T = sum_s exp(i_s + F_T - F_s - m_T) k_s v_s^T (and n likewise).
    """
    b, t, d = x.shape
    y = mlstm_forward(params, cfg, x, positions)
    up = x @ params["w_up"]
    xu, _ = jnp.split(up, 2, axis=-1)
    q, k, v, i_log, f_log, conv_state = _mlstm_qkv_gates(params, cfg, xu)
    logf = jax.nn.log_sigmoid(f_log)
    f_cum = jnp.cumsum(logf, axis=1)                 # [B,T,H]
    f_total = f_cum[:, -1:]
    lw = i_log + f_total - f_cum                     # [B,T,H]
    m = jnp.max(lw, axis=1)                          # [B,H]
    w = jnp.exp(lw - m[:, None, :])                  # [B,T,H]
    c_state = jnp.einsum("bth,bthd,bthv->bhdv", w, k.astype(jnp.float32),
                         v.astype(jnp.float32))
    n_state = jnp.einsum("bth,bthd->bhd", w, k.astype(jnp.float32))
    return y, {"conv": conv_state, "c": c_state, "n": n_state, "m": m}


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype):
    h = cfg.num_heads
    d_up = int(cfg.xlstm.proj_factor * cfg.d_model)
    dh = d_up // h
    return {
        "conv": jnp.zeros((batch, cfg.xlstm.conv_kernel - 1, d_up), dtype),
        "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, h, dh), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(params: Params, cfg: ModelConfig, x: jax.Array, cache,
                 positions=None):
    b = x.shape[0]
    up = x @ params["w_up"]
    xu, z = jnp.split(up, 2, axis=-1)
    q, k, v, i_log, f_log, conv_state = _mlstm_qkv_gates(
        params, cfg, xu, conv_state=cache["conv"]
    )
    q, k, v = q[:, 0], k[:, 0], v[:, 0]              # [B,H,dh]
    i_log, f_log = i_log[:, 0], f_log[:, 0]          # [B,H]
    logf = jax.nn.log_sigmoid(f_log)
    m_new = jnp.maximum(logf + cache["m"], i_log)
    f_eff = jnp.exp(logf + cache["m"] - m_new)
    i_eff = jnp.exp(i_log - m_new)
    c_new = (
        f_eff[..., None, None] * cache["c"]
        + i_eff[..., None, None] * (k[..., :, None] * v[..., None, :])
    )
    n_new = f_eff[..., None] * cache["n"] + i_eff[..., None] * k
    num = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), c_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n_new))
    den = jnp.maximum(den, jnp.exp(-m_new))
    out = (num / den[..., None]).reshape(b, 1, -1)
    out = _group_norm(out, params["gn_scale"], cfg.num_heads, cfg.norm_eps)
    out = out * jax.nn.silu(z.astype(jnp.float32))
    y = out.astype(x.dtype) @ params["w_down"]
    return y, {"conv": conv_state, "c": c_new, "n": n_new, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(cfg: ModelConfig, key: jax.Array, dtype) -> Params:
    d = cfg.d_model
    h = cfg.num_heads
    dh = d // h
    ks = jax.random.split(key, 4)
    std = cfg.init_std
    d_ff = int(cfg.xlstm.slstm_ff_factor * d)
    return {
        # input weights for (z, i, f, o)
        "w_in": normal_init(ks[0], (d, 4 * d), std, dtype),
        "b_in": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 3.0), jnp.zeros((d,))]
        ).astype(dtype),
        # block-diagonal recurrent mixing: per head [dh, dh] for each gate
        "r_rec": normal_init(ks[1], (4, h, dh, dh), std / math.sqrt(dh),
                             jnp.float32),
        "gn_scale": jnp.ones((d,), dtype),
        "ff_up": normal_init(ks[2], (d, d_ff), std, dtype),
        "ff_down": normal_init(ks[3], (d_ff, d), std, dtype),
    }


def _slstm_cell(params: Params, cfg: ModelConfig, wx: jax.Array, state):
    """wx: [B, 4, H, dh] precomputed input contribution; one time step."""
    h_prev, c_prev, n_prev, m_prev = state                 # [B,H,dh] x3, [B,H,dh]
    hh = cfg.num_heads
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, params["r_rec"])
    pre = wx.astype(jnp.float32) + rec                     # [B,4,H,dh]
    z_t = jnp.tanh(pre[:, 0])
    i_log = pre[:, 1]
    f_log = jax.nn.log_sigmoid(pre[:, 2])
    o_t = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(f_log + m_prev, i_log)
    i_eff = jnp.exp(i_log - m_new)
    f_eff = jnp.exp(f_log + m_prev - m_new)
    c_new = f_eff * c_prev + i_eff * z_t
    n_new = f_eff * n_prev + i_eff
    h_new = o_t * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, c_new, n_new, m_new


def slstm_forward(params: Params, cfg: ModelConfig, x: jax.Array,
                  positions=None) -> jax.Array:
    b, t, d = x.shape
    h = cfg.num_heads
    dh = d // h
    wx = (x @ params["w_in"] + params["b_in"].astype(x.dtype)).reshape(
        b, t, 4, h, dh
    )

    def step(state, wx_t):
        h_new, c, n, m = _slstm_cell(params, cfg, wx_t, state)
        return (h_new, c, n, m), h_new

    zeros = jnp.zeros((b, h, dh), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((b, h, dh), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(wx, 1, 0))
    out = jnp.moveaxis(hs, 0, 1).reshape(b, t, d)
    out = _group_norm(out, params["gn_scale"], h, cfg.norm_eps)
    y = out.astype(x.dtype)
    # post-cell feed-forward (xLSTM block's ff, gelu)
    return jax.nn.gelu(y @ params["ff_up"]) @ params["ff_down"]


def slstm_prefill_cache(params: Params, cfg: ModelConfig, x: jax.Array,
                        positions, max_len: int):
    """Forward + final recurrent state (the scan's carry)."""
    b, t, d = x.shape
    h = cfg.num_heads
    dh = d // h
    wx = (x @ params["w_in"] + params["b_in"].astype(x.dtype)).reshape(
        b, t, 4, h, dh
    )

    def step(state, wx_t):
        h_new, c, n, m = _slstm_cell(params, cfg, wx_t, state)
        return (h_new, c, n, m), h_new

    zeros = jnp.zeros((b, h, dh), jnp.float32)
    state0 = (zeros, zeros, zeros, jnp.full((b, h, dh), -1e30, jnp.float32))
    (h_f, c_f, n_f, m_f), hs = jax.lax.scan(step, state0,
                                            jnp.moveaxis(wx, 1, 0))
    out = jnp.moveaxis(hs, 0, 1).reshape(b, t, d)
    out = _group_norm(out, params["gn_scale"], h, cfg.norm_eps)
    y = out.astype(x.dtype)
    y = jax.nn.gelu(y @ params["ff_up"]) @ params["ff_down"]
    return y, {"h": h_f, "c": c_f, "n": n_f, "m": m_f}


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype):
    h = cfg.num_heads
    dh = cfg.d_model // h
    zeros = jnp.zeros((batch, h, dh), jnp.float32)
    return {
        "h": zeros,
        "c": zeros,
        "n": zeros,
        "m": jnp.full((batch, h, dh), -1e30, jnp.float32),
    }


def slstm_decode(params: Params, cfg: ModelConfig, x: jax.Array, cache,
                 positions=None):
    b, _, d = x.shape
    h = cfg.num_heads
    dh = d // h
    wx = (x @ params["w_in"] + params["b_in"].astype(x.dtype)).reshape(
        b, 4, h, dh
    )
    state = (cache["h"], cache["c"], cache["n"], cache["m"])
    h_new, c, n, m = _slstm_cell(params, cfg, wx, state)
    out = h_new.reshape(b, 1, d)
    out = _group_norm(out, params["gn_scale"], h, cfg.norm_eps)
    y = out.astype(x.dtype)
    y = jax.nn.gelu(y @ params["ff_up"]) @ params["ff_down"]
    return y, {"h": h_new, "c": c, "n": n, "m": m}
