"""Roofline-term derivation from compiled XLA artifacts (no hardware).

Per (arch x shape x mesh) the dry-run produces a compiled SPMD program; from
it we derive the three roofline terms (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
    memory     = HLO_bytes_per_device / HBM_bw_per_chip
    collective = sum(collective payload bytes per device) / link_bw

Notes on sources:
  * ``compiled.cost_analysis()`` reports per-device FLOPs/bytes for the SPMD
    partitioned module (shapes in the HLO are shard shapes).
  * collective bytes are NOT in cost_analysis: we parse the post-optimization
    HLO text and sum RESULT-shape bytes of every all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (result-shape ==
    received payload per device; all-reduce counted twice — reduce-scatter +
    all-gather phases of a ring).
  * Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
    ~50 GB/s/link ICI (conservative single-link figure).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches e.g. "f32[16,128,1024]{2,1,0}" or "bf16[8]"
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu_v5e"
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link


HW_V5E = HardwareSpec()


def _shape_bytes(segment: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(segment):
        if dtype not in _DTYPE_BYTES:
            continue
        if dims == "":
            n = 1
        else:
            n = int(np.prod([int(d) for d in dims.split(",")]))
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Sum per-device payload bytes by collective type from HLO text.

    Counts each op's RESULT shapes (the bytes received per device). The
    ``*-start`` async forms are counted; their ``*-done`` twins are skipped
    (same payload, would double count).
    """
    out: Dict[str, Dict[str, float]] = {
        c: {"bytes": 0.0, "count": 0} for c in _COLLECTIVES
    }
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        for coll in _COLLECTIVES:
            # e.g. "%ar = f32[..] all-reduce(" / "all-reduce-start("
            m = re.search(rf"=\s+(.*?)\s+{coll}(-start)?\(", line)
            if m is None:
                continue
            if f"{coll}-done" in line:
                continue
            payload = _shape_bytes(m.group(1))
            out[coll]["bytes"] += payload
            out[coll]["count"] += 1
            break
    return out


def _maybe(obj, attr):
    try:
        v = getattr(obj, attr)
        return v() if callable(v) else v
    except Exception:
        return None


def memory_analysis_dict(compiled) -> Dict[str, Optional[float]]:
    ma = None
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    )
    return {k: _maybe(ma, k) for k in keys}


def roofline_from_compiled(
    compiled,
    num_devices: int,
    hw: HardwareSpec = HW_V5E,
    hlo_text: Optional[str] = None,
) -> Dict[str, Any]:
    """The three roofline terms + raw counters for one compiled step."""
    cost = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # older jax returns [dict]
            ca = ca[0]
        cost = dict(ca) if ca else {}
    except Exception:
        pass
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    text = hlo_text
    if text is None:
        try:
            text = compiled.as_text()
        except Exception:
            text = ""
    colls = collective_bytes_from_hlo(text or "")
    coll_bytes = sum(v["bytes"] for v in colls.values())

    t_comp = flops / hw.peak_flops
    t_mem = bytes_accessed / hw.hbm_bw
    t_coll = coll_bytes / hw.link_bw
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    return {
        "hw": hw.name,
        "num_devices": num_devices,
        "per_device_flops": flops,
        "per_device_bytes": bytes_accessed,
        "per_device_collective_bytes": coll_bytes,
        "collectives": colls,
        **terms,
        "dominant": dominant.replace("_s", ""),
        "memory_analysis": memory_analysis_dict(compiled),
    }


# ---------------------------------------------------------------------------
# inner-loop flop corrections
# ---------------------------------------------------------------------------
# XLA's HloCostAnalysis counts a while-loop body ONCE. The dry-run unrolls
# the LAYER scan (so per-layer ops and all collectives are exact), but
# within-layer chunk loops — blockwise exact attention, the Mamba chunk
# scan, the chunkwise mLSTM, the sequential sLSTM — remain loops. Their
# missing (trips - 1) * body_flops is added analytically here and reported
# as ``hlo_flops_corrected``. Formulas are documented per family; bytes are
# NOT corrected (the memory term carries a CPU-backend no-fusion bias that
# dwarfs this — see EXPERIMENTS.md §Roofline methodology).
_ATTN_BLOCK = 1024  # matches attention._BLOCK_Q/_BLOCK_K


def analytic_inner_loop_flops(cfg, seq_len: int, global_batch: int,
                              kind: str) -> float:
    """GLOBAL missing flops from loop bodies counted once (fwd+bwd)."""
    if kind == "decode":
        return 0.0  # single-token steps have no inner chunk loops
    t, b = seq_len, global_batch
    # train: fwd(1) + remat fwd(1) + bwd(2) instances of each loop; the HLO
    # contains each loop ~3x (fwd, recompute, bwd) each counted once, so the
    # missing multiplier is (trips-1) per instance ~= (trips-1)*4 flops-wise.
    factor = 4.0 if kind == "train" else 1.0
    missing = 0.0
    n_layers = cfg.num_layers
    pattern = list(cfg.block_pattern) * cfg.num_scanned_groups
    pattern = [cfg.block_pattern[0]] * cfg.first_k_dense + pattern

    for kind_b in pattern:
        mixer = kind_b.split("_")[0]
        if mixer in ("attn", "mla") and cfg.attention_mode == "exact" \
                and t > 2048:
            h = cfg.num_heads
            dh = (cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
                  if mixer == "mla" else cfg.resolved_head_dim)
            dv = cfg.mla.v_head_dim if mixer == "mla" else cfg.resolved_head_dim
            bq = bk = min(_ATTN_BLOCK, t)
            nq, nk = -(-t // bq), -(-t // bk)
            trips = nq * nk
            body = 2.0 * b * h * bq * bk * (dh + dv)  # scores + pv matmuls
            missing += (trips - 1) * body * factor
        elif mixer == "mamba":
            mc = cfg.mamba
            d_in = mc.expand * cfg.d_model
            c = min(mc.scan_chunk, t)
            trips = -(-t // c)
            import math as _math

            logc = max(1.0, _math.log2(c))
            # assoc-scan (~4 flops/elem/level) + y-einsum + gates
            body = b * c * d_in * mc.d_state * (4.0 * logc + 8.0)
            missing += (trips - 1) * body * factor
        elif mixer == "mlstm":
            h = cfg.num_heads
            d_up = int(cfg.xlstm.proj_factor * cfg.d_model)
            dh = d_up // h
            c = min(cfg.xlstm.chunk, t)
            trips = -(-t // c)
            body = b * h * (4.0 * c * c * dh + 8.0 * c * dh * dh)
            missing += (trips - 1) * body * factor
        elif mixer == "slstm":
            h = cfg.num_heads
            dh = cfg.d_model // h
            body = b * h * (8.0 * dh * dh + 40.0 * dh)
            missing += (t - 1) * body * factor
    return missing


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful-work reference)
# ---------------------------------------------------------------------------
def count_params(shapes_tree, active_moe_fraction: Optional[float] = None):
    """(total, active) param counts from a ShapeDtypeStruct tree.

    ``active``: MoE expert weights scaled by top_k/num_experts (leaves under
    a "moe" path named w_gate/w_up/w_down).
    """
    import jax

    total = 0
    active = 0

    def _walk(path, node):
        nonlocal total, active
        if isinstance(node, dict):
            for k, v in node.items():
                _walk(path + (k,), v)
            return
        n = int(np.prod(node.shape))
        total += n
        frac = 1.0
        if active_moe_fraction is not None and "moe" in path and \
                path[-1] in ("w_gate", "w_up", "w_down"):
            frac = active_moe_fraction
        active += int(n * frac)

    _walk((), shapes_tree)
    return total, active


def model_flops(kind: str, n_active_params: int, tokens: int) -> float:
    """6*N*D for training, 2*N*D for inference forward passes."""
    if kind == "train":
        return 6.0 * n_active_params * tokens
    return 2.0 * n_active_params * tokens
