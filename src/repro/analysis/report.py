"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from results JSONs.

    PYTHONPATH=src python -m repro.analysis.report
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}GB"


def load(mesh: str):
    recs = []
    base = RESULTS / mesh
    if not base.exists():
        return recs
    for p in sorted(base.glob("*.json")):
        if p.name.endswith(".FAILED.json"):
            continue
        recs.append(json.loads(p.read_text()))
    return recs


def dryrun_table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | mode | compile | fits HBM | args/dev | temp/dev "
        "(scanned) | collectives (per-dev bytes: AR/AG/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r.get("skipped"):
            rows.append(
                f"| {r['arch']} | {r['shape']} | - | SKIP | - | - | - | "
                f"{r['skip_reason']} |")
            continue
        ma = r.get("memory_analysis_scanned") or r.get("memory_analysis") or {}
        c = r["collectives"]
        coll = "/".join(
            _fmt_bytes(c[k]["bytes"]) for k in
            ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
        )
        accum = r.get("grad_accum")
        mode = r["attention_mode"] + (f",ga{accum}" if accum and accum > 1
                                      else "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {mode} | "
            f"{r['compile_s']:.0f}s | {r.get('fits_hbm')} | "
            f"{_fmt_bytes(ma.get('argument_size_in_bytes'))} | "
            f"{_fmt_bytes(ma.get('temp_size_in_bytes'))} | {coll} |"
        )
    return "\n".join(rows)


def roofline_table(mesh: str = "single") -> str:
    rows = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS | HLO_FLOPS (corr) | useful ratio | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r.get("skipped"):
            continue
        comp = r.get("compute_s_corrected", r.get("compute_s"))
        terms = {"compute": comp, "memory": r["memory_s"],
                 "collective": r["collective_s"]}
        dom = max(terms, key=terms.get)
        note = _bottleneck_note(r, dom)
        if r.get("approx_scaled_by_groups"):
            note = f"[≈ scanned×{r['approx_scaled_by_groups']}] " + note
        rows.append(
            f"| {r['arch']} | {r['shape']} | {comp:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | {dom} | "
            f"{r['model_flops']:.3g} | {r['hlo_flops_corrected']:.3g} | "
            f"{r['useful_flops_ratio']:.3f} | {note} |"
        )
    return "\n".join(rows)


def _bottleneck_note(r, dom) -> str:
    kind = r["kind"]
    if dom == "collective":
        big = max(r["collectives"], key=lambda k: r["collectives"][k]["bytes"])
        return (f"{big} dominates — reshard/overlap it")
    if dom == "memory":
        if kind == "decode":
            return "cache/weight streaming bound (expected for decode)"
        return "bytes-accessed bound; fuse casts / shrink materialized acts"
    return "compute-bound — good; push MXU utilization"


def main():
    print("## §Dry-run (single-pod 16x16)\n")
    print(dryrun_table("single"))
    print("\n## §Dry-run (multi-pod 2x16x16)\n")
    print(dryrun_table("multi"))
    print("\n## §Roofline (single-pod, per-device terms)\n")
    print(roofline_table("single"))


if __name__ == "__main__":
    main()
