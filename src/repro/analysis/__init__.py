from repro.analysis.roofline import (
    HW_V5E,
    HardwareSpec,
    collective_bytes_from_hlo,
    roofline_from_compiled,
    model_flops,
)

__all__ = [
    "HW_V5E",
    "HardwareSpec",
    "collective_bytes_from_hlo",
    "roofline_from_compiled",
    "model_flops",
]
