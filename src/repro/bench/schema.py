"""The canonical BENCH_core.json schema + coverage checks.

One JSON layout for every benchmark artifact the repo commits
(``BENCH_core.json`` at the root, plus the thin-CLI outputs): a payload
header (schema version, backend, interpret/quick flags) and per-shape
entries whose ``cells`` map ``"<estimator>/<precision>"`` to the measured
metrics. The CI ``bench-core`` job calls ``check_payload`` on BOTH the
fresh artifact and the committed file and fails on any missing cell, so
"full estimator x {fp32, bf16} x shape coverage" is a gate, not a habit.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence

__all__ = [
    "SCHEMA_VERSION",
    "SERVING_SCHEMA_VERSION",
    "REQUIRED_CELL_KEYS",
    "ATTN_REQUIRED_CELL_KEYS",
    "SERVING_REQUIRED_SLO_KEYS",
    "cell_key",
    "check_payload",
    "check_file",
    "check_serving_payload",
    "diff_coverage",
]

# v2: adds the required ``fused_attention`` section (fused featurize+
# attention vs two-launch composition, DESIGN.md §13).
SCHEMA_VERSION = 2

# Every cell must carry these metrics (runner.run_cell emits a superset).
REQUIRED_CELL_KEYS = (
    "fused_us",
    "oracle_us",
    "fused_feats_per_s",
    "output_dim",
    "gram_rmse",
    "flops",
    "bytes_moved",
)

# Every fused_attention cell must carry these. ``fused_supported`` mirrors
# the registry capability flag: families without a fused attention path
# (tensor_sketch, ctr) measure the two-launch fallback for BOTH timing
# columns, so speedup == 1.0 there by construction.
ATTN_REQUIRED_CELL_KEYS = (
    "fused_us",
    "two_launch_us",
    "speedup",
    "hbm_bytes_fused",
    "hbm_bytes_two_launch",
    "fused_supported",
)

_REQUIRED_SHAPE_KEYS = ("kernel", "d", "F", "batch", "cells")

# The optional ``selection`` section (repro.core.select.selection_section):
# per benched shape, the (estimator, D, precision) decision table at a
# small (eps, delta) target grid, priced from the payload's own rows.
# Optional because thin CLI outputs predate it; when PRESENT it must be
# complete — every results shape gets a decision list and every decision
# carries the accuracy contract fields.
_REQUIRED_SELECTION_KEYS = ("targets", "measure", "radius", "decisions")

_REQUIRED_DECISION_KEYS = ("estimator", "precision", "num_features",
                           "eps", "delta", "eps_certified")

_REQUIRED_ATTN_SHAPE_KEYS = ("kernel", "d", "F", "heads", "T", "dv",
                             "batch", "chunk", "cells")


def cell_key(estimator: str, precision: str) -> str:
    """The canonical cell id: ``"<estimator>/<precision>"``."""
    return f"{estimator}/{precision}"


def check_payload(
    payload: Dict,
    *,
    estimators: Optional[Sequence[str]] = None,
    precisions: Sequence[str] = ("fp32", "bf16"),
    min_shapes: int = 3,
) -> List[str]:
    """Return a list of human-readable schema/coverage violations.

    ``estimators=None`` checks against the live registry, so a newly
    registered family makes stale artifacts fail loudly in CI instead of
    silently dropping out of the trajectory.
    """
    if estimators is None:
        from repro.core import registry

        estimators = registry.list_estimators()
    errors: List[str] = []
    if payload.get("schema_version") != SCHEMA_VERSION:
        errors.append(
            f"schema_version {payload.get('schema_version')!r} != "
            f"{SCHEMA_VERSION}"
        )
    results = payload.get("results")
    if not isinstance(results, dict) or not results:
        return errors + ["payload has no results"]
    if len(results) < min_shapes:
        errors.append(f"only {len(results)} shapes, need >= {min_shapes}")
    for label, entry in results.items():
        for k in _REQUIRED_SHAPE_KEYS:
            if k not in entry:
                errors.append(f"{label}: missing shape key {k!r}")
        cells = entry.get("cells", {})
        for est in estimators:
            for prec in precisions:
                ck = cell_key(est, prec)
                if ck not in cells:
                    errors.append(f"{label}: missing cell {ck}")
                    continue
                for mk in REQUIRED_CELL_KEYS:
                    if mk not in cells[ck]:
                        errors.append(f"{label}/{ck}: missing metric {mk!r}")

    selection = payload.get("selection")
    if selection is not None:
        for k in _REQUIRED_SELECTION_KEYS:
            if k not in selection:
                errors.append(f"selection: missing key {k!r}")
        decisions = selection.get("decisions")
        if isinstance(decisions, dict):
            n_targets = len(selection.get("targets") or [])
            for label in results:
                decs = decisions.get(label)
                if decs is None:
                    errors.append(f"selection: no decisions for shape "
                                  f"{label}")
                    continue
                if n_targets and len(decs) != n_targets:
                    errors.append(
                        f"selection/{label}: {len(decs)} decisions for "
                        f"{n_targets} targets")
                for i, dec in enumerate(decs):
                    for mk in _REQUIRED_DECISION_KEYS:
                        if mk not in dec:
                            errors.append(
                                f"selection/{label}[{i}]: missing "
                                f"field {mk!r}")

    # v2: the fused_attention section (fused vs two-launch per estimator x
    # precision). Same coverage law as results: every registry family must
    # have a cell — unsupported families report the fallback measurement
    # with fused_supported=False rather than dropping out of the grid.
    attn = payload.get("fused_attention")
    if not isinstance(attn, dict) or not attn:
        return errors + ["payload has no fused_attention section"]
    for label, entry in attn.items():
        for k in _REQUIRED_ATTN_SHAPE_KEYS:
            if k not in entry:
                errors.append(
                    f"fused_attention/{label}: missing shape key {k!r}")
        cells = entry.get("cells", {})
        for est in estimators:
            for prec in precisions:
                ck = cell_key(est, prec)
                if ck not in cells:
                    errors.append(
                        f"fused_attention/{label}: missing cell {ck}")
                    continue
                for mk in ATTN_REQUIRED_CELL_KEYS:
                    if mk not in cells[ck]:
                        errors.append(
                            f"fused_attention/{label}/{ck}: "
                            f"missing metric {mk!r}")
    return errors


# ---------------------------------------------------------------------------
# BENCH_serving.json (schema v1): the loadgen SLO artifact. Identified by
# ``"kind": "serving"`` — ``check_file``/``--check`` auto-dispatch on it.
# ---------------------------------------------------------------------------
SERVING_SCHEMA_VERSION = 1

# Every percentile block must carry these.
_SERVING_PCT_KEYS = ("p50", "p99", "mean", "n")

# Top-level slo cells the serve-sim gate requires. The two throughput
# figures are scalars; ttft/inter-token are percentile blocks.
SERVING_REQUIRED_SLO_KEYS = (
    "ttft_s",
    "inter_token_s",
    "tokens_per_s_saturated",
    "tokens_per_s_overall",
    "saturated_steps",
    "total_steps",
    "requests_submitted",
    "requests_finished",
    "requests_truncated",
)

_SERVING_REQUIRED_WORKLOAD_KEYS = (
    "arch", "scheduler", "num_slots", "max_len", "num_requests", "seed")


def check_serving_payload(payload: Dict) -> List[str]:
    """Schema/coverage violations for a ``BENCH_serving.json`` payload.

    Mirrors the bench-core gate: a missing SLO cell (a percentile that
    silently fell out of the loadgen) fails CI rather than shrinking the
    artifact.
    """
    errors: List[str] = []
    if payload.get("kind") != "serving":
        errors.append(f"kind {payload.get('kind')!r} != 'serving'")
    if payload.get("schema_version") != SERVING_SCHEMA_VERSION:
        errors.append(
            f"serving schema_version {payload.get('schema_version')!r} != "
            f"{SERVING_SCHEMA_VERSION}")
    if not isinstance(payload.get("provenance"), dict):
        errors.append("payload has no provenance stamp")
    workload = payload.get("workload")
    if not isinstance(workload, dict):
        errors.append("payload has no workload section")
    else:
        for k in _SERVING_REQUIRED_WORKLOAD_KEYS:
            if k not in workload:
                errors.append(f"workload: missing key {k!r}")
    slo = payload.get("slo")
    if not isinstance(slo, dict):
        return errors + ["payload has no slo section"]
    for k in SERVING_REQUIRED_SLO_KEYS:
        if k not in slo:
            errors.append(f"slo: missing cell {k!r}")
    for pct in ("ttft_s", "inter_token_s"):
        block = slo.get(pct)
        if not isinstance(block, dict):
            continue
        for k in _SERVING_PCT_KEYS:
            if k not in block:
                errors.append(f"slo/{pct}: missing percentile {k!r}")
    # a run that finished nothing has no percentiles to gate on — reject
    # it outright so an accidentally-empty workload can't pass CI
    if isinstance(slo.get("requests_finished"), int) \
            and slo["requests_finished"] == 0:
        errors.append("slo: requests_finished == 0 (empty run)")
    return errors


def check_file(
    path,
    *,
    estimators: Optional[Sequence[str]] = None,
    precisions: Sequence[str] = ("fp32", "bf16"),
    min_shapes: int = 3,
) -> List[str]:
    """Schema check on a JSON artifact file; unreadable file -> one error.

    Dispatches on the payload's ``kind``: ``"serving"`` artifacts get
    :func:`check_serving_payload`, everything else the core
    :func:`check_payload`.
    """
    try:
        payload = json.loads(Path(path).read_text())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    if payload.get("kind") == "serving":
        return check_serving_payload(payload)
    return check_payload(payload, estimators=estimators,
                         precisions=precisions, min_shapes=min_shapes)


def diff_coverage(committed: Dict, fresh: Dict) -> List[str]:
    """Schema/coverage drift between two payloads (either direction).

    The two runs may use different SHAPE grids (the committed trajectory
    is the full grid; CI smoke runs --quick), so the diff compares the
    estimator x precision CELL-KEY sets and the schema version — the axes
    where a silent shrink means a family or a precision fell out of the
    trajectory. Per-shape completeness is ``check_payload``'s job.
    """
    errors: List[str] = []
    if committed.get("kind") != fresh.get("kind"):
        return [f"artifact kind mismatch: committed "
                f"{committed.get('kind')!r} vs fresh {fresh.get('kind')!r}"]
    if committed.get("schema_version") != fresh.get("schema_version"):
        errors.append(
            f"schema_version drift: committed "
            f"{committed.get('schema_version')!r} vs fresh "
            f"{fresh.get('schema_version')!r}"
        )

    def _cell_keys(payload: Dict, section: str):
        out = set()
        for entry in (payload.get(section) or {}).values():
            out.update(entry.get("cells") or {})
        return out

    for section in ("results", "fused_attention"):
        a = _cell_keys(committed, section)
        b = _cell_keys(fresh, section)
        errors += [f"{section} cell {c} covered in committed file but not "
                   f"in fresh run" for c in sorted(a - b)]
        errors += [f"{section} cell {c} covered in fresh run but not in "
                   f"committed file" for c in sorted(b - a)]
    return errors
