"""Roofline rows from the dry-run artifacts (bench-subsystem home).

Reads ``results/dryrun/single/*.json`` (produced by ``python -m
repro.launch.dryrun``) and emits one row per (arch x shape):
``roofline/<arch>/<shape>,compute_us,dominant_term_seconds``. If the
dry-run hasn't been executed, emits a pointer row instead of failing (the
dry-run needs the 512-device XLA flag and ~1-2h of compiles).

``benchmarks/roofline_bench.py`` is the thin CLI over this module.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import List, Optional

__all__ = ["dryrun_roofline_rows"]


def dryrun_roofline_rows(results_dir: Optional[Path] = None) -> List[str]:
    """CSV rows derived from the compiled-program roofline terms."""
    results = (Path(results_dir) if results_dir is not None
               else Path.cwd() / "results" / "dryrun" / "single")
    rows: List[str] = []
    if not results.exists():
        return ["roofline/NOT_RUN(run repro.launch.dryrun),0,0"]
    for path in sorted(results.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("skipped"):
            rows.append(f"roofline/{rec['arch']}/{rec['shape']}/SKIP,0,0")
            continue
        comp = rec.get("compute_s_corrected", rec.get("compute_s", 0.0))
        dom = max(comp, rec.get("memory_s", 0), rec.get("collective_s", 0))
        rows.append(
            f"roofline/{rec['arch']}/{rec['shape']},"
            f"{comp * 1e6:.0f},{dom:.4f}"
        )
    return rows or ["roofline/EMPTY,0,0"]
