"""Roofline rows from the dry-run artifacts (bench-subsystem home), plus
the shape-level analytic launch costs the obs kernel spans attach.

Reads ``results/dryrun/single/*.json`` (produced by ``python -m
repro.launch.dryrun``) and emits one row per (arch x shape):
``roofline/<arch>/<shape>,compute_us,dominant_term_seconds``. If the
dry-run hasn't been executed, emits a pointer row instead of failing (the
dry-run needs the 512-device XLA flag and ~1-2h of compiles).

:func:`launch_cost` is the companion of ``runner.analytic_cost`` for call
sites that only know SHAPES, not plans: the fused Pallas wrapper ops
(``kernels/*/ops.py``) attach its FLOPs/HBM-bytes to their ``kernel/*``
trace spans (repro.obs.trace.kernel_scope), so a Perfetto view of a serve
trace carries the analytic roofline next to every launch.

``benchmarks/roofline_bench.py`` is the thin CLI over this module.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

__all__ = ["dryrun_roofline_rows", "launch_cost"]


def launch_cost(kernel: str, *, batch: int, d: int, depth: int, f: int,
                dv: int = 0, t: int = 0, itemsize: int = 4) -> Dict[str, float]:
    """Analytic FLOPs + HBM bytes of one fused launch, from shapes alone.

    Upper-bound accounting (every feature column at the packed tensor's
    ``depth``; the per-plan ``runner.analytic_cost`` refines this with the
    real degree allocation). Families:

    * ``rm_feature`` / ``ctr_feature`` — (batch, feature)-tiled product
      kernels: one x read, ``n_w`` packed weight tensors, fp32 output.
    * ``tensor_sketch`` — adds the two [f, f] inverse-DFT operands and the
      stage-2 matmul FLOPs.
    * ``rm_attn_fused`` — the fused featurize+attention causal kernel:
      featurize FLOPs for q and k rows plus the chunked attention GEMMs;
      bytes stream q/k/v/w once and emit out + the (S, n) decode state
      (Z never touches HBM — DESIGN.md §13).
    """
    if kernel == "rm_attn_fused":
        rows = batch * t
        feat_flops = 2.0 * 2 * rows * d * depth * f
        attn_flops = 4.0 * rows * f * (dv + 1)
        bytes_moved = (itemsize * (2 * rows * d + depth * f * d)
                       + 4.0 * rows * 2 * dv
                       + 4.0 * batch * (f * dv + f))
        flops = feat_flops + attn_flops
    else:
        n_w = 2 if kernel in ("ctr_feature", "tensor_sketch") else 1
        flops = 2.0 * n_w * batch * d * depth * f
        weight_elems = n_w * depth * f * d
        out_cols = 2 * f if kernel == "ctr_feature" else f
        if kernel == "tensor_sketch":
            flops += 4.0 * batch * f * f   # stage-2 inverse-DFT matmuls
            weight_elems += 2 * f * f
        bytes_moved = (itemsize * (batch * d + weight_elems)
                       + 4.0 * batch * out_cols)
    return {"flops": float(flops), "hbm_bytes": float(bytes_moved)}


def dryrun_roofline_rows(results_dir: Optional[Path] = None) -> List[str]:
    """CSV rows derived from the compiled-program roofline terms."""
    results = (Path(results_dir) if results_dir is not None
               else Path.cwd() / "results" / "dryrun" / "single")
    rows: List[str] = []
    if not results.exists():
        return ["roofline/NOT_RUN(run repro.launch.dryrun),0,0"]
    for path in sorted(results.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("skipped"):
            rows.append(f"roofline/{rec['arch']}/{rec['shape']}/SKIP,0,0")
            continue
        comp = rec.get("compute_s_corrected", rec.get("compute_s", 0.0))
        dom = max(comp, rec.get("memory_s", 0), rec.get("collective_s", 0))
        rows.append(
            f"roofline/{rec['arch']}/{rec['shape']},"
            f"{comp * 1e6:.0f},{dom:.4f}"
        )
    return rows or ["roofline/EMPTY,0,0"]
