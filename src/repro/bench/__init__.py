"""repro.bench — the unified perf-regression benchmark subsystem.

One declarative grid (``BenchSpec``: estimator x precision x shape), one
runner (fused-vs-oracle apply timing, Gram RMSE vs the exact kernel,
analytic roofline counters), one canonical JSON schema
(``BENCH_core.json``), and a measured block-ladder autotune pass. The CLI
is ``python -m repro.bench`` (see ``--help``); the ad-hoc scripts under
``benchmarks/`` are thin wrappers over these entry points, and the CI
``bench-core`` job gates the committed artifact's coverage with
``--check``. docs/performance.md is the usage guide.
"""
from repro.bench.schema import (
    ATTN_REQUIRED_CELL_KEYS,
    REQUIRED_CELL_KEYS,
    SCHEMA_VERSION,
    SERVING_SCHEMA_VERSION,
    cell_key,
    check_file,
    check_payload,
    check_serving_payload,
    diff_coverage,
)
from repro.bench.spec import (
    AttnShapeSpec,
    BenchSpec,
    ShapeSpec,
    default_spec,
    make_kernel,
    quick_spec,
)
from repro.bench.runner import (
    analytic_cost,
    attention_hbm_bytes,
    autotune_spec,
    run_spec,
)

__all__ = [
    "AttnShapeSpec",
    "BenchSpec",
    "ShapeSpec",
    "default_spec",
    "quick_spec",
    "make_kernel",
    "run_spec",
    "autotune_spec",
    "analytic_cost",
    "attention_hbm_bytes",
    "SCHEMA_VERSION",
    "SERVING_SCHEMA_VERSION",
    "REQUIRED_CELL_KEYS",
    "ATTN_REQUIRED_CELL_KEYS",
    "cell_key",
    "check_payload",
    "check_serving_payload",
    "check_file",
    "diff_coverage",
]
