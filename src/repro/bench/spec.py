"""Declarative benchmark specs: estimator x precision x shape grids.

A ``BenchSpec`` fully determines one benchmark run — which registry
estimators, which feature-kernel precision policies, which (kernel, d, F,
batch) shapes, how many timing repeats, and which execution paths — so the
runner (``repro.bench.runner``) is pure mechanism and every entry point
(``python -m repro.bench``, the thin CLIs in ``benchmarks/``, the CI
``bench-core`` job) is a spec choice, not a separate script.

Specs are frozen dataclasses of plain hashable data; the runner iterates
shapes x estimators x precisions in deterministic order, and the schema
checker (``repro.bench.schema``) enforces the resulting cell coverage
against committed JSON.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = [
    "ShapeSpec",
    "AttnShapeSpec",
    "BenchSpec",
    "DEFAULT_PRECISIONS",
    "default_spec",
    "quick_spec",
    "make_kernel",
]


DEFAULT_PRECISIONS: Tuple[str, ...] = ("fp32", "bf16")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One benchmark shape: a kernel, data dim, feature budget, batch.

    ``kernel`` is a symbolic name resolved by ``make_kernel`` ("exp",
    "poly3", "poly7", ...), so specs stay plain data. ``gram_points`` is
    the held-out point count for the Gram-RMSE measurement.
    """

    label: str
    kernel: str
    d: int
    F: int
    batch: int
    gram_points: int = 64


@dataclasses.dataclass(frozen=True)
class AttnShapeSpec:
    """One fused-attention benchmark shape (DESIGN.md §13).

    ``d`` is the per-head q/k dim the feature map consumes, ``F`` the
    feature budget, ``dv`` the value dim, ``(batch, heads, T)`` the
    attention problem; ``chunk`` is the causal chunk length handed to both
    the fused and the two-launch attention kernels so the comparison
    isolates the Z(x) HBM round-trip, not a tiling choice.
    """

    label: str
    kernel: str
    d: int
    F: int
    heads: int
    T: int
    dv: int
    batch: int = 1
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class BenchSpec:
    """The full grid for one benchmark run.

    ``estimators=()`` means "every registry entry at run time" — the
    runner resolves it against ``registry.list_estimators()`` so newly
    registered families land in the trajectory with no spec edits.
    ``include_bucketed`` adds the legacy per-degree-launch RM baseline
    (fp32 only) next to the fused cells — the comparison
    ``benchmarks/rm_feature_bench.py`` exists for.
    """

    shapes: Tuple[ShapeSpec, ...]
    attention_shapes: Tuple[AttnShapeSpec, ...] = ()
    estimators: Tuple[str, ...] = ()
    precisions: Tuple[str, ...] = DEFAULT_PRECISIONS
    repeats: int = 5
    interpret: bool = False
    include_bucketed: bool = False
    quick: bool = False


def make_kernel(name: str):
    """Resolve a symbolic kernel name to a DotProductKernel instance."""
    from repro.core import ExponentialDotProductKernel, PolynomialKernel

    if name == "exp":
        return ExponentialDotProductKernel(1.0)
    if name.startswith("poly"):
        return PolynomialKernel(int(name[len("poly"):]), 1.0)
    raise ValueError(f"unknown bench kernel {name!r} (exp | poly<N>)")


# The trajectory grids. Shapes are chosen so the FULL grid stays tractable
# under interpret-mode Pallas on a CPU runner (the throughput columns off
# TPU measure the interpreter, not the hardware — read the RMSE and
# roofline columns there) while still spanning low/high degree kernels and
# thin/wide feature budgets.
_DEFAULT_SHAPES = (
    ShapeSpec("exp_d64_F256_b1024", "exp", d=64, F=256, batch=1024),
    ShapeSpec("poly7_d32_F512_b512", "poly7", d=32, F=512, batch=512),
    ShapeSpec("exp_d24_F192_b512", "exp", d=24, F=192, batch=512),
)

_QUICK_SHAPES = (
    ShapeSpec("exp_d16_F128_b128", "exp", d=16, F=128, batch=128,
              gram_points=32),
    ShapeSpec("poly3_d8_F64_b64", "poly3", d=8, F=64, batch=64,
              gram_points=32),
    ShapeSpec("exp_d32_F96_b64", "exp", d=32, F=96, batch=64,
              gram_points=32),
)

# Fused-attention shapes. The canonical grid mirrors serving-relevant
# prefill problems (long-ish T, one-or-two feature tiles); the quick grid
# keeps interpret-mode Pallas on a CPU runner tractable while still
# exercising a multi-chunk, multi-feature-block launch.
_DEFAULT_ATTN_SHAPES = (
    AttnShapeSpec("attn_exp_d32_F128_T256", "exp", d=32, F=128, heads=2,
                  T=256, dv=32, batch=1, chunk=32),
    AttnShapeSpec("attn_poly7_d16_F128_T192", "poly7", d=16, F=128, heads=2,
                  T=192, dv=16, batch=1, chunk=64),
)

_QUICK_ATTN_SHAPES = (
    AttnShapeSpec("attn_poly7_d8_F64_T64", "poly7", d=8, F=64, heads=2,
                  T=64, dv=8, batch=1, chunk=16),
)


def default_spec(*, interpret: bool = False, repeats: int = 5,
                 include_bucketed: bool = False) -> BenchSpec:
    """The committed-trajectory grid (BENCH_core.json)."""
    return BenchSpec(shapes=_DEFAULT_SHAPES,
                     attention_shapes=_DEFAULT_ATTN_SHAPES, repeats=repeats,
                     interpret=interpret,
                     include_bucketed=include_bucketed)


def quick_spec(*, interpret: bool = True, repeats: int = 2,
               include_bucketed: bool = False) -> BenchSpec:
    """The CI smoke grid: small shapes, full estimator x precision coverage
    (the bench-core job fails on missing cells, so quick mode still spans
    >= 3 shapes)."""
    return BenchSpec(shapes=_QUICK_SHAPES,
                     attention_shapes=_QUICK_ATTN_SHAPES, repeats=repeats,
                     interpret=interpret,
                     include_bucketed=include_bucketed, quick=True)
