"""The benchmark runner: one mechanism behind every perf entry point.

``run_spec`` walks a ``BenchSpec`` grid and, per (shape, estimator,
precision) cell, measures:

* **fused vs oracle apply time** — the fused Pallas path (interpret mode
  off-TPU when ``spec.interpret``) against the jnp/XLA mirror, median
  wall time over ``spec.repeats`` post-compile calls;
* **Gram estimation** — wall time of a row-chunked ``estimate_gram`` plus
  RMSE against the EXACT kernel matrix on a held-out point set (the
  quality axis: precision policies trade it against throughput);
* **roofline counters** — analytic useful FLOPs and bytes moved per apply
  (per estimator family, precision-aware itemsize), plus the TPU-v5e
  projections derived with the existing roofline hardware model
  (``repro.analysis.roofline.HW_V5E``). Off-TPU the measured throughput
  columns time the Pallas INTERPRETER — read the RMSE/roofline columns
  there; on TPU they are the real trajectory.

``autotune_spec`` drives the measured block-ladder autotuner
(``repro.kernels.common``) over the same grid: per cell it launches the
REAL fused kernel at every feasible ladder tile and persists the fastest
in the block cache all three wrappers consult.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HW_V5E
from repro.bench.schema import SCHEMA_VERSION, cell_key
from repro.bench.spec import AttnShapeSpec, BenchSpec, ShapeSpec, make_kernel
from repro.common.dtypes import resolve_precision
from repro.obs import clock as _obs_clock

__all__ = ["run_spec", "autotune_spec", "time_call", "analytic_cost",
           "attention_hbm_bytes"]


def time_call(fn: Callable, x, repeats: int = 5) -> float:
    """Median wall-time (us) of a jitted call, excluding compile.

    Reads the shared obs monotonic clock (``repro.obs.clock``) — the same
    instrument behind the autotuner's ladder timings and the serving
    engine's TTFT/per-token histograms, so bench and runtime numbers are
    measured identically.
    """
    fn(x).block_until_ready()
    times = []
    for _ in range(repeats):
        t0 = _obs_clock.monotonic()
        fn(x).block_until_ready()
        times.append(_obs_clock.monotonic() - t0)
    return sorted(times)[len(times) // 2] * 1e6


def analytic_cost(est_name: str, plan, batch: int,
                  precision: str) -> Dict[str, float]:
    """Useful FLOPs + bytes moved per apply, by estimator family.

    FLOPs count occupied product slots only (2*B*d per real dot — the
    fused kernels' early-exit makes padded slots free); bytes count one
    HBM read of x and the packed weight tensors at the precision policy's
    itemsize plus one fp32 write of the output. v5e projections come from
    the same hardware model the dry-run roofline uses.
    """
    prec = resolve_precision(precision)
    itemsize = jnp.dtype(prec.compute_dtype).itemsize
    d = plan.input_dim
    k = plan.max_degree
    slots = sum(c * n for c, n in zip(plan.counts, plan.degrees))
    out_dim = plan.output_dim
    if est_name == "rm":
        if plan.h01:
            slots += d                       # identity block, degree 1
        flops = 2.0 * batch * d * slots
        weight_elems = k * out_dim * d       # packed [k, F, d]
    elif est_name == "ctr":
        flops = 4.0 * batch * d * slots      # wr AND wi dot per slot
        weight_elems = 2 * k * plan.num_complex * d
    elif est_name == "tensor_sketch":
        fs = plan.num_sketch_cols
        flops = 4.0 * batch * d * slots      # stage 1: complex projections
        flops += sum(4.0 * batch * c * c for c in plan.counts)  # stage 2
        weight_elems = 2 * k * fs * d + 2 * fs * fs
    elif est_name == "structured":
        # Per occupied (stack, degree) slot: diag mult (m) + butterfly WHT
        # (m log2 m adds) + second diag (m) + product accumulate (m) — the
        # O(F log d) sublinear apply that motivates the family.
        m = plan.d_pad
        flops = batch * plan.total_slots * m * (np.log2(max(m, 2)) + 3.0)
        weight_elems = 2 * k * plan.total_stacks * m   # packed d1/d2
    else:  # third-party family: generic product-feature model
        flops = 2.0 * batch * d * slots
        weight_elems = k * out_dim * d
    bytes_moved = (itemsize * (batch * d + weight_elems)
                   + 4.0 * batch * out_dim)
    return {
        "flops": float(flops),
        "bytes_moved": float(bytes_moved),
        "intensity_flops_per_byte": float(flops / max(bytes_moved, 1.0)),
        "v5e_compute_us": float(flops / HW_V5E.peak_flops * 1e6),
        "v5e_memory_us": float(bytes_moved / HW_V5E.hbm_bw * 1e6),
    }


def _gram_rmse_and_us(fm, kern, X, *, precision: str,
                      repeats: int) -> Tuple[float, float]:
    """(RMSE vs exact kernel, median Gram wall-time us) on the oracle path."""
    K = np.asarray(kern.gram(X))

    @jax.jit
    def gram(Z):
        return fm.estimate_gram(Z, use_pallas=False, precision=precision)

    us = time_call(gram, X, repeats=repeats)
    est = np.asarray(gram(X))
    return float(np.sqrt(np.mean((est - K) ** 2))), us


def run_cell(
    shape: ShapeSpec,
    est_name: str,
    precision: str,
    *,
    interpret: bool,
    repeats: int,
) -> Dict[str, float]:
    """All metrics for one (shape, estimator, precision) cell."""
    from repro.core import make_feature_map

    kern = make_kernel(shape.kernel)
    on_tpu = jax.default_backend() == "tpu"
    fm = make_feature_map(kern, shape.d, shape.F, jax.random.PRNGKey(0),
                          estimator=est_name, measure="proportional")
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (shape.batch, shape.d)) * 0.2

    fused = jax.jit(lambda xx: fm.apply(
        xx, use_pallas=True, interpret=interpret or not on_tpu,
        precision=precision))
    oracle = jax.jit(lambda xx: fm.apply(
        xx, use_pallas=False, precision=precision))

    cell: Dict[str, float] = {
        "output_dim": int(fm.output_dim),
        "fused_us": time_call(fused, x, repeats=repeats),
        "oracle_us": time_call(oracle, x, repeats=repeats),
    }
    cell["fused_feats_per_s"] = (shape.batch * fm.output_dim
                                 / (cell["fused_us"] * 1e-6))
    cell["oracle_feats_per_s"] = (shape.batch * fm.output_dim
                                  / (cell["oracle_us"] * 1e-6))

    Xg = jax.random.normal(jax.random.PRNGKey(7),
                           (shape.gram_points, shape.d))
    Xg = Xg / jnp.linalg.norm(Xg, axis=1, keepdims=True) * 0.8
    cell["gram_rmse"], cell["gram_us"] = _gram_rmse_and_us(
        fm, kern, Xg, precision=precision, repeats=repeats)

    cell.update(analytic_cost(est_name, fm.plan, shape.batch, precision))
    return cell


def attention_hbm_bytes(est_name: str, plan, shape: AttnShapeSpec,
                        out_dim: int, precision: str) -> Dict[str, float]:
    """Analytic HBM traffic of fused vs two-launch causal attention.

    The two-launch composition pays the Z(x) round-trip in full — the two
    featurize launches WRITE Z(q)/Z(k) to HBM ([rows, F] fp32 each) and the
    attention launch READS them back — plus a second read of the packed
    weights (one per featurize launch). The fused kernel streams q/k/v and
    the weights from HBM once and Z lives only in VMEM, so the removed
    traffic is the 4 * rows * F * 4-byte round-trip: O(T * F), the term
    that dominates at serving shapes. Featurize-side byte accounting
    (operand reads at the precision policy's itemsize, fp32 Z) reuses
    ``analytic_cost`` so the two tables stay consistent.
    """
    prec = resolve_precision(precision)
    itemsize = jnp.dtype(prec.compute_dtype).itemsize
    rows = shape.batch * shape.heads * shape.T
    feat = analytic_cost(est_name, plan, rows, precision)["bytes_moved"]
    w_bytes = feat - itemsize * rows * shape.d - 4.0 * rows * out_dim
    # q+k reads at the compute itemsize; v read + out write in fp32
    qkv_out = itemsize * 2 * rows * shape.d + 4.0 * rows * 2 * shape.dv
    # the fused causal kernel also emits the decode state (S, n) once
    state = 4.0 * shape.batch * shape.heads * (out_dim * shape.dv + out_dim)
    fused = qkv_out + w_bytes + state
    z_round_trip = 2 * 2 * 4.0 * rows * out_dim   # write then read, q and k
    two_launch = qkv_out + 2 * w_bytes + z_round_trip
    return {"hbm_bytes_fused": float(fused),
            "hbm_bytes_two_launch": float(two_launch)}


def run_attention_cell(
    shape: AttnShapeSpec,
    est_name: str,
    precision: str,
    *,
    interpret: bool,
    repeats: int,
) -> Dict[str, float]:
    """Fused vs two-launch causal attention timings for one cell.

    Families without a fused path (``fused_attention_supported`` False in
    the registry) measure the two-launch composition for BOTH columns —
    that IS what the model layers run for them — with ``fused_supported``
    False so readers don't mistake the 1.0x for a fusion result.
    """
    from repro.core import make_feature_map, registry
    from repro.kernels.rm_attention import (rm_attention_causal,
                                            rm_attention_fused_causal)

    kern = make_kernel(shape.kernel)
    on_tpu = jax.default_backend() == "tpu"
    interpret = interpret or not on_tpu
    prec = resolve_precision(precision)
    cd = prec.compute_dtype
    ent = registry.get(est_name)
    fm = make_feature_map(kern, shape.d, shape.F, jax.random.PRNGKey(0),
                          estimator=est_name, measure="proportional")
    b, h, t, d, dv = shape.batch, shape.heads, shape.T, shape.d, shape.dv
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = (jax.random.normal(kq, (b, h, t, d)) * 0.2).astype(cd)
    k = (jax.random.normal(kk, (b, h, t, d)) * 0.2).astype(cd)
    v = jax.random.normal(kv, (b, h, t, dv), jnp.float32)

    def _two_launch(qq):
        zq = fm.apply(qq.reshape(b * h * t, d), use_pallas=True,
                      interpret=interpret, precision=precision)
        zk = fm.apply(k.reshape(b * h * t, d), use_pallas=True,
                      interpret=interpret, precision=precision)
        return rm_attention_causal(zq.reshape(b, h, t, -1),
                                   zk.reshape(b, h, t, -1), v,
                                   chunk=shape.chunk, use_pallas=True,
                                   interpret=interpret)

    cell: Dict[str, float] = {
        "output_dim": int(fm.output_dim),
        "fused_supported": bool(ent.fused_attention_supported),
        "two_launch_us": time_call(jax.jit(_two_launch), q,
                                   repeats=repeats),
    }
    if ent.fused_attention_supported:
        params = ({"omegas": fm.omegas} if hasattr(fm, "omegas")
                  else fm.params)
        w, col_deg, col_scale = ent.pack_fused(fm.plan, params)
        w = jnp.asarray(w).astype(cd)
        deg_t = tuple(int(x) for x in np.asarray(col_deg))
        scale_t = tuple(float(x) for x in np.asarray(col_scale))
        fused = jax.jit(lambda qq: rm_attention_fused_causal(
            qq, k, v, w, deg_t, scale_t, chunk=shape.chunk,
            use_pallas=True, interpret=interpret))
        cell["fused_us"] = time_call(fused, q, repeats=repeats)
    else:
        cell["fused_us"] = cell["two_launch_us"]
    cell["speedup"] = cell["two_launch_us"] / max(cell["fused_us"], 1e-9)
    hbm = attention_hbm_bytes(est_name, fm.plan, shape, int(fm.output_dim),
                              precision)
    if not ent.fused_attention_supported:
        hbm["hbm_bytes_fused"] = hbm["hbm_bytes_two_launch"]
    cell.update(hbm)
    return cell


def _bucketed_us(shape: ShapeSpec, *, interpret: bool,
                 repeats: int) -> float:
    """Legacy one-launch-per-degree RM baseline (fp32), for the fused
    speedup column ``benchmarks/rm_feature_bench.py`` tracks."""
    from repro.core import make_feature_map
    from repro.kernels.rm_feature import apply_feature_map_bucketed

    kern = make_kernel(shape.kernel)
    on_tpu = jax.default_backend() == "tpu"
    fm = make_feature_map(kern, shape.d, shape.F, jax.random.PRNGKey(0),
                          measure="proportional")
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (shape.batch, shape.d)) * 0.2
    fn = jax.jit(lambda xx: apply_feature_map_bucketed(
        fm, xx, use_pallas=True, interpret=interpret or not on_tpu))
    return time_call(fn, x, repeats=repeats)


def run_spec(
    spec: BenchSpec,
    *,
    emit: Optional[Callable[[str], None]] = None,
) -> Dict:
    """Run the grid; return the canonical BENCH_core payload."""
    from repro.core import registry

    say = emit or (lambda _row: None)
    estimators = spec.estimators or registry.list_estimators()
    results: Dict[str, Dict] = {}
    for shape in spec.shapes:
        entry = results.setdefault(shape.label, {
            "kernel": shape.kernel, "d": shape.d, "F": shape.F,
            "batch": shape.batch, "cells": {},
        })
        for est in estimators:
            for prec in spec.precisions:
                cell = run_cell(shape, est, prec,
                                interpret=spec.interpret,
                                repeats=spec.repeats)
                ck = cell_key(est, prec)
                entry["cells"][ck] = cell
                say(f"bench/{shape.label}/{ck},"
                    f"{cell['fused_us']:.1f},"
                    f"{cell['fused_feats_per_s']:.3e}")
                say(f"bench/{shape.label}/{ck}/gram_rmse,"
                    f"{cell['gram_rmse']:.5f},{cell['gram_us']:.1f}")
        if spec.include_bucketed and "rm" in estimators:
            us = _bucketed_us(shape, interpret=spec.interpret,
                              repeats=spec.repeats)
            entry["rm_bucketed_us"] = us
            # the baseline is fp32; compare against the first rm cell the
            # spec actually ran (fp32 when present)
            ref_prec = ("fp32" if "fp32" in spec.precisions
                        else spec.precisions[0])
            fused = entry["cells"][cell_key("rm", ref_prec)]["fused_us"]
            entry["rm_fused_speedup"] = us / fused
            say(f"bench/{shape.label}/rm_bucketed,{us:.1f},"
                f"{entry['rm_fused_speedup']:.3f}")

    attn: Dict[str, Dict] = {}
    for ashape in spec.attention_shapes:
        entry = attn.setdefault(ashape.label, {
            "kernel": ashape.kernel, "d": ashape.d, "F": ashape.F,
            "heads": ashape.heads, "T": ashape.T, "dv": ashape.dv,
            "batch": ashape.batch, "chunk": ashape.chunk, "cells": {},
        })
        for est in estimators:
            for prec in spec.precisions:
                cell = run_attention_cell(ashape, est, prec,
                                          interpret=spec.interpret,
                                          repeats=spec.repeats)
                ck = cell_key(est, prec)
                entry["cells"][ck] = cell
                say(f"bench/attn/{ashape.label}/{ck},"
                    f"{cell['fused_us']:.1f},{cell['two_launch_us']:.1f},"
                    f"{cell['speedup']:.3f}")
    from repro.common.env import platform_provenance

    payload = {
        "schema_version": SCHEMA_VERSION,
        "backend": jax.default_backend(),
        "provenance": platform_provenance(),
        "interpret": bool(spec.interpret),
        "quick": bool(spec.quick),
        "precisions": list(spec.precisions),
        "estimators": list(estimators),
        "results": results,
        "fused_attention": attn,
    }
    # the adaptive-accuracy worked example: what select_budget decides for
    # each benched shape, priced from THIS payload's own throughput rows
    # (docs/adaptive.md; validated by schema.check_payload when present)
    from repro.core.select import selection_section

    payload["selection"] = selection_section(payload)
    for shape_label, decs in payload["selection"]["decisions"].items():
        for dec in decs:
            say(f"bench/selection/{shape_label},eps={dec['eps']:g},"
                f"{dec['estimator']}/{dec['precision']},"
                f"D={dec['num_features']}")
    return payload


# ---------------------------------------------------------------------------
# measured block-ladder autotune over a spec grid
# ---------------------------------------------------------------------------
def autotune_cell(shape: ShapeSpec, est_name: str, precision: str,
                  *, interpret: bool, repeats: int = 3) -> Optional[tuple]:
    """Autotune the fused launch for one cell; returns the winning blocks.

    Builds the cell's map, packs its fused tensors, and times the REAL
    kernel at every feasible ladder tile via the wrappers' ``blocks=``
    hook; the winner lands in the persistent per-(kernel, shape, dtype,
    backend) cache (``repro.kernels.common``).
    """
    from repro.core import make_feature_map
    from repro.kernels import common as kcommon

    kern = make_kernel(shape.kernel)
    prec = resolve_precision(precision)
    cd = prec.compute_dtype
    # Same backend rule as run_cell: off-TPU the only viable Pallas mode is
    # the interpreter — passing interpret=False there would make every
    # ladder candidate fail and the "winner" would be unmeasured.
    interpret = interpret or jax.default_backend() != "tpu"
    fm = make_feature_map(kern, shape.d, shape.F, jax.random.PRNGKey(0),
                          estimator=est_name, measure="proportional")
    x = (jax.random.normal(jax.random.PRNGKey(1),
                           (shape.batch, shape.d)) * 0.2).astype(cd)
    plan = fm.plan
    b, d, k = shape.batch, shape.d, plan.max_degree
    if k == 0:
        return None

    if est_name == "rm":
        from repro.core.plan import pack_omegas
        from repro.kernels.rm_feature.ops import rm_feature_fused

        w = pack_omegas(plan, fm.omegas).astype(cd)
        deg = jnp.asarray(plan.column_degrees())
        sc = jnp.asarray(plan.column_scales())
        launch = lambda bm, bf: rm_feature_fused(
            x, w, deg, sc, interpret=interpret, blocks=(bm, bf))
        return kcommon.autotune_feature_blocks(
            "rm_feature", launch, d, k, b, plan.output_dim,
            dtype=cd, repeats=repeats)
    if est_name == "ctr":
        from repro.ctr.plan import pack_ctr
        from repro.kernels.ctr_feature.ops import ctr_feature_fused

        wr, wi = pack_ctr(plan, fm.params)
        wr, wi = wr.astype(cd), wi.astype(cd)
        deg = jnp.asarray(plan.column_degrees())
        sc = jnp.asarray(plan.column_scales())
        launch = lambda bm, bf: ctr_feature_fused(
            x, wr, wi, deg, sc, interpret=interpret, blocks=(bm, bf))
        return kcommon.autotune_feature_blocks(
            "ctr_feature", launch, d, k, b, plan.num_complex,
            dtype=cd, weight_tensors=2, accumulators=4, repeats=repeats)
    if est_name == "tensor_sketch":
        from repro.kernels.tensor_sketch.ops import tensor_sketch_fused
        from repro.sketch.plan import pack_sketch

        wr, wi, mr, mi = (t.astype(cd)
                          for t in pack_sketch(plan, fm.params,
                                               dtype=jnp.float32))
        deg = jnp.asarray(plan.column_degrees())
        sc = jnp.asarray(plan.column_scales())
        f_pad = kcommon.round_up(max(plan.num_sketch_cols, 128), 128)
        launch = lambda bm, _bf: tensor_sketch_fused(
            x, wr, wi, deg, mr, mi, sc, interpret=interpret,
            blocks=(bm, f_pad))
        cands = [(bm, f_pad) for bm in (512, 256, 128, 64, 32, 16, 8)
                 if bm <= max(b, 8) * 2]
        return kcommon.autotune_feature_blocks(
            "tensor_sketch", launch, d, k, b, f_pad,
            dtype=cd, candidates=cands, repeats=repeats)
    if est_name == "structured":
        from repro.kernels.structured_feature.ops import (
            structured_feature_fused,
        )
        from repro.structured.plan import pack_structured

        m = plan.d_pad
        d1, d2 = pack_structured(plan, fm.params)
        d1, d2 = d1.astype(cd), d2.astype(cd)
        deg = jnp.asarray(plan.padded_column_degrees())
        sc = jnp.asarray(plan.padded_column_scales())
        xp = jnp.pad(x, ((0, 0), (0, m - shape.d)))
        cols = plan.padded_num_cols
        launch = lambda bm, bf: structured_feature_fused(
            xp, d1, d2, deg, sc, interpret=interpret, blocks=(bm, bf))
        # feature tiles must hold whole stacks: snap the ladder to
        # multiples of d_pad and dedupe collapsed candidates
        cands = sorted({(bm, max(m, bf - bf % m))
                        for bm, bf in kcommon.feasible_feature_blocks(
                            m, k, b, cols, weight_tensors=2,
                            accumulators=4,
                            itemsize=kcommon.dtype_itemsize(cd))},
                       reverse=True)
        return kcommon.autotune_feature_blocks(
            "structured_feature", launch, m, k, b, cols,
            dtype=cd, weight_tensors=2, accumulators=4,
            candidates=cands, repeats=repeats)
    return None


def autotune_attention_cell(shape: AttnShapeSpec, est_name: str,
                            precision: str, *, interpret: bool,
                            repeats: int = 3) -> Optional[tuple]:
    """Autotune the fused featurize+attention launch for one cell.

    Times the REAL fused causal kernel at every feasible (chunk, block_f)
    ladder tile; the winner persists under the ``rm_attn_fused`` attention
    cache key (``repro.kernels.common.attention_cache_key``) the fused
    ops' default-block resolution reads. Families without a fused path
    return None — there is nothing to tune.
    """
    from repro.core import make_feature_map, registry
    from repro.kernels import common as kcommon
    from repro.kernels.rm_attention import rm_attention_fused_causal

    ent = registry.get(est_name)
    if not ent.fused_attention_supported or ent.pack_fused is None:
        return None
    kern = make_kernel(shape.kernel)
    interpret = interpret or jax.default_backend() != "tpu"
    cd = resolve_precision(precision).compute_dtype
    fm = make_feature_map(kern, shape.d, shape.F, jax.random.PRNGKey(0),
                          estimator=est_name, measure="proportional")
    b, h, t, d, dv = shape.batch, shape.heads, shape.T, shape.d, shape.dv
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = (jax.random.normal(kq, (b, h, t, d)) * 0.2).astype(cd)
    k = (jax.random.normal(kk, (b, h, t, d)) * 0.2).astype(cd)
    v = jax.random.normal(kv, (b, h, t, dv), jnp.float32)
    params = {"omegas": fm.omegas} if hasattr(fm, "omegas") else fm.params
    w, col_deg, col_scale = ent.pack_fused(fm.plan, params)
    w = jnp.asarray(w).astype(cd)
    deg_t = tuple(int(x) for x in np.asarray(col_deg))
    scale_t = tuple(float(x) for x in np.asarray(col_scale))
    if w.shape[0] == 0:
        return None
    launch = lambda c, bf: rm_attention_fused_causal(
        q, k, v, w, deg_t, scale_t, chunk=c, block_f=bf,
        use_pallas=True, interpret=interpret)
    # key fields must mirror the fused ops' default-block lookup
    # (_fused_defaults): d/depth/t from the q and w actually launched,
    # f pre-padding, dv pinned to 0.
    return kcommon.autotune_attention_blocks(
        "rm_attn_fused", launch, d=d, depth=int(w.shape[0]), t=t,
        f=int(w.shape[1]), dv=0, dtype=cd, repeats=repeats)


def autotune_spec(spec: BenchSpec,
                  *, emit: Optional[Callable[[str], None]] = None,
                  estimators: Optional[Iterable[str]] = None) -> None:
    """Autotune every cell of the grid (populates the block cache)."""
    from repro.core import registry

    say = emit or (lambda _row: None)
    names = tuple(estimators or spec.estimators
                  or registry.list_estimators())
    for shape in spec.shapes:
        for est in names:
            for prec in spec.precisions:
                best = autotune_cell(shape, est, prec,
                                     interpret=spec.interpret)
                say(f"autotune/{shape.label}/{cell_key(est, prec)},"
                    f"{best}")
    for ashape in spec.attention_shapes:
        for est in names:
            for prec in spec.precisions:
                best = autotune_attention_cell(ashape, est, prec,
                                               interpret=spec.interpret)
                say(f"autotune/attn/{ashape.label}/{cell_key(est, prec)},"
                    f"{best}")
