"""CLI for the benchmark subsystem: ``python -m repro.bench``.

Modes:

* default — run the grid and write the canonical JSON artifact:
    python -m repro.bench [--quick] [--interpret] [--out BENCH_core.json]
* ``--check FILE`` — validate an artifact's schema + coverage (every
  registry estimator x every precision x >= 3 shapes) WITHOUT running
  anything; ``--against OTHER`` additionally diffs the cell grids of the
  two files. This is what the CI ``bench-core`` job gates on.
* ``--autotune`` — before timing, run the measured block-ladder autotune
  over the grid (persists winners to the shared block cache).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _warn_if_interpret_cpu(path: str) -> None:
    """ROADMAP item 1 nag: shout when an artifact's throughput columns
    timed the Pallas INTERPRETER on CPU rather than real hardware, so an
    interpret-mode committed trajectory can't silently pass for measured
    kernel performance."""
    try:
        payload = json.loads(Path(path).read_text())
    except (OSError, ValueError):
        return
    if payload.get("kind") == "serving":
        # serving artifacts time the scheduler (often on a fake clock),
        # not Pallas kernels — the interpret nag doesn't apply
        return
    prov = payload.get("provenance", {})
    backend = prov.get("backend", payload.get("backend"))
    interpret = payload.get("interpret", prov.get("interpret"))
    if interpret and backend != "tpu":
        print(f"WARNING: {path} was produced in Pallas INTERPRET mode on "
              f"backend={backend!r} — its throughput columns time the "
              "interpreter, not hardware. Re-run the grid on a real "
              "GPU/TPU backend before reading them as the perf "
              "trajectory (ROADMAP item 1).")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="unified estimator x precision x shape benchmark",
    )
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / fewer repeats (CI smoke; still "
                         "full estimator x precision x >=3-shape coverage)")
    ap.add_argument("--interpret", action="store_true",
                    help="run the fused Pallas paths in interpret mode "
                         "(off-TPU CI; throughput then measures the "
                         "interpreter, read the RMSE/roofline columns)")
    ap.add_argument("--out", default="BENCH_core.json",
                    help="output artifact path (default: ./BENCH_core.json)")
    ap.add_argument("--estimators", default=None,
                    help="comma-separated registry names "
                         "(default: every registry entry)")
    ap.add_argument("--precisions", default=None,
                    help="comma-separated precision policies "
                         "(default: fp32,bf16)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timing repeats per cell")
    ap.add_argument("--bucketed", action="store_true",
                    help="also time the legacy per-degree RM baseline")
    ap.add_argument("--autotune", action="store_true",
                    help="measured block-ladder autotune before timing")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"],
                    help="pin the jax platform before backend init "
                         "(repro.common.env.set_platform)")
    ap.add_argument("--check", metavar="FILE", default=None,
                    help="validate FILE's schema/coverage and exit")
    ap.add_argument("--against", metavar="FILE", default=None,
                    help="with --check: also diff cell coverage vs FILE")
    args = ap.parse_args(argv)

    if args.platform:
        from repro.common import env

        env.set_platform(args.platform)

    from repro.bench import schema

    if args.check is not None:
        errors = schema.check_file(args.check)
        if args.against is not None:
            errors += schema.check_file(args.against)
            if not errors:
                committed = json.loads(Path(args.against).read_text())
                fresh = json.loads(Path(args.check).read_text())
                errors += schema.diff_coverage(committed, fresh)
        for path in filter(None, (args.check, args.against)):
            _warn_if_interpret_cpu(path)
        if errors:
            print(f"BENCH COVERAGE FAILURES ({args.check}):")
            for e in errors:
                print(f"  {e}")
            return 1
        print(f"bench coverage OK: {args.check}"
              + (f" (vs {args.against})" if args.against else ""))
        return 0

    import dataclasses

    from repro.bench import runner, spec as spec_mod

    spec = (spec_mod.quick_spec(interpret=args.interpret,
                                include_bucketed=args.bucketed)
            if args.quick else
            spec_mod.default_spec(interpret=args.interpret,
                                  include_bucketed=args.bucketed))
    overrides = {}
    if args.estimators:
        overrides["estimators"] = tuple(args.estimators.split(","))
    if args.precisions:
        overrides["precisions"] = tuple(args.precisions.split(","))
    if args.repeats:
        overrides["repeats"] = args.repeats
    if overrides:
        spec = dataclasses.replace(spec, **overrides)

    if args.autotune:
        runner.autotune_spec(spec, emit=print)
    payload = runner.run_spec(spec, emit=print)
    out = Path(args.out)
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out}")
    errors = schema.check_payload(payload,
                                  estimators=spec.estimators or None,
                                  precisions=spec.precisions,
                                  min_shapes=min(3, len(spec.shapes)))
    if errors:
        print("WARNING: fresh payload fails its own coverage check:")
        for e in errors:
            print(f"  {e}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
