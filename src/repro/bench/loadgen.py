"""Open-loop serving load generator + SLO accounting (``BENCH_serving.json``).

The serving analogue of the core bench grid: drive the continuous-batching
:class:`~repro.serve.scheduler.Scheduler` with an OPEN-LOOP arrival
process (requests arrive on a schedule that does not wait for the server —
the honest way to measure saturation; a closed loop self-throttles and
hides queueing collapse) and account per-request SLOs:

  * **TTFT** — submit-to-first-token, p50/p99 across requests;
  * **inter-token latency** — successive-token gaps, p50/p99 pooled over
    every request's token timestamps;
  * **tokens/sec at saturation** — decode throughput measured ONLY over
    steps where every slot was busy, so idle tail steps can't flatter the
    number (plus the overall figure for contrast).

Arrivals come from :func:`poisson_trace` (seeded exponential
inter-arrivals) or a JSONL trace file (:func:`load_trace` /
:func:`save_trace`), so production traces replay through the same harness.
Everything reads the injectable ``repro.obs`` clock: under ``FakeClock``
the whole run — arrivals, queueing, SLO percentiles — is deterministic,
which is how the CI ``serve-sim`` job gates the ``BENCH_serving.json``
schema-v1 artifact (``python -m repro.bench --check``) without timing
noise.

CLI::

    PYTHONPATH=src python -m repro.bench.loadgen --quick --fake-clock \
        --out BENCH_serving.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

__all__ = [
    "Arrival",
    "SERVING_SCHEMA_VERSION",
    "load_trace",
    "poisson_trace",
    "run_load",
    "save_trace",
    "serving_payload",
    "slo_summary",
]

# mirrored by repro.bench.schema.check_serving_payload
SERVING_SCHEMA_VERSION = 1


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: WHEN it arrives and WHAT it asks for.

    Prompt tokens are not stored — they are derived deterministically from
    ``(prompt_seed, request_id, prompt_len)`` at run time, so trace files
    stay tiny and replays are exact.
    """

    t: float                       # arrival time (harness clock seconds)
    request_id: int
    prompt_len: int
    max_new_tokens: int = 16
    temperature: float = 0.0
    priority: int = 0


def poisson_trace(rate: float, num_requests: int, *, seed: int = 0,
                  prompt_len_range=(4, 24), max_new_range=(4, 16),
                  temperature_choices: Sequence[float] = (0.0,),
                  priority_choices: Sequence[int] = (0,)) -> List[Arrival]:
    """Seeded open-loop Poisson arrivals: Exp(rate) inter-arrival gaps."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(num_requests):
        t += float(rng.exponential(1.0 / rate))
        out.append(Arrival(
            t=t, request_id=i,
            prompt_len=int(rng.integers(*prompt_len_range)),
            max_new_tokens=int(rng.integers(*max_new_range)),
            temperature=float(rng.choice(np.asarray(temperature_choices))),
            priority=int(rng.choice(np.asarray(priority_choices)))))
    return out


def save_trace(path, arrivals: Sequence[Arrival]) -> None:
    """Write an arrival trace as JSONL (one request per line)."""
    with open(path, "w") as f:
        for a in arrivals:
            f.write(json.dumps(dataclasses.asdict(a)) + "\n")


def load_trace(path) -> List[Arrival]:
    """Read a JSONL arrival trace; validates ordering and uniqueness."""
    out = []
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(Arrival(**json.loads(line)))
            except (ValueError, TypeError) as e:
                raise ValueError(f"{path}:{ln}: bad trace record ({e})")
    if any(b.t < a.t for a, b in zip(out, out[1:])):
        raise ValueError(f"{path}: arrival times must be non-decreasing")
    if len({a.request_id for a in out}) != len(out):
        raise ValueError(f"{path}: duplicate request_id in trace")
    return out


def _prompt_for(arrival: Arrival, vocab_size: int, prompt_seed: int):
    rng = np.random.default_rng((prompt_seed, arrival.request_id))
    return rng.integers(0, vocab_size, size=arrival.prompt_len)


def run_load(scheduler, arrivals: Sequence[Arrival], *, clock=None,
             prompt_seed: int = 0, max_steps: int = 100_000) -> Dict:
    """Open-loop drive: submit each arrival at its scheduled time, step
    until drained, return raw accounting (per-request states + per-step
    infos) for :func:`slo_summary`.

    ``clock`` is the harness clock object — the SAME one behind the
    scheduler's ``obs`` — consulted for "has request i arrived yet".  When
    it exposes ``advance`` (``FakeClock``) and the scheduler goes idle
    before the next arrival, time jumps straight to it (a real clock would
    spin-step; under the fake clock the jump keeps runs deterministic AND
    models the idle gap for queue-age/TTFT accounting).
    """
    from repro.serve import Request

    obs = scheduler.obs
    clock = clock if clock is not None else obs.now
    now_fn = clock if callable(clock) else clock.now  # FakeClock is callable
    vocab = scheduler.cfg.vocab_size
    pending = list(arrivals)
    steps: List[Any] = []
    submitted = 0
    while (pending or scheduler.pending()) and len(steps) < max_steps:
        now = now_fn()
        while pending and pending[0].t <= now:
            a = pending.pop(0)
            scheduler.submit(Request(
                request_id=a.request_id,
                prompt=_prompt_for(a, vocab, prompt_seed),
                max_new_tokens=a.max_new_tokens,
                temperature=a.temperature,
                priority=a.priority))
            submitted += 1
        if not scheduler.pending():
            if pending and hasattr(clock, "advance"):
                gap = pending[0].t - now_fn()
                if gap > 0:
                    clock.advance(gap)
            continue
        steps.append(scheduler.step())
    truncated = (len(pending)
                 + scheduler.queue_depth
                 + sum(s is not None for s in scheduler.slots))
    return {
        "finished": dict(scheduler.finished),
        "steps": steps,
        "submitted": submitted,
        "truncated": truncated,
        "num_slots": scheduler.num_slots,
    }


def _pct(vals: Sequence[float]) -> Dict[str, float]:
    arr = np.asarray(sorted(vals), dtype=np.float64)
    if arr.size == 0:
        return {"p50": float("nan"), "p99": float("nan"),
                "mean": float("nan"), "n": 0}
    return {
        "p50": float(np.percentile(arr, 50)),
        "p99": float(np.percentile(arr, 99)),
        "mean": float(arr.mean()),
        "n": int(arr.size),
    }


def slo_summary(raw: Dict) -> Dict:
    """Collapse :func:`run_load` accounting into the SLO block of the
    serving artifact (see module docstring for metric definitions)."""
    finished = raw["finished"]
    steps = raw["steps"]
    num_slots = raw["num_slots"]
    ttfts = [s.t_first_token - s.t_enqueue for s in finished.values()
             if s.t_first_token is not None]
    inter = [b - a for s in finished.values()
             for a, b in zip(s.t_tokens, s.t_tokens[1:])]
    total_tokens = sum(len(s.generated) for s in finished.values())

    sat = [st for st in steps if st.active == num_slots]
    sat_tokens = sum(st.new_tokens for st in sat)
    sat_wall = sum(st.t_end - st.t_start for st in sat)
    all_wall = sum(st.t_end - st.t_start for st in steps)
    return {
        "ttft_s": _pct(ttfts),
        "inter_token_s": _pct(inter),
        "tokens_per_s_saturated": (
            sat_tokens / sat_wall if sat_wall > 0 else float("nan")),
        "tokens_per_s_overall": (
            total_tokens / all_wall if all_wall > 0 else float("nan")),
        "saturated_steps": len(sat),
        "total_steps": len(steps),
        "requests_submitted": raw["submitted"],
        "requests_finished": len(finished),
        "requests_truncated": raw["truncated"],
        "total_tokens": total_tokens,
        "finish_reasons": {
            r: sum(1 for s in finished.values() if s.finish_reason == r)
            for r in sorted({s.finish_reason for s in finished.values()})},
    }


def serving_payload(slo: Dict, workload: Dict,
                    provenance: Optional[Dict] = None) -> Dict:
    """Assemble the schema-v1 ``BENCH_serving.json`` payload."""
    if provenance is None:
        from repro.common.env import platform_provenance

        provenance = platform_provenance()
    return {
        "kind": "serving",
        "schema_version": SERVING_SCHEMA_VERSION,
        "provenance": provenance,
        "workload": workload,
        "slo": slo,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.loadgen",
        description="open-loop serving load generator (SLO artifact)")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--quick", action="store_true",
                    help="smoke config + small workload (CI serve-sim)")
    ap.add_argument("--fake-clock", action="store_true",
                    help="deterministic FakeClock: arrival times and SLO "
                         "percentiles become exactly reproducible")
    ap.add_argument("--estimator", default=None,
                    help="feature-estimator registry name")
    ap.add_argument("--attention-mode", default=None,
                    choices=[None, "exact", "rm"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrival rate (requests per clock second)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="replay a JSONL arrival trace instead of Poisson")
    ap.add_argument("--save-trace", default=None, metavar="FILE",
                    help="write the generated arrival trace as JSONL")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--platform", default=None,
                    choices=["cpu", "gpu", "tpu"])
    args = ap.parse_args(argv)

    if args.platform:
        from repro.common import env

        env.set_platform(args.platform)

    import jax

    from repro import obs as obs_mod
    from repro.configs import get_config
    from repro.models import init_model
    from repro.obs import clock as clock_mod
    from repro.serve import Scheduler

    if args.quick:
        args.requests = min(args.requests, 12)
    cfg = get_config(args.arch, smoke=args.quick,
                     attention_mode=args.attention_mode,
                     estimator=args.estimator)
    params = init_model(cfg, jax.random.PRNGKey(args.seed))

    clk = clock_mod.FakeClock(step=0.01) if args.fake_clock else None
    obs = obs_mod.Obs(clock=clk)
    sched = Scheduler(cfg, params, num_slots=args.slots,
                      max_len=args.max_len, rng_seed=args.seed, obs=obs)

    if args.trace:
        arrivals = load_trace(args.trace)
    else:
        arrivals = poisson_trace(args.rate, args.requests, seed=args.seed)
    if args.save_trace:
        save_trace(args.save_trace, arrivals)
        print(f"wrote trace -> {args.save_trace}")

    raw = run_load(sched, arrivals, clock=clk, prompt_seed=args.seed)
    slo = slo_summary(raw)
    payload = serving_payload(slo, workload={
        "arch": args.arch, "scheduler": "continuous",
        "num_slots": args.slots, "max_len": args.max_len,
        "rate": args.rate, "num_requests": len(arrivals),
        "seed": args.seed, "quick": bool(args.quick),
        "fake_clock": bool(args.fake_clock),
        "trace": args.trace,
    })
    obs.close()
    Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {args.out}")
    print(f"[loadgen] {slo['requests_finished']}/{slo['requests_submitted']}"
          f" finished, ttft p50={slo['ttft_s']['p50']:.3f}s "
          f"p99={slo['ttft_s']['p99']:.3f}s, "
          f"tok/s saturated={slo['tokens_per_s_saturated']:.2f} "
          f"({slo['saturated_steps']}/{slo['total_steps']} steps), "
          f"overall={slo['tokens_per_s_overall']:.2f}")

    from repro.bench import schema

    errors = schema.check_serving_payload(payload)
    if errors:
        print("WARNING: fresh serving payload fails its own check:")
        for e in errors:
            print(f"  {e}")
        return 1
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
