"""Computation-environment helpers: platform, XLA flags, host device count.

One place for the process-level knobs every entry point (``python -m
repro.bench``, ``launch/serve.py``, the distributed tests) otherwise
re-implements ad hoc. All of these only take full effect when called BEFORE
the jax backend initializes (i.e. before the first array op / device query),
so CLIs call them first thing in ``main``.
"""
from __future__ import annotations

import os
import warnings
from multiprocessing import cpu_count

import jax

__all__ = [
    "set_platform",
    "set_host_device_count",
    "jax_enable_x64",
    "set_debug_nan",
    "add_xla_flags",
    "platform_provenance",
]


def platform_provenance() -> dict:
    """Where-did-this-number-come-from stamp for every emitted artifact.

    One dict — backend name, physical device kind/count, whether Pallas
    launches run the interpreter on this backend, and the jax version —
    attached to bench payloads (``repro.bench``), metrics snapshots and
    trace headers (``repro.obs``). The point is ROADMAP item 1's nag made
    structural: an artifact claiming kernel performance must SAY it was
    measured on interpret-mode CPU. Calling this initializes the jax
    backend, so CLIs stamp AFTER ``set_platform``/``set_host_device_count``.
    """
    from repro.kernels.common import default_interpret

    devices = jax.devices()
    return {
        "backend": jax.default_backend(),
        "device_kind": devices[0].device_kind if devices else "none",
        "device_count": len(devices),
        "interpret": bool(default_interpret()),
        "jax_version": jax.__version__,
    }


def add_xla_flags(flags: str) -> None:
    """Append to ``XLA_FLAGS`` without clobbering flags already set."""
    existing = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = (existing + " " + flags).strip()


def set_platform(platform: str = "cpu") -> None:
    """Pin the jax platform ('cpu' | 'gpu' | 'tpu').

    Only takes effect at the beginning of the program (before backend
    init). On GPU also sets the standard XLA perf flags from the jax GPU
    performance-tips page.
    """
    if platform not in ("cpu", "gpu", "tpu"):
        raise ValueError(
            f"platform must be 'cpu', 'gpu' or 'tpu'; got {platform!r}")
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        # https://jax.readthedocs.io/en/latest/gpu_performance_tips.html
        add_xla_flags(
            "--xla_gpu_triton_gemm_any=True "
            "--xla_gpu_enable_latency_hiding_scheduler=true"
        )


def set_host_device_count(n: int) -> None:
    """Expose ``n`` host (CPU) devices to jax via XLA_FLAGS.

    The multi-device tests and data-parallel serving smoke runs use this to
    build a mesh on one machine. Must run before backend init; warns and
    clamps when asked for more than the physical core count.
    """
    n = int(n)
    total = cpu_count()
    if n > total:
        warnings.warn(
            f"only {total} CPUs available; using {total} host devices",
            stacklevel=2)
        n = total
    add_xla_flags(f"--xla_force_host_platform_device_count={n}")


def jax_enable_x64(use_x64: bool) -> None:
    """Switch default array precision to 64-bit (or back to 32-bit).

    Falls back to ``$JAX_ENABLE_X64`` when called with False, mirroring the
    env-var behavior jax itself honors.
    """
    if not use_x64:
        use_x64 = bool(os.getenv("JAX_ENABLE_X64", 0))
    jax.config.update("jax_enable_x64", use_x64)


def set_debug_nan(flag: bool) -> None:
    """Raise on NaN production (jax debugging flag); expensive — debug only."""
    jax.config.update("jax_debug_nans", flag)
