"""Shared utilities: pytree helpers, dtype policy, PRNG discipline."""
from repro.common.tree import (
    tree_size,
    tree_bytes,
    tree_map_with_path,
    flatten_dict,
    unflatten_dict,
)
from repro.common.dtypes import (
    DTypePolicy,
    Precision,
    PRECISIONS,
    canonical_dtype,
    resolve_precision,
)

__all__ = [
    "tree_size",
    "tree_bytes",
    "tree_map_with_path",
    "flatten_dict",
    "unflatten_dict",
    "DTypePolicy",
    "Precision",
    "PRECISIONS",
    "canonical_dtype",
    "resolve_precision",
]
