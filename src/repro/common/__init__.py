"""Shared utilities: pytree helpers, dtype policy, PRNG discipline."""
from repro.common.tree import (
    tree_size,
    tree_bytes,
    tree_map_with_path,
    flatten_dict,
    unflatten_dict,
)
from repro.common.dtypes import (
    DTypePolicy,
    Precision,
    PRECISIONS,
    canonical_dtype,
    resolve_precision,
)
from repro.common.env import (
    add_xla_flags,
    jax_enable_x64,
    set_debug_nan,
    set_host_device_count,
    set_platform,
)

__all__ = [
    "add_xla_flags",
    "jax_enable_x64",
    "set_debug_nan",
    "set_host_device_count",
    "set_platform",
    "tree_size",
    "tree_bytes",
    "tree_map_with_path",
    "flatten_dict",
    "unflatten_dict",
    "DTypePolicy",
    "Precision",
    "PRECISIONS",
    "canonical_dtype",
    "resolve_precision",
]
