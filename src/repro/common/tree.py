"""Small pytree utilities used across the framework (no flax dependency)."""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of array elements in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree of arrays / ShapeDtypeStructs."""
    total = 0
    for x in jax.tree_util.tree_leaves(tree):
        total += int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize
    return total


def tree_map_with_path(fn: Callable[[Tuple[str, ...], Any], Any], tree: Any) -> Any:
    """tree_map where fn receives a tuple-of-strings path (dict keys only)."""

    def _walk(path: Tuple[str, ...], node: Any) -> Any:
        if isinstance(node, dict):
            return {k: _walk(path + (str(k),), v) for k, v in node.items()}
        return fn(path, node)

    return _walk((), tree)


# sentinel path suffix marking an EMPTY dict subtree (e.g. the param dict of
# OLMo's non-parametric LayerNorm) so flatten/unflatten stays a bijection —
# without it, restored pytrees would lose empty subtrees and break structure
# checks against live models.
EMPTY_SENTINEL = "__empty_dict__"


def flatten_dict(tree: Dict[str, Any], sep: str = "/") -> Dict[str, Any]:
    """Flatten a nested dict pytree into {path: leaf}."""
    out: Dict[str, Any] = {}

    def _walk(prefix: str, node: Any) -> None:
        if isinstance(node, dict) and not node:
            out[f"{prefix}{sep}{EMPTY_SENTINEL}" if prefix
                else EMPTY_SENTINEL] = np.zeros((0,), dtype=np.float32)
        elif isinstance(node, dict):
            for k, v in node.items():
                _walk(f"{prefix}{sep}{k}" if prefix else str(k), v)
        else:
            out[prefix] = node

    _walk("", tree)
    return out


def unflatten_dict(flat: Dict[str, Any], sep: str = "/") -> Dict[str, Any]:
    """Inverse of flatten_dict."""
    out: Dict[str, Any] = {}
    for path, leaf in flat.items():
        keys = path.split(sep)
        node = out
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        if keys[-1] == EMPTY_SENTINEL:
            continue  # presence of the key already created the empty dict
        node[keys[-1]] = leaf
    return out
