"""Mixed-precision policies.

Two related but distinct knobs live here:

* ``DTypePolicy`` — the MODEL policy (params / activations / reductions)
  used by the transformer stacks and the trainer.
* ``Precision`` — the FEATURE-KERNEL policy threaded through the estimator
  registry's ``apply`` and the three fused Pallas kernels
  (``kernels/rm_feature``, ``kernels/tensor_sketch``,
  ``kernels/ctr_feature``): which dtype the kernel INPUTS (x and the packed
  weight tensors) are stored/loaded in. Accumulation is ALWAYS fp32 —
  inside the Pallas bodies every ``dot_general`` carries
  ``preferred_element_type=float32`` and the running products live in fp32
  VMEM accumulators; the jnp oracles mirror this with
  fp32-preferred dots over compute-dtype operands
  (tests/test_precision.py asserts the bf16 path does NOT collapse to bf16
  accumulation). The estimator parameters themselves (Rademacher signs,
  fourth-roots-of-unity, CountSketch signs) take values in {0, +-1}, so
  bf16 storage is LOSSLESS for the params of all three families; the lossy
  steps are rounding x and (for TensorSketch) the packed cos/sin tensors.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp


def canonical_dtype(name: str):
    return {
        "float32": jnp.float32,
        "bfloat16": jnp.bfloat16,
        "float16": jnp.float16,
        "int8": jnp.int8,
        "int32": jnp.int32,
    }[name]


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Which dtype each class of tensor uses.

    ``param``: master weights; ``compute``: activations & matmul inputs;
    ``accum``: reductions (attention normalizers, RM feature products, losses).
    """

    param: str = "float32"
    compute: str = "bfloat16"
    accum: str = "float32"

    @property
    def param_dtype(self):
        return canonical_dtype(self.param)

    @property
    def compute_dtype(self):
        return canonical_dtype(self.compute)

    @property
    def accum_dtype(self):
        return canonical_dtype(self.accum)


FP32 = DTypePolicy(param="float32", compute="float32", accum="float32")
MIXED = DTypePolicy(param="float32", compute="bfloat16", accum="float32")


# ---------------------------------------------------------------------------
# feature-kernel precision policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Precision:
    """Input/weight dtype policy for the fused feature kernels.

    ``compute`` is the dtype of x and the packed weight tensors as they
    enter the kernel (HBM storage + MXU operand dtype); ``accum`` is the
    accumulator dtype and is fp32 for every built-in policy — the bf16
    policy is bf16-in / fp32-accum, never bf16 accumulation.
    """

    name: str
    compute: str
    accum: str = "float32"

    @property
    def compute_dtype(self):
        return canonical_dtype(self.compute)

    @property
    def accum_dtype(self):
        return canonical_dtype(self.accum)


PRECISION_FP32 = Precision(name="fp32", compute="float32")
PRECISION_BF16 = Precision(name="bf16", compute="bfloat16")

PRECISIONS = {p.name: p for p in (PRECISION_FP32, PRECISION_BF16)}


def resolve_precision(
    precision: Optional[Union[str, Precision]] = None,
) -> Precision:
    """Normalize a precision argument to a ``Precision`` record.

    ``None`` means fp32 (the historical behavior of every apply path), a
    string is looked up in ``PRECISIONS``, and a ``Precision`` instance
    passes through — so consumer configs can carry the policy as a plain
    hashable string (``cfg.rm.precision``) while library code works with
    the resolved record.

    Raises:
        ValueError: unknown name; the message carries the available names
            so consumer-side validation (e.g. the serving engine's
            constructor check) is self-explanatory.
    """
    if precision is None:
        return PRECISION_FP32
    if isinstance(precision, Precision):
        return precision
    try:
        return PRECISIONS[precision]
    except KeyError:
        raise ValueError(
            f"unknown precision {precision!r}; "
            f"available: {tuple(sorted(PRECISIONS))}"
        ) from None
