"""Mixed-precision policy: params fp32, activations bf16 (configurable)."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


def canonical_dtype(name: str):
    return {
        "float32": jnp.float32,
        "bfloat16": jnp.bfloat16,
        "float16": jnp.float16,
        "int8": jnp.int8,
        "int32": jnp.int32,
    }[name]


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """Which dtype each class of tensor uses.

    ``param``: master weights; ``compute``: activations & matmul inputs;
    ``accum``: reductions (attention normalizers, RM feature products, losses).
    """

    param: str = "float32"
    compute: str = "bfloat16"
    accum: str = "float32"

    @property
    def param_dtype(self):
        return canonical_dtype(self.param)

    @property
    def compute_dtype(self):
        return canonical_dtype(self.compute)

    @property
    def accum_dtype(self):
        return canonical_dtype(self.accum)


FP32 = DTypePolicy(param="float32", compute="float32", accum="float32")
MIXED = DTypePolicy(param="float32", compute="bfloat16", accum="float32")
