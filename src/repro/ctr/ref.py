"""jnp reference paths for the complex-to-real (CTR) estimator.

Two oracles (DESIGN.md §11), both emitting the random section only — the
deterministic prefix columns (h01 block / degree-0 const) are concatenated
by ``apply_ctr_plan``:

* ``ctr_blocks_ref`` — the production off-TPU path: ONE flat ``complex64``
  matmul ``x @ (wr + i wi)^T`` plus segmented products per degree bucket
  (``sum_n c_n n`` projection columns, the exact complex analogue of
  ``core.plan._apply_plan_flat``). Ground truth for the fused kernel.
* ``ctr_feature_fused_ref`` — the exact jnp mirror of the Pallas kernel's
  masked complex running product on the packed ``pack_ctr`` tensors. Used
  for raw array-level parity tests of ``ctr_feature_fused``.

Output layout (both): ``[ Re of all complex columns, buckets ascending |
Im of all complex columns, buckets ascending ]`` — ``2 * num_complex``
real columns, each scaled by its complex column's scale.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.ctr.plan import CtrPlan

__all__ = ["ctr_blocks_ref", "ctr_feature_fused_ref"]


def ctr_blocks_ref(
    plan: CtrPlan, params: Dict[str, jax.Array], x: jax.Array
) -> jax.Array:
    """All degree buckets via complex64: ``x [B, d] -> [B, 2 * num_complex]``.

    Complex feature i of bucket n is ``scale_n * prod_{j<n} <w_ij, x>`` with
    ``w = wr + i wi``; the output stacks ``[Re | Im]`` (CtR convention), so
    the plain real inner product of two outputs is
    ``Re(<z(x), conj(z(y))>)`` — the unbiased kernel estimate.
    """
    xf = x.astype(jnp.float32)
    w = (params["wr"].astype(jnp.float32)
         + 1j * params["wi"].astype(jnp.float32))       # [rows, d] complex64
    if w.shape[0] == 0:
        return jnp.zeros((xf.shape[0], 0), jnp.float32)
    proj = xf.astype(jnp.complex64) @ w.T               # [B, rows]
    res, ims = [], []
    off = 0
    for n, c, scale in zip(plan.degrees, plan.counts, plan.scales):
        rows = c * n
        block = proj[:, off : off + rows].reshape(-1, c, n)
        z = jnp.prod(block, axis=-1) * jnp.float32(scale)   # [B, c] complex
        res.append(z.real)
        ims.append(z.imag)
        off += rows
    return jnp.concatenate(res + ims, axis=-1)


def ctr_feature_fused_ref(
    x: jax.Array,          # [B, d]
    wr: jax.Array,         # [max_degree, Fc, d] real part (pack_ctr)
    wi: jax.Array,         # [max_degree, Fc, d] imag part
    col_deg: jax.Array,    # [Fc] int32 per-column product depth
    col_scale: jax.Array,  # [Fc] per-complex-column scale
) -> jax.Array:            # [B, 2 * Fc] float32
    """jnp mirror of the fused kernel: masked complex product, ``[Re | Im]``.

    Column f of each half is ``col_scale[f] * Re/Im( prod_{j < col_deg[f]}
    <wr[j,f] + i wi[j,f], x> )`` — identical ordering and masking to
    ``ctr_feature_fused_pallas``, in plain jnp.
    """
    xf = x.astype(jnp.float32)
    k, fc, _ = wr.shape
    ar = jnp.ones((xf.shape[0], fc), jnp.float32)
    ai = jnp.zeros((xf.shape[0], fc), jnp.float32)
    for j in range(k):
        pr = xf @ wr[j].astype(jnp.float32).T
        pi = xf @ wi[j].astype(jnp.float32).T
        keep = (j < col_deg)[None, :]
        nr = ar * pr - ai * pi
        ni = ar * pi + ai * pr
        ar = jnp.where(keep, nr, ar)
        ai = jnp.where(keep, ni, ai)
    sc = col_scale[None, :].astype(jnp.float32)
    return jnp.concatenate([ar * sc, ai * sc], axis=-1)
