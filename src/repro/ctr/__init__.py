"""repro.ctr — the complex-to-real (CtR) estimator subsystem (DESIGN.md §11).

A third random-feature family for the paper's dot-product kernels, driven by
the SAME Taylor-coefficient degree measures as Random Maclaurin but built
from COMPLEX Rademacher products (Wacker et al., *Improved Random Features
for Dot Product Kernels*, 2022) whose real/imaginary parts are stacked into
real columns — lower per-degree variance than RM at a matched real feature
budget for every degree >= 2 on aligned pairs (see DESIGN.md §11 for the
exact condition), and measured lowest Gram MSE of the three families on
the exponential kernel. Registered as ``"ctr"`` in the
estimator registry (``repro.core.registry``); consumers pick estimators by
name.
"""
from repro.ctr.plan import (
    CtrPlan,
    apply_ctr_plan,
    init_ctr_params,
    make_ctr_plan,
    pack_ctr,
)
from repro.ctr.feature_map import CtrFeatureMap, make_ctr_feature_map
from repro.ctr.ref import ctr_blocks_ref, ctr_feature_fused_ref

__all__ = [
    "CtrPlan",
    "apply_ctr_plan",
    "init_ctr_params",
    "make_ctr_plan",
    "pack_ctr",
    "CtrFeatureMap",
    "make_ctr_feature_map",
    "ctr_blocks_ref",
    "ctr_feature_fused_ref",
]
