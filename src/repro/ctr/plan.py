"""CtrPlan — complex-to-real (CtR) improved random features.

Wacker, Kanagawa & Filippone, *Improved Random Features for Dot Product
Kernels* (2022), replace the paper's real Rademacher draws with COMPLEX
Rademacher entries ``w_i ~ Uniform{1, i, -1, -i}``. The degree-n product
feature

    z(x) = prod_{j < n} <w_j, x>,      E[ z(x) conj(z(y)) ] = <x, y>^n

stays unbiased (``E[w_i conj(w_k)] = delta_ik``), but the extra phase kills
the self-pairing terms real Rademacher pays: ``E[w_i^2] = 0``, so the
per-degree second moment changes from ``R^n`` with ``R = |x|^2|y|^2 + 2t^2
- 2s`` to ``(B1^n + B2^n)/2`` with ``B1 = |x|^2|y|^2 + t^2 - s``,
``B2 = 2t^2 - s`` (``t = <x,y>``, ``s = sum x_i^2 y_i^2``). Since
``B1 + B2 = R + t^2`` exactly and ``B2 <= B1 <= R`` whenever ``s <= t^2``,
majorization gives the matched-budget win ``B1^n + B2^n <= R^n + t^{2n}``
for every degree n >= 2 on such pairs (a tie at n = 1) — the
aligned/high-kernel-value pairs that dominate Gram error. It is NOT a
pointwise guarantee: mixed-sign near-orthogonal pairs with ``s > t^2`` can
favor real Rademacher. The measured net effect is what the deterministic
test pins: lowest Gram MSE of the three families on the exponential kernel
at matched F. See DESIGN.md §11.

The **complex-to-real** trick makes the estimator a real feature map: stack

    z_R(x) = [ Re z(x) | Im z(x) ],
    <z_R(x), z_R(y)> = Re( z(x) conj(z(y)) ),

so one complex feature yields TWO real columns whose plain real inner
product is the unbiased kernel estimate — downstream consumers (linear
models, linear attention, Gram estimation, feature-axis sharding) never see
a complex dtype. At a matched REAL budget F, CTR draws F/2 complex features
where RM draws F real ones and wins on variance wherever degree >= 2 mass
exists.

This module mirrors ``repro.core.plan`` / ``repro.sketch.plan`` exactly:

    degree measure  ->  complex-feature allocation  ->  sqrt(a_n / c_n)
                    ->  packed fused layout (two real tensors, DESIGN.md §11)

A ``CtrPlan`` is a hashable NamedTuple (jit-static). Column layout:

    [ h01 const | h01 identity block | degree-0 const
      | Re of complex columns, buckets ascending
      | Im of complex columns, buckets ascending ]

Degree 0 (and the H0/1 prefix) are exact real columns computed outside the
kernel, exactly as in the sketch subsystem; only degrees >= 1 draw complex
randomness.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maclaurin import DotProductKernel
from repro.core.plan import BIAS_TAIL_DEGREES, allocate_features

__all__ = [
    "CtrPlan",
    "make_ctr_plan",
    "init_ctr_params",
    "pack_ctr",
    "apply_ctr_plan",
]


class CtrPlan(NamedTuple):
    """Hashable complex-to-real feature-map plan: static through jit/scan.

    ``degrees``/``counts``/``scales`` describe the degree >= 1 COMPLEX
    feature buckets (ascending): bucket n holds ``counts[i]`` complex
    features of per-feature scale ``scales[i]`` — each contributing one Re
    and one Im real output column at that same scale. ``seed`` records the
    ``allocate_features`` seed so plans reproduce across hosts (``to_json``
    carries every field).
    """

    degrees: Tuple[int, ...]
    counts: Tuple[int, ...]           # complex features per degree bucket
    scales: Tuple[float, ...]         # per-complex-feature scale
    const: float                      # exact degree-0 column (0.0 when absent)
    h01: bool
    h01_a0: float
    h01_a1: float
    input_dim: int
    num_random: int                   # F, the REAL feature budget
    # a_0..a_{n_max + BIAS_TAIL_DEGREES} (tail window: bias diagnostics only)
    coefs_host: Tuple[float, ...]
    seed: int                         # allocation seed (reproducibility)

    # -- sizes ---------------------------------------------------------------
    @property
    def total_rows(self) -> int:
        """Complex Rademacher rows backing the buckets: ``sum_n c_n * n``."""
        return int(sum(c * n for c, n in zip(self.counts, self.degrees)))

    @property
    def max_degree(self) -> int:
        """Product depth of the packed layout (0 for a const-only plan)."""
        return max(self.degrees) if self.degrees else 0

    @property
    def num_complex(self) -> int:
        """Complex features across all buckets (each emits 2 real columns)."""
        return int(sum(self.counts))

    @property
    def num_prefix_columns(self) -> int:
        """Deterministic (exact, zero-variance) columns ahead of the
        random section."""
        pre = 0
        if self.h01:
            pre += 1 + self.input_dim
        if self.const != 0.0:
            pre += 1
        return pre

    @property
    def output_dim(self) -> int:
        """Real output columns: prefix + Re half + Im half."""
        return self.num_prefix_columns + 2 * self.num_complex

    # -- fused column layout (host-side, static; complex section only) -------
    def column_degrees(self) -> np.ndarray:
        """Per COMPLEX column product depth, int32 ``[num_complex]``."""
        deg = []
        for n, c in zip(self.degrees, self.counts):
            deg.extend([n] * c)
        return np.asarray(deg, dtype=np.int32)

    def column_scales(self) -> np.ndarray:
        """Per COMPLEX column scale, float32 ``[num_complex]``.

        The same scale multiplies both the Re and the Im real output column
        of that complex feature.
        """
        sc = []
        for s, c in zip(self.scales, self.counts):
            sc.extend([float(s)] * c)
        return np.asarray(sc, dtype=np.float32)

    # -- diagnostics ---------------------------------------------------------
    def truncation_bias(self, radius: float) -> float:
        """Worst-case dropped-degree mass ``sum a_n R^{2n}`` (paper §4.2),
        tail window beyond n_max included (see core.plan.BIAS_TAIL_DEGREES)."""
        present = set(self.degrees)
        if self.const != 0.0:
            present.add(0)
        if self.h01:
            present.update((0, 1))
        bias = 0.0
        for n, a_n in enumerate(self.coefs_host):
            if a_n > 0.0 and n not in present:
                bias += a_n * radius ** (2 * n)
        return bias

    # -- serialization (shared body with FeaturePlan/SketchPlan) -------------
    def to_json(self) -> str:
        """Full plan state (seed + realized allocation included) as JSON."""
        from repro.core.plan import plan_to_json

        return plan_to_json(self)

    @classmethod
    def from_json(cls, s: str) -> "CtrPlan":
        """Inverse of ``to_json`` (lossless: conformance-tested)."""
        from repro.core.plan import plan_from_json

        return plan_from_json(cls, s)


def make_ctr_plan(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    *,
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    stratified: bool = True,
    seed: int = 0,
) -> CtrPlan:
    """Allocate complex features across degrees of the Maclaurin measure.

    Args mirror ``core.plan.make_feature_plan`` (the estimator-registry
    ``make_plan`` signature). ``num_features`` is the REAL output budget F:
    after reserving the exact prefix columns (degree-0 const, or the H0/1
    block when ``h01``), the remaining budget funds ``(F - prefix) // 2``
    complex features, each worth two real columns.

    The SAME degree-measure machinery as RM/TensorSketch splits that complex
    budget (``core.feature_map.degree_measure`` over degrees >= 1 — degree 0
    is always exact here, as in the sketch family). Both allocation modes are
    supported: ``stratified=True`` gives deterministic largest-remainder
    counts with exact scales ``sqrt(a_n / c_n)``; ``stratified=False`` is the
    paper-faithful iid draw with importance weights ``sqrt(a_n / q_n) /
    sqrt(D_c)`` (seeded by ``seed``, recorded on the plan).

    Returns the hashable ``CtrPlan``.
    """
    from repro.core.feature_map import degree_measure

    kernel.validate_positive_definite(n_max)
    if h01 and measure == "geometric":
        measure = "geometric_ge2"
    a0 = float(kernel.coef(0))
    a1 = float(kernel.coef(1))
    if h01 and a0 == 0.0 and a1 == 0.0:
        raise ValueError(
            f"H0/1 is a no-op for kernel {kernel.name}: a_0 = a_1 = 0 "
            "(e.g. homogeneous polynomial kernels — paper §6.2)."
        )
    min_degree = 2 if h01 else 1
    q = degree_measure(kernel, n_max, p=p, kind=measure, radius=radius,
                       min_degree=min_degree)
    coefs = kernel.coefs(n_max)
    coefs_diag = kernel.coefs(n_max + BIAS_TAIL_DEGREES)

    prefix = (1 + input_dim) if h01 else (1 if a0 > 0.0 else 0)
    budget = max((num_features - prefix) // 2, 0)
    counts_all, scales_all = allocate_features(
        coefs, q, budget, stratified=stratified, seed=seed
    )

    degrees, counts, scales = [], [], []
    for n in range(min_degree, n_max + 1):
        c = int(counts_all[n])
        if c > 0 and coefs[n] > 0.0:
            degrees.append(n)
            counts.append(c)
            scales.append(float(scales_all[n]))

    return CtrPlan(
        degrees=tuple(degrees),
        counts=tuple(counts),
        scales=tuple(scales),
        const=float(np.sqrt(a0)) if (a0 > 0.0 and not h01) else 0.0,
        h01=h01,
        h01_a0=a0 if h01 else 0.0,
        h01_a1=a1 if h01 else 0.0,
        input_dim=input_dim,
        num_random=num_features,
        coefs_host=tuple(float(c) for c in coefs_diag),
        seed=seed,
    )


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def init_ctr_params(
    plan: CtrPlan, key: jax.Array, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    """Complex Rademacher rows for one plan instance, as two REAL tensors.

    Returns ``{"wr": dtype [total_rows, d], "wi": dtype [total_rows, d]}``
    with ``wr + i*wi`` uniform over the fourth roots of unity
    ``{1, i, -1, -i}`` — entries are EXACT 0.0 / +-1.0 floats (drawn as an
    int in {0..3}, not via cos/sin, so no float rounding enters the draws).
    Row layout is bucket-major then feature-major, exactly like RM omegas:
    rows ``[off_n + i*n, off_n + (i+1)*n)`` belong to complex feature i of
    degree bucket n. Like RM omegas these are frozen model constants.
    """
    t = jax.random.randint(key, (plan.total_rows, plan.input_dim), 0, 4)
    wr = jnp.where(t == 0, 1.0, jnp.where(t == 2, -1.0, 0.0)).astype(dtype)
    wi = jnp.where(t == 1, 1.0, jnp.where(t == 3, -1.0, 0.0)).astype(dtype)
    return {"wr": wr, "wi": wi}


# ---------------------------------------------------------------------------
# packing for the fused kernel
# ---------------------------------------------------------------------------
def pack_ctr(
    plan: CtrPlan, params: Dict[str, jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """Flat rows ``[total_rows, d]`` x2 -> fused ``(wr, wi)`` tensors.

    Each output is ``[max_degree, num_complex, d]``: complex column f's
    product slots are ``wr/wi[0:col_degree[f], f, :]``; unused slots are
    zero (masked inside the kernel, never multiplied). Pure
    reshape/pad/concat — same traffic note as ``core.plan.pack_omegas``:
    callers applying one plan repeatedly should pack once and pass
    ``packed=`` to ``apply_ctr_plan``.
    """
    d = plan.input_dim
    k = plan.max_degree

    def _pack(flat):
        parts = []
        off = 0
        for n, c in zip(plan.degrees, plan.counts):
            rows = flat[off : off + c * n].reshape(c, n, d)
            off += c * n
            parts.append(jnp.pad(rows, ((0, 0), (0, k - n), (0, 0))))
        if not parts:
            return jnp.zeros((k, 0, d), flat.dtype)
        packed = jnp.concatenate(parts, axis=0)                 # [Fc, k, d]
        return jnp.transpose(packed, (1, 0, 2))                 # [k, Fc, d]

    return _pack(params["wr"]), _pack(params["wi"])


# ---------------------------------------------------------------------------
# application — ONE fused launch (or the jnp complex oracle)
# ---------------------------------------------------------------------------
def apply_ctr_plan(
    plan: CtrPlan,
    params: Dict[str, jax.Array],
    x: jax.Array,
    accum_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    packed: Optional[Tuple[jax.Array, jax.Array]] = None,
    precision=None,
) -> jax.Array:
    """Featurize ``x [..., d] -> [..., plan.output_dim]``.

    The deterministic prefix columns (h01 block / degree-0 const) are exact
    jnp fills; the complex buckets run as ONE fused Pallas launch
    (``repro.kernels.ctr_feature``) on TPU, or the ``complex64`` oracle
    (``repro.ctr.ref.ctr_blocks_ref``) elsewhere. Mirrors
    ``core.plan.apply_plan``'s contract so the estimator registry exposes
    all families behind one ``apply``; ``packed`` short-circuits
    ``pack_ctr`` for callers that cache the packed tensors.

    ``precision`` selects the input dtype policy: under ``"bf16"`` x and the
    packed ``wr``/``wi`` tensors enter the kernel in bf16 — the fourth-root
    values {0, +-1} are exact in bf16, so only x is rounded — while both
    accumulators stay fp32. The complex64 oracle has no bf16 path, so
    off-Pallas the policy only rounds x.
    """
    from repro.common.dtypes import resolve_precision
    from repro.ctr.ref import ctr_blocks_ref
    from repro.kernels.ctr_feature.ops import ctr_feature_fused

    if x.shape[-1] != plan.input_dim:
        raise ValueError(
            f"expected trailing dim {plan.input_dim}, got {x.shape}"
        )
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    prec = resolve_precision(precision)
    compute_dtype = prec.compute_dtype
    batch_shape = x.shape[:-1]
    xf = x.reshape(-1, plan.input_dim).astype(accum_dtype)
    feats = []
    if plan.h01:
        feats.append(jnp.full((xf.shape[0], 1), np.sqrt(plan.h01_a0),
                              dtype=accum_dtype))
        feats.append(jnp.asarray(np.sqrt(plan.h01_a1), accum_dtype)
                     * xf.astype(compute_dtype).astype(accum_dtype))
    if plan.const != 0.0:
        feats.append(jnp.full((xf.shape[0], 1), plan.const,
                              dtype=accum_dtype))
    if plan.num_complex:
        if use_pallas:
            wr, wi = (packed if packed is not None
                      else pack_ctr(plan, params))
            z = ctr_feature_fused(
                xf.astype(compute_dtype),
                wr.astype(compute_dtype), wi.astype(compute_dtype),
                jnp.asarray(plan.column_degrees()),
                jnp.asarray(plan.column_scales()),
                use_pallas=True, interpret=interpret,
            ).astype(accum_dtype)
        else:
            z = ctr_blocks_ref(
                plan, params, xf.astype(compute_dtype)
            ).astype(accum_dtype)
        feats.append(z)
    if not feats:
        # fully degenerate plan (a_0 = 0 and the halved budget funded no
        # complex features): a valid 0-column map, not a concat error —
        # its Gram estimate is identically 0, matching output_dim == 0.
        return jnp.zeros((*batch_shape, 0), accum_dtype)
    out = jnp.concatenate(feats, axis=-1)
    return out.reshape(*batch_shape, out.shape[-1])
