from repro.data.synthetic import SyntheticLMDataset, byte_tokenize
from repro.data.toy import (
    unit_ball_points,
    make_classification_dataset,
    UCI_LIKE_SPECS,
)

__all__ = [
    "SyntheticLMDataset",
    "byte_tokenize",
    "unit_ball_points",
    "make_classification_dataset",
    "UCI_LIKE_SPECS",
]
