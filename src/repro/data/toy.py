"""Datasets for the paper's experiments (Figures 1-2, Table 1).

The paper's UCI datasets are unavailable offline; ``UCI_LIKE_SPECS`` mirrors
their (N, d) and the evaluation protocol (60% train / 40% test, vectors
normalized to the unit ball — the paper normalizes because dot product
kernels are unbounded, §3). The synthetic generator plants a polynomial
decision boundary so that non-linear kernels genuinely beat linear ones —
the qualitative structure Table 1 demonstrates.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# name: (N, d) — mirrors the paper's Table 1 datasets
UCI_LIKE_SPECS: Dict[str, Tuple[int, int]] = {
    "nursery": (13000, 8),
    "spambase": (4600, 57),
    "cod-rna": (20000, 8),      # capped at 20000 like the paper's protocol
    "adult": (20000, 123),
    "ijcnn": (20000, 22),
    "covertype": (20000, 54),
}


def unit_ball_points(key: jax.Array, n: int, d: int) -> jax.Array:
    """Uniform-ish points with ||x||_2 <= 1 (paper's toy experiment)."""
    x = jax.random.normal(key, (n, d))
    r = jax.random.uniform(key, (n, 1)) ** (1.0 / d)
    return x / jnp.linalg.norm(x, axis=1, keepdims=True) * r


def make_classification_dataset(
    name: str, seed: int = 0, noise: float = 0.05,
) -> Dict[str, jax.Array]:
    """Synthetic stand-in for one Table-1 dataset: degree-3 polynomial
    boundary in a random low-dim subspace + label noise."""
    n, d = UCI_LIKE_SPECS[name]
    key = jax.random.PRNGKey(hash(name) % (2**31) + seed)
    kx, kw, kq, kn, kp = jax.random.split(key, 5)
    x = jax.random.normal(kx, (n, d))
    x = x / (jnp.linalg.norm(x, axis=1, keepdims=True) + 1e-9)

    # boundary: w.x + (q1.x)(q2.x) + (q3.x)^3
    w = jax.random.normal(kw, (d,))
    q = jax.random.normal(kq, (3, d))
    score = (
        x @ w
        + 2.0 * (x @ q[0]) * (x @ q[1])
        + 3.0 * (x @ q[2]) ** 3
    )
    y = jnp.sign(score - jnp.median(score))
    flip = jax.random.bernoulli(kn, noise, (n,))
    y = jnp.where(flip, -y, y)
    y = jnp.where(y == 0, 1.0, y)

    perm = jax.random.permutation(kp, n)
    x, y = x[perm], y[perm]
    n_train = int(0.6 * n)
    return {
        "x_train": x[:n_train],
        "y_train": y[:n_train],
        "x_test": x[n_train:],
        "y_test": y[n_train:],
    }
