"""Deterministic synthetic data pipeline.

Requirements at scale: (1) per-host sharding without coordination — every
host computes its own shard from (step, host_index) alone; (2) exactly
resumable — the stream is a pure function of the step, so restoring a
checkpoint at step k replays from k with zero state; (3) deterministic
across restarts and topologies.

``SyntheticLMDataset`` generates a second-order Markov "language" from a
hashed transition table — enough structure that a ~10-100M model's loss
drops well below the uniform baseline within a few hundred steps (used by
examples/train_lm.py), while remaining fully offline.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


def byte_tokenize(text: str, vocab_size: int = 256) -> np.ndarray:
    data = np.frombuffer(text.encode("utf-8"), dtype=np.uint8)
    return (data % vocab_size).astype(np.int32)


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int = 512
    seq_len: int = 256
    global_batch: int = 8
    seed: int = 0
    branching: int = 8          # markov fan-out per context
    num_contexts: int = 512     # transition-table rows (task difficulty)
    order: int = 1              # markov order: 1 = learnable without
    #                             attention (fast CI), 2 = needs a
    #                             previous-token attention circuit
    num_hosts: int = 1
    host_index: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0
        self.local_batch = self.global_batch // self.num_hosts
        rng = np.random.Generator(np.random.Philox(self.seed))
        # second-order transition table: context hash -> branching successors
        self._succ = rng.integers(
            0, self.vocab_size, size=(self.num_contexts, self.branching),
            dtype=np.int64,
        )

    def _gen_sequences(self, step: int) -> np.ndarray:
        """[local_batch, seq_len + 1] tokens, pure function of (step, host)."""
        n = self.local_batch
        rng = np.random.Generator(
            np.random.Philox(key=self.seed,
                             counter=step * self.num_hosts + self.host_index)
        )
        out = np.empty((n, self.seq_len + 1), dtype=np.int64)
        out[:, 0] = rng.integers(0, self.vocab_size, n)
        out[:, 1] = rng.integers(0, self.vocab_size, n)
        choices = rng.integers(0, self.branching, size=(n, self.seq_len + 1))
        tbl = self._succ
        h = len(tbl)
        for t in range(2, self.seq_len + 1):
            if self.order == 1:
                ctx = (out[:, t - 1] * 31) % h
            else:
                ctx = (out[:, t - 1] * 31 + out[:, t - 2] * 7) % h
            out[:, t] = tbl[ctx, choices[:, t]]
        return out.astype(np.int32)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        seq = self._gen_sequences(step)
        return {
            "tokens": jnp.asarray(seq[:, :-1]),
            "targets": jnp.asarray(seq[:, 1:]),
        }

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
