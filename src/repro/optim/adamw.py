"""AdamW from scratch (no optax): decoupled weight decay, global-norm grad
clipping, non-trainable masking (RM plan omegas are frozen constants).

Optimizer state lives in the same sharding as the parameters (FSDP-friendly:
mu/nu inherit each param's PartitionSpec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.tree import tree_map_with_path

# parameter names that must never be updated (static draws of the paper's
# feature maps are part of the model DEFINITION, not learnable weights).
# "rm_est" is the estimator-registry param subtree (RM omegas, TensorSketch
# hash tables — the latter are int32 and must never see an optimizer step).
FROZEN_LEAF_NAMES = ("rm_omegas",)
FROZEN_SUBTREES = ("rm_est",)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    # 1D params (norm scales, biases) skip weight decay, standard practice
    decay_min_ndim: int = 2


def _is_frozen(path: Tuple[str, ...]) -> bool:
    return path[-1] in FROZEN_LEAF_NAMES or any(
        p in FROZEN_SUBTREES for p in path
    )


def adamw_init(params: Any) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), norm


def mask_frozen(grads: Any) -> Any:
    """Zero gradients of non-trainable leaves."""
    return tree_map_with_path(
        lambda path, g: jnp.zeros_like(g) if _is_frozen(path) else g, grads
    )


def adamw_update(
    params: Any,
    grads: Any,
    opt_state: Dict[str, Any],
    lr: jax.Array,
    cfg: AdamWConfig = AdamWConfig(),
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    grads = mask_frozen(grads)
    grads, grad_norm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1.0 - b1) * g.astype(m.dtype),
        opt_state["mu"], grads,
    )
    new_nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1.0 - b2) * jnp.square(g.astype(v.dtype)),
        opt_state["nu"], grads,
    )

    def leaf_update(path, p):
        g_m = _get(new_mu, path)
        g_v = _get(new_nu, path)
        if _is_frozen(path):
            return p
        update = (g_m / bc1) / (jnp.sqrt(g_v / bc2) + cfg.eps)
        if p.ndim >= cfg.decay_min_ndim:
            update = update + cfg.weight_decay * p.astype(update.dtype)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype)

    new_params = tree_map_with_path(leaf_update, params)
    metrics = {"grad_norm": grad_norm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics


def _get(tree, path):
    node = tree
    for k in path:
        node = node[k]
    return node
