"""Gradient compression for cross-pod links: int8 quantization with error
feedback (1-bit-Adam-style residual correction).

The inter-pod ICI/DCN link is the scarcest bandwidth at multi-pod scale; the
data-parallel gradient all-reduce over the "pod" axis is its dominant user.
``compressed_psum_with_feedback`` runs inside a shard_map over the pod axis:

    q, scale = quantize(g + residual);  q_sum = psum(q);  g' = dequant(q_sum)
    residual' = (g + residual) - dequant(q)      # local error feedback

Error feedback makes the compression *unbiased over time*: the quantization
error of step t is re-injected at step t+1, so SGD/Adam convergence is
preserved (Karimireddy et al., 2019). Property-tested in
tests/test_compression.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_with_feedback(
    grads: Any, residuals: Any, axis_name: str
) -> Tuple[Any, Any]:
    """All-reduce ``grads`` over ``axis_name`` in int8 with error feedback.

    Must be called inside shard_map/pmap over ``axis_name``. Returns
    (mean-reduced fp32 grads, new residuals). Bandwidth on the axis drops 4x
    vs fp32 (int8 payload + one scalar scale per leaf).
    """
    n = jax.lax.psum(1, axis_name)

    def _one(g, r):
        corrected = g.astype(jnp.float32) + r
        # shared codebook: max |value| across the axis so every pod encodes
        # with the same scale and the int payloads are summable.
        local_scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
        shared_scale = jax.lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(corrected / shared_scale), -127, 127)
        new_r = corrected - q * shared_scale
        summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
        return (summed.astype(jnp.float32) * shared_scale / n), new_r

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    out_g, out_r = [], []
    for g, r in zip(flat_g, flat_r):
        og, orr = _one(g, r)
        out_g.append(og)
        out_r.append(orr)
    return (jax.tree_util.tree_unflatten(tdef, out_g),
            jax.tree_util.tree_unflatten(tdef, out_r))
