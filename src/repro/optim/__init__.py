from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine, warmup_linear
from repro.optim.compression import (
    quantize_int8,
    dequantize_int8,
    compressed_psum_with_feedback,
)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "warmup_linear",
    "quantize_int8",
    "dequantize_int8",
    "compressed_psum_with_feedback",
]
