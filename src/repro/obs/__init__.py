"""``repro.obs`` — tracing, metrics and online (eps, delta) accuracy
monitoring (DESIGN.md §14, docs/observability.md).

Public surface:

* :class:`Obs` / :data:`NOOP` / :func:`resolve` — the facade every
  instrumented layer threads (``ServingEngine(obs=...)``,
  ``Trainer(obs=...)``); ``None`` resolves to a zero-overhead no-op.
* :mod:`repro.obs.clock` — the ONE monotonic clock behind bench timings,
  span durations and serving latencies (tests inject ``FakeClock``).
* :class:`MetricsRegistry` (counters/gauges/histograms, p50/p90/p99
  summaries, provenance-stamped JSON snapshots).
* :class:`Tracer` + :func:`chrome_trace` (JSONL spans/events, Perfetto
  export) and :func:`kernel_scope` (named_scope/TraceAnnotation + analytic
  launch costs inside the four fused Pallas wrapper ops).
* :class:`DriftMonitor` — the paper's concentration bound as a live SLO.

CLI: ``python -m repro.obs {summarize,diff,chrome} trace.jsonl``.
"""
from repro.obs import clock
from repro.obs.core import NOOP, NoopObs, Obs, resolve
from repro.obs.drift import DriftMonitor, DriftReport, hoeffding_eps
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import (
    TRACE_SCHEMA,
    Tracer,
    chrome_trace,
    current_tracer,
    install_tracer,
    kernel_scope,
    read_trace,
    write_chrome,
)

__all__ = [
    "Obs", "NoopObs", "NOOP", "resolve", "clock",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "Tracer", "TRACE_SCHEMA", "chrome_trace", "read_trace", "write_chrome",
    "install_tracer", "current_tracer", "kernel_scope",
    "DriftMonitor", "DriftReport", "hoeffding_eps",
]
