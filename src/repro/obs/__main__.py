"""Trace-file CLI: ``python -m repro.obs <cmd> trace.jsonl``.

Commands:

* ``summarize FILE`` — provenance header plus one row per span/event name
  (count, total and p50/p90/p99 durations for spans).
* ``diff A B`` — per-name count and p50-duration deltas between two trace
  files (e.g. a before/after pair of serve runs).
* ``chrome FILE [-o OUT]`` — convert to the Chrome ``traceEvents`` format
  (default ``FILE`` with a ``.chrome.json`` suffix) for Perfetto /
  ``chrome://tracing``.
"""
from __future__ import annotations

import argparse
import sys
from collections import defaultdict
from pathlib import Path
from typing import Dict, List

from repro.obs.metrics import percentile
from repro.obs.trace import read_trace, write_chrome


def _span_stats(records: List[Dict]) -> Dict[str, Dict]:
    stats: Dict[str, Dict] = {}
    by_name: Dict[str, List[float]] = defaultdict(list)
    counts: Dict[str, int] = defaultdict(int)
    kinds: Dict[str, str] = {}
    for rec in records:
        if rec.get("type") == "span":
            by_name[rec["name"]].append(float(rec.get("dur_us", 0.0)))
            kinds[rec["name"]] = "span"
        elif rec.get("type") == "event":
            counts[rec["name"]] += 1
            kinds.setdefault(rec["name"], "event")
    for name, durs in by_name.items():
        srt = sorted(durs)
        stats[name] = {
            "kind": "span", "count": len(durs), "total_us": sum(durs),
            "p50_us": percentile(srt, 50.0), "p90_us": percentile(srt, 90.0),
            "p99_us": percentile(srt, 99.0),
        }
    for name, c in counts.items():
        if name not in stats:
            stats[name] = {"kind": "event", "count": c, "total_us": 0.0,
                           "p50_us": 0.0, "p90_us": 0.0, "p99_us": 0.0}
    return stats


def _meta(records: List[Dict]) -> Dict:
    for rec in records:
        if rec.get("type") == "meta":
            return rec
    return {}


def cmd_summarize(path: str) -> int:
    records = read_trace(path)
    meta = _meta(records)
    print(f"trace: {path}  schema={meta.get('schema', '?')}  "
          f"provenance={meta.get('provenance', {})}")
    stats = _span_stats(records)
    if not stats:
        print("  (no spans or events)")
        return 0
    print(f"  {'name':40s} {'kind':5s} {'count':>7s} {'total_us':>12s} "
          f"{'p50_us':>10s} {'p99_us':>10s}")
    for name in sorted(stats):
        s = stats[name]
        print(f"  {name:40s} {s['kind']:5s} {s['count']:7d} "
              f"{s['total_us']:12.1f} {s['p50_us']:10.1f} "
              f"{s['p99_us']:10.1f}")
    return 0


def cmd_diff(a: str, b: str) -> int:
    sa, sb = _span_stats(read_trace(a)), _span_stats(read_trace(b))
    names = sorted(set(sa) | set(sb))
    print(f"diff {a} -> {b}")
    print(f"  {'name':40s} {'count':>13s} {'p50_us':>21s}")
    for name in names:
        ca = sa.get(name, {}).get("count", 0)
        cb = sb.get(name, {}).get("count", 0)
        pa = sa.get(name, {}).get("p50_us", 0.0)
        pb = sb.get(name, {}).get("p50_us", 0.0)
        print(f"  {name:40s} {ca:5d} -> {cb:5d} {pa:9.1f} -> {pb:9.1f}")
    return 0


def cmd_chrome(path: str, out: str | None) -> int:
    dest = Path(out) if out else Path(path).with_suffix(".chrome.json")
    write_chrome(read_trace(path), dest)
    print(f"wrote {dest}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description="trace-file summarize/diff/"
                                             "chrome-export")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p = sub.add_parser("summarize", help="per-name span/event stats")
    p.add_argument("file")
    p = sub.add_parser("diff", help="count/p50 deltas between two traces")
    p.add_argument("a")
    p.add_argument("b")
    p = sub.add_parser("chrome", help="convert to Chrome traceEvents JSON")
    p.add_argument("file")
    p.add_argument("-o", "--out", default=None)
    args = ap.parse_args(argv)
    if args.cmd == "summarize":
        return cmd_summarize(args.file)
    if args.cmd == "diff":
        return cmd_diff(args.a, args.b)
    return cmd_chrome(args.file, args.out)


if __name__ == "__main__":
    sys.exit(main())
