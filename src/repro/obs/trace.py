"""Span tracing: JSONL events, Chrome-trace export, kernel-launch scopes.

A :class:`Tracer` records two record kinds on the shared monotonic clock
(``repro.obs.clock``), streamed to a ``.jsonl`` file when a path is given
and always kept in memory::

    {"type": "meta",  "schema": ..., "provenance": {...}, "wall_time": ...}
    {"type": "span",  "name": ..., "ts_us": ..., "dur_us": ..., "attrs": {}}
    {"type": "event", "name": ..., "ts_us": ...,               "attrs": {}}

The first line of every trace file is the ``meta`` record (schema version +
platform provenance), which is what ``tools/check_trace.py`` validates and
``python -m repro.obs`` summarizes/diffs. :func:`chrome_trace` converts a
record list to the Chrome ``traceEvents`` format, so any trace opens in
Perfetto / ``chrome://tracing`` (spans become complete "X" slices, events
instant "i" marks).

Kernel launches are traced through the AMBIENT tracer: the four fused
Pallas wrapper ops (rm_feature, tensor_sketch, ctr_feature, rm_attention)
run under :func:`kernel_scope`, which always applies ``jax.named_scope``
(so device profiles / HLO dumps carry the kernel name at zero cost) and —
only when a tracer is installed via ``install_tracer`` — additionally
wraps the launch in ``jax.profiler.TraceAnnotation`` and records a span
with the analytic FLOPs/HBM-bytes for that launch shape
(``repro.bench.roofline.launch_cost``). Inside a ``jit`` trace the wrapper
body runs once per compile, not per call; such spans carry
``"traced": true`` and their duration is TRACE time — per-call device
timing belongs to the jax profiler, the span marks which kernels a
compilation touched and what they cost analytically.
"""
from __future__ import annotations

import contextlib
import json
from pathlib import Path
from typing import Any, Callable, Dict, Iterable, List, Optional

from repro.obs import clock as _clock

__all__ = ["TRACE_SCHEMA", "Tracer", "install_tracer", "current_tracer",
           "kernel_scope", "chrome_trace", "read_trace", "write_chrome"]

TRACE_SCHEMA = "repro.obs.trace/v1"


class Tracer:
    """Append-only span/event recorder on the shared monotonic clock.

    Args:
        path: optional ``.jsonl`` destination — records stream to it as
            they are recorded (the meta header first), so a crashed run
            still leaves a readable trace.
        now: clock override (tests inject ``FakeClock``).
        provenance: platform stamp override for the meta record.
    """

    def __init__(self, path=None,
                 now: Callable[[], float] = _clock.monotonic,
                 provenance: Optional[Dict] = None):
        self._now = now
        self.records: List[Dict] = []
        self._fh = None
        if path is not None:
            p = Path(path)
            p.parent.mkdir(parents=True, exist_ok=True)
            self._fh = p.open("w")
        if provenance is None:
            from repro.common.env import platform_provenance

            provenance = platform_provenance()
        self._emit({"type": "meta", "schema": TRACE_SCHEMA,
                    "wall_time": _clock.wall(), "provenance": provenance})

    # -- recording ----------------------------------------------------------
    def _emit(self, rec: Dict) -> None:
        self.records.append(rec)
        if self._fh is not None:
            self._fh.write(json.dumps(rec) + "\n")
            self._fh.flush()

    def now_us(self) -> float:
        return self._now() * 1e6

    def event(self, name: str, **attrs: Any) -> None:
        """Record an instantaneous event."""
        self._emit({"type": "event", "name": name, "ts_us": self.now_us(),
                    "attrs": attrs})

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any):
        """Record a duration span around the ``with`` body."""
        t0 = self.now_us()
        try:
            yield self
        finally:
            t1 = self.now_us()
            self._emit({"type": "span", "name": name, "ts_us": t0,
                        "dur_us": t1 - t0, "attrs": attrs})

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    # -- convenience --------------------------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Dict]:
        out = [r for r in self.records if r["type"] == "span"]
        return out if name is None else [r for r in out if r["name"] == name]

    def events(self, name: Optional[str] = None) -> List[Dict]:
        out = [r for r in self.records if r["type"] == "event"]
        return out if name is None else [r for r in out if r["name"] == name]


# ---------------------------------------------------------------------------
# ambient tracer for the kernel wrappers
# ---------------------------------------------------------------------------
_CURRENT: Optional[Tracer] = None


def install_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    """Set (or clear, with None) the process-ambient tracer.

    The kernel wrappers consult this instead of taking an ``obs`` argument
    — their call signatures stay pure jax, and the disabled path is one
    global ``is None`` check. Returns the previous tracer so callers can
    restore it (``Obs.activate`` does).
    """
    global _CURRENT
    prev = _CURRENT
    _CURRENT = tracer
    return prev


def current_tracer() -> Optional[Tracer]:
    return _CURRENT


@contextlib.contextmanager
def kernel_scope(kernel: str, x=None, cost: Optional[Dict] = None,
                 **attrs: Any):
    """Name a fused-kernel launch for device profiles and the obs trace.

    Always enters ``jax.named_scope(kernel)`` — the HLO ops produced inside
    carry the kernel name, so TPU/XLA profiles group by kernel family with
    no tracer installed and no measurable overhead. With an ambient tracer,
    also enters ``jax.profiler.TraceAnnotation`` (host profiler timeline)
    and records a ``kernel/<name>`` span: ``x`` (any operand) marks the
    span ``traced=True`` when the launch is being traced under jit rather
    than executed eagerly, and ``cost`` (shape kwargs for
    ``repro.bench.roofline.launch_cost``) attaches the analytic
    FLOPs/HBM-bytes — computed ONLY when a tracer is installed, so the
    disabled path never pays it.
    """
    import jax

    tracer = _CURRENT
    if tracer is None:
        with jax.named_scope(kernel):
            yield
        return
    traced = isinstance(x, jax.core.Tracer) if x is not None else False
    if cost is not None:
        from repro.bench.roofline import launch_cost

        attrs.update(launch_cost(kernel, **cost))
    with jax.named_scope(kernel), \
            jax.profiler.TraceAnnotation(f"repro.{kernel}"), \
            tracer.span(f"kernel/{kernel}", traced=traced, **attrs):
        yield


# ---------------------------------------------------------------------------
# file IO + Chrome-trace conversion
# ---------------------------------------------------------------------------
def read_trace(path) -> List[Dict]:
    """Load a ``.jsonl`` trace file into a record list."""
    records = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if line:
            records.append(json.loads(line))
    return records


def chrome_trace(records: Iterable[Dict]) -> Dict:
    """Convert obs records to the Chrome ``traceEvents`` JSON format.

    Spans become complete ("ph": "X") slices and events instant ("ph": "i")
    marks, all on one pid/tid; ``attrs`` ride along as ``args`` so Perfetto
    shows the analytic FLOPs/HBM-bytes on kernel slices. The meta record
    maps to process metadata.
    """
    out: List[Dict] = []
    for rec in records:
        if rec.get("type") == "meta":
            out.append({"name": "process_name", "ph": "M", "pid": 0,
                        "args": {"name": "repro.obs "
                                 + str(rec.get("provenance", {}))}})
        elif rec.get("type") == "span":
            out.append({"name": rec["name"], "ph": "X", "pid": 0, "tid": 0,
                        "ts": rec["ts_us"], "dur": rec.get("dur_us", 0.0),
                        "args": rec.get("attrs", {})})
        elif rec.get("type") == "event":
            out.append({"name": rec["name"], "ph": "i", "pid": 0, "tid": 0,
                        "ts": rec["ts_us"], "s": "g",
                        "args": rec.get("attrs", {})})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome(records: Iterable[Dict], path) -> Path:
    """Write the Chrome-trace conversion of ``records`` to ``path``."""
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(chrome_trace(records)) + "\n")
    return p
