"""The shared monotonic clock behind every timing number in the repo.

One rule (ISSUE 7 satellite): anything that measures a duration — the
bench runner, the kernels.common measured autotuner, the serving engine's
TTFT/per-token clocks, span durations — reads THIS module's ``monotonic()``
so "bench time" and "runtime time" are the same instrument. ``wall()`` is
for provenance stamps only (absolute timestamps in artifacts), never for
durations.

Tests inject a :class:`FakeClock` (deterministic, advances by a fixed step
per read) through ``Obs(clock=...)``; everything downstream — histograms,
span durations, the serving lifecycle timestamps — then becomes exactly
reproducible (tests/test_serve_obs.py asserts histogram VALUES, not just
counts).
"""
from __future__ import annotations

import time

__all__ = ["monotonic", "wall", "FakeClock"]


def monotonic() -> float:
    """Seconds on the process-wide monotonic clock (``perf_counter``)."""
    return time.perf_counter()


def wall() -> float:
    """Absolute wall-clock seconds since the epoch (provenance stamps)."""
    return time.time()


class FakeClock:
    """Deterministic clock for tests: each read advances by ``step``.

    Callable with the same signature as :func:`monotonic`, so it drops into
    ``Obs(clock=...)`` unchanged. ``advance()`` adds extra time between
    reads when a test wants unequal intervals.
    """

    def __init__(self, start: float = 0.0, step: float = 1.0):
        self.t = float(start)
        self.step = float(step)

    def __call__(self) -> float:
        t = self.t
        self.t += self.step
        return t

    def advance(self, dt: float) -> None:
        self.t += float(dt)
