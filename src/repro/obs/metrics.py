"""Counters, gauges and histograms with JSON snapshots.

Design constraints (DESIGN.md §14):

* **host-side only** — metrics record python floats the moment a value is
  already on the host (a sampled token, a blocked-on step time). Nothing
  here ever touches a jax array, so recording can't add device syncs.
* **shared clock** — histograms remember the monotonic time of their first
  and last observation (``repro.obs.clock``), so rates (e.g. tokens/sec)
  derive from the same instrument the bench runner times kernels with.
* **cheap percentiles** — histograms keep raw observations up to a bounded
  reservoir (default 4096; beyond that, uniform replacement sampling), so
  p50/p90/p99 are exact for every realistic serving run and remain a
  bounded-memory estimate under abuse.

``MetricsRegistry.snapshot()`` returns a plain JSON-able dict stamped with
platform provenance (``repro.common.env.platform_provenance``) — the same
stamp the bench artifacts carry, so a metrics file always says which
backend produced it.
"""
from __future__ import annotations

import json
import random
from pathlib import Path
from typing import Callable, Dict, List, Optional

from repro.obs import clock as _clock

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "percentile"]

_RESERVOIR = 4096


def percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile of an already-sorted list (q in [0, 100])."""
    if not sorted_vals:
        return 0.0
    idx = int(round(q / 100.0 * (len(sorted_vals) - 1)))
    return float(sorted_vals[min(max(idx, 0), len(sorted_vals) - 1)])


class Counter:
    """Monotone event count (``inc``)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount


class Gauge:
    """Last-write-wins instantaneous value (queue depth, slot occupancy)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Distribution of observations with p50/p90/p99 summaries.

    Keeps raw values up to ``_RESERVOIR`` then switches to uniform
    replacement sampling (count/sum/min/max stay exact either way). The
    ``summary()`` percentiles are what the serve CLI prints and what the
    lifecycle tests assert against.
    """

    def __init__(self, name: str, now: Callable[[], float] = _clock.monotonic):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._vals: List[float] = []
        self._now = now
        self.t_first: Optional[float] = None
        self.t_last: Optional[float] = None
        self._rng = random.Random(0)

    def observe(self, value: float) -> None:
        value = float(value)
        t = self._now()
        if self.t_first is None:
            self.t_first = t
        self.t_last = t
        self.count += 1
        self.total += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)
        if len(self._vals) < _RESERVOIR:
            self._vals.append(value)
        else:
            j = self._rng.randrange(self.count)
            if j < _RESERVOIR:
                self._vals[j] = value

    def summary(self) -> Dict[str, float]:
        vals = sorted(self._vals)
        return {
            "count": self.count,
            "mean": self.total / self.count if self.count else 0.0,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "p50": percentile(vals, 50.0),
            "p90": percentile(vals, 90.0),
            "p99": percentile(vals, 99.0),
        }


class MetricsRegistry:
    """Named counters/gauges/histograms plus the JSON snapshot.

    Instruments are created on first use (``counter("a/b").inc()``) so
    call sites never pre-declare; names are slash-paths by convention
    (``serve/ttft_s``, ``drift/sup_err``).
    """

    def __init__(self, now: Callable[[], float] = _clock.monotonic):
        self._now = now
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if name not in self.counters:
            self.counters[name] = Counter(name)
        return self.counters[name]

    def gauge(self, name: str) -> Gauge:
        if name not in self.gauges:
            self.gauges[name] = Gauge(name)
        return self.gauges[name]

    def histogram(self, name: str) -> Histogram:
        if name not in self.histograms:
            self.histograms[name] = Histogram(name, now=self._now)
        return self.histograms[name]

    def snapshot(self, provenance: Optional[Dict] = None) -> Dict:
        """JSON-able state of every instrument, provenance-stamped.

        ``provenance`` defaults to ``repro.common.env.platform_provenance()``
        (backend, device kind, interpret flag) — pass an explicit dict in
        tests to keep snapshots platform-independent.
        """
        if provenance is None:
            from repro.common.env import platform_provenance

            provenance = platform_provenance()
        return {
            "schema": "repro.obs.metrics/v1",
            "wall_time": _clock.wall(),
            "provenance": provenance,
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
        }

    def write_json(self, path, provenance: Optional[Dict] = None) -> Path:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.snapshot(provenance), indent=2,
                                sort_keys=True) + "\n")
        return p
