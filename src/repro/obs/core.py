"""The ``Obs`` facade: one object threading metrics + tracing + drift
monitoring through the hot paths, and a true no-op when disabled.

Every instrumented layer (``ServingEngine``, ``Trainer``, the launch CLIs)
takes ``obs=None`` and resolves it through :func:`resolve`: ``None`` maps
to the shared :data:`NOOP` singleton whose every method is a ``pass`` (and
whose ``span`` returns a pre-built null context), so the disabled path
costs one attribute call per site — no branches at call sites, no config
flags, and decode outputs stay bit-identical because observability never
touches a jax value (tests/test_serve_obs.py pins both properties).

An enabled ``Obs`` owns a :class:`~repro.obs.metrics.MetricsRegistry` and
a :class:`~repro.obs.trace.Tracer` on ONE clock (injectable — tests use
``FakeClock`` for exact lifecycle assertions), optionally installs its
tracer as the process-ambient kernel tracer (so the four fused Pallas
wrapper ops contribute ``kernel/*`` spans), and optionally drives a
:class:`~repro.obs.drift.DriftMonitor` every ``drift_every`` ticks of the
serving/training loop.
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, Optional

from repro.obs import clock as _clock
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer, install_tracer

__all__ = ["Obs", "NoopObs", "NOOP", "resolve"]

_NULL_CTX = contextlib.nullcontext()


class NoopObs:
    """Disabled observability: every hook is a no-op, ``now`` still ticks.

    ``now()`` stays a real monotonic read so engine timestamp fields keep
    their meaning whether or not observability is on; everything else does
    nothing and allocates nothing.
    """

    enabled = False
    drift = None

    def now(self) -> float:
        return _clock.monotonic()

    def event(self, name: str, **attrs: Any) -> None:
        pass

    def span(self, name: str, **attrs: Any):
        return _NULL_CTX

    def counter(self, name: str, amount: float = 1.0) -> None:
        pass

    def gauge(self, name: str, value: float) -> None:
        pass

    def histogram(self, name: str, value: float) -> None:
        pass

    def tick_drift(self, rows=None) -> None:
        pass

    def close(self) -> None:
        pass


NOOP = NoopObs()


def resolve(obs: Optional["Obs"]) -> "Obs":
    """``None`` -> the shared no-op; anything else passes through."""
    return NOOP if obs is None else obs


class Obs:
    """Enabled observability: metrics + tracer + optional drift monitor.

    Args:
        trace_path: stream the JSONL trace here (None = in-memory only).
        clock: monotonic-clock override shared by metrics, tracer and the
            engine timestamps (tests inject ``FakeClock``).
        provenance: platform-stamp override for trace/metrics headers.
        drift: a ``DriftMonitor`` to drive from the serving/training loop.
        drift_every: run ``drift.check()`` every N ``tick_drift`` calls
            (0 disables ticking even with a monitor attached).
        install_kernel_tracing: make this tracer the process-ambient
            kernel tracer for the lifetime of the object (the fused Pallas
            wrapper ops then record ``kernel/*`` spans with analytic
            FLOPs/HBM-bytes). Restore/clear happens in ``close()``.
    """

    enabled = True

    def __init__(self, trace_path=None,
                 clock: Optional[Callable[[], float]] = None,
                 provenance: Optional[Dict] = None,
                 drift=None, drift_every: int = 0,
                 install_kernel_tracing: bool = False):
        self._now = clock if clock is not None else _clock.monotonic
        self.metrics = MetricsRegistry(now=self._now)
        self.tracer = Tracer(path=trace_path, now=self._now,
                             provenance=provenance)
        self.drift = drift
        self.drift_every = int(drift_every)
        self._drift_tick = 0
        self._prev_tracer = None
        self._installed = False
        if install_kernel_tracing:
            self._prev_tracer = install_tracer(self.tracer)
            self._installed = True

    # -- clock / trace / metrics passthroughs --------------------------------
    def now(self) -> float:
        return self._now()

    def event(self, name: str, **attrs: Any) -> None:
        self.tracer.event(name, **attrs)

    def span(self, name: str, **attrs: Any):
        return self.tracer.span(name, **attrs)

    def counter(self, name: str, amount: float = 1.0) -> None:
        self.metrics.counter(name).inc(amount)

    def gauge(self, name: str, value: float) -> None:
        self.metrics.gauge(name).set(value)

    def histogram(self, name: str, value: float) -> None:
        self.metrics.histogram(name).observe(value)

    # -- drift ----------------------------------------------------------------
    def tick_drift(self, rows=None) -> None:
        """One serving/training loop tick: maybe run the drift check.

        ``rows`` (optional host array) feeds the monitor's sentinel
        reservoir before checking, so the watched points track live data.
        Emits ``drift/sup_err`` + ``drift/eps_bound`` gauges, the
        ``drift/checks``/``drift/violations`` counters, and a
        ``drift/violation`` event when the observed error leaves the
        (eps, delta) envelope.
        """
        if self.drift is None or self.drift_every <= 0:
            return
        self._drift_tick += 1
        if self._drift_tick % self.drift_every:
            return
        if rows is not None:
            self.drift.ingest(rows)
        with self.span("drift/check"):
            report = self.drift.check()
        self.gauge("drift/sup_err", report.sup_err)
        self.gauge("drift/eps_bound", report.eps_bound)
        self.counter("drift/checks")
        if not report.ok:
            self.counter("drift/violations")
            self.event("drift/violation", sup_err=report.sup_err,
                       eps_bound=report.eps_bound,
                       num_features=report.num_features)
            rec = self.drift.recommend()
            if rec is not None:
                self.gauge("drift/recommended_features",
                           rec.num_features_target)
                self.event("drift/grow_recommendation",
                           num_features_now=rec.num_features_now,
                           num_features_target=rec.num_features_target,
                           eps_bound_target=rec.eps_bound_target,
                           reason=rec.reason)

    # -- lifecycle ------------------------------------------------------------
    def write_metrics(self, path) -> None:
        self.metrics.write_json(path)

    def close(self) -> None:
        """Flush the trace file and restore the ambient kernel tracer."""
        if self._installed:
            install_tracer(self._prev_tracer)
            self._installed = False
        self.tracer.close()
