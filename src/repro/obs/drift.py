"""Online Gram-drift monitoring: the paper's (eps, delta) guarantee as a
live SLO.

The entire value proposition of a random feature map is probabilistic —
Kar & Karnick's Hoeffding-style concentration (PAPER.md Thm 6 / Theorem 12,
inverted in ``repro.core.bounds``) promises ``sup |<Z(x), Z(y)> - K(x, y)|
<= eps`` with probability ``1 - delta`` at the deployed feature budget D.
Nothing about a serving or training run re-checks that promise: a buggy
param splice, a bad precision cast, or an under-budget D would silently
degrade every downstream Gram estimate / attention score.

:class:`DriftMonitor` makes the bound observable. It holds a small
reservoir of sentinel points in the kernel's domain ball, and on every
``check()`` recomputes the empirical ``sup |<Z(x), Z(y)> - K(x, y)|`` over
all sentinel pairs (oracle jnp path — a few microseconds at reservoir
scale) and compares it against the per-pair Hoeffding + union bound at the
map's actual D::

    eps(D, delta) = sqrt(8 C^2 log(2 n_pairs / delta) / D) + bias

where ``C`` is the measure-matched estimator bound from
``repro.core.bounds.constants_for`` (the beyond-paper ``f(R^2)`` for the
proportional measure these maps default to) and ``bias`` is the plan's
deterministic truncation bias. The same formula gates the offline (eps,
delta) acceptance suite (tests/test_statistical_bounds.py) — the monitor
is that suite running continuously inside serving/training, wired to
metrics/trace via ``Obs`` (``drift/sup_err`` gauge, ``drift/violations``
counter, a ``drift/violation`` trace event when it fires).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

__all__ = ["DriftReport", "DriftMonitor", "GrowthRecommendation",
           "hoeffding_eps"]


def hoeffding_eps(kernel, radius: float, dim: int, num_features: int,
                  n_pairs: int, delta: float,
                  measure: str = "proportional") -> float:
    """Per-pair Hoeffding + union-over-pairs error bound at budget D.

    The inversion of ``core.bounds.pointwise_failure_prob`` for a FIXED
    sentinel set (n_pairs pairs) rather than the paper's epsilon-net over
    the whole domain — the right bound for a monitor that watches specific
    points. A thin wrapper over ``core.bounds.pairwise_eps`` (kept for the
    monitor-facing default measure): the arithmetic lives in ONE place so
    the online monitor and the offline (eps, delta) acceptance suite can
    never drift apart (tests/test_bounds_roundtrip.py pins the
    delegation).
    """
    from repro.core import bounds

    return bounds.pairwise_eps(kernel, radius, dim, num_features, n_pairs,
                               delta, measure=measure)


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """One ``check()`` result: the observed sup error vs the live bound."""

    sup_err: float
    eps_bound: float
    num_features: int
    n_pairs: int
    ok: bool


@dataclasses.dataclass(frozen=True)
class GrowthRecommendation:
    """``DriftMonitor.recommend()``'s answer to an (eps, delta) violation:
    double the budget (docs/adaptive.md's drift -> grow loop).

    ``num_features_target`` is what a ``GrowableFeatureMap.grow()`` (or a
    rebuild at 2D) buys; ``eps_bound_target`` is the envelope the monitor
    would hold the grown map to — tighter by ``1/sqrt(2)`` per doubling.
    """

    num_features_now: int
    num_features_target: int
    eps_bound_now: float
    eps_bound_target: float
    sup_err: float
    reason: str


class DriftMonitor:
    """Watch a deployed feature map's Gram error against its (eps, delta)
    bound.

    Args:
        feature_map: any registry feature-map object (``estimate_gram`` +
            ``plan`` + ``output_dim`` — every family conforms).
        kernel: the exact ``DotProductKernel`` the map approximates.
        delta: failure probability the bound is evaluated at.
        n_sentinels: reservoir size (n_pairs grows quadratically; 16
            sentinel points = 136 monitored pairs).
        radius: domain ball radius the sentinels are drawn in (must match
            the deployment's data scaling — the bound constants depend on
            it).
        seed: sentinel draw seed.
        measure: degree measure the map was built with (selects the
            estimator constant C, see ``core.bounds``).
        margin: multiplier on the bound before flagging (1.0 = flag
            exactly at eps(D, delta)).
    """

    def __init__(self, feature_map, kernel, *, delta: float = 0.05,
                 n_sentinels: int = 16, radius: float = 0.9, seed: int = 0,
                 measure: str = "proportional", margin: float = 1.0):
        self.fm = feature_map
        self.kernel = kernel
        self.delta = float(delta)
        self.radius = float(radius)
        self.measure = measure
        self.margin = float(margin)
        self.checks = 0
        self.violations = 0
        self.last: Optional[DriftReport] = None
        d = int(feature_map.plan.input_dim)
        rng = np.random.default_rng(seed)
        pts = rng.standard_normal((n_sentinels, d))
        pts /= np.linalg.norm(pts, axis=1, keepdims=True)
        # span radii up to R (not all on the shell): drift in low-degree
        # terms shows up at small radii, high-degree at the boundary
        pts *= np.linspace(0.3, 1.0, n_sentinels)[:, None] * self.radius
        self._sentinels = np.asarray(pts, np.float32)
        self._rng = rng

    @classmethod
    def for_estimator(cls, kernel, dim: int, num_features: int, *,
                      estimator: str = "rm", seed: int = 0,
                      measure: str = "proportional", **kwargs):
        """Build a fresh map of ``estimator`` at budget D and monitor it.

        The serve/train CLIs use this when no live map object is handy:
        the monitor then watches a map drawn EXACTLY like the deployed one
        (same registry entry, measure and budget), which observes the
        family's concentration at the deployed D rather than one specific
        parameter draw.
        """
        import jax

        from repro.core import make_feature_map

        fm = make_feature_map(kernel, dim, num_features,
                              jax.random.PRNGKey(seed), estimator=estimator,
                              measure=measure)
        return cls(fm, kernel, measure=measure, **kwargs)

    @property
    def n_pairs(self) -> int:
        n = self._sentinels.shape[0]
        return n * (n + 1) // 2

    def ingest(self, rows) -> None:
        """Reservoir-sample live data rows into the sentinel set.

        Rows are clipped to the domain ball (the bound constants only hold
        inside radius R). Each incoming row replaces a uniformly random
        sentinel with probability ``n_sentinels / seen`` — standard
        reservoir sampling, so the sentinel set tracks the live input
        distribution without growing.
        """
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        norms = np.linalg.norm(rows, axis=1, keepdims=True)
        scale = np.minimum(1.0, self.radius / np.maximum(norms, 1e-12))
        rows = rows * scale
        n = self._sentinels.shape[0]
        for row in rows:
            j = self._rng.integers(0, n * 4)
            if j < n:
                self._sentinels[j] = row

    def eps_bound(self) -> float:
        """The live (eps, delta) envelope at the monitored map's D."""
        stat = hoeffding_eps(
            self.kernel, self.radius, int(self.fm.plan.input_dim),
            int(self.fm.output_dim), self.n_pairs, self.delta,
            measure=self.measure)
        bias = float(self.fm.plan.truncation_bias(self.radius))
        return stat + bias

    def check(self) -> DriftReport:
        """Recompute sup Gram error over the sentinels; compare to bound."""
        X = self._sentinels
        G = np.asarray(self.fm.estimate_gram(X, use_pallas=False))
        K = np.asarray(self.kernel.gram(X))
        sup_err = float(np.max(np.abs(G - K)))
        bound = self.eps_bound()
        ok = sup_err <= self.margin * bound
        self.checks += 1
        if not ok:
            self.violations += 1
        self.last = DriftReport(sup_err=sup_err, eps_bound=bound,
                                num_features=int(self.fm.output_dim),
                                n_pairs=self.n_pairs, ok=ok)
        return self.last

    def recommend(self) -> Optional[GrowthRecommendation]:
        """The adaptive-accuracy hook: after a violating ``check()``,
        recommend the doubled budget.

        Returns ``None`` while the last check (or no check yet) is within
        the envelope.  On a violation, returns the doubled feature budget
        and the tightened envelope it buys — ``GrowableFeatureMap.grow()``
        applies it without redrawing, after which the caller rebinds the
        monitor via :meth:`rebind` and the next ``check()`` runs against
        the stricter bound.  Doubling (not jumping straight to
        ``required_d`` at the observed error) keeps the loop geometric:
        repeated violations escalate exponentially, transient ones cost
        one doubling.
        """
        if self.last is None or self.last.ok:
            return None
        now = int(self.fm.output_dim)
        target = 2 * now
        stat = hoeffding_eps(
            self.kernel, self.radius, int(self.fm.plan.input_dim),
            target, self.n_pairs, self.delta, measure=self.measure)
        bias = float(self.fm.plan.truncation_bias(self.radius))
        return GrowthRecommendation(
            num_features_now=now,
            num_features_target=target,
            eps_bound_now=self.last.eps_bound,
            eps_bound_target=stat + bias,
            sup_err=self.last.sup_err,
            reason=(f"sup_err={self.last.sup_err:.3g} exceeded "
                    f"eps_bound={self.last.eps_bound:.3g} at "
                    f"D={now}; double to D={target}"),
        )

    def rebind(self, feature_map) -> None:
        """Point the monitor at a grown/rebuilt map (same kernel & domain).
        Counters survive — growth is part of one monitored deployment —
        but the stale report is dropped so ``recommend()`` doesn't re-fire
        off the pre-growth check."""
        self.fm = feature_map
        self.last = None
