"""repro.sketch — the TensorSketch estimator subsystem (DESIGN.md §9).

A second random-feature family for the paper's dot-product kernels, driven by
the SAME Taylor-coefficient degree measures as Random Maclaurin but built
from CountSketch composition + FFT (Pham & Pagh) instead of Rademacher
products. Registered as ``"tensor_sketch"`` in the estimator registry
(``repro.core.registry``); consumers pick estimators by name.
"""
from repro.sketch.plan import (
    SketchPlan,
    apply_sketch_plan,
    init_sketch_params,
    make_sketch_plan,
    pack_sketch,
)
from repro.sketch.feature_map import SketchFeatureMap, make_sketch_feature_map
from repro.sketch.ref import (
    count_sketch_ref,
    tensor_sketch_blocks_ref,
    tensor_sketch_fused_ref,
)

__all__ = [
    "SketchPlan",
    "apply_sketch_plan",
    "init_sketch_params",
    "make_sketch_plan",
    "pack_sketch",
    "SketchFeatureMap",
    "make_sketch_feature_map",
    "count_sketch_ref",
    "tensor_sketch_blocks_ref",
    "tensor_sketch_fused_ref",
]
