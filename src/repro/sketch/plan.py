"""SketchPlan — TensorSketch plans for dot-product kernels.

TensorSketch (Pham & Pagh, KDD 2013) approximates the degree-n component of a
dot product kernel ``f(<x,y>) = sum_n a_n <x,y>^n`` with the circular
convolution of ``n`` independent CountSketches:

    S_n(x) = IFFT( prod_{j<n} FFT( C_j x ) ),   E[<S_n(x), S_n(y)>] = <x,y>^n.

Where Random Maclaurin (repro.core.plan) pays ``O(d)`` Rademacher projections
per *column*, TensorSketch pays ``O(d + F_n log F_n)`` per degree *block* —
the whole block jointly estimates one monomial, so its width ``F_n`` is a
variance knob, not a sum of independent estimators.

This module mirrors ``repro.core.plan`` deliberately:

    degree measure  ->  width allocation (largest remainder)  ->  sqrt(a_n)
                    ->  packed frequency-domain layout (DESIGN.md §9)

A ``SketchPlan`` is a hashable NamedTuple (jit-static). Column layout:

    [ h01 const | h01 identity block | degree-0 const | degree blocks asc ]

The deterministic prefix columns are exact (zero variance) and computed
outside the kernels; the random section is the concatenation of the degree
blocks in ascending degree order.

Frequency-domain packing (``pack_sketch``): because the FFT is linear, the
per-slot transform ``FFT(C_j x)`` is a dense complex projection

    FFT(C_j x)[f] = sum_i s_j(i) exp(-2 pi i f h_j(i) / F_n) x_i = <G_j[f], x>

so the WHOLE map becomes (i) a masked complex running product over degree
slots — exactly the ``rm_feature_fused`` structure with two (real, imag)
accumulators — followed by (ii) one block-diagonal inverse-DFT matmul. Both
stages are MXU matmuls, which is what ``tensor_sketch_fused`` fuses into one
Pallas launch; the ``jnp.fft`` path in ``repro.sketch.ref`` is the
O(F log F) oracle it is checked against.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maclaurin import DotProductKernel
from repro.core.plan import BIAS_TAIL_DEGREES, allocate_features

__all__ = [
    "SketchPlan",
    "make_sketch_plan",
    "init_sketch_params",
    "pack_sketch",
    "apply_sketch_plan",
]


class SketchPlan(NamedTuple):
    """Hashable TensorSketch plan: static through jit/scan.

    ``degrees``/``counts``/``scales`` describe the degree >= 1 sketch blocks
    (ascending): block n has sketch width ``counts[i]`` and block scale
    ``scales[i] = sqrt(a_n)`` (the whole block estimates ``a_n <x,y>^n``).
    ``seed`` records the width-allocation seed so plans reproduce across
    hosts (see ``to_json``).
    """

    degrees: Tuple[int, ...]
    counts: Tuple[int, ...]           # sketch width F_n per degree block
    scales: Tuple[float, ...]         # sqrt(a_n) per block
    const: float                      # exact degree-0 column (0.0 when absent)
    h01: bool
    h01_a0: float
    h01_a1: float
    input_dim: int
    num_random: int                   # D, the total feature budget
    # a_0..a_{n_max + BIAS_TAIL_DEGREES} (tail window: bias diagnostics only)
    coefs_host: Tuple[float, ...]
    seed: int                         # allocation seed (reproducibility)

    # -- sizes ---------------------------------------------------------------
    @property
    def num_funcs(self) -> int:
        """CountSketch hash functions backing the blocks: sum_n n."""
        return int(sum(self.degrees))

    @property
    def max_degree(self) -> int:
        return max(self.degrees) if self.degrees else 0

    @property
    def num_sketch_cols(self) -> int:
        return int(sum(self.counts))

    @property
    def num_prefix_columns(self) -> int:
        pre = 0
        if self.h01:
            pre += 1 + self.input_dim
        if self.const != 0.0:
            pre += 1
        return pre

    @property
    def output_dim(self) -> int:
        return self.num_prefix_columns + self.num_sketch_cols

    # -- fused column layout (host-side, static; random section only) --------
    def column_degrees(self) -> np.ndarray:
        """Per sketch column product depth, int32 ``[num_sketch_cols]``."""
        deg = []
        for n, c in zip(self.degrees, self.counts):
            deg.extend([n] * c)
        return np.asarray(deg, dtype=np.int32)

    def column_scales(self) -> np.ndarray:
        """Per sketch column scale sqrt(a_n), float32 ``[num_sketch_cols]``."""
        sc = []
        for s, c in zip(self.scales, self.counts):
            sc.extend([float(s)] * c)
        return np.asarray(sc, dtype=np.float32)

    # -- diagnostics ---------------------------------------------------------
    def truncation_bias(self, radius: float) -> float:
        """Worst-case dropped-degree mass ``sum a_n R^{2n}`` (paper §4.2),
        tail window beyond n_max included (see core.plan.BIAS_TAIL_DEGREES)."""
        present = set(self.degrees)
        if self.const != 0.0:
            present.add(0)
        if self.h01:
            present.update((0, 1))
        bias = 0.0
        for n, a_n in enumerate(self.coefs_host):
            if a_n > 0.0 and n not in present:
                bias += a_n * radius ** (2 * n)
        return bias

    # -- serialization (shared body with FeaturePlan) ------------------------
    def to_json(self) -> str:
        """Full plan state (seed + realized allocation included) as JSON."""
        from repro.core.plan import plan_to_json

        return plan_to_json(self)

    @classmethod
    def from_json(cls, s: str) -> "SketchPlan":
        """Inverse of ``to_json`` (lossless: conformance-tested)."""
        from repro.core.plan import plan_from_json

        return plan_from_json(cls, s)


def make_sketch_plan(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    *,
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    stratified: bool = True,
    seed: int = 0,
) -> SketchPlan:
    """Allocate sketch widths across degrees of the Maclaurin measure.

    The SAME Taylor-coefficient measure machinery as the RM estimator
    (``core.feature_map.degree_measure``) splits the feature budget; here the
    per-degree count is a sketch WIDTH (variance knob), not a number of
    independent columns, so widths are always deterministic largest-remainder
    rounding — ``stratified`` is accepted for estimator-protocol uniformity
    and ignored. ``seed`` is recorded on the plan.
    """
    from repro.core.feature_map import degree_measure

    kernel.validate_positive_definite(n_max)
    if h01 and measure == "geometric":
        measure = "geometric_ge2"
    a0 = float(kernel.coef(0))
    a1 = float(kernel.coef(1))
    if h01 and a0 == 0.0 and a1 == 0.0:
        raise ValueError(
            f"H0/1 is a no-op for kernel {kernel.name}: a_0 = a_1 = 0 "
            "(e.g. homogeneous polynomial kernels — paper §6.2)."
        )
    min_degree = 2 if h01 else 1
    q = degree_measure(kernel, n_max, p=p, kind=measure, radius=radius,
                       min_degree=min_degree)
    coefs = kernel.coefs(n_max)
    coefs_diag = kernel.coefs(n_max + BIAS_TAIL_DEGREES)

    prefix = (1 + input_dim) if h01 else (1 if a0 > 0.0 else 0)
    budget = max(num_features - prefix, 0)
    counts_all, _ = allocate_features(coefs, q, budget, stratified=True,
                                      seed=seed)

    degrees, counts, scales = [], [], []
    for n in range(min_degree, n_max + 1):
        c = int(counts_all[n])
        if c > 0 and coefs[n] > 0.0:
            degrees.append(n)
            counts.append(c)
            scales.append(float(np.sqrt(coefs[n])))

    return SketchPlan(
        degrees=tuple(degrees),
        counts=tuple(counts),
        scales=tuple(scales),
        const=float(np.sqrt(a0)) if (a0 > 0.0 and not h01) else 0.0,
        h01=h01,
        h01_a0=a0 if h01 else 0.0,
        h01_a1=a1 if h01 else 0.0,
        input_dim=input_dim,
        num_random=num_features,
        coefs_host=tuple(float(c) for c in coefs_diag),
        seed=seed,
    )


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def init_sketch_params(
    plan: SketchPlan, key: jax.Array, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    """CountSketch hash tensors for one plan instance.

    Returns ``{"h": int32 [num_funcs, d], "s": dtype [num_funcs, d]}``. Rows
    are block-major then slot-major: rows ``[off_i, off_i + n)`` are the n
    independent CountSketches of degree block n (``off_i = sum of earlier
    degrees``); row values of block i live in ``[0, counts[i])``. Fully random
    hash tables (stronger than the 2-/3-wise independence TensorSketch
    requires) — like RM omegas, these are model constants, never trained.
    """
    d = plan.input_dim
    hs, ss = [], []
    for n, c in zip(plan.degrees, plan.counts):
        for _ in range(n):
            key, kh, ks = jax.random.split(key, 3)
            hs.append(jax.random.randint(kh, (d,), 0, c, dtype=jnp.int32))
            ss.append(2.0 * jax.random.bernoulli(ks, 0.5, (d,)).astype(dtype)
                      - 1.0)
    if not hs:
        return {
            "h": jnp.zeros((0, d), jnp.int32),
            "s": jnp.zeros((0, d), dtype),
        }
    return {"h": jnp.stack(hs), "s": jnp.stack(ss)}


# ---------------------------------------------------------------------------
# frequency-domain packing for the fused kernel
# ---------------------------------------------------------------------------
def pack_sketch(
    plan: SketchPlan, params: Dict[str, jax.Array], dtype=jnp.float32
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Hash tensors -> fused tensors ``(wr, wi, mr, mi)``.

    * ``wr/wi [max_degree, Fs, d]`` — real/imag of the per-slot DFT'd
      CountSketch projections: column f of block (n, c) with local frequency
      ``fl`` and slot j holds ``s_j(i) * exp(-2 pi i fl h_j(i) / c)``.
      Slots ``j >= n`` are zero (masked by ``column_degrees`` in the kernel).
    * ``mr/mi [Fs, Fs]`` — the block-diagonal inverse-DFT:
      ``M[g, f] = exp(+2 pi i g f / c) / c`` within a block, 0 across blocks.
      ``real(M P) = mr @ Pr - mi @ Pi`` recovers the circular convolution.

    Phase indices are reduced mod c in int32 BEFORE the float angle (exact:
    ``f * h < c^2 < 2^31`` for any practical width), so large frequencies
    don't lose precision in float32.
    """
    d = plan.input_dim
    k = plan.max_degree
    fs = plan.num_sketch_cols
    wr = jnp.zeros((k, fs, d), dtype)
    wi = jnp.zeros((k, fs, d), dtype)
    mr = jnp.zeros((fs, fs), dtype)
    mi = jnp.zeros((fs, fs), dtype)
    col = 0
    row = 0
    for n, c in zip(plan.degrees, plan.counts):
        freqs = jnp.arange(c, dtype=jnp.int32)
        for j in range(n):
            h = params["h"][row + j]                       # [d] int32
            s = params["s"][row + j].astype(dtype)         # [d]
            ph = (freqs[:, None] * h[None, :]) % c         # [c, d] exact
            ang = (2.0 * np.pi / c) * ph.astype(dtype)
            wr = wr.at[j, col : col + c, :].set(s[None, :] * jnp.cos(ang))
            wi = wi.at[j, col : col + c, :].set(-s[None, :] * jnp.sin(ang))
        gf = (freqs[:, None] * freqs[None, :]) % c         # [c, c] exact
        ang = (2.0 * np.pi / c) * gf.astype(dtype)
        mr = mr.at[col : col + c, col : col + c].set(jnp.cos(ang) / c)
        mi = mi.at[col : col + c, col : col + c].set(jnp.sin(ang) / c)
        col += c
        row += n
    return wr, wi, mr, mi


# ---------------------------------------------------------------------------
# application — ONE fused launch (or the jnp.fft oracle)
# ---------------------------------------------------------------------------
def apply_sketch_plan(
    plan: SketchPlan,
    params: Dict[str, jax.Array],
    x: jax.Array,
    accum_dtype=jnp.float32,
    use_pallas=None,
    interpret=None,
    packed=None,
    precision=None,
) -> jax.Array:
    """Featurize ``x [..., d] -> [..., plan.output_dim]``.

    The deterministic prefix columns (h01 block / degree-0 const) are exact
    jnp fills; the sketch blocks run as ONE fused Pallas launch
    (``repro.kernels.tensor_sketch``) on TPU, or the ``jnp.fft`` oracle
    elsewhere. Mirrors ``core.plan.apply_plan``'s contract so the estimator
    registry can expose both behind one ``apply``: ``packed`` short-circuits
    ``pack_sketch`` — the frequency-domain tensors depend only on the frozen
    hash tables, so callers applying one plan repeatedly (per-layer featurize,
    decode steps) should pack once and pass ``packed=(wr, wi, mr, mi)``.

    ``precision`` selects the input dtype policy: under ``"bf16"`` x and the
    four packed frequency-domain tensors enter the fused launch in bf16
    (accumulation stays fp32 inside the kernel). The packing itself always
    runs in fp32 — the cos/sin phases are computed at full precision, then
    rounded ONCE to the storage dtype. The ``jnp.fft`` oracle has no bf16
    path (complex bf16 doesn't exist), so off-Pallas the policy only rounds
    x; fp32/complex64 carries the rest.
    """
    from repro.common.dtypes import resolve_precision
    from repro.kernels.tensor_sketch.ops import tensor_sketch_fused
    from repro.sketch.ref import tensor_sketch_blocks_ref

    if x.shape[-1] != plan.input_dim:
        raise ValueError(
            f"expected trailing dim {plan.input_dim}, got {x.shape}"
        )
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    prec = resolve_precision(precision)
    compute_dtype = prec.compute_dtype
    batch_shape = x.shape[:-1]
    xf = x.reshape(-1, plan.input_dim).astype(accum_dtype)
    feats = []
    if plan.h01:
        feats.append(jnp.full((xf.shape[0], 1), np.sqrt(plan.h01_a0),
                              dtype=accum_dtype))
        feats.append(jnp.asarray(np.sqrt(plan.h01_a1), accum_dtype)
                     * xf.astype(compute_dtype).astype(accum_dtype))
    if plan.const != 0.0:
        feats.append(jnp.full((xf.shape[0], 1), plan.const,
                              dtype=accum_dtype))
    if plan.num_sketch_cols:
        if use_pallas:
            wr, wi, mr, mi = (packed if packed is not None
                              else pack_sketch(plan, params,
                                               dtype=jnp.float32))
            z = tensor_sketch_fused(
                xf.astype(compute_dtype),
                wr.astype(compute_dtype), wi.astype(compute_dtype),
                jnp.asarray(plan.column_degrees()),
                mr.astype(compute_dtype), mi.astype(compute_dtype),
                jnp.asarray(plan.column_scales()),
                use_pallas=True, interpret=interpret,
            ).astype(accum_dtype)
        else:
            z = tensor_sketch_blocks_ref(
                plan, params, xf.astype(compute_dtype)
            ).astype(accum_dtype)
        feats.append(z)
    out = jnp.concatenate(feats, axis=-1)
    return out.reshape(*batch_shape, out.shape[-1])
