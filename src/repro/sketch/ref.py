"""jnp reference paths for the TensorSketch estimator.

Two oracles (DESIGN.md §9):

* ``count_sketch_ref`` / ``tensor_sketch_blocks_ref`` — the textbook
  O(d + F log F) path: scatter-by-hash CountSketch (``.at[:, h].add``) then
  ``jnp.fft`` product + inverse. This is what XLA runs in production off-TPU
  (``apply_sketch_plan(use_pallas=False)``) and the ground truth the fused
  kernel is checked against.
* ``tensor_sketch_fused_ref`` — the exact jnp mirror of the Pallas kernel's
  frequency-domain formulation (complex masked running product + block-diag
  inverse-DFT matmul) on the packed ``pack_sketch`` tensors. Used for raw
  array-level parity tests of ``tensor_sketch_fused``.

Both emit the sketch-block section only; the deterministic prefix columns
(h01 block / degree-0 const) are concatenated by ``apply_sketch_plan``.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.sketch.plan import SketchPlan

__all__ = [
    "count_sketch_ref",
    "tensor_sketch_blocks_ref",
    "tensor_sketch_fused_ref",
]


def count_sketch_ref(
    x: jax.Array, h: jax.Array, s: jax.Array, width: int
) -> jax.Array:
    """One CountSketch: ``x [B, d] -> [B, width]``.

    ``C(x)[b, m] = sum_{i : h[i] == m} s[i] x[b, i]`` — a scatter-add over
    hash buckets (duplicate indices accumulate).
    """
    vals = x * s[None, :].astype(x.dtype)
    out = jnp.zeros((x.shape[0], width), x.dtype)
    return out.at[:, h].add(vals)


def tensor_sketch_blocks_ref(
    plan: SketchPlan, params: Dict[str, jax.Array], x: jax.Array
) -> jax.Array:
    """All degree blocks via FFT: ``x [B, d] -> [B, num_sketch_cols]``.

    Degree-n block: ``sqrt(a_n) * real(IFFT(prod_j FFT(C_j x)))`` — the
    circular convolution of the n CountSketches (Pham & Pagh).
    """
    xf = x.astype(jnp.float32)
    feats = []
    row = 0
    for n, c, scale in zip(plan.degrees, plan.counts, plan.scales):
        prod = jnp.ones((xf.shape[0], c), jnp.complex64)
        for j in range(n):
            cs = count_sketch_ref(
                xf, params["h"][row + j], params["s"][row + j], c
            )
            prod = prod * jnp.fft.fft(cs, axis=-1)
            del cs
        row += n
        feats.append(jnp.fft.ifft(prod, axis=-1).real * jnp.float32(scale))
    if not feats:
        return jnp.zeros((xf.shape[0], 0), jnp.float32)
    return jnp.concatenate(feats, axis=-1)


def tensor_sketch_fused_ref(
    x: jax.Array,          # [B, d]
    wr: jax.Array,         # [max_degree, Fs, d] real part (pack_sketch)
    wi: jax.Array,         # [max_degree, Fs, d] imag part
    col_deg: jax.Array,    # [Fs] int32 per-column product depth
    mr: jax.Array,         # [Fs, Fs] block-diag inverse-DFT, real
    mi: jax.Array,         # [Fs, Fs] block-diag inverse-DFT, imag
    col_scale: jax.Array,  # [Fs] per-column scale
) -> jax.Array:            # [B, Fs] float32
    """jnp mirror of the fused kernel: complex product + inverse-DFT matmul."""
    xf = x.astype(jnp.float32)
    k, fs, _ = wr.shape
    ar = jnp.ones((xf.shape[0], fs), jnp.float32)
    ai = jnp.zeros((xf.shape[0], fs), jnp.float32)
    for j in range(k):
        pr = xf @ wr[j].astype(jnp.float32).T
        pi = xf @ wi[j].astype(jnp.float32).T
        keep = (j < col_deg)[None, :]
        nr = ar * pr - ai * pi
        ni = ar * pi + ai * pr
        ar = jnp.where(keep, nr, ar)
        ai = jnp.where(keep, ni, ai)
    z = ar @ mr.astype(jnp.float32).T - ai @ mi.astype(jnp.float32).T
    return z * col_scale[None, :].astype(jnp.float32)
