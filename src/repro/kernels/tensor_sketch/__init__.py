from repro.kernels.tensor_sketch.ops import tensor_sketch_fused
from repro.kernels.tensor_sketch.tensor_sketch import tensor_sketch_fused_pallas

__all__ = ["tensor_sketch_fused", "tensor_sketch_fused_pallas"]
