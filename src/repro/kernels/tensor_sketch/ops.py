"""jit'd public wrapper around the fused TensorSketch Pallas kernel.

``tensor_sketch_fused`` applies the whole sketch-block section of a
``SketchPlan`` (packed frequency-domain layout, ``repro.sketch.plan
.pack_sketch``) in one Pallas launch: it pads the batch to a VMEM-budgeted
tile and the feature axis to lane alignment, and falls back to the pure-jnp
mirror (``repro.sketch.ref.tensor_sketch_fused_ref``) when Pallas is off or
the plan has no sketch blocks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret as _default_interpret
from repro.kernels.common import get_batch_block as _get_batch_block
from repro.kernels.common import round_up as _round_up
from repro.obs.trace import kernel_scope as _kernel_scope
from repro.sketch.ref import tensor_sketch_fused_ref
from repro.kernels.tensor_sketch.tensor_sketch import tensor_sketch_fused_pallas


def tensor_sketch_fused(
    x: jax.Array,          # [..., d]
    wr: jax.Array,         # [max_degree, Fs, d]   (pack_sketch)
    wi: jax.Array,         # [max_degree, Fs, d]
    col_deg: jax.Array,    # [Fs] int32 per-column product depth
    mr: jax.Array,         # [Fs, Fs] block-diag inverse-DFT, real
    mi: jax.Array,         # [Fs, Fs] block-diag inverse-DFT, imag
    col_scale: jax.Array,  # [Fs] per-column scale
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[tuple] = None,
) -> jax.Array:            # [..., Fs] float32
    """Apply the packed sketch blocks: one Pallas launch for every column.

    SPMD-safe (no host callbacks, shape-static tiling): usable inside a
    ``shard_map`` body, where the sharded estimator path runs one launch
    per feature shard over that shard's degree blocks. Note the 128-lane
    feature pad is a per-LAUNCH cost, so very thin shards (Fs << 128) pay
    proportionally more padding than a single-device launch would.

    ``x``/``wr``/``wi``/``mr``/``mi`` enter the launch in their incoming
    dtype (bf16 under the mixed precision policy — the stage-2 inverse-DFT
    is upcast to fp32 inside the kernel); accumulation is always fp32.
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    d = x.shape[-1]
    k, fs, _ = wr.shape
    xf = x.reshape(-1, d)
    if xf.shape[0] == 0:   # degenerate row chunk: skip the padded launch
        return jnp.zeros((*batch_shape, fs), jnp.float32)
    if not use_pallas or k == 0 or fs == 0:
        out = tensor_sketch_fused_ref(xf, wr, wi, col_deg, mr, mi, col_scale)
        return out.reshape(*batch_shape, fs)

    b = xf.shape[0]
    f_pad = _round_up(max(fs, 128), 128)
    # budget at the PADDED feature count; blocks=(block_b, _) overrides the
    # cached/heuristic batch tile (the autotuner hook — feature axis stays
    # fully resident in this kernel, so only the batch tile is tunable).
    if blocks is not None:
        bm = int(blocks[0])
    else:
        bm = _get_batch_block("tensor_sketch", d, k, f_pad, b, dtype=x.dtype)
    with _kernel_scope("tensor_sketch", x=x,
                       cost=dict(batch=b, d=d, depth=k, f=fs,
                                 itemsize=jnp.dtype(x.dtype).itemsize),
                       blocks=[bm, f_pad], interpret=bool(interpret)):
        b_pad = _round_up(max(b, bm), bm)
        xp = jnp.pad(xf, ((0, b_pad - b), (0, 0)))
        pf = f_pad - fs
        wrp = jnp.pad(wr, ((0, 0), (0, pf), (0, 0)))
        wip = jnp.pad(wi, ((0, 0), (0, pf), (0, 0)))
        # padded columns: depth 0 keeps the accumulator at (1, 0); zero
        # inverse-DFT rows and zero scales make their outputs exactly 0
        # before the slice.
        deg_p = jnp.pad(col_deg.astype(jnp.int32), ((0, pf),))
        mrp = jnp.pad(mr, ((0, pf), (0, pf)))
        mip = jnp.pad(mi, ((0, pf), (0, pf)))
        scale_p = jnp.pad(col_scale.astype(jnp.float32), ((0, pf),))
        out = tensor_sketch_fused_pallas(
            xp, wrp, wip, deg_p, mrp, mip, scale_p,
            block_b=bm, interpret=interpret,
        )
    return out[:b, :fs].reshape(*batch_shape, fs)
