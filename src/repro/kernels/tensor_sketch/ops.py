"""jit'd public wrapper around the fused TensorSketch Pallas kernel.

``tensor_sketch_fused`` applies the whole sketch-block section of a
``SketchPlan`` (packed frequency-domain layout, ``repro.sketch.plan
.pack_sketch``) in one Pallas launch: it pads the batch to a VMEM-budgeted
tile and the feature axis to lane alignment, and falls back to the pure-jnp
mirror (``repro.sketch.ref.tensor_sketch_fused_ref``) when Pallas is off or
the plan has no sketch blocks.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import VMEM_BUDGET as _VMEM_BUDGET
from repro.kernels.common import round_up as _round_up
from repro.sketch.ref import tensor_sketch_fused_ref
from repro.kernels.tensor_sketch.tensor_sketch import tensor_sketch_fused_pallas


def _pick_block_b(d: int, k: int, fs: int, b: int) -> int:
    """Largest batch tile whose working set fits the VMEM budget.

    Working set: x tile + both packed weight tensors + both inverse-DFT
    matrices + three [bm, Fs] live accumulators (out, ar/ai).
    """
    fixed = 4 * (2 * k * fs * d + 2 * fs * fs)
    for bm in (512, 256, 128, 64, 32, 16, 8):
        if bm > max(b, 8) * 2:
            continue
        if fixed + 4 * bm * (d + 3 * fs) <= _VMEM_BUDGET:
            return bm
    return 8


def tensor_sketch_fused(
    x: jax.Array,          # [..., d]
    wr: jax.Array,         # [max_degree, Fs, d]   (pack_sketch)
    wi: jax.Array,         # [max_degree, Fs, d]
    col_deg: jax.Array,    # [Fs] int32 per-column product depth
    mr: jax.Array,         # [Fs, Fs] block-diag inverse-DFT, real
    mi: jax.Array,         # [Fs, Fs] block-diag inverse-DFT, imag
    col_scale: jax.Array,  # [Fs] per-column scale
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:            # [..., Fs] float32
    """Apply the packed sketch blocks: one Pallas launch for every column.

    SPMD-safe (no host callbacks, shape-static tiling): usable inside a
    ``shard_map`` body, where the sharded estimator path runs one launch
    per feature shard over that shard's degree blocks. Note the 128-lane
    feature pad is a per-LAUNCH cost, so very thin shards (Fs << 128) pay
    proportionally more padding than a single-device launch would.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    batch_shape = x.shape[:-1]
    d = x.shape[-1]
    k, fs, _ = wr.shape
    xf = x.reshape(-1, d)
    if xf.shape[0] == 0:   # degenerate row chunk: skip the padded launch
        return jnp.zeros((*batch_shape, fs), jnp.float32)
    if not use_pallas or k == 0 or fs == 0:
        out = tensor_sketch_fused_ref(xf, wr, wi, col_deg, mr, mi, col_scale)
        return out.reshape(*batch_shape, fs)

    b = xf.shape[0]
    f_pad = _round_up(max(fs, 128), 128)
    bm = _pick_block_b(d, k, f_pad, b)   # budget at the PADDED feature count
    b_pad = _round_up(max(b, bm), bm)
    xp = jnp.pad(xf, ((0, b_pad - b), (0, 0)))
    pf = f_pad - fs
    wrp = jnp.pad(wr, ((0, 0), (0, pf), (0, 0)))
    wip = jnp.pad(wi, ((0, 0), (0, pf), (0, 0)))
    # padded columns: depth 0 keeps the accumulator at (1, 0); zero inverse-DFT
    # rows and zero scales make their outputs exactly 0 before the slice.
    deg_p = jnp.pad(col_deg.astype(jnp.int32), ((0, pf),))
    mrp = jnp.pad(mr, ((0, pf), (0, pf)))
    mip = jnp.pad(mi, ((0, pf), (0, pf)))
    scale_p = jnp.pad(col_scale.astype(jnp.float32), ((0, pf),))
    out = tensor_sketch_fused_pallas(
        xp, wrp, wip, deg_p, mrp, mip, scale_p,
        block_b=bm, interpret=interpret,
    )
    return out[:b, :fs].reshape(*batch_shape, fs)
