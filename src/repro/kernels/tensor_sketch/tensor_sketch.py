"""Pallas TPU kernel for fused TensorSketch application.

``tensor_sketch_fused_pallas`` applies every sketch block of a ``SketchPlan``
in ONE launch, using the frequency-domain formulation of DESIGN.md §9: the
FFT of a CountSketch is a dense complex projection of x (FFT is linear), so

    stage 1: masked complex running product over degree slots
             (Ar, Ai) <- (Ar Pr - Ai Pi, Ar Pi + Ai Pr),  P_j = x W_j^T,
             exactly the ``rm_feature_fused`` loop with two accumulators;
    stage 2: one block-diagonal inverse-DFT matmul
             z = Ar Mr^T - Ai Mi^T   (the real part of the circular
             convolution of the CountSketches), then per-column scales.

Both stages are MXU matmuls; the accumulators and the [Fs, Fs] inverse-DFT
stay in VMEM. The grid tiles the BATCH dimension only: stage 2 mixes all
frequencies of a block, and blocks are packed contiguously, so the whole
feature axis stays resident per tile (ops.py budgets the batch tile so the
working set — x, wr/wi, mr/mi, three [bm, Fs] accumulators — fits VMEM).

Like ``rm_feature_fused``, the product loop bound is the max depth over the
resident columns, so low-degree plans exit early.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ts_fused_kernel(x_ref, wr_ref, wi_ref, deg_ref, mr_ref, mi_ref,
                     scale_ref, o_ref):
    # Stage-1 MXU operands stay in their STORED dtype (fp32, or bf16 under
    # the precision policy — halving the HBM read of x and both packed
    # weight tensors); the complex accumulator pair is fp32 VMEM and every
    # dot carries preferred_element_type=float32. Stage 2 runs in fp32 (the
    # accumulators already are; mr/mi are upcast after their bf16 HBM read).
    x = x_ref[...]                                # [bm, d]
    deg = deg_ref[...]                            # [1, Fs] int32
    bm = x.shape[0]
    fs = deg.shape[-1]

    def step(j, carry):
        ar, ai = carry
        wr = pl.load(wr_ref, (pl.ds(j, 1), slice(None), slice(None)))
        wr = wr.reshape(wr.shape[1], wr.shape[2])
        wi = pl.load(wi_ref, (pl.ds(j, 1), slice(None), slice(None)))
        wi = wi.reshape(wi.shape[1], wi.shape[2])
        dims = (((1,), (1,)), ((), ()))
        pr = jax.lax.dot_general(x, wr, dimension_numbers=dims,
                                 preferred_element_type=jnp.float32)
        pi = jax.lax.dot_general(x, wi, dimension_numbers=dims,
                                 preferred_element_type=jnp.float32)
        nr = ar * pr - ai * pi
        ni = ar * pi + ai * pr
        keep = j < deg
        return jnp.where(keep, nr, ar), jnp.where(keep, ni, ai)

    depth = jnp.max(deg)                          # resident product depth
    ar, ai = jax.lax.fori_loop(
        0, depth, step,
        (jnp.ones((bm, fs), jnp.float32), jnp.zeros((bm, fs), jnp.float32)),
    )
    mr = mr_ref[...].astype(jnp.float32)          # [Fs, Fs]
    mi = mi_ref[...].astype(jnp.float32)
    dims = (((1,), (1,)), ((), ()))
    z = (jax.lax.dot_general(ar, mr, dimension_numbers=dims,
                             preferred_element_type=jnp.float32)
         - jax.lax.dot_general(ai, mi, dimension_numbers=dims,
                               preferred_element_type=jnp.float32))
    o_ref[...] = (z * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def tensor_sketch_fused_pallas(
    x: jax.Array,          # [B, d]               (B pre-padded to block_b)
    wr: jax.Array,         # [max_degree, Fs, d]  (Fs pre-padded, lane-aligned)
    wi: jax.Array,         # [max_degree, Fs, d]
    col_deg: jax.Array,    # [Fs] int32           (padding columns: 0)
    mr: jax.Array,         # [Fs, Fs]             (padding rows/cols: 0)
    mi: jax.Array,         # [Fs, Fs]
    col_scale: jax.Array,  # [Fs] float32         (padding columns: 0)
    *,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:            # [B, Fs] float32
    b, d = x.shape
    k, fs, _ = wr.shape
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b,)
    return pl.pallas_call(
        _ts_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((k, fs, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, fs, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, fs), lambda i: (0, 0)),
            pl.BlockSpec((fs, fs), lambda i: (0, 0)),
            pl.BlockSpec((fs, fs), lambda i: (0, 0)),
            pl.BlockSpec((1, fs), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, fs), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, fs), jnp.float32),
        interpret=interpret,
    )(x, wr, wi, col_deg.reshape(1, fs), mr, mi, col_scale.reshape(1, fs))
