"""Shared tiling helpers for the Pallas kernel wrappers.

One VMEM working-set budget for every kernel family, so a budget tune lands
everywhere at once. v5e has ~128MiB of VMEM per core; we budget well under
it to leave room for double buffering.
"""
from __future__ import annotations

VMEM_BUDGET = 12 * 1024 * 1024  # bytes


def round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
