"""Shared tiling helpers for the Pallas kernel wrappers.

One VMEM working-set budget for every kernel family, so a budget tune lands
everywhere at once. v5e has ~128MiB of VMEM per core; we budget well under
it to leave room for double buffering.
"""
from __future__ import annotations

VMEM_BUDGET = 12 * 1024 * 1024  # bytes

# Candidate (block_b, block_f) tiles, largest first — shared by every
# (batch, feature)-tiled feature-map kernel so a ladder tune lands on all
# of them at once.
_BLOCK_LADDER = ((512, 256), (256, 256), (256, 128), (128, 128), (128, 64),
                 (64, 64), (32, 32), (16, 16), (8, 8))


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return (x + m - 1) // m * m


def pick_feature_blocks(
    d: int,
    depth: int,
    b: int,
    f: int,
    *,
    weight_tensors: int = 1,
    accumulators: int = 2,
) -> tuple[int, int]:
    """Largest (block_b, block_f) tile whose working set fits VMEM.

    Shared by the (batch, feature)-tiled feature-map kernels
    (``rm_feature``: one packed weight tensor, two [bm, bf] live buffers;
    ``ctr_feature``: two weight tensors for the complex pair, four
    buffers). Working set in fp32 bytes per tile:

        4 * (bm*d + weight_tensors * depth*bf*d + accumulators * bm*bf).
    """
    for bm, bf in _BLOCK_LADDER:
        if bm > max(b, 8) * 2 or bf > max(f, 8) * 2:
            continue
        working = 4 * (bm * d + weight_tensors * depth * bf * d
                       + accumulators * bm * bf)
        if working <= VMEM_BUDGET:
            return bm, bf
    return 8, 8
