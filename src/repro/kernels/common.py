"""Shared tiling + launch-default helpers for the Pallas kernel wrappers.

Three responsibilities, shared by ALL kernel families so a tune or a policy
change lands everywhere at once:

* ``default_interpret()`` — the ONE backend-detection rule deciding whether
  a launch runs the Pallas interpreter (off-TPU) or compiles (TPU). The
  rm/sketch/ctr/attention ops wrappers all resolve ``interpret=None``
  through this function instead of each repeating the backend check.
* VMEM-budget tile heuristics — ``pick_feature_blocks`` for the
  (batch, feature)-tiled kernels (rm_feature, ctr_feature) and
  ``pick_batch_block`` for the batch-only-tiled TensorSketch kernel. Both
  are dtype-aware: bf16 inputs halve the x/weight working set, so the
  heuristic can afford larger tiles at the same budget (accumulators are
  always fp32 — see repro.common.dtypes.Precision).
* The measured ladder autotuner — ``autotune_feature_blocks`` times real
  launches over the feasible ladder and persists the winner in a
  per-(kernel, shape, dtype, backend) JSON cache; ``get_feature_blocks`` /
  ``get_batch_block`` consult that cache before falling back to the
  heuristic. Lookups are pure host-side dict reads, so they are safe at
  trace time; MEASURING only happens through the explicit autotune entry
  points (``python -m repro.bench --autotune`` drives them), never inside
  a jitted apply.

v5e has ~128MiB of VMEM per core; we budget well under it to leave room
for double buffering.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.obs import clock as _obs_clock

VMEM_BUDGET = 12 * 1024 * 1024  # bytes

# Candidate (block_b, block_f) tiles, largest first — shared by every
# (batch, feature)-tiled feature-map kernel so a ladder tune lands on all
# of them at once.
_BLOCK_LADDER = ((512, 256), (256, 256), (256, 128), (128, 128), (128, 64),
                 (64, 64), (32, 32), (16, 16), (8, 8))

# Batch-tile ladder for kernels that keep the whole feature axis resident
# (tensor_sketch).
_BATCH_LADDER = (512, 256, 128, 64, 32, 16, 8)

# (chunk, block_f) ladder for the fused featurize+attention kernels
# (kernels/rm_attention/fused.py): the chunk axis tiles the sequence, the
# feature axis tiles the packed omega layout. Largest first.
_ATTN_LADDER = ((256, 256), (128, 256), (128, 128), (64, 128), (64, 64),
                (32, 64), (32, 32), (16, 16), (8, 8))


def default_interpret() -> bool:
    """The one backend-detection rule for Pallas launches.

    Off-TPU backends run the Pallas interpreter (a correctness harness, not
    a performance target); on TPU the kernels compile. Every ops wrapper
    resolves ``interpret=None`` through this function — tests monkeypatch
    it to steer all launches at once.
    """
    return jax.default_backend() != "tpu"


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return (x + m - 1) // m * m


def dtype_itemsize(dtype) -> int:
    """Bytes per element for a dtype name / jnp dtype (bf16 -> 2)."""
    return int(jnp.dtype(dtype).itemsize)


# ---------------------------------------------------------------------------
# VMEM-budget heuristics (the autotuner's fallback)
# ---------------------------------------------------------------------------
def pick_feature_blocks(
    d: int,
    depth: int,
    b: int,
    f: int,
    *,
    weight_tensors: int = 1,
    accumulators: int = 2,
    itemsize: int = 4,
) -> Tuple[int, int]:
    """Largest (block_b, block_f) tile whose working set fits VMEM.

    Shared by the (batch, feature)-tiled feature-map kernels
    (``rm_feature``: one packed weight tensor, two [bm, bf] live buffers;
    ``ctr_feature``: two weight tensors for the complex pair, four
    buffers). Working set per tile: x and the packed weights at
    ``itemsize`` bytes/element (2 for bf16 inputs), accumulators always
    fp32:

        itemsize * (bm*d + weight_tensors * depth*bf*d)
            + 4 * accumulators * bm*bf.
    """
    for bm, bf in _BLOCK_LADDER:
        if bm > max(b, 8) * 2 or bf > max(f, 8) * 2:
            continue
        working = (itemsize * (bm * d + weight_tensors * depth * bf * d)
                   + 4 * accumulators * bm * bf)
        if working <= VMEM_BUDGET:
            return bm, bf
    return 8, 8


def pick_batch_block(
    d: int,
    depth: int,
    fs: int,
    b: int,
    *,
    itemsize: int = 4,
) -> int:
    """Largest batch tile for the whole-feature-axis-resident kernels.

    Working set (tensor_sketch): x tile + both packed weight tensors +
    both inverse-DFT matrices at ``itemsize`` bytes, three [bm, Fs] live
    fp32 accumulators (out, ar/ai).
    """
    fixed = itemsize * (2 * depth * fs * d + 2 * fs * fs)
    for bm in _BATCH_LADDER:
        if bm > max(b, 8) * 2:
            continue
        if fixed + itemsize * bm * d + 4 * bm * 3 * fs <= VMEM_BUDGET:
            return bm
    return 8


# ---------------------------------------------------------------------------
# persistent per-(kernel, shape, dtype, backend) block cache
# ---------------------------------------------------------------------------
_CACHE_ENV = "REPRO_BLOCK_CACHE"
_DEFAULT_CACHE = "~/.cache/repro/feature_blocks.json"

_block_cache: Optional[Dict[str, list]] = None
_block_cache_path: Optional[Path] = None


def block_cache_path() -> Path:
    """Where the measured-block cache lives (override: $REPRO_BLOCK_CACHE)."""
    return Path(os.environ.get(_CACHE_ENV, _DEFAULT_CACHE)).expanduser()


def cache_key(kernel: str, d: int, depth: int, b: int, f: int,
              dtype) -> str:
    """One cache row per (kernel family, shape, input dtype, backend).

    Key schema (feature-map kernels, value ``[block_b, block_f]`` — the
    batch-only-tiled kernels store ``[block_b, block_b]``)::

        {kernel}/d{input_dim}/k{max_degree}/b{batch}/f{features}/{dtype}/{backend}

    e.g. ``rm_feature/d64/k8/b4096/f256/float32/tpu``. The attention-fused
    kernels use the richer :func:`attention_cache_key` schema; the two key
    families share one JSON file (``$REPRO_BLOCK_CACHE``) and cannot
    collide because the attention keys carry ``t{...}``/``v{...}`` fields.
    """
    name = jnp.dtype(dtype).name
    return (f"{kernel}/d{d}/k{depth}/b{b}/f{f}/{name}/"
            f"{jax.default_backend()}")


def attention_cache_key(kernel: str, d: int, depth: int, t: int, f: int,
                        dv: int, dtype) -> str:
    """Cache row for the fused featurize+attention kernels.

    Key schema (value is the measured ``[chunk, block_f]`` pair)::

        {kernel}/d{head_dim}/k{max_degree}/t{seq_len}/f{features}/v{value_dim}/{dtype}/{backend}

    e.g. ``rm_attn_fused/d64/k8/t1024/f256/v64/bfloat16/tpu``. ``t`` and
    ``dv`` are part of the key because the score tile ([chunk, chunk]) and
    the state scratch (f * dv) dominate the fused kernel's VMEM working
    set, so the best tile genuinely shifts with them.
    """
    name = jnp.dtype(dtype).name
    return (f"{kernel}/d{d}/k{depth}/t{t}/f{f}/v{dv}/{name}/"
            f"{jax.default_backend()}")


def load_block_cache(path: Optional[Path] = None) -> Dict[str, list]:
    """Read (and memoize) the persisted cache; missing/corrupt -> empty."""
    global _block_cache, _block_cache_path
    p = Path(path) if path is not None else block_cache_path()
    if _block_cache is not None and _block_cache_path == p:
        return _block_cache
    cache: Dict[str, list] = {}
    try:
        cache = json.loads(p.read_text())
        if not isinstance(cache, dict):
            cache = {}
    except (OSError, ValueError):
        cache = {}
    _block_cache, _block_cache_path = cache, p
    return cache


def save_block_cache(cache: Dict[str, list],
                     path: Optional[Path] = None) -> Path:
    """Persist the cache (and refresh the in-process memo)."""
    global _block_cache, _block_cache_path
    p = Path(path) if path is not None else block_cache_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(cache, indent=2, sort_keys=True))
    _block_cache, _block_cache_path = dict(cache), p
    return p


def clear_block_cache_memo() -> None:
    """Drop the in-process memo (tests point $REPRO_BLOCK_CACHE elsewhere)."""
    global _block_cache, _block_cache_path
    _block_cache = None
    _block_cache_path = None


def get_feature_blocks(
    kernel: str,
    d: int,
    depth: int,
    b: int,
    f: int,
    *,
    dtype=jnp.float32,
    weight_tensors: int = 1,
    accumulators: int = 2,
) -> Tuple[int, int]:
    """Measured blocks if the cache has this shape, else the heuristic.

    The lookup is a host-side dict read — safe inside a jit trace (shapes
    are static there). All three fused wrappers route through here, so one
    ``autotune`` pass (or a shipped cache file) retargets every launch.
    """
    hit = load_block_cache().get(cache_key(kernel, d, depth, b, f, dtype))
    if hit is not None and len(hit) == 2:
        return int(hit[0]), int(hit[1])
    return pick_feature_blocks(
        d, depth, b, f, weight_tensors=weight_tensors,
        accumulators=accumulators, itemsize=dtype_itemsize(dtype),
    )


def get_batch_block(
    kernel: str,
    d: int,
    depth: int,
    fs: int,
    b: int,
    *,
    dtype=jnp.float32,
) -> int:
    """Batch-tile variant of ``get_feature_blocks`` (tensor_sketch)."""
    hit = load_block_cache().get(cache_key(kernel, d, depth, b, fs, dtype))
    if hit is not None and len(hit) == 2:
        return int(hit[0])
    return pick_batch_block(d, depth, fs, b,
                            itemsize=dtype_itemsize(dtype))


# ---------------------------------------------------------------------------
# fused featurize+attention (chunk, feature-block) tiles
# ---------------------------------------------------------------------------
def _attention_working_set(d: int, depth: int, f: int, dv: int, c: int,
                           bf: int, itemsize: int) -> int:
    """VMEM bytes for one fused-attention program at tile (chunk=c, bf).

    Streamed operands at input itemsize (q, k chunks + v chunk + the packed
    omega block), fp32 live tiles (zq, zk, score [c, c], num/den), and the
    fp32 state scratch over the WHOLE padded feature axis (it persists
    across the chunk sweep — see fused.py docstring).
    """
    f_pad = round_up(max(f, 1), bf)
    streamed = itemsize * (2 * c * d + c * dv + depth * bf * d)
    live = 4 * (2 * c * bf + c * c + c * dv + c)
    state = 4 * (f_pad * dv + f_pad)
    return streamed + live + state


def pick_attention_blocks(
    d: int,
    depth: int,
    t: int,
    f: int,
    dv: int,
    *,
    itemsize: int = 4,
) -> Tuple[int, int]:
    """Largest feasible (chunk, block_f) for the fused attention kernels."""
    for c, bf in _ATTN_LADDER:
        if c > max(t, 8) * 2 or bf > max(f, 8) * 2:
            continue
        if _attention_working_set(d, depth, f, dv, c, bf,
                                  itemsize) <= VMEM_BUDGET:
            return c, bf
    return 8, 8


def feasible_attention_blocks(
    d: int,
    depth: int,
    t: int,
    f: int,
    dv: int,
    *,
    itemsize: int = 4,
) -> Tuple[Tuple[int, int], ...]:
    """Ladder candidates whose fused-attention working set fits VMEM."""
    out = []
    for c, bf in _ATTN_LADDER:
        if c > max(t, 8) * 2 or bf > max(f, 8) * 2:
            continue
        if _attention_working_set(d, depth, f, dv, c, bf,
                                  itemsize) <= VMEM_BUDGET:
            out.append((c, bf))
    return tuple(out) or ((8, 8),)


def get_attention_blocks(
    kernel: str,
    *,
    d: int,
    depth: int,
    t: int,
    f: int,
    dv: int,
    dtype=jnp.float32,
) -> Tuple[int, int]:
    """Measured (chunk, block_f) if cached, else the VMEM heuristic.

    Same contract as ``get_feature_blocks``: a pure host-side dict read
    keyed by :func:`attention_cache_key`, safe at trace time; measurement
    only happens via :func:`autotune_attention_blocks`.
    """
    hit = load_block_cache().get(
        attention_cache_key(kernel, d, depth, t, f, dv, dtype))
    if hit is not None and len(hit) == 2:
        return int(hit[0]), int(hit[1])
    return pick_attention_blocks(d, depth, t, f, dv,
                                 itemsize=dtype_itemsize(dtype))


def autotune_attention_blocks(
    kernel: str,
    launch: Callable[[int, int], object],
    *,
    d: int,
    depth: int,
    t: int,
    f: int,
    dv: int,
    dtype=jnp.float32,
    candidates: Optional[Iterable[Tuple[int, int]]] = None,
    repeats: int = 3,
    path: Optional[Path] = None,
) -> Tuple[int, int]:
    """Measured-ladder tune for the fused attention kernels.

    ``launch(chunk, block_f)`` must run the real fused kernel end-to-end;
    the median-of-``repeats`` winner is persisted under
    :func:`attention_cache_key` in the same ``$REPRO_BLOCK_CACHE`` file the
    feature-map kernels use. Host-side offline pass only (driven by
    ``python -m repro.bench --autotune``).
    """
    cands = tuple(candidates) if candidates is not None else \
        feasible_attention_blocks(d, depth, t, f, dv,
                                  itemsize=dtype_itemsize(dtype))
    best, best_t = None, float("inf")
    for c, bf in cands:
        try:
            tm = _median_seconds(lambda: launch(c, bf), repeats)
        except Exception:  # infeasible tile (e.g. VMEM OOM on TPU): skip
            continue
        if tm < best_t:
            best, best_t = (c, bf), tm
    if best is None:
        best = pick_attention_blocks(d, depth, t, f, dv,
                                     itemsize=dtype_itemsize(dtype))
    cache = dict(load_block_cache(path))
    cache[attention_cache_key(kernel, d, depth, t, f, dv, dtype)] = \
        list(best)
    save_block_cache(cache, path)
    return best


# ---------------------------------------------------------------------------
# measured ladder autotune
# ---------------------------------------------------------------------------
def feasible_feature_blocks(
    d: int,
    depth: int,
    b: int,
    f: int,
    *,
    weight_tensors: int = 1,
    accumulators: int = 2,
    itemsize: int = 4,
) -> Tuple[Tuple[int, int], ...]:
    """The ladder candidates whose working set fits VMEM for this shape."""
    out = []
    for bm, bf in _BLOCK_LADDER:
        if bm > max(b, 8) * 2 or bf > max(f, 8) * 2:
            continue
        working = (itemsize * (bm * d + weight_tensors * depth * bf * d)
                   + 4 * accumulators * bm * bf)
        if working <= VMEM_BUDGET:
            out.append((bm, bf))
    return tuple(out) or ((8, 8),)


def _median_seconds(fn: Callable[[], object], repeats: int) -> float:
    # warm up / compile outside the timed region — and BLOCK on it, so the
    # async warm-up tail can't bleed into the first timed repeat. Timing
    # reads the shared obs monotonic clock (repro.obs.clock), the same
    # instrument behind bench timings and serving latencies.
    jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = _obs_clock.monotonic()
        out = fn()
        jax.block_until_ready(out)
        times.append(_obs_clock.monotonic() - t0)
    return sorted(times)[len(times) // 2]


def autotune_feature_blocks(
    kernel: str,
    launch: Callable[[int, int], object],
    d: int,
    depth: int,
    b: int,
    f: int,
    *,
    dtype=jnp.float32,
    weight_tensors: int = 1,
    accumulators: int = 2,
    candidates: Optional[Iterable[Tuple[int, int]]] = None,
    repeats: int = 3,
    path: Optional[Path] = None,
) -> Tuple[int, int]:
    """Time ``launch(block_b, block_f)`` over the ladder; persist the winner.

    ``launch`` must run the REAL kernel end-to-end with the given blocks
    and return its (jax) result; each candidate is warmed once (compile)
    then timed ``repeats`` times, median wins. The winning pair lands in
    the persistent cache under this (kernel, shape, dtype, backend) key so
    every later ``get_feature_blocks`` call — in any process on the same
    cache — uses the measured tiles. This is a HOST-side offline pass:
    never call it from inside a jitted function.
    """
    cands = tuple(candidates) if candidates is not None else \
        feasible_feature_blocks(
            d, depth, b, f, weight_tensors=weight_tensors,
            accumulators=accumulators, itemsize=dtype_itemsize(dtype),
        )
    best, best_t = None, float("inf")
    for bm, bf in cands:
        try:
            t = _median_seconds(lambda: launch(bm, bf), repeats)
        except Exception:  # infeasible tile (e.g. VMEM OOM on TPU): skip
            continue
        if t < best_t:
            best, best_t = (bm, bf), t
    if best is None:
        best = pick_feature_blocks(
            d, depth, b, f, weight_tensors=weight_tensors,
            accumulators=accumulators, itemsize=dtype_itemsize(dtype),
        )
    cache = dict(load_block_cache(path))
    cache[cache_key(kernel, d, depth, b, f, dtype)] = list(best)
    save_block_cache(cache, path)
    return best
