"""Pallas TPU kernel for fused complex-to-real (CTR) feature application.

``ctr_feature_fused_pallas`` applies every complex bucket of a ``CtrPlan``
in ONE launch (DESIGN.md §11): a masked COMPLEX running product over degree
slots — the ``rm_feature_fused`` loop with (real, imag) accumulator pairs,
exactly the stage-1 structure of the TensorSketch kernel —

    (Ar, Ai) <- (Ar Pr - Ai Pi, Ar Pi + Ai Pr),   P_j = x (Wr_j + i Wi_j)^T,

followed by per-column scales on BOTH accumulators, written to two output
tiles (the Re half and the Im half of the CtR feature vector). Every slot
projection is an MXU matmul; the accumulators stay in VMEM.

Unlike TensorSketch there is no cross-column mixing stage (no inverse DFT),
so the grid tiles (batch, complex-feature) like ``rm_feature_fused`` — and
like there, columns are laid out in ascending degree order, so each feature
tile's loop exits at the TILE's max depth, not the global one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ctr_fused_kernel(x_ref, wr_ref, wi_ref, deg_ref, scale_ref,
                      ore_ref, oim_ref):
    # Native-dtype MXU operands (fp32 or bf16 under the precision policy);
    # both accumulators are fp32 VMEM buffers and every dot carries
    # preferred_element_type=float32 — bf16-in / fp32-accum.
    x = x_ref[...]                                # [bm, d]
    deg = deg_ref[...]                            # [1, bf] int32
    bm = x.shape[0]
    bf = deg.shape[-1]

    def step(j, carry):
        ar, ai = carry
        wr = pl.load(wr_ref, (pl.ds(j, 1), slice(None), slice(None)))
        wr = wr.reshape(wr.shape[1], wr.shape[2])
        wi = pl.load(wi_ref, (pl.ds(j, 1), slice(None), slice(None)))
        wi = wi.reshape(wi.shape[1], wi.shape[2])
        dims = (((1,), (1,)), ((), ()))
        pr = jax.lax.dot_general(x, wr, dimension_numbers=dims,
                                 preferred_element_type=jnp.float32)
        pi = jax.lax.dot_general(x, wi, dimension_numbers=dims,
                                 preferred_element_type=jnp.float32)
        nr = ar * pr - ai * pi
        ni = ar * pi + ai * pr
        keep = j < deg
        return jnp.where(keep, nr, ar), jnp.where(keep, ni, ai)

    depth = jnp.max(deg)                          # tile-local product depth
    ar, ai = jax.lax.fori_loop(
        0, depth, step,
        (jnp.ones((bm, bf), jnp.float32), jnp.zeros((bm, bf), jnp.float32)),
    )
    scale = scale_ref[...].astype(jnp.float32)
    ore_ref[...] = (ar * scale).astype(ore_ref.dtype)
    oim_ref[...] = (ai * scale).astype(oim_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_f", "interpret")
)
def ctr_feature_fused_pallas(
    x: jax.Array,          # [B, d]               (B pre-padded to block_b)
    wr: jax.Array,         # [max_degree, Fc, d]  (Fc pre-padded to block_f)
    wi: jax.Array,         # [max_degree, Fc, d]
    col_deg: jax.Array,    # [Fc] int32           (padding columns: 0)
    col_scale: jax.Array,  # [Fc] float32         (padding columns: 0)
    *,
    block_b: int = 256,
    block_f: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:   # ([B, Fc], [B, Fc]) float32 (Re, Im)
    """One launch over (batch, complex-feature) tiles; two output tensors.

    Returns the (Re, Im) halves separately — the ops-layer wrapper
    concatenates them into the ``[Re | Im]`` CtR column layout after
    un-padding, keeping the kernel free of cross-half indexing.
    """
    b, d = x.shape
    k, fc, _ = wr.shape
    assert b % block_b == 0 and fc % block_f == 0, (b, fc, block_b, block_f)
    grid = (b // block_b, fc // block_f)
    out_shape = jax.ShapeDtypeStruct((b, fc), jnp.float32)
    return pl.pallas_call(
        _ctr_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_f, d), lambda i, j: (0, j, 0)),
            pl.BlockSpec((k, block_f, d), lambda i, j: (0, j, 0)),
            pl.BlockSpec((1, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_f), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, block_f), lambda i, j: (i, j)),
            pl.BlockSpec((block_b, block_f), lambda i, j: (i, j)),
        ],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(x, wr, wi, col_deg.reshape(1, fc), col_scale.reshape(1, fc))
