"""jit'd public wrapper around the fused CTR Pallas kernel.

``ctr_feature_fused`` applies the whole complex-bucket section of a
``CtrPlan`` (packed layout, ``repro.ctr.plan.pack_ctr``) in one Pallas
launch: it pads (batch, complex-feature) to MXU-aligned tiles, picks
VMEM-budgeted block sizes, and falls back to the pure-jnp mirror
(``repro.ctr.ref.ctr_feature_fused_ref``) when Pallas is off or the plan
has no complex columns.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.ctr.ref import ctr_feature_fused_ref
from repro.kernels.common import default_interpret as _default_interpret
from repro.kernels.common import get_feature_blocks as _get_blocks
from repro.kernels.common import round_up as _round_up
from repro.obs.trace import kernel_scope as _kernel_scope
from repro.kernels.ctr_feature.ctr_feature import ctr_feature_fused_pallas


def ctr_feature_fused(
    x: jax.Array,          # [..., d]
    wr: jax.Array,         # [max_degree, Fc, d]  (pack_ctr)
    wi: jax.Array,         # [max_degree, Fc, d]
    col_deg: jax.Array,    # [Fc] int32 per-column product depth
    col_scale: jax.Array,  # [Fc] per-complex-column scale
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[tuple] = None,
) -> jax.Array:            # [..., 2 * Fc] float32, layout [Re | Im]
    """Apply the packed complex buckets: one Pallas launch for every column.

    SPMD-safe (no host callbacks, shape-static tiling): usable inside a
    ``shard_map`` body, where the sharded estimator path runs one launch per
    feature shard over that shard's ``[max_degree, Fc/S, d]`` slice of the
    packed tensors (tests/dist_scripts/run_sharded_estimators.py checks
    interpret-mode parity under shard_map for every registry entry).

    ``x``/``wr``/``wi`` enter the launch in their incoming dtype (bf16
    under the mixed precision policy); both accumulators are fp32.
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    d = x.shape[-1]
    k, fc, _ = wr.shape
    xf = x.reshape(-1, d)
    if xf.shape[0] == 0:   # degenerate row chunk: skip the padded launch
        return jnp.zeros((*batch_shape, 2 * fc), jnp.float32)
    if not use_pallas or k == 0 or fc == 0:
        out = ctr_feature_fused_ref(xf, wr, wi, col_deg, col_scale)
        return out.reshape(*batch_shape, 2 * fc)

    b = xf.shape[0]
    # TWO packed weight tensors and four [bm, bf] live buffers (complex
    # accumulator pair + both output halves)
    bm, bf = blocks or _get_blocks("ctr_feature", d, k, b, fc, dtype=x.dtype,
                                   weight_tensors=2, accumulators=4)
    with _kernel_scope("ctr_feature", x=x,
                       cost=dict(batch=b, d=d, depth=k, f=fc,
                                 itemsize=jnp.dtype(x.dtype).itemsize),
                       blocks=[bm, bf], interpret=bool(interpret)):
        b_pad = _round_up(max(b, bm), bm)
        f_pad = _round_up(max(fc, bf), bf)
        xp = jnp.pad(xf, ((0, b_pad - b), (0, 0)))
        pf = f_pad - fc
        wrp = jnp.pad(wr, ((0, 0), (0, pf), (0, 0)))
        wip = jnp.pad(wi, ((0, 0), (0, pf), (0, 0)))
        # padded columns: depth 0 keeps the accumulator at (1, 0); zero
        # scales make both halves exactly 0 before the slice.
        deg_p = jnp.pad(col_deg.astype(jnp.int32), ((0, pf),))
        scale_p = jnp.pad(col_scale.astype(jnp.float32), ((0, pf),))
        re, im = ctr_feature_fused_pallas(
            xp, wrp, wip, deg_p, scale_p,
            block_b=bm, block_f=bf, interpret=interpret,
        )
        out = jnp.concatenate([re[:b, :fc], im[:b, :fc]], axis=-1)
    return out.reshape(*batch_shape, 2 * fc)
