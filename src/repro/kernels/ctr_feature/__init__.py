from repro.kernels.ctr_feature.ops import ctr_feature_fused
from repro.kernels.ctr_feature.ctr_feature import ctr_feature_fused_pallas

__all__ = ["ctr_feature_fused", "ctr_feature_fused_pallas"]
