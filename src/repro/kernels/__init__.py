"""Pallas TPU kernels for the perf-critical compute of the paper's technique.

Two kernels (each with ``ops.py`` jit'd wrapper + ``ref.py`` pure-jnp oracle):

  * ``rm_feature``   — fused Random-Maclaurin feature map application
                       (projection + degree-product, VMEM-tiled, MXU-aligned).
  * ``rm_attention`` — chunked causal linear attention over RM features
                       (the intra-chunk masked [C,C] x [C,dv] hot loop).

Kernels target TPU; on this CPU container they are validated with
``interpret=True`` against the oracles (tests/test_kernels_*.py).
"""
from repro.kernels.rm_feature import ops as rm_feature_ops
from repro.kernels.rm_attention import ops as rm_attention_ops

__all__ = ["rm_feature_ops", "rm_attention_ops"]
