"""Pallas TPU kernels for the perf-critical compute of the paper's technique.

Five kernels (each with an ``ops.py`` jit'd wrapper + a pure-jnp oracle):

  * ``rm_feature``     — fused Random-Maclaurin feature map application
                         (projection + degree-product, VMEM-tiled,
                         MXU-aligned).
  * ``tensor_sketch``  — fused TensorSketch application (frequency-domain
                         CountSketch product + block-diag inverse-DFT; oracle
                         in ``repro.sketch.ref``, DESIGN.md §9).
  * ``ctr_feature``    — fused complex-to-real application (masked complex
                         running product, stacked Re/Im output halves;
                         oracle in ``repro.ctr.ref``, DESIGN.md §11).
  * ``structured_feature`` — fused Hadamard-structured application
                         (in-VMEM butterfly WHT of diagonally-signed
                         inputs + masked running product; oracle in
                         ``repro.structured.ref``, DESIGN.md §15).
  * ``rm_attention``   — chunked causal linear attention over any
                         estimator's features (the intra-chunk masked
                         [C,C] x [C,dv] hot loop).

Kernels target TPU; on this CPU container they are validated with
``interpret=True`` against the oracles (tests/test_kernels_*.py,
tests/test_sketch.py, tests/test_ctr.py).
"""
from repro.kernels.rm_feature import ops as rm_feature_ops
from repro.kernels.rm_attention import ops as rm_attention_ops
from repro.kernels.tensor_sketch import ops as tensor_sketch_ops
from repro.kernels.ctr_feature import ops as ctr_feature_ops
from repro.kernels.structured_feature import ops as structured_feature_ops

__all__ = ["rm_feature_ops", "rm_attention_ops", "tensor_sketch_ops",
           "ctr_feature_ops", "structured_feature_ops"]
