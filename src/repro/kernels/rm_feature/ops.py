"""jit'd public wrappers around the rm_feature Pallas kernels.

``rm_feature_fused`` applies a WHOLE feature map (FeaturePlan packed layout)
in one Pallas launch: it pads (batch, feature) to MXU-aligned tiles, picks
VMEM-budgeted block sizes, and falls back to the pure-jnp oracle when Pallas
is off or the plan is degenerate (no product columns). ``rm_feature_bucket``
is the legacy per-degree launch, kept as the benchmark baseline.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rm_feature.ref import (
    rm_feature_bucket_ref,
    rm_feature_fused_ref,
)
from repro.kernels.rm_feature.rm_feature import (
    rm_feature_bucket_pallas,
    rm_feature_fused_pallas,
)

from repro.kernels.common import default_interpret as _default_interpret
from repro.kernels.common import get_feature_blocks as _get_blocks
from repro.kernels.common import round_up as _round_up
from repro.obs.trace import kernel_scope as _kernel_scope


# ---------------------------------------------------------------------------
# fused whole-map application — ONE launch
# ---------------------------------------------------------------------------
def rm_feature_fused(
    x: jax.Array,          # [..., d]
    w: jax.Array,          # [max_degree, F, d] packed (core.plan.pack_omegas)
    col_deg: jax.Array,    # [F] int32 per-column product depth
    col_scale: jax.Array,  # [F] per-column scale
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[tuple] = None,
) -> jax.Array:            # [..., F] float32
    """Apply a packed feature map: one Pallas launch for every column.

    ``blocks=(block_b, block_f)`` overrides the cached/heuristic tile
    choice — the measured ladder autotuner (kernels.common) drives real
    launches through this hook.

    SPMD-safe: no host callbacks and shape-static tiling, so the launch can
    sit inside a ``shard_map`` body — the sharded estimator path
    (repro.distributed.estimator) runs one launch per feature shard with the
    shard's ``[max_degree, F/S, d]`` slice of the packed tensor
    (tests/dist_scripts/run_sharded_estimators.py checks interpret-mode
    parity under shard_map).

    ``x``/``w`` enter the launch in their incoming dtype — the precision
    policy (repro.common.dtypes.Precision) casts them to bf16 upstream for
    the mixed path; accumulation inside the kernel is always fp32 and the
    output is fp32.
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    d = x.shape[-1]
    k, f, _ = w.shape
    xf = x.reshape(-1, d)
    if xf.shape[0] == 0:   # degenerate row chunk: skip the padded launch
        return jnp.zeros((*batch_shape, f), jnp.float32)
    if not use_pallas or k == 0 or f == 0:
        out = rm_feature_fused_ref(xf, w, col_deg, col_scale)
        return out.reshape(*batch_shape, f)

    b = xf.shape[0]
    bm, bf = blocks or _get_blocks("rm_feature", d, k, b, f, dtype=x.dtype)
    with _kernel_scope("rm_feature", x=x,
                       cost=dict(batch=b, d=d, depth=k, f=f,
                                 itemsize=jnp.dtype(x.dtype).itemsize),
                       blocks=[bm, bf], interpret=bool(interpret)):
        b_pad = _round_up(max(b, bm), bm)
        f_pad = _round_up(max(f, bf), bf)
        xp = jnp.pad(xf, ((0, b_pad - b), (0, 0)))
        wp = jnp.pad(w, ((0, 0), (0, f_pad - f), (0, 0)))
        deg_p = jnp.pad(col_deg.astype(jnp.int32), ((0, f_pad - f),))
        scale_p = jnp.pad(col_scale.astype(jnp.float32), ((0, f_pad - f),))
        out = rm_feature_fused_pallas(
            xp, wp, deg_p, scale_p, block_b=bm, block_f=bf,
            interpret=interpret,
        )
    return out[:b, :f].reshape(*batch_shape, f)


def apply_feature_map(
    fmap,
    x: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    precision=None,
) -> jax.Array:
    """Pallas-accelerated equivalent of ``RMFeatureMap.__call__``.

    Thin wrapper over the fused path: identical feature layout (h01 block,
    const column, degree buckets ascending) in ONE launch, so downstream code
    can swap paths freely. ``precision`` selects the feature-kernel input
    dtype policy (``"fp32"`` / ``"bf16"`` — see repro.common.dtypes).
    """
    from repro.core.plan import apply_plan

    return apply_plan(
        fmap.plan, fmap.omegas, x, use_pallas=use_pallas, interpret=interpret,
        precision=precision,
    )


# ---------------------------------------------------------------------------
# legacy per-bucket path (benchmark baseline / kernel tests)
# ---------------------------------------------------------------------------
def rm_feature_bucket(
    x: jax.Array,
    omega: jax.Array,
    degree: int,
    scale: float,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Apply one degree bucket: x [.., d], omega [count*degree, d] -> [.., count]."""
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    d = x.shape[-1]
    count = omega.shape[0] // degree
    if not use_pallas or degree < 1:
        out = rm_feature_bucket_ref(x.reshape(-1, d), omega, degree, scale)
        return out.reshape(*batch_shape, count)

    xf = x.reshape(-1, d)
    b = xf.shape[0]
    bm, bf = _get_blocks("rm_feature", d, degree, b, count, dtype=x.dtype)
    b_pad = _round_up(max(b, bm), bm)
    f_pad = _round_up(max(count, bf), bf)
    xp = jnp.pad(xf, ((0, b_pad - b), (0, 0)))
    # omega rows are feature-major: [count, degree, d] -> pad count -> [degree, F, d]
    w = omega.reshape(count, degree, d)
    w = jnp.pad(w, ((0, f_pad - count), (0, 0), (0, 0)))
    w = jnp.transpose(w, (1, 0, 2))  # [degree, F, d]
    out = rm_feature_bucket_pallas(
        xp, w, degree=degree, scale=float(scale), block_b=bm, block_f=bf,
        interpret=interpret,
    )
    return out[:b, :count].reshape(*batch_shape, count)


def apply_feature_map_bucketed(
    fmap,
    x: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """The pre-fusion path: one launch PER degree bucket plus a concatenate.

    Kept only as the comparison baseline for parity tests and
    ``benchmarks/rm_feature_bench.py``; production paths use
    ``apply_feature_map`` / ``core.plan.apply_plan``.
    """
    plan = fmap.plan
    batch_shape = x.shape[:-1]
    xf = x.reshape(-1, plan.input_dim)
    feats = []
    if plan.h01:
        feats.append(jnp.full((xf.shape[0], 1), np.sqrt(plan.h01_a0),
                              dtype=jnp.float32))
        feats.append(np.sqrt(plan.h01_a1) * xf.astype(jnp.float32))
    if plan.const != 0.0:
        feats.append(jnp.full((xf.shape[0], 1), plan.const, dtype=jnp.float32))
    for deg, scale, omega in zip(plan.degrees, plan.scales,
                                 fmap.bucket_omegas()):
        feats.append(
            rm_feature_bucket(
                xf, omega, deg, float(scale),
                use_pallas=use_pallas, interpret=interpret,
            )
        )
    z = jnp.concatenate(feats, axis=-1)
    return z.reshape(*batch_shape, z.shape[-1])
