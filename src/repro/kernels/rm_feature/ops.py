"""jit'd public wrapper around the rm_feature Pallas kernel.

Handles padding to MXU-aligned tiles, VMEM-budgeted block-size selection, and
the multi-bucket (whole feature map) application. Falls back to the pure-jnp
oracle automatically when Pallas is unavailable or shapes are degenerate.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rm_feature.ref import rm_feature_bucket_ref
from repro.kernels.rm_feature.rm_feature import rm_feature_bucket_pallas

# Conservative per-core VMEM working-set budget (bytes). v5e has ~128MiB of
# VMEM per core; we budget well under it to leave room for double buffering.
_VMEM_BUDGET = 12 * 1024 * 1024


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_blocks(d: int, degree: int, b: int, f: int) -> tuple[int, int]:
    """Largest 128-multiple (block_b, block_f) whose working set fits VMEM."""
    for bm, bf in ((512, 256), (256, 256), (256, 128), (128, 128), (128, 64), (64, 64), (32, 32), (16, 16), (8, 8)):
        if bm > max(b, 8) * 2 or bf > max(f, 8) * 2:
            continue
        working = 4 * (bm * d + degree * bf * d + 2 * bm * bf)
        if working <= _VMEM_BUDGET:
            return bm, bf
    return 8, 8


def rm_feature_bucket(
    x: jax.Array,
    omega: jax.Array,
    degree: int,
    scale: float,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Apply one degree bucket: x [.., d], omega [count*degree, d] -> [.., count]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    batch_shape = x.shape[:-1]
    d = x.shape[-1]
    count = omega.shape[0] // degree
    if not use_pallas or degree < 1:
        out = rm_feature_bucket_ref(x.reshape(-1, d), omega, degree, scale)
        return out.reshape(*batch_shape, count)

    xf = x.reshape(-1, d)
    b = xf.shape[0]
    bm, bf = _pick_blocks(d, degree, b, count)
    b_pad = _round_up(max(b, bm), bm)
    f_pad = _round_up(max(count, bf), bf)
    xp = jnp.pad(xf, ((0, b_pad - b), (0, 0)))
    # omega rows are feature-major: [count, degree, d] -> pad count -> [degree, F, d]
    w = omega.reshape(count, degree, d)
    w = jnp.pad(w, ((0, f_pad - count), (0, 0), (0, 0)))
    w = jnp.transpose(w, (1, 0, 2))  # [degree, F, d]
    out = rm_feature_bucket_pallas(
        xp, w, degree=degree, scale=float(scale), block_b=bm, block_f=bf,
        interpret=interpret,
    )
    return out[:b, :count].reshape(*batch_shape, count)


def apply_feature_map(
    fmap,
    x: jax.Array,
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Pallas-accelerated equivalent of ``RMFeatureMap.__call__``.

    Produces the identical feature layout (h01 block, const column, degree
    buckets in ascending order) so downstream code can swap paths freely.
    """
    batch_shape = x.shape[:-1]
    xf = x.reshape(-1, fmap.input_dim)
    feats = []
    if fmap.h01:
        a0, a1 = fmap.h01_coefs[0], fmap.h01_coefs[1]
        feats.append(jnp.full((xf.shape[0], 1), jnp.sqrt(a0), dtype=jnp.float32))
        feats.append(jnp.sqrt(a1) * xf.astype(jnp.float32))
    if fmap.const is not None:
        feats.append(jnp.broadcast_to(fmap.const, (xf.shape[0], 1)).astype(jnp.float32))
    for deg, cnt, omega, scale in zip(fmap.degrees, fmap.counts, fmap.omegas,
                                      fmap.scales):
        feats.append(
            rm_feature_bucket(
                xf, omega, deg, float(scale), use_pallas=use_pallas,
                interpret=interpret,
            )
        )
    z = jnp.concatenate(feats, axis=-1)
    return z.reshape(*batch_shape, z.shape[-1])
