from repro.kernels.rm_feature.ops import (
    apply_feature_map,
    apply_feature_map_bucketed,
    rm_feature_bucket,
    rm_feature_fused,
)
from repro.kernels.rm_feature.ref import (
    rm_feature_bucket_ref,
    rm_feature_fused_ref,
)

__all__ = [
    "apply_feature_map",
    "apply_feature_map_bucketed",
    "rm_feature_bucket",
    "rm_feature_fused",
    "rm_feature_bucket_ref",
    "rm_feature_fused_ref",
]
