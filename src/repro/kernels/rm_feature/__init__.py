from repro.kernels.rm_feature.ops import apply_feature_map, rm_feature_bucket

__all__ = ["apply_feature_map", "rm_feature_bucket"]
