"""Pallas TPU kernel: fused Random-Maclaurin feature bucket.

Computes, for a degree-n bucket of ``F`` features,

    out[b, f] = scale * prod_{j < n} <omega[f, j, :], x[b, :]>

as n back-to-back MXU matmuls with the running product held in VMEM —
one HBM read of x / omega, one HBM write of the output tile. This is the
TPU-native replacement for the paper's per-feature loop (DESIGN.md §3).

Tiling: grid (B/bm, F/bf); x tile [bm, d] and omega tile [n, bf, d] live in
VMEM; the MXU sees [bm, d] x [d, bf] per product step. d is kept whole inside
the block (RM attention uses d = d_head <= 256; the SVM path pads d to a
multiple of 128). ``ops.py`` chooses bm/bf so the VMEM working set
(bm*d + n*bf*d + 2*bm*bf floats) stays under the budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rm_feature_kernel(x_ref, w_ref, o_ref, *, degree: int, scale: float):
    x = x_ref[...].astype(jnp.float32)            # [bm, d]
    acc = None
    for j in range(degree):
        w = w_ref[j].astype(jnp.float32)          # [bf, d]
        pj = jax.lax.dot_general(
            x, w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                         # [bm, bf]
        acc = pj if acc is None else acc * pj
    o_ref[...] = (acc * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("degree", "scale", "block_b", "block_f", "interpret"),
)
def rm_feature_bucket_pallas(
    x: jax.Array,        # [B, d]   (B, d already padded by ops.py)
    omega: jax.Array,    # [degree, F, d]
    *,
    degree: int,
    scale: float,
    block_b: int = 256,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:          # [B, F] float32
    b, d = x.shape
    f = omega.shape[1]
    assert b % block_b == 0 and f % block_f == 0, (b, f, block_b, block_f)
    grid = (b // block_b, f // block_f)
    return pl.pallas_call(
        functools.partial(_rm_feature_kernel, degree=degree, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((degree, block_f, d), lambda i, j: (0, j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        interpret=interpret,
    )(x, omega)
