"""Pallas TPU kernels for Random-Maclaurin feature maps.

Two kernels (DESIGN.md §3):

``rm_feature_fused_pallas`` — the whole map in ONE launch. Inputs follow the
``FeaturePlan`` packed layout: ``w [max_degree, F, d]`` holds every column's
product slots (const columns use none, the H0/1 identity block uses slot 0,
degree-n columns use slots 0..n-1), ``col_deg [F]`` is each column's product
depth and ``col_scale [F]`` its final scale. Per (batch, feature) tile the
kernel runs a masked running product

    acc <- 1;  for j < max(col_deg in tile):  acc <- where(j < deg, acc * x W_j^T, acc)

as back-to-back MXU matmuls with the accumulator held in VMEM — one HBM read
of x, one of w, one HBM write of the output tile, no per-bucket relaunch and
no final concatenate. The loop bound is the max depth of the *tile*, not the
global max: columns are laid out in ascending degree order, so low-degree
tiles exit after their own depth (this is where the fused kernel beats the
per-bucket path even on FLOPs).

``rm_feature_bucket_pallas`` — the legacy single-bucket kernel (one launch
per degree). Kept as the comparison baseline for tests and
``benchmarks/rm_feature_bench.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# fused whole-map kernel
# ---------------------------------------------------------------------------
def _rm_fused_kernel(x_ref, w_ref, deg_ref, scale_ref, o_ref):
    # x/w stay in their STORED dtype (fp32 or bf16 under the bf16 precision
    # policy) — the MXU operands are native, while every dot carries
    # preferred_element_type=float32 and the running product accumulates in
    # an fp32 VMEM buffer. bf16-in / fp32-accum, never bf16 accumulation.
    x = x_ref[...]                                # [bm, d]
    deg = deg_ref[...]                            # [1, bf] int32
    bm = x.shape[0]
    bf = deg.shape[-1]

    def step(j, acc):
        w = pl.load(w_ref, (pl.ds(j, 1), slice(None), slice(None)))
        w = w.reshape(w.shape[1], w.shape[2])     # [bf, d]
        pj = jax.lax.dot_general(
            x, w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                         # [bm, bf]
        return jnp.where(j < deg, acc * pj, acc)

    depth = jnp.max(deg)                          # tile-local product depth
    acc = jax.lax.fori_loop(0, depth, step, jnp.ones((bm, bf), jnp.float32))
    o_ref[...] = (acc * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_f", "interpret")
)
def rm_feature_fused_pallas(
    x: jax.Array,          # [B, d]              (B pre-padded to block_b)
    w: jax.Array,          # [max_degree, F, d]  (F pre-padded to block_f)
    col_deg: jax.Array,    # [F] int32           (padding columns: 0)
    col_scale: jax.Array,  # [F] float32         (padding columns: 0)
    *,
    block_b: int = 256,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:            # [B, F] float32
    b, d = x.shape
    k, f, _ = w.shape
    assert b % block_b == 0 and f % block_f == 0, (b, f, block_b, block_f)
    grid = (b // block_b, f // block_f)
    return pl.pallas_call(
        _rm_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_f, d), lambda i, j: (0, j, 0)),
            pl.BlockSpec((1, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_f), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        interpret=interpret,
    )(x, w, col_deg.reshape(1, f), col_scale.reshape(1, f))


# ---------------------------------------------------------------------------
# legacy per-bucket kernel (comparison baseline)
# ---------------------------------------------------------------------------
def _rm_feature_kernel(x_ref, w_ref, o_ref, *, degree: int, scale: float):
    x = x_ref[...]                                # [bm, d] native dtype
    acc = None
    for j in range(degree):
        w = w_ref[j]                              # [bf, d]
        pj = jax.lax.dot_general(
            x, w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                         # [bm, bf]
        acc = pj if acc is None else acc * pj
    o_ref[...] = (acc * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("degree", "scale", "block_b", "block_f", "interpret"),
)
def rm_feature_bucket_pallas(
    x: jax.Array,        # [B, d]   (B, d already padded by ops.py)
    omega: jax.Array,    # [degree, F, d]
    *,
    degree: int,
    scale: float,
    block_b: int = 256,
    block_f: int = 128,
    interpret: bool = False,
) -> jax.Array:          # [B, F] float32
    b, d = x.shape
    f = omega.shape[1]
    assert b % block_b == 0 and f % block_f == 0, (b, f, block_b, block_f)
    grid = (b // block_b, f // block_f)
    return pl.pallas_call(
        functools.partial(_rm_feature_kernel, degree=degree, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i, j: (i, 0)),
            pl.BlockSpec((degree, block_f, d), lambda i, j: (0, j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_f), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        interpret=interpret,
    )(x, omega)
