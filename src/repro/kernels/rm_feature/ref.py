"""Pure-jnp oracles for the Random Maclaurin feature kernels.

``rm_feature_fused_ref`` mirrors the fused Pallas kernel over the
``FeaturePlan`` packed layout (DESIGN.md §3): column f of the output is

    z[b, f] = col_scale[f] * prod_{j < col_deg[f]} <w[j, f, :], x[b, :]>

— const columns (depth 0) reduce to their scale, the H0/1 identity block is
depth 1 with one-hot rows, degree-n buckets are depth n. This is the
``use_pallas=False`` parity path used by ``RMFeatureMap.__call__`` and
``apply_plan`` off-TPU.

``rm_feature_bucket_ref`` is the legacy single-degree oracle: ``omega`` holds
``count * degree`` Rademacher rows; feature i is
``scale * prod_{j<degree} <omega[i*degree+j], x>``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rm_feature_fused_ref(
    x: jax.Array,          # [B, d]
    w: jax.Array,          # [max_degree, F, d]
    col_deg: jax.Array,    # [F] int32
    col_scale: jax.Array,  # [F]
    accum_dtype=jnp.float32,
) -> jax.Array:            # [B, F]
    k = w.shape[0]
    xf = x.astype(accum_dtype)
    proj = jnp.einsum("bd,kfd->kbf", xf, w.astype(accum_dtype))
    mask = jnp.arange(k)[:, None, None] < col_deg[None, None, :]
    prod = jnp.prod(jnp.where(mask, proj, 1.0), axis=0)        # [B, F]
    return prod * col_scale.astype(accum_dtype)


def rm_feature_bucket_ref(
    x: jax.Array,          # [B, d]
    omega: jax.Array,      # [count * degree, d]
    degree: int,
    scale: float,
    accum_dtype=jnp.float32,
) -> jax.Array:            # [B, count]
    if degree < 1:
        raise ValueError("bucket oracle handles degree >= 1")
    count = omega.shape[0] // degree
    proj = x.astype(accum_dtype) @ omega.astype(accum_dtype).T  # [B, count*degree]
    proj = proj.reshape(x.shape[0], count, degree)
    return jnp.prod(proj, axis=-1) * jnp.asarray(scale, accum_dtype)
