"""Pure-jnp oracle for the Random Maclaurin feature bucket.

A "bucket" is the set of all features sharing one degree n (DESIGN.md §3):
``omega`` holds ``count * degree`` Rademacher rows; feature i is
``scale * prod_{j<degree} <omega[i*degree+j], x>``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rm_feature_bucket_ref(
    x: jax.Array,          # [B, d]
    omega: jax.Array,      # [count * degree, d]
    degree: int,
    scale: float,
    accum_dtype=jnp.float32,
) -> jax.Array:            # [B, count]
    if degree < 1:
        raise ValueError("bucket oracle handles degree >= 1")
    count = omega.shape[0] // degree
    proj = x.astype(accum_dtype) @ omega.astype(accum_dtype).T  # [B, count*degree]
    proj = proj.reshape(x.shape[0], count, degree)
    return jnp.prod(proj, axis=-1) * jnp.asarray(scale, accum_dtype)
