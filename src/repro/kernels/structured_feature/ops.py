"""jit'd public wrapper around the fused structured Pallas kernel.

``structured_feature_fused`` applies the whole padded random section of a
``StructuredPlan`` (packed layout, ``repro.structured.plan
.pack_structured``) in one Pallas launch: it pads (batch, stack) to
VMEM-budgeted tiles — feature tiles are whole d_pad-column stacks, so the
generic block ladder's feature width is snapped down to a stack multiple —
and falls back to the pure-jnp mirror
(``repro.structured.ref.structured_feature_fused_ref``) when Pallas is off
or the plan has no random columns.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret as _default_interpret
from repro.kernels.common import get_feature_blocks as _get_blocks
from repro.kernels.common import round_up as _round_up
from repro.kernels.structured_feature.structured_feature import (
    structured_feature_fused_pallas,
)
from repro.obs.trace import kernel_scope as _kernel_scope
from repro.structured.ref import structured_feature_fused_ref


def structured_feature_fused(
    x: jax.Array,          # [..., d_pad] (zero-padded to the Hadamard size)
    d1: jax.Array,         # [max_degree, S, d_pad]  (pack_structured)
    d2: jax.Array,         # [max_degree, S, d_pad]
    col_deg: jax.Array,    # [S * d_pad] int32 per-column product depth
    col_scale: jax.Array,  # [S * d_pad] per-column scale (0 on surplus)
    *,
    use_pallas: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[tuple] = None,
) -> jax.Array:            # [..., S * d_pad] float32
    """Apply the packed structured buckets: one Pallas launch, every column.

    SPMD-safe (no host callbacks, shape-static tiling): usable inside a
    ``shard_map`` body, where the sharded estimator path runs one launch
    per feature shard over that shard's ``[max_degree, S/shards, d_pad]``
    slice of the packed tensors (tests/dist_scripts/
    run_sharded_estimators.py checks interpret-mode parity under shard_map
    for every registry entry).

    ``x``/``d1``/``d2`` enter the launch in their incoming dtype (bf16
    under the mixed precision policy); the accumulator is fp32.
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    m = x.shape[-1]
    k, s, _ = d1.shape
    cols = s * m
    xf = x.reshape(-1, m)
    if xf.shape[0] == 0:   # degenerate row chunk: skip the padded launch
        return jnp.zeros((*batch_shape, cols), jnp.float32)
    if not use_pallas or k == 0 or s == 0:
        out = structured_feature_fused_ref(xf, d1, d2, col_deg, col_scale)
        return out.reshape(*batch_shape, cols)

    b = xf.shape[0]
    # TWO packed sign tensors; the fp32 live set per tile is the
    # accumulator plus the WHT intermediate and the output buffer
    bm, bf = blocks or _get_blocks("structured_feature", m, k, b, cols,
                                   dtype=x.dtype, weight_tensors=2,
                                   accumulators=4)
    # feature tiles must cover WHOLE stacks: snap the ladder width down to
    # a multiple of d_pad (never below one stack)
    bf = max(m, bf - bf % m)
    bs = bf // m
    with _kernel_scope("structured_feature", x=x,
                       cost=dict(batch=b, d=m, depth=k, f=cols,
                                 itemsize=jnp.dtype(x.dtype).itemsize),
                       blocks=[bm, bf], interpret=bool(interpret)):
        b_pad = _round_up(max(b, bm), bm)
        s_pad = _round_up(max(s, bs), bs)
        xp = jnp.pad(xf, ((0, b_pad - b), (0, 0)))
        ps = s_pad - s
        d1p = jnp.pad(d1, ((0, 0), (0, ps), (0, 0)))
        d2p = jnp.pad(d2, ((0, 0), (0, ps), (0, 0)))
        # padding stacks: depth 0 keeps the accumulator at 1; zero scales
        # make every pad column exactly 0 before the slice.
        deg_p = jnp.pad(col_deg.astype(jnp.int32), ((0, ps * m),))
        scale_p = jnp.pad(col_scale.astype(jnp.float32), ((0, ps * m),))
        out = structured_feature_fused_pallas(
            xp, d1p, d2p, deg_p, scale_p,
            block_b=bm, block_s=bs, interpret=interpret,
        )[:b, :cols]
    return out.reshape(*batch_shape, cols)
