from repro.kernels.structured_feature.ops import structured_feature_fused
from repro.kernels.structured_feature.structured_feature import (
    structured_feature_fused_pallas,
)

__all__ = ["structured_feature_fused", "structured_feature_fused_pallas"]
