"""Pallas TPU kernel for fused structured (Hadamard) feature application.

``structured_feature_fused_pallas`` applies every degree bucket of a
``StructuredPlan`` in ONE launch (DESIGN.md §15): a masked running product
over degree slots — the ``rm_feature_fused`` loop — where slot j's
projection is not an MXU matmul against drawn rows but the in-VMEM
butterfly Walsh-Hadamard transform of the diagonally-signed input,

    P_j = reshape( d2_j ∘ WHT( d1_j ∘ x ) ),

computed per (batch, stack) tile in O(d_pad log d_pad) adds on the VPU —
the sublinear-time structure of Choromanski & Sindhwani (2016). The
butterfly matches the SYLVESTER Hadamard order exactly (the dense-matmul
oracle in ``repro.structured.ref`` is the ground truth), unrolling
log2(d_pad) reshape+concat stages at trace time.

The grid tiles (batch, stack): each feature tile covers ``block_s`` whole
stacks of ``d_pad`` columns, so the signed transforms broadcast cleanly and
the per-column degree/scale metadata stays a flat ``[1, block_s * d_pad]``
row. Columns are laid out in ascending degree order, so each tile's loop
exits at the TILE's max depth, not the global one. The accumulator is an
fp32 VMEM buffer; bf16 inputs are widened once on load (bf16-in /
fp32-accum, same policy as the other feature kernels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wht(v: jax.Array) -> jax.Array:
    """Butterfly Walsh-Hadamard transform along the last axis (length a
    power of two, static): Sylvester order, unnormalized (+-1 entries).
    Unrolls at trace time — log2(m) reshape/concat stages."""
    bm, bs, m = v.shape
    h = 1
    while h < m:
        v = v.reshape(bm, bs, m // (2 * h), 2, h)
        a = v[:, :, :, 0, :]
        b = v[:, :, :, 1, :]
        v = jnp.concatenate([a + b, a - b], axis=-1).reshape(bm, bs, m)
        h *= 2
    return v


def _structured_fused_kernel(x_ref, d1_ref, d2_ref, deg_ref, scale_ref,
                             o_ref):
    # Widen once on load: the WHT is pure adds/subs, so fp32 intermediates
    # keep the running product exactly fp32-accumulated under bf16 inputs.
    x = x_ref[...].astype(jnp.float32)            # [bm, m]
    deg = deg_ref[...]                            # [1, bs * m] int32
    k, bs, m = d1_ref.shape
    bm = x.shape[0]

    def step(j, acc):
        d1 = pl.load(d1_ref, (pl.ds(j, 1), slice(None), slice(None)))
        d1 = d1.reshape(bs, m).astype(jnp.float32)
        d2 = pl.load(d2_ref, (pl.ds(j, 1), slice(None), slice(None)))
        d2 = d2.reshape(bs, m).astype(jnp.float32)
        u = x[:, None, :] * d1[None]              # [bm, bs, m]
        v = _wht(u) * d2[None]
        p = v.reshape(bm, bs * m)
        keep = j < deg
        return jnp.where(keep, acc * p, acc)

    depth = jnp.max(deg)                          # tile-local product depth
    acc = jax.lax.fori_loop(
        0, depth, step, jnp.ones((bm, bs * m), jnp.float32)
    )
    scale = scale_ref[...].astype(jnp.float32)
    o_ref[...] = (acc * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_s", "interpret")
)
def structured_feature_fused_pallas(
    x: jax.Array,          # [B, d_pad]          (B pre-padded to block_b)
    d1: jax.Array,         # [max_degree, S, d_pad]  (S pre-padded to block_s)
    d2: jax.Array,         # [max_degree, S, d_pad]
    col_deg: jax.Array,    # [S * d_pad] int32   (padding stacks: 0)
    col_scale: jax.Array,  # [S * d_pad] float32 (padding stacks: 0)
    *,
    block_b: int = 256,
    block_s: int = 8,
    interpret: bool = False,
) -> jax.Array:            # [B, S * d_pad] float32
    """One launch over (batch, stack) tiles; feature tiles are whole stacks.

    ``col_deg``/``col_scale`` are per PADDED column (``S * d_pad`` entries,
    stack-major) — the ops-layer wrapper builds them from the plan and
    slices off both the pad stacks and each bucket's surplus columns after
    the launch, keeping the kernel free of bucket bookkeeping.
    """
    b, m = x.shape
    k, s, _ = d1.shape
    assert b % block_b == 0 and s % block_s == 0, (b, s, block_b, block_s)
    grid = (b // block_b, s // block_s)
    return pl.pallas_call(
        _structured_fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_s, m), lambda i, j: (0, j, 0)),
            pl.BlockSpec((k, block_s, m), lambda i, j: (0, j, 0)),
            pl.BlockSpec((1, block_s * m), lambda i, j: (0, j)),
            pl.BlockSpec((1, block_s * m), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_s * m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, s * m), jnp.float32),
        interpret=interpret,
    )(x, d1, d2, col_deg.reshape(1, s * m), col_scale.reshape(1, s * m))
