"""Public jit'd RM-attention ops: causal (chunked Pallas forward + custom
VJP), non-causal (pure matmul), and the O(1)-state decode step.

The Pallas kernel has no automatic VJP, so ``rm_attention_causal`` is a
``jax.custom_vjp``: the forward runs the Pallas kernel, the backward
differentiates ``_causal_chunked_jnp`` — an algebraically identical chunked
formulation whose peak memory is O(T * chunk) instead of O(T^2).

Shapes use [B, H, T, F] features and [B, H, T, dv] values throughout.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rm_attention.fused import (
    rm_fused_apply_pallas,
    rm_fused_attention_pallas,
    rm_fused_state_pallas,
)
from repro.kernels.rm_attention.ref import (
    _clamp_den,
    rm_attention_decode_ref,
    rm_attention_ref,
)
from repro.kernels.rm_attention.rm_attention import rm_attention_chunked_pallas
from repro.obs.trace import kernel_scope as _kernel_scope


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _chunk_states(zk_p, v_p, chunk):
    """Per-chunk key states + exclusive prefixes. zk_p: [B,H,T,F] padded."""
    b, h, t, f = zk_p.shape
    dv = v_p.shape[-1]
    n = t // chunk
    zk_c = zk_p.reshape(b, h, n, chunk, f).astype(jnp.float32)
    v_c = v_p.reshape(b, h, n, chunk, dv).astype(jnp.float32)
    s_chunk = jnp.einsum("bhncf,bhncd->bhnfd", zk_c, v_c)
    n_chunk = jnp.sum(zk_c, axis=3)
    s_prev = jnp.cumsum(s_chunk, axis=2) - s_chunk
    n_prev = jnp.cumsum(n_chunk, axis=2) - n_chunk
    return zk_c, v_c, s_prev, n_prev


def _pad_t(x, pad):
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


def _causal_chunked_jnp(zq, zk, v, chunk: int, eps: float):
    """Differentiable chunk-parallel causal linear attention (XLA path)."""
    b, h, t, f = zq.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    pad = _round_up(t, chunk) - t
    zq_p, zk_p, v_p = _pad_t(zq, pad), _pad_t(zk, pad), _pad_t(v, pad)
    n = (t + pad) // chunk
    zq_c = zq_p.reshape(b, h, n, chunk, f).astype(jnp.float32)
    zk_c, v_c, s_prev, n_prev = _chunk_states(zk_p, v_p, chunk)

    scores = jnp.einsum("bhnqf,bhnkf->bhnqk", zq_c, zk_c)
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    scores = jnp.where(mask, scores, 0.0)
    num = jnp.einsum("bhnqk,bhnkd->bhnqd", scores, v_c)
    num += jnp.einsum("bhnqf,bhnfd->bhnqd", zq_c, s_prev)
    den = jnp.sum(scores, axis=-1)
    den += jnp.einsum("bhnqf,bhnf->bhnq", zq_c, n_prev)
    den = _clamp_den(den, eps)
    out = num / den[..., None]
    return out.reshape(b, h, t + pad, dv)[:, :, :t]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _causal_pallas(zq, zk, v, chunk: int, eps: float, interpret: bool):
    b, h, t, f = zq.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    pad = _round_up(t, chunk) - t
    zq_p, zk_p, v_p = _pad_t(zq, pad), _pad_t(zk, pad), _pad_t(v, pad)
    n = (t + pad) // chunk
    _, _, s_prev, n_prev = _chunk_states(zk_p, v_p, chunk)
    with _kernel_scope("rm_attention", x=zq, chunk=chunk,
                       interpret=bool(interpret)):
        out = rm_attention_chunked_pallas(
            zq_p.reshape(b * h, t + pad, f),
            zk_p.reshape(b * h, t + pad, f),
            v_p.reshape(b * h, t + pad, dv),
            s_prev.reshape(b * h, n, f, dv),
            n_prev.reshape(b * h, n, f, 1),
            chunk=chunk,
            eps=eps,
            interpret=interpret,
        )
    return out.reshape(b, h, t + pad, dv)[:, :, :t]


def _causal_pallas_fwd(zq, zk, v, chunk, eps, interpret):
    return _causal_pallas(zq, zk, v, chunk, eps, interpret), (zq, zk, v)


def _causal_pallas_bwd(chunk, eps, interpret, res, g):
    zq, zk, v = res
    _, vjp = jax.vjp(
        lambda a, b_, c: _causal_chunked_jnp(a, b_, c, chunk, eps), zq, zk, v
    )
    return vjp(g.astype(jnp.float32))


_causal_pallas.defvjp(_causal_pallas_fwd, _causal_pallas_bwd)


def rm_attention_causal(
    zq: jax.Array,
    zk: jax.Array,
    v: jax.Array,
    *,
    chunk: int = 128,
    eps: float = 1e-4,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Causal linear attention, O(T * F * (C + dv)) work vs exact O(T^2 * dv).

    Pallas forward with a chunked-XLA custom VJP. ``use_pallas`` defaults to
    True on TPU and False elsewhere: interpret-mode Pallas unrolls the grid
    into the HLO, which is fine for kernel tests but would bloat dry-run
    compiles (tests opt in explicitly with use_pallas=True, interpret=True).
    """
    from repro.kernels.common import default_interpret

    if use_pallas is None:
        use_pallas = not default_interpret()
    if interpret is None:
        interpret = default_interpret()
    if not use_pallas:
        return _causal_chunked_jnp(zq, zk, v, chunk, eps)
    return _causal_pallas(zq, zk, v, chunk, eps, interpret)


def rm_attention_noncausal(
    zq: jax.Array,
    zk: jax.Array,
    v: jax.Array,
    *,
    eps: float = 1e-4,
) -> jax.Array:
    """Bidirectional linear attention: two GEMMs, no kernel needed."""
    zq = zq.astype(jnp.float32)
    zk = zk.astype(jnp.float32)
    v = v.astype(jnp.float32)
    s = jnp.einsum("bhsf,bhsd->bhfd", zk, v)           # [B,H,F,dv]
    n = jnp.sum(zk, axis=2)                            # [B,H,F]
    num = jnp.einsum("bhtf,bhfd->bhtd", zq, s)
    den = _clamp_den(jnp.einsum("bhtf,bhf->bht", zq, n), eps)
    return num / den[..., None]


def rm_attention_decode_step(
    zq: jax.Array,       # [B, H, F]
    zk: jax.Array,       # [B, H, F]
    v: jax.Array,        # [B, H, dv]
    state_s: jax.Array,  # [B, H, F, dv]
    state_n: jax.Array,  # [B, H, F]
    *,
    eps: float = 1e-4,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """O(1)-memory decode: rank-1 state update + two GEMVs.

    This is what replaces the growing KV cache for `long_500k` decoding.
    """
    return rm_attention_decode_ref(zq, zk, v, state_s, state_n, eps=eps)


def rm_attention_prefill_final_state(
    zk: jax.Array, v: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """States after consuming a whole prefix (to switch prefill->decode)."""
    s = jnp.einsum("bhsf,bhsd->bhfd", zk.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = jnp.sum(zk.astype(jnp.float32), axis=2)
    return s, n


# ===========================================================================
# Fused featurize+attention (DESIGN.md §13)
#
# The ops below take RAW (pre-scaled) q/k plus the packed RM layout
# (``w [max_degree, F, d]``, per-column degrees and scales from
# ``core.plan``) instead of pre-featurized Z — featurization happens inside
# the attention kernel's VMEM tiles, so the O(T * F) Z tensors never touch
# HBM. Numerically they match the two-launch composition
# ``rm_attention_*(rm_feature_fused(q), rm_feature_fused(k) * kvalid, v)``
# exactly in structure (same fp32 accumulation order per tile), so parity
# holds at 1e-5.
#
# ``col_deg``/``col_scale`` must be HOST constants (numpy, from
# ``plan.column_degrees()`` / ``plan.column_scales()``): they ride through
# ``jax.custom_vjp`` as static hashable tuples, which sidesteps the
# integer-cotangent (float0) bookkeeping a traced int32 operand would need.
# ===========================================================================
def _static_cols(col_deg, col_scale) -> Tuple[Tuple[int, ...],
                                              Tuple[float, ...]]:
    if isinstance(col_deg, tuple) and isinstance(col_scale, tuple):
        return col_deg, col_scale
    return (tuple(int(x) for x in np.asarray(col_deg)),
            tuple(float(x) for x in np.asarray(col_scale)))


def _featurize_ref4(x, w, deg, scale):
    """Differentiable featurize over [B, H, T, d] via the rm_feature ref."""
    from repro.kernels.rm_feature.ref import rm_feature_fused_ref

    b, h, t, d = x.shape
    z = rm_feature_fused_ref(x.reshape(b * h * t, d), w, deg, scale)
    return z.reshape(b, h, t, -1)


def _fused_causal_jnp(q, k, v, kvalid, w, deg, scale, chunk: int,
                      eps: float):
    """jnp oracle AND backward-pass formulation of the fused causal op."""
    zq = _featurize_ref4(q, w, deg, scale)
    zk = _featurize_ref4(k, w, deg, scale) * kvalid[:, None, :, None]
    return _causal_chunked_jnp(zq, zk, v, chunk, eps)


def _fused_noncausal_jnp(q, k, v, kvalid, w, deg, scale, eps: float):
    zq = _featurize_ref4(q, w, deg, scale)
    zk = _featurize_ref4(k, w, deg, scale) * kvalid[:, None, :, None]
    return rm_attention_noncausal(zq, zk, v, eps=eps)


def _fused_pad(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f):
    """Pad T to the chunk multiple and F to the feature-block multiple.

    Padded feature columns get degree 0 / scale 0, so their running product
    collapses to ``1 * 0 = 0`` and they contribute nothing to scores or
    state. Padded key rows are zeroed through ``kvalid``.
    """
    b, h, t, d = q.shape
    f = w.shape[1]
    chunk = max(1, min(chunk, _round_up(t, 8)))
    bf = max(1, min(block_f, _round_up(f, 8)))
    tp = _round_up(t, chunk)
    f_pad = _round_up(f, bf)
    q_p, k_p, v_p = _pad_t(q, tp - t), _pad_t(k, tp - t), _pad_t(v, tp - t)
    kval = jnp.pad(kvalid.astype(jnp.float32), ((0, 0), (0, tp - t)))
    kval3 = jnp.broadcast_to(kval[:, None, :], (b, h, tp))
    w_p = jnp.pad(w, ((0, 0), (0, f_pad - f), (0, 0)))
    deg = jnp.asarray(deg_t + (0,) * (f_pad - f), jnp.int32)
    scale = jnp.asarray(scale_t + (0.0,) * (f_pad - f), jnp.float32)
    dv = v.shape[-1]
    return (q_p.reshape(b * h, tp, d), k_p.reshape(b * h, tp, d),
            v_p.reshape(b * h, tp, dv), kval3.reshape(b * h, tp, 1),
            w_p, deg, scale, chunk, bf, tp, f_pad)


def _fused_causal_launch(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f,
                         eps, interpret):
    """Pallas causal launch; returns (out, s_final, n_final) cropped."""
    b, h, t, d = q.shape
    dv = v.shape[-1]
    f = w.shape[1]
    (qf, kf, vf, kval3, w_p, deg, scale, chunk, bf, tp,
     f_pad) = _fused_pad(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f)
    with _kernel_scope("rm_attn_fused", x=q,
                       cost=dict(batch=b * h, t=t, d=d, depth=w.shape[0],
                                 f=f, dv=dv,
                                 itemsize=jnp.dtype(q.dtype).itemsize),
                       blocks=[chunk, bf], interpret=bool(interpret)):
        out, s, n = rm_fused_attention_pallas(
            qf, kf, vf, kval3, w_p, deg, scale,
            chunk=chunk, block_f=bf, eps=eps, interpret=interpret)
    return (out.reshape(b, h, tp, dv)[:, :, :t],
            s.reshape(b, h, f_pad, dv)[:, :, :f],
            n.reshape(b, h, f_pad)[:, :, :f])


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def _fused_causal(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f, eps,
                  interpret):
    out, _, _ = _fused_causal_launch(q, k, v, kvalid, w, deg_t, scale_t,
                                     chunk, block_f, eps, interpret)
    return out


def _fused_causal_fwd(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f,
                      eps, interpret):
    out = _fused_causal(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f,
                        eps, interpret)
    return out, (q, k, v, kvalid, w)


def _fused_causal_bwd(deg_t, scale_t, chunk, block_f, eps, interpret, res,
                      g):
    q, k, v, kvalid, w = res
    deg = jnp.asarray(deg_t, jnp.int32)
    scale = jnp.asarray(scale_t, jnp.float32)
    _, vjp = jax.vjp(
        lambda a, b_, c, kv, ww: _fused_causal_jnp(a, b_, c, kv, ww, deg,
                                                   scale, chunk, eps),
        q, k, v, kvalid, w)
    return vjp(g.astype(jnp.float32))


_fused_causal.defvjp(_fused_causal_fwd, _fused_causal_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def _fused_noncausal(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f,
                     eps, interpret):
    b, h, t, d = q.shape
    dv = v.shape[-1]
    (qf, kf, vf, kval3, w_p, deg, scale, chunk, bf, tp,
     f_pad) = _fused_pad(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f)
    with _kernel_scope("rm_attn_fused", x=q, mode="noncausal",
                       cost=dict(batch=b * h, t=t, d=d, depth=w.shape[0],
                                 f=w.shape[1], dv=dv,
                                 itemsize=jnp.dtype(q.dtype).itemsize),
                       blocks=[chunk, bf], interpret=bool(interpret)):
        s, n = rm_fused_state_pallas(kf, vf, kval3, w_p, deg, scale,
                                     chunk=chunk, block_f=bf,
                                     interpret=interpret)
        out = rm_fused_apply_pallas(qf, s, n, w_p, deg, scale, chunk=chunk,
                                    block_f=bf, eps=eps, interpret=interpret)
    return out.reshape(b, h, tp, dv)[:, :, :t]


def _fused_noncausal_fwd(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f,
                         eps, interpret):
    out = _fused_noncausal(q, k, v, kvalid, w, deg_t, scale_t, chunk,
                           block_f, eps, interpret)
    return out, (q, k, v, kvalid, w)


def _fused_noncausal_bwd(deg_t, scale_t, chunk, block_f, eps, interpret,
                         res, g):
    q, k, v, kvalid, w = res
    deg = jnp.asarray(deg_t, jnp.int32)
    scale = jnp.asarray(scale_t, jnp.float32)
    _, vjp = jax.vjp(
        lambda a, b_, c, kv, ww: _fused_noncausal_jnp(a, b_, c, kv, ww, deg,
                                                      scale, eps),
        q, k, v, kvalid, w)
    return vjp(g.astype(jnp.float32))


_fused_noncausal.defvjp(_fused_noncausal_fwd, _fused_noncausal_bwd)


def _fused_defaults(q, w, kvalid, chunk, block_f, use_pallas, interpret):
    from repro.kernels.common import default_interpret, get_attention_blocks

    if use_pallas is None:
        use_pallas = not default_interpret()
    if interpret is None:
        interpret = default_interpret()
    if kvalid is None:
        kvalid = jnp.ones((q.shape[0], q.shape[2]), jnp.float32)
    if chunk is None or block_f is None:
        sel_chunk, sel_bf = get_attention_blocks(
            "rm_attn_fused", d=q.shape[-1], depth=w.shape[0],
            t=q.shape[2], f=w.shape[1], dv=0, dtype=q.dtype)
        chunk = sel_chunk if chunk is None else chunk
        block_f = sel_bf if block_f is None else block_f
    return kvalid, chunk, block_f, use_pallas, interpret


def rm_attention_fused_causal(
    q: jax.Array,          # [B, H, T, d]  pre-scaled queries (NOT features)
    k: jax.Array,          # [B, H, T, d]
    v: jax.Array,          # [B, H, T, dv]
    w: jax.Array,          # [max_degree, F, d] packed omegas (pack_omegas)
    col_deg,               # [F] host int array/tuple (plan.column_degrees())
    col_scale,             # [F] host float array/tuple
    *,
    kvalid: Optional[jax.Array] = None,   # [B, T] 1.0 real / 0.0 padded key
    chunk: Optional[int] = 128,
    block_f: Optional[int] = None,
    eps: float = 1e-4,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused causal RM attention: featurize q/k in VMEM, never write Z.

    Equivalent to ``rm_attention_causal(Z(q), Z(k) * kvalid, v)`` with
    ``Z = rm_feature_fused(.., w, col_deg, col_scale)``; differentiable via
    a chunked-XLA custom VJP (the backward featurizes in XLA — training
    still saves the two forward Z round-trips).
    """
    kvalid, chunk, block_f, use_pallas, interpret = _fused_defaults(
        q, w, kvalid, chunk, block_f, use_pallas, interpret)
    deg_t, scale_t = _static_cols(col_deg, col_scale)
    if q.shape[0] * q.shape[1] == 0 or q.shape[2] == 0:
        return jnp.zeros(v.shape, jnp.float32)
    if not use_pallas or w.shape[0] == 0 or w.shape[1] == 0:
        return _fused_causal_jnp(q, k, v, kvalid, w,
                                 jnp.asarray(deg_t, jnp.int32),
                                 jnp.asarray(scale_t, jnp.float32),
                                 chunk, eps)
    return _fused_causal(q, k, v, kvalid, w, deg_t, scale_t, chunk, block_f,
                         eps, interpret)


def rm_attention_fused_noncausal(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    col_deg,
    col_scale,
    *,
    kvalid: Optional[jax.Array] = None,
    chunk: Optional[int] = 128,
    block_f: Optional[int] = None,
    eps: float = 1e-4,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused bidirectional RM attention (state kernel + apply kernel)."""
    kvalid, chunk, block_f, use_pallas, interpret = _fused_defaults(
        q, w, kvalid, chunk, block_f, use_pallas, interpret)
    deg_t, scale_t = _static_cols(col_deg, col_scale)
    if q.shape[0] * q.shape[1] == 0 or q.shape[2] == 0:
        return jnp.zeros(v.shape, jnp.float32)
    if not use_pallas or w.shape[0] == 0 or w.shape[1] == 0:
        return _fused_noncausal_jnp(q, k, v, kvalid, w,
                                    jnp.asarray(deg_t, jnp.int32),
                                    jnp.asarray(scale_t, jnp.float32), eps)
    return _fused_noncausal(q, k, v, kvalid, w, deg_t, scale_t, chunk,
                            block_f, eps, interpret)


def rm_attention_fused_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,
    col_deg,
    col_scale,
    *,
    kvalid: Optional[jax.Array] = None,
    chunk: Optional[int] = 128,
    block_f: Optional[int] = None,
    eps: float = 1e-4,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused prefill: causal outputs AND the final decode state (S, n) from
    the SAME launch — the causal kernel's state scratch holds exactly the
    whole-prefix state after the last chunk, so prefill->decode handoff
    costs zero extra HBM passes. Serving-only (no VJP)."""
    kvalid, chunk, block_f, use_pallas, interpret = _fused_defaults(
        q, w, kvalid, chunk, block_f, use_pallas, interpret)
    deg_t, scale_t = _static_cols(col_deg, col_scale)
    b, h, t, _ = q.shape
    f, dv = w.shape[1], v.shape[-1]
    if b * h == 0 or t == 0:
        return (jnp.zeros(v.shape, jnp.float32),
                jnp.zeros((b, h, f, dv), jnp.float32),
                jnp.zeros((b, h, f), jnp.float32))
    if not use_pallas or w.shape[0] == 0 or w.shape[1] == 0:
        deg = jnp.asarray(deg_t, jnp.int32)
        scale = jnp.asarray(scale_t, jnp.float32)
        out = _fused_causal_jnp(q, k, v, kvalid, w, deg, scale, chunk, eps)
        zk = _featurize_ref4(k, w, deg, scale) * kvalid[:, None, :, None]
        s, n = rm_attention_prefill_final_state(zk, v)
        return out, s, n
    return _fused_causal_launch(q, k, v, kvalid, w, deg_t, scale_t, chunk,
                                block_f, eps, interpret)


def rm_attention_fused_decode_step(
    q: jax.Array,        # [B, H, d]  pre-scaled query (NOT features)
    k: jax.Array,        # [B, H, d]
    v: jax.Array,        # [B, H, dv]
    state_s: jax.Array,  # [B, H, F, dv]
    state_n: jax.Array,  # [B, H, F]
    w: jax.Array,        # [max_degree, F, d]
    col_deg,
    col_scale,
    *,
    eps: float = 1e-4,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused decode step: ONE featurize launch for q and k together.

    The two-launch decode path featurizes the new query and key separately
    (two ``rm_feature_fused`` launches per generated token). Stacking them
    along the row axis halves the per-token launch count; the O(1) state
    update itself is two GEMVs and stays in XLA.
    """
    from repro.kernels.common import default_interpret
    from repro.kernels.rm_feature.ops import rm_feature_fused

    if use_pallas is None:
        use_pallas = not default_interpret()
    b, h, d = q.shape
    f = w.shape[1]
    x2 = jnp.concatenate([q.reshape(b * h, d), k.reshape(b * h, d)], axis=0)
    z2 = rm_feature_fused(x2, w, jnp.asarray(col_deg, jnp.int32),
                          jnp.asarray(col_scale, jnp.float32),
                          use_pallas=use_pallas, interpret=interpret)
    zq = z2[:b * h].reshape(b, h, f)
    zk = z2[b * h:].reshape(b, h, f)
    return rm_attention_decode_ref(zq, zk, v, state_s, state_n, eps=eps)
