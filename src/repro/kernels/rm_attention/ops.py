"""Public jit'd RM-attention ops: causal (chunked Pallas forward + custom
VJP), non-causal (pure matmul), and the O(1)-state decode step.

The Pallas kernel has no automatic VJP, so ``rm_attention_causal`` is a
``jax.custom_vjp``: the forward runs the Pallas kernel, the backward
differentiates ``_causal_chunked_jnp`` — an algebraically identical chunked
formulation whose peak memory is O(T * chunk) instead of O(T^2).

Shapes use [B, H, T, F] features and [B, H, T, dv] values throughout.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.rm_attention.ref import (
    _clamp_den,
    rm_attention_decode_ref,
    rm_attention_ref,
)
from repro.kernels.rm_attention.rm_attention import rm_attention_chunked_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _chunk_states(zk_p, v_p, chunk):
    """Per-chunk key states + exclusive prefixes. zk_p: [B,H,T,F] padded."""
    b, h, t, f = zk_p.shape
    dv = v_p.shape[-1]
    n = t // chunk
    zk_c = zk_p.reshape(b, h, n, chunk, f).astype(jnp.float32)
    v_c = v_p.reshape(b, h, n, chunk, dv).astype(jnp.float32)
    s_chunk = jnp.einsum("bhncf,bhncd->bhnfd", zk_c, v_c)
    n_chunk = jnp.sum(zk_c, axis=3)
    s_prev = jnp.cumsum(s_chunk, axis=2) - s_chunk
    n_prev = jnp.cumsum(n_chunk, axis=2) - n_chunk
    return zk_c, v_c, s_prev, n_prev


def _pad_t(x, pad):
    return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))


def _causal_chunked_jnp(zq, zk, v, chunk: int, eps: float):
    """Differentiable chunk-parallel causal linear attention (XLA path)."""
    b, h, t, f = zq.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    pad = _round_up(t, chunk) - t
    zq_p, zk_p, v_p = _pad_t(zq, pad), _pad_t(zk, pad), _pad_t(v, pad)
    n = (t + pad) // chunk
    zq_c = zq_p.reshape(b, h, n, chunk, f).astype(jnp.float32)
    zk_c, v_c, s_prev, n_prev = _chunk_states(zk_p, v_p, chunk)

    scores = jnp.einsum("bhnqf,bhnkf->bhnqk", zq_c, zk_c)
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    scores = jnp.where(mask, scores, 0.0)
    num = jnp.einsum("bhnqk,bhnkd->bhnqd", scores, v_c)
    num += jnp.einsum("bhnqf,bhnfd->bhnqd", zq_c, s_prev)
    den = jnp.sum(scores, axis=-1)
    den += jnp.einsum("bhnqf,bhnf->bhnq", zq_c, n_prev)
    den = _clamp_den(den, eps)
    out = num / den[..., None]
    return out.reshape(b, h, t + pad, dv)[:, :, :t]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _causal_pallas(zq, zk, v, chunk: int, eps: float, interpret: bool):
    b, h, t, f = zq.shape
    dv = v.shape[-1]
    chunk = min(chunk, t)
    pad = _round_up(t, chunk) - t
    zq_p, zk_p, v_p = _pad_t(zq, pad), _pad_t(zk, pad), _pad_t(v, pad)
    n = (t + pad) // chunk
    _, _, s_prev, n_prev = _chunk_states(zk_p, v_p, chunk)
    out = rm_attention_chunked_pallas(
        zq_p.reshape(b * h, t + pad, f),
        zk_p.reshape(b * h, t + pad, f),
        v_p.reshape(b * h, t + pad, dv),
        s_prev.reshape(b * h, n, f, dv),
        n_prev.reshape(b * h, n, f, 1),
        chunk=chunk,
        eps=eps,
        interpret=interpret,
    )
    return out.reshape(b, h, t + pad, dv)[:, :, :t]


def _causal_pallas_fwd(zq, zk, v, chunk, eps, interpret):
    return _causal_pallas(zq, zk, v, chunk, eps, interpret), (zq, zk, v)


def _causal_pallas_bwd(chunk, eps, interpret, res, g):
    zq, zk, v = res
    _, vjp = jax.vjp(
        lambda a, b_, c: _causal_chunked_jnp(a, b_, c, chunk, eps), zq, zk, v
    )
    return vjp(g.astype(jnp.float32))


_causal_pallas.defvjp(_causal_pallas_fwd, _causal_pallas_bwd)


def rm_attention_causal(
    zq: jax.Array,
    zk: jax.Array,
    v: jax.Array,
    *,
    chunk: int = 128,
    eps: float = 1e-4,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Causal linear attention, O(T * F * (C + dv)) work vs exact O(T^2 * dv).

    Pallas forward with a chunked-XLA custom VJP. ``use_pallas`` defaults to
    True on TPU and False elsewhere: interpret-mode Pallas unrolls the grid
    into the HLO, which is fine for kernel tests but would bloat dry-run
    compiles (tests opt in explicitly with use_pallas=True, interpret=True).
    """
    from repro.kernels.common import default_interpret

    if use_pallas is None:
        use_pallas = not default_interpret()
    if interpret is None:
        interpret = default_interpret()
    if not use_pallas:
        return _causal_chunked_jnp(zq, zk, v, chunk, eps)
    return _causal_pallas(zq, zk, v, chunk, eps, interpret)


def rm_attention_noncausal(
    zq: jax.Array,
    zk: jax.Array,
    v: jax.Array,
    *,
    eps: float = 1e-4,
) -> jax.Array:
    """Bidirectional linear attention: two GEMMs, no kernel needed."""
    zq = zq.astype(jnp.float32)
    zk = zk.astype(jnp.float32)
    v = v.astype(jnp.float32)
    s = jnp.einsum("bhsf,bhsd->bhfd", zk, v)           # [B,H,F,dv]
    n = jnp.sum(zk, axis=2)                            # [B,H,F]
    num = jnp.einsum("bhtf,bhfd->bhtd", zq, s)
    den = _clamp_den(jnp.einsum("bhtf,bhf->bht", zq, n), eps)
    return num / den[..., None]


def rm_attention_decode_step(
    zq: jax.Array,       # [B, H, F]
    zk: jax.Array,       # [B, H, F]
    v: jax.Array,        # [B, H, dv]
    state_s: jax.Array,  # [B, H, F, dv]
    state_n: jax.Array,  # [B, H, F]
    *,
    eps: float = 1e-4,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """O(1)-memory decode: rank-1 state update + two GEMVs.

    This is what replaces the growing KV cache for `long_500k` decoding.
    """
    return rm_attention_decode_ref(zq, zk, v, state_s, state_n, eps=eps)


def rm_attention_prefill_final_state(
    zk: jax.Array, v: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """States after consuming a whole prefix (to switch prefill->decode)."""
    s = jnp.einsum("bhsf,bhsd->bhfd", zk.astype(jnp.float32),
                   v.astype(jnp.float32))
    n = jnp.sum(zk.astype(jnp.float32), axis=2)
    return s, n
