"""Pallas TPU kernels: FUSED featurize+attention over the packed RM layout.

The two-launch pipeline (``rm_feature_fused`` -> ``rm_attention``) pays an
O(T * F) HBM round-trip for Z(q) and Z(k) between the launches. The math
says we shouldn't: each attention tile only ever needs the slice of
Z it is currently contracting, and that slice is a masked running product
over the packed ``[max_degree, F, d]`` omega tensor (DESIGN.md §3) — cheap
enough to recompute in VMEM. These kernels tile the featurize step INTO the
attention grid, so q/k/v stream from HBM once and Z never leaves VMEM
(DESIGN.md §13).

Three kernels share the in-VMEM featurize helper:

``rm_fused_attention_pallas`` — causal chunked linear attention. Grid
``(BH, nchunks, nfb)`` with the feature-block axis innermost; per program
(b, i, j) it featurizes chunk i of q and k against feature block j (masked
running product, fp32 accumulators per the precision policy), accumulates
the chunk-local score tile ``zq_ij zk_ij^T`` and the cross-chunk
numerator/denominator contributions ``zq_ij S_j`` / ``zq_ij n_j``, then
folds chunk i into the per-feature-block state scratch (``S_j += zk^T v``).
The state scratch persists across the chunk axis (sequential TPU grid), so
the inter-chunk prefix sum that the two-launch path computes in XLA happens
in VMEM for free; the last chunk also emits the final (S, n) — prefill gets
its decode state from the SAME launch.

``rm_fused_state_pallas`` — (k, v) -> final (S, n) only (non-causal
denominators, standalone state builds). Chunk axis innermost so the state
scratch is one ``[BF, dv]`` tile.

``rm_fused_apply_pallas`` — q + (S, n) -> output (the non-causal apply /
a fused one-shot decode over a batch of queries).

VMEM working set of the causal kernel (fp32): 2*C*d (q, k chunk) + C*dv (v)
+ depth*BF*d (w block) + C*C (scores) + C*dv + C (num/den) + F_pad*dv +
F_pad (state scratch, the WHOLE padded feature axis). E.g. C=128, F=256,
d=64, dv=64, depth 4: ~0.45 MB — the state scratch is the new term and
stays tiny because linear-attention state is O(F * dv), not O(T).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _featurize_block(x, w_ref, deg, scale):
    """Z slice for one (rows, feature-block) tile, entirely in registers/VMEM.

    ``x [C, d]`` stays in its stored dtype (bf16 under the mixed policy);
    every dot carries ``preferred_element_type=float32`` and the running
    product accumulates in fp32 — bf16-in / fp32-accum, never bf16
    accumulation (the same contract as ``kernels/rm_feature``).
    """
    c = x.shape[0]
    bf = deg.shape[-1]

    def step(j, acc):
        w = pl.load(w_ref, (pl.ds(j, 1), slice(None), slice(None)))
        w = w.reshape(w.shape[1], w.shape[2])          # [bf, d]
        pj = jax.lax.dot_general(
            x, w,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # [c, bf]
        return jnp.where(j < deg, acc * pj, acc)

    depth = jnp.max(deg)                               # tile-local depth
    acc = jax.lax.fori_loop(0, depth, step, jnp.ones((c, bf), jnp.float32))
    return acc * scale.astype(jnp.float32)


def _clamp(den, eps):
    return jnp.where(jnp.abs(den) < eps, jnp.where(den >= 0, eps, -eps), den)


# ---------------------------------------------------------------------------
# fused causal attention (+ final state)
# ---------------------------------------------------------------------------
def _fused_causal_kernel(q_ref, k_ref, v_ref, kval_ref, w_ref, deg_ref,
                         scale_ref, o_ref, s_ref, n_ref,
                         score_scr, num_scr, den_scr, s_scr, n_scr, *,
                         eps: float, nchunks: int, nfb: int):
    i = pl.program_id(1)                               # chunk
    j = pl.program_id(2)                               # feature block

    # new (batch*head) row: the state scratch restarts from zero. j is
    # innermost, so (i == 0, j == 0) runs before any other cell of this row.
    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _zero_state():
        s_scr[...] = jnp.zeros_like(s_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    # new chunk: reset the per-chunk accumulators.
    @pl.when(j == 0)
    def _zero_chunk():
        score_scr[...] = jnp.zeros_like(score_scr)
        num_scr[...] = jnp.zeros_like(num_scr)
        den_scr[...] = jnp.zeros_like(den_scr)

    deg = deg_ref[...]                                 # [1, bf]
    scale = scale_ref[...]
    zq = _featurize_block(q_ref[0], w_ref, deg, scale)        # [C, bf] f32
    zk = _featurize_block(k_ref[0], w_ref, deg, scale)
    zk = zk * kval_ref[0].astype(jnp.float32)                 # [C, 1] mask

    # chunk-local scores accumulate over feature blocks; the causal mask is
    # feature-independent, so it is applied once at finalize.
    score_scr[...] += jax.lax.dot_general(
        zq, zk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # cross-chunk contribution reads the state BEFORE chunk i is folded in
    # (the state scratch holds chunks < i for this feature block).
    s_j = pl.load(s_scr, (pl.ds(j, 1), slice(None), slice(None)))[0]
    n_j = pl.load(n_scr, (pl.ds(j, 1), slice(None)))           # [1, bf]
    num_scr[...] += jax.lax.dot_general(
        zq, s_j, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    den_scr[...] += jax.lax.dot_general(
        zq, n_j, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    v = v_ref[0].astype(jnp.float32)                   # [C, dv]
    s_new = s_j + jax.lax.dot_general(
        zk, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                  # [bf, dv]
    n_new = n_j + jnp.sum(zk, axis=0, keepdims=True)   # [1, bf]
    pl.store(s_scr, (pl.ds(j, 1), slice(None), slice(None)), s_new[None])
    pl.store(n_scr, (pl.ds(j, 1), slice(None)), n_new)

    # last feature block: mask, combine intra-chunk and carried terms, emit.
    @pl.when(j == nfb - 1)
    def _emit_out():
        c = score_scr.shape[0]
        row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
        scores = jnp.where(row >= col, score_scr[...], 0.0)
        num = num_scr[...] + jax.lax.dot_general(
            scores, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        den = den_scr[...] + jnp.sum(scores, axis=-1, keepdims=True)
        o_ref[0] = (num / _clamp(den, eps)).astype(o_ref.dtype)

    # last chunk: the state scratch now holds the full-prefix (S, n).
    @pl.when(i == nchunks - 1)
    def _emit_state():
        s_ref[0] = s_new.astype(s_ref.dtype)
        n_ref[0] = jnp.transpose(n_new, (1, 0)).astype(n_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_f", "eps", "interpret")
)
def rm_fused_attention_pallas(
    q: jax.Array,          # [BH, T, d]   (T % chunk == 0; pre-scaled inputs)
    k: jax.Array,          # [BH, T, d]
    v: jax.Array,          # [BH, T, dv]
    kvalid: jax.Array,     # [BH, T, 1]   1.0 real key / 0.0 padding
    w: jax.Array,          # [kdeg, F_pad, d] packed omegas (F_pad % block_f == 0)
    col_deg: jax.Array,    # [F_pad] int32  (padding columns: 0)
    col_scale: jax.Array,  # [F_pad] float32 (padding columns: 0)
    *,
    chunk: int,
    block_f: int,
    eps: float = 1e-4,
    interpret: bool = False,
):
    """Causal fused featurize+attention; returns (out, s_final, n_final).

    ``out [BH, T, dv]`` matches the two-launch composition
    ``rm_attention_causal(rm_feature_fused(q), rm_feature_fused(k) * kvalid,
    v)``; ``s_final [BH, F_pad, dv]`` / ``n_final [BH, F_pad, 1]`` are the
    whole-prefix linear-attention state (what
    ``rm_attention_prefill_final_state`` computes) from the same launch.
    """
    bh, t, d = q.shape
    dv = v.shape[-1]
    kdeg, f_pad, _ = w.shape
    assert t % chunk == 0, (t, chunk)
    assert f_pad % block_f == 0, (f_pad, block_f)
    nchunks = t // chunk
    nfb = f_pad // block_f
    grid = (bh, nchunks, nfb)
    kernel = functools.partial(
        _fused_causal_kernel, eps=eps, nchunks=nchunks, nfb=nfb
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, chunk, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((kdeg, block_f, d), lambda b, i, j: (0, j, 0)),
            pl.BlockSpec((1, block_f), lambda b, i, j: (0, j)),
            pl.BlockSpec((1, block_f), lambda b, i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_f, dv), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_f, 1), lambda b, i, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, f_pad, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, f_pad, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((chunk, chunk), jnp.float32),
            pltpu.VMEM((chunk, dv), jnp.float32),
            pltpu.VMEM((chunk, 1), jnp.float32),
            pltpu.VMEM((nfb, block_f, dv), jnp.float32),
            pltpu.VMEM((nfb, block_f), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, kvalid, w, col_deg.reshape(1, f_pad),
      col_scale.reshape(1, f_pad))


# ---------------------------------------------------------------------------
# fused state build: (k, v) -> (S, n)
# ---------------------------------------------------------------------------
def _fused_state_kernel(k_ref, v_ref, kval_ref, w_ref, deg_ref, scale_ref,
                        s_ref, n_ref, s_scr, n_scr, *, nchunks: int):
    i = pl.program_id(2)                               # chunk (innermost)

    @pl.when(i == 0)
    def _zero():
        s_scr[...] = jnp.zeros_like(s_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    zk = _featurize_block(k_ref[0], w_ref, deg_ref[...], scale_ref[...])
    zk = zk * kval_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s_scr[...] += jax.lax.dot_general(
        zk, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_scr[...] += jnp.sum(zk, axis=0, keepdims=True)

    @pl.when(i == nchunks - 1)
    def _emit():
        s_ref[0] = s_scr[...].astype(s_ref.dtype)
        n_ref[0] = jnp.transpose(n_scr[...], (1, 0)).astype(n_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_f", "interpret")
)
def rm_fused_state_pallas(
    k: jax.Array,          # [BH, T, d]
    v: jax.Array,          # [BH, T, dv]
    kvalid: jax.Array,     # [BH, T, 1]
    w: jax.Array,          # [kdeg, F_pad, d]
    col_deg: jax.Array,    # [F_pad] int32
    col_scale: jax.Array,  # [F_pad] float32
    *,
    chunk: int,
    block_f: int,
    interpret: bool = False,
):
    """(S, n) of the whole sequence without materializing Z(k) to HBM."""
    bh, t, d = k.shape
    dv = v.shape[-1]
    kdeg, f_pad, _ = w.shape
    assert t % chunk == 0 and f_pad % block_f == 0
    grid = (bh, f_pad // block_f, t // chunk)
    kernel = functools.partial(_fused_state_kernel, nchunks=t // chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((kdeg, block_f, d), lambda b, j, i: (0, j, 0)),
            pl.BlockSpec((1, block_f), lambda b, j, i: (0, j)),
            pl.BlockSpec((1, block_f), lambda b, j, i: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_f, dv), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, block_f, 1), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, f_pad, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, f_pad, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_f, dv), jnp.float32),
            pltpu.VMEM((1, block_f), jnp.float32),
        ],
        interpret=interpret,
    )(k, v, kvalid, w, col_deg.reshape(1, f_pad),
      col_scale.reshape(1, f_pad))


# ---------------------------------------------------------------------------
# fused apply: q + (S, n) -> out
# ---------------------------------------------------------------------------
def _fused_apply_kernel(q_ref, s_in_ref, n_in_ref, w_ref, deg_ref, scale_ref,
                        o_ref, num_scr, den_scr, *, eps: float, nfb: int):
    j = pl.program_id(2)                               # feature block

    @pl.when(j == 0)
    def _zero():
        num_scr[...] = jnp.zeros_like(num_scr)
        den_scr[...] = jnp.zeros_like(den_scr)

    zq = _featurize_block(q_ref[0], w_ref, deg_ref[...], scale_ref[...])
    num_scr[...] += jax.lax.dot_general(
        zq, s_in_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    den_scr[...] += jax.lax.dot_general(
        zq, n_in_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == nfb - 1)
    def _emit():
        o_ref[0] = (num_scr[...] / _clamp(den_scr[...], eps)).astype(
            o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "block_f", "eps", "interpret")
)
def rm_fused_apply_pallas(
    q: jax.Array,          # [BH, T, d]
    s: jax.Array,          # [BH, F_pad, dv]
    n: jax.Array,          # [BH, F_pad, 1]
    w: jax.Array,          # [kdeg, F_pad, d]
    col_deg: jax.Array,    # [F_pad] int32
    col_scale: jax.Array,  # [F_pad] float32
    *,
    chunk: int,
    block_f: int,
    eps: float = 1e-4,
    interpret: bool = False,
) -> jax.Array:            # [BH, T, dv]
    """Featurize q in VMEM and contract it against a precomputed state."""
    bh, t, d = q.shape
    dv = s.shape[-1]
    kdeg, f_pad, _ = w.shape
    assert t % chunk == 0 and f_pad % block_f == 0
    nfb = f_pad // block_f
    grid = (bh, t // chunk, nfb)
    kernel = functools.partial(_fused_apply_kernel, eps=eps, nfb=nfb)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_f, dv), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_f, 1), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((kdeg, block_f, d), lambda b, i, j: (0, j, 0)),
            pl.BlockSpec((1, block_f), lambda b, i, j: (0, j)),
            pl.BlockSpec((1, block_f), lambda b, i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((chunk, dv), jnp.float32),
            pltpu.VMEM((chunk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, s, n, w, col_deg.reshape(1, f_pad), col_scale.reshape(1, f_pad))
