"""Pure-jnp oracles for RM linear attention.

Given feature-mapped queries/keys ``zq, zk`` ([B, H, T, F]) and values ``v``
([B, H, T, dv]), linear attention is

    out_t = ( sum_{s in S(t)} (zq_t . zk_s) v_s ) / ( sum_{s in S(t)} zq_t . zk_s )

with S(t) = {s <= t} (causal) or all of [T] (non-causal). Because RM features
are *signed*, the denominator can pass through zero; both oracle and kernel
clamp it to ``sign(den) * max(|den|, eps)`` (DESIGN.md §7).

The oracle is the O(T^2) direct evaluation — it is also, exactly, what
softmax attention converges to as the RM feature count grows (the kernel
estimate of exp(q.k) in numerator and normalizer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _clamp_den(den: jax.Array, eps: float) -> jax.Array:
    return jnp.where(jnp.abs(den) < eps, jnp.where(den >= 0, eps, -eps), den)


def rm_attention_ref(
    zq: jax.Array,   # [B, H, T, F]
    zk: jax.Array,   # [B, H, T, F]
    v: jax.Array,    # [B, H, T, dv]
    causal: bool = True,
    eps: float = 1e-4,
) -> jax.Array:      # [B, H, T, dv]
    zq = zq.astype(jnp.float32)
    zk = zk.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w = jnp.einsum("bhtf,bhsf->bhts", zq, zk)
    if causal:
        t = zq.shape[2]
        mask = jnp.tril(jnp.ones((t, t), dtype=bool))
        w = jnp.where(mask, w, 0.0)
    num = jnp.einsum("bhts,bhsd->bhtd", w, v)
    den = _clamp_den(jnp.sum(w, axis=-1), eps)
    return num / den[..., None]


def rm_attention_scan_ref(
    zq: jax.Array, zk: jax.Array, v: jax.Array, eps: float = 1e-4
) -> jax.Array:
    """Sequential-state reference (the decode recurrence, scanned over T).

    Mathematically identical to ``rm_attention_ref(causal=True)``; used to
    check the chunked kernel's state bookkeeping and the decode step.
    """
    zq = zq.astype(jnp.float32)
    zk = zk.astype(jnp.float32)
    v = v.astype(jnp.float32)
    b, h, t, f = zq.shape
    dv = v.shape[-1]

    def step(carry, xs):
        s, n = carry                      # [B,H,F,dv], [B,H,F]
        zq_t, zk_t, v_t = xs              # [B,H,F], [B,H,F], [B,H,dv]
        s = s + zk_t[..., None] * v_t[..., None, :]
        n = n + zk_t
        num = jnp.einsum("bhf,bhfd->bhd", zq_t, s)
        den = _clamp_den(jnp.einsum("bhf,bhf->bh", zq_t, n), eps)
        return (s, n), num / den[..., None]

    s0 = jnp.zeros((b, h, f, dv), jnp.float32)
    n0 = jnp.zeros((b, h, f), jnp.float32)
    xs = (
        jnp.moveaxis(zq, 2, 0),
        jnp.moveaxis(zk, 2, 0),
        jnp.moveaxis(v, 2, 0),
    )
    _, out = jax.lax.scan(step, (s0, n0), xs)
    return jnp.moveaxis(out, 0, 2)


def rm_attention_decode_ref(
    zq: jax.Array,    # [B, H, F]
    zk: jax.Array,    # [B, H, F]
    v: jax.Array,     # [B, H, dv]
    state_s: jax.Array,  # [B, H, F, dv]
    state_n: jax.Array,  # [B, H, F]
    eps: float = 1e-4,
):
    """One decode step; returns (out [B,H,dv], new_s, new_n)."""
    s = state_s + zk[..., None] * v[..., None, :]
    n = state_n + zk
    num = jnp.einsum("bhf,bhfd->bhd", zq.astype(jnp.float32), s)
    den = _clamp_den(jnp.einsum("bhf,bhf->bh", zq.astype(jnp.float32), n), eps)
    return num / den[..., None], s, n
