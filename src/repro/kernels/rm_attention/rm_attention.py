"""Pallas TPU kernel: chunked causal linear attention over RM features.

Two-pass chunk-parallel formulation (no sequential dependency inside the
kernel — TPU-friendly; the tiny inter-chunk prefix sum happens outside):

  pass A (plain einsum, XLA):   S_i = Zk_i^T V_i   [F, dv],  n_i = Zk_i^T 1 [F]
  prefix (lax.cumsum, outside): S_prev_i = sum_{j<i} S_j,  n_prev_i likewise
  pass B (THIS kernel):         out_i = (tril(Zq_i Zk_i^T) V_i + Zq_i S_prev_i)
                                        / clamp(rowsum + Zq_i n_prev_i)

Pass B is the hot loop: per (batch*head, chunk) grid cell it runs a
[C,F]x[F,C] masked score matmul, a [C,C]x[C,dv] value matmul and a
[C,F]x[F,dv] state matmul entirely in VMEM. C and dv are 128-aligned;
F (feature dim) is padded to 128 by ops.py.

VMEM working set (fp32): C*F (zq) + C*F (zk) + C*dv (v) + F*dv (S_prev)
+ C*C (scores) + C*dv (acc) — e.g. C=256, F=256, dv=128: ~0.9 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rm_attn_kernel(zq_ref, zk_ref, v_ref, sprev_ref, nprev_ref, o_ref, *,
                    eps: float):
    zq = zq_ref[0].astype(jnp.float32)        # [C, F]
    zk = zk_ref[0].astype(jnp.float32)        # [C, F]
    v = v_ref[0].astype(jnp.float32)          # [C, dv]
    s_prev = sprev_ref[0, 0].astype(jnp.float32)  # [F, dv]
    n_prev = nprev_ref[0, 0].astype(jnp.float32)  # [F, 1]

    c = zq.shape[0]
    scores = jax.lax.dot_general(
        zq, zk, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [C, C]
    row = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    scores = jnp.where(row >= col, scores, 0.0)

    num = jax.lax.dot_general(
        scores, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    num += jax.lax.dot_general(
        zq, s_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [C, dv]

    den = jnp.sum(scores, axis=-1, keepdims=True)          # [C, 1]
    den += jax.lax.dot_general(
        zq, n_prev, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # [C, 1]
    den = jnp.where(jnp.abs(den) < eps, jnp.where(den >= 0, eps, -eps), den)
    o_ref[0] = (num / den).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "eps", "interpret")
)
def rm_attention_chunked_pallas(
    zq: jax.Array,      # [BH, T, F]  (T % chunk == 0, F 128-aligned)
    zk: jax.Array,      # [BH, T, F]
    v: jax.Array,       # [BH, T, dv]
    s_prev: jax.Array,  # [BH, T//chunk, F, dv]  exclusive chunk prefix of Zk^T V
    n_prev: jax.Array,  # [BH, T//chunk, F, 1]   exclusive chunk prefix of Zk^T 1
    *,
    chunk: int,
    eps: float = 1e-4,
    interpret: bool = False,
) -> jax.Array:         # [BH, T, dv] float32
    bh, t, f = zq.shape
    dv = v.shape[-1]
    assert t % chunk == 0, (t, chunk)
    nchunks = t // chunk
    grid = (bh, nchunks)
    return pl.pallas_call(
        functools.partial(_rm_attn_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, f), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, f), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1, f, dv), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, 1, f, 1), lambda b, i: (b, i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, dv), jnp.float32),
        interpret=interpret,
    )(zq, zk, v, s_prev, n_prev)
