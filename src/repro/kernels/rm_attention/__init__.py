from repro.kernels.rm_attention.ops import (
    rm_attention_causal,
    rm_attention_noncausal,
    rm_attention_decode_step,
    rm_attention_fused_causal,
    rm_attention_fused_noncausal,
    rm_attention_fused_prefill,
    rm_attention_fused_decode_step,
)

__all__ = [
    "rm_attention_causal",
    "rm_attention_noncausal",
    "rm_attention_decode_step",
    "rm_attention_fused_causal",
    "rm_attention_fused_noncausal",
    "rm_attention_fused_prefill",
    "rm_attention_fused_decode_step",
]
