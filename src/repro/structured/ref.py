"""jnp reference paths for the structured (Hadamard) estimator.

Two oracles (DESIGN.md §15), both emitting the PADDED random section
(``total_stacks * d_pad`` columns — surplus columns carry zero scale); the
deterministic prefix columns and the per-bucket surplus slice live in
``apply_structured_plan``:

* ``structured_blocks_ref`` — the production off-TPU path: the dense-WHT
  matmul formulation. Per degree bucket, slot j of every stack computes
  ``(x ∘ d1_j) @ H * d2_j`` with the materialized Sylvester Hadamard
  matrix (H is symmetric, so the right-matmul equals ``H (d1_j ∘ x)``),
  then multiplies slots. Ground truth for the fused kernel.
* ``structured_feature_fused_ref`` — the exact jnp mirror of the Pallas
  kernel's masked running product on the packed ``pack_structured``
  tensors. Used for raw array-level parity tests of
  ``structured_feature_fused``.

Column layout (both): buckets ascending, stack-major within a bucket —
stack i of a bucket owns its columns ``[i * d_pad, (i+1) * d_pad)``.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.structured.plan import StructuredPlan

__all__ = [
    "hadamard_matrix",
    "structured_blocks_ref",
    "structured_feature_fused_ref",
]


@functools.lru_cache(maxsize=None)
def hadamard_matrix(m: int) -> np.ndarray:
    """Unnormalized Sylvester Walsh-Hadamard matrix ``[m, m]`` (+-1 float32,
    symmetric). ``m`` must be a power of two."""
    if m & (m - 1):
        raise ValueError(f"Hadamard size must be a power of two, got {m}")
    h = np.ones((1, 1), np.float32)
    while h.shape[0] < m:
        h = np.block([[h, h], [h, -h]])
    return h


def structured_blocks_ref(
    plan: StructuredPlan, params: Dict[str, jax.Array], x: jax.Array
) -> jax.Array:
    """All degree buckets via dense WHT matmuls:
    ``x [B, d] -> [B, plan.padded_num_cols]`` float32.

    Stack i of bucket n emits the d_pad columns
    ``scale_n * prod_{j<n} (d2_ij ∘ H (d1_ij ∘ x_pad))`` — surplus columns
    (beyond the bucket's c_n) come out as exact zeros via the zero tail of
    ``padded_column_scales``.
    """
    m = plan.d_pad
    xf = x.astype(jnp.float32)
    xf = jnp.pad(xf, ((0, 0), (0, m - plan.input_dim)))
    if plan.padded_num_cols == 0:
        return jnp.zeros((xf.shape[0], 0), jnp.float32)
    hmat = jnp.asarray(hadamard_matrix(m))
    cols, off = [], 0
    for n, s in zip(plan.degrees, plan.stacks_per_bucket):
        d1 = params["d1"][off : off + s * n].astype(jnp.float32)
        d2 = params["d2"][off : off + s * n].astype(jnp.float32)
        off += s * n
        d1 = d1.reshape(s, n, m)
        d2 = d2.reshape(s, n, m)
        u = xf[:, None, None, :] * d1[None]                # [B, s, n, m]
        v = (u @ hmat) * d2[None]                          # H symmetric
        z = jnp.prod(v, axis=2)                            # [B, s, m]
        cols.append(z.reshape(xf.shape[0], s * m))
    out = jnp.concatenate(cols, axis=-1)
    scale = jnp.asarray(plan.padded_column_scales())
    return out * scale[None, :]


def structured_feature_fused_ref(
    x: jax.Array,          # [B, d_pad] (zero-padded to the Hadamard size)
    d1: jax.Array,         # [max_degree, S, d_pad]    (pack_structured)
    d2: jax.Array,         # [max_degree, S, d_pad]
    col_deg: jax.Array,    # [S * d_pad] int32 per-column product depth
    col_scale: jax.Array,  # [S * d_pad] per-column scale (0 on surplus)
) -> jax.Array:            # [B, S * d_pad] float32
    """jnp mirror of the fused kernel: masked running product of WHT slots.

    Column f is ``col_scale[f] * prod_{j < col_deg[f]} (d2[j] ∘ H (d1[j] ∘
    x))_f`` — identical ordering and masking to
    ``structured_feature_fused_pallas``, via the dense H matmul.
    """
    xf = x.astype(jnp.float32)
    k, s, m = d1.shape
    hmat = jnp.asarray(hadamard_matrix(m))
    acc = jnp.ones((xf.shape[0], s * m), jnp.float32)
    for j in range(k):
        u = xf[:, None, :] * d1[j][None].astype(jnp.float32)   # [B, s, m]
        v = (u @ hmat) * d2[j][None].astype(jnp.float32)
        p = v.reshape(xf.shape[0], s * m)
        keep = (j < col_deg)[None, :]
        acc = jnp.where(keep, acc * p, acc)
    return acc * col_scale[None, :].astype(jnp.float32)
