"""StructuredPlan — Hadamard-structured (HD) sublinear feature maps.

Choromanski & Sindhwani, *Recycling Randomness with Structure for Sublinear
time Kernel Expansions* (2016), replace the paper's i.i.d. Rademacher rows
with STRUCTURED projection stacks: each degree-n product slot applies

    P_j x = D2_j H D1_j x,

where ``D1_j, D2_j`` are independent diagonal Rademacher matrices and ``H``
is the (unnormalized, +-1) Sylvester Walsh-Hadamard matrix of size
``d_pad = 2^ceil(log2 d)``. One stack produces ``d_pad`` output columns per
slot from only ``2 d_pad`` random signs, and applies in ``O(d_pad log
d_pad)`` via the butterfly WHT instead of the ``O(d_pad^2)`` of a dense
draw — across F features the apply cost drops from O(dF) to O(F log d).

Unbiasedness is column-exact: output column f of one slot is
``<h_f ∘ d1, x>`` with ``h_f`` the (+-1) f-th Hadamard row, and
``E[(h_f ∘ d1)_a (h_f ∘ d1)_b] = h_fa h_fb E[d1_a d1_b] = delta_ab``
— every single column is distributed EXACTLY like one RM Rademacher
projection (the outer ``D2`` sign is a per-column Rademacher that cancels
in products of independent slots). Degree-n features multiply n
independent stacks, so ``E[z_f(x) z_f(y)] = <x,y>^n`` with zero-padded
inputs and the SAME ``sqrt(a_n / c_n)`` scales as RM. What changes is only
the joint law of the d_pad columns WITHIN one stack (they share d1/d2) —
the cross-column covariance argument lives in DESIGN.md §15.

This module mirrors ``repro.ctr.plan`` exactly:

    degree measure  ->  per-degree feature allocation  ->  sqrt(a_n / c_n)
                    ->  packed fused layout (two sign tensors, DESIGN.md §15)

A ``StructuredPlan`` is a hashable NamedTuple (jit-static). Column layout:

    [ h01 const | h01 identity block | degree-0 const
      | random columns, buckets ascending ]

Bucket n funds ``ceil(c_n / d_pad)`` independent stacks of ``d_pad``
columns each; the trailing ``S_n d_pad - c_n`` surplus columns of the last
stack are computed but carry scale 0 and are sliced off by ``apply`` —
allocation counts stay exactly the degree-measure counts, so plans,
output_dim and truncation diagnostics are budget-identical to RM.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.maclaurin import DotProductKernel
from repro.core.plan import BIAS_TAIL_DEGREES, allocate_features

__all__ = [
    "StructuredPlan",
    "make_structured_plan",
    "init_structured_params",
    "pack_structured",
    "apply_structured_plan",
]


def _next_pow2(n: int) -> int:
    m = 1
    while m < n:
        m *= 2
    return m


class StructuredPlan(NamedTuple):
    """Hashable Hadamard-structured feature-map plan: static through jit.

    ``degrees``/``counts``/``scales`` describe the degree >= 1 REAL feature
    buckets (ascending): bucket n holds ``counts[i]`` features of
    per-feature scale ``scales[i]``, backed by ``ceil(counts[i] / d_pad)``
    independent D2·H·D1 stacks per degree slot. ``seed`` records the
    ``allocate_features`` seed so plans reproduce across hosts (``to_json``
    carries every field).
    """

    degrees: Tuple[int, ...]
    counts: Tuple[int, ...]           # real features per degree bucket
    scales: Tuple[float, ...]         # per-feature scale sqrt(a_n / c_n)
    const: float                      # exact degree-0 column (0.0 when absent)
    h01: bool
    h01_a0: float
    h01_a1: float
    input_dim: int
    num_random: int                   # F, the real feature budget
    # a_0..a_{n_max + BIAS_TAIL_DEGREES} (tail window: bias diagnostics only)
    coefs_host: Tuple[float, ...]
    seed: int                         # allocation seed (reproducibility)

    # -- sizes ---------------------------------------------------------------
    @property
    def d_pad(self) -> int:
        """Hadamard size: next power of two >= input_dim (x is zero-padded;
        zero padding is exact — padded coordinates never contribute)."""
        return _next_pow2(max(self.input_dim, 1))

    @property
    def stacks_per_bucket(self) -> Tuple[int, ...]:
        """Independent D2·H·D1 stacks funding each bucket:
        ``ceil(c_n / d_pad)``."""
        m = self.d_pad
        return tuple((c + m - 1) // m for c in self.counts)

    @property
    def total_stacks(self) -> int:
        return int(sum(self.stacks_per_bucket))

    @property
    def total_slots(self) -> int:
        """Diagonal-sign rows backing the buckets: ``sum_n S_n * n`` (each
        stack draws one (d1, d2) pair per degree slot)."""
        return int(sum(s * n
                       for s, n in zip(self.stacks_per_bucket, self.degrees)))

    @property
    def max_degree(self) -> int:
        """Product depth of the packed layout (0 for a const-only plan)."""
        return max(self.degrees) if self.degrees else 0

    @property
    def num_prefix_columns(self) -> int:
        """Deterministic (exact, zero-variance) columns ahead of the
        random section."""
        pre = 0
        if self.h01:
            pre += 1 + self.input_dim
        if self.const != 0.0:
            pre += 1
        return pre

    @property
    def num_random_cols(self) -> int:
        """Real random columns surviving the surplus slice: sum of counts."""
        return int(sum(self.counts))

    @property
    def padded_num_cols(self) -> int:
        """Columns the fused launch actually computes:
        ``total_stacks * d_pad`` (surplus columns included)."""
        return self.total_stacks * self.d_pad

    @property
    def output_dim(self) -> int:
        """Real output columns: prefix + allocated features."""
        return self.num_prefix_columns + self.num_random_cols

    # -- fused column layout (host-side, static; padded section) -------------
    def padded_column_degrees(self) -> np.ndarray:
        """Per PADDED column product depth, int32 ``[padded_num_cols]``
        (surplus columns keep their bucket's degree; their zero scale
        removes them before the slice)."""
        m = self.d_pad
        deg = []
        for n, s in zip(self.degrees, self.stacks_per_bucket):
            deg.extend([n] * (s * m))
        return np.asarray(deg, dtype=np.int32)

    def padded_column_scales(self) -> np.ndarray:
        """Per PADDED column scale, float32 ``[padded_num_cols]``: the
        bucket scale on its first ``c_n`` columns (stack-major layout keeps
        them contiguous), 0.0 on the surplus tail."""
        m = self.d_pad
        sc = []
        for scale, c, s in zip(self.scales, self.counts,
                               self.stacks_per_bucket):
            sc.extend([float(scale)] * c)
            sc.extend([0.0] * (s * m - c))
        return np.asarray(sc, dtype=np.float32)

    # -- diagnostics ---------------------------------------------------------
    def truncation_bias(self, radius: float) -> float:
        """Worst-case dropped-degree mass ``sum a_n R^{2n}`` (paper §4.2),
        tail window beyond n_max included (see core.plan.BIAS_TAIL_DEGREES)."""
        present = set(self.degrees)
        if self.const != 0.0:
            present.add(0)
        if self.h01:
            present.update((0, 1))
        bias = 0.0
        for n, a_n in enumerate(self.coefs_host):
            if a_n > 0.0 and n not in present:
                bias += a_n * radius ** (2 * n)
        return bias

    # -- serialization (shared body with FeaturePlan/CtrPlan) ----------------
    def to_json(self) -> str:
        """Full plan state (seed + realized allocation included) as JSON."""
        from repro.core.plan import plan_to_json

        return plan_to_json(self)

    @classmethod
    def from_json(cls, s: str) -> "StructuredPlan":
        """Inverse of ``to_json`` (lossless: conformance-tested)."""
        from repro.core.plan import plan_from_json

        return plan_from_json(cls, s)


def make_structured_plan(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    *,
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    stratified: bool = True,
    seed: int = 0,
) -> StructuredPlan:
    """Allocate structured features across degrees of the Maclaurin measure.

    Args mirror ``core.plan.make_feature_plan`` (the estimator-registry
    ``make_plan`` signature). The budget split is IDENTICAL to RM — the
    same degree measure, the same ``allocate_features`` counts, the same
    ``sqrt(a_n / c_n)`` scales (each structured column is distributed like
    one RM column, see the module docstring) — only the backing randomness
    changes: ``ceil(c_n / d_pad)`` (d1, d2) sign-pair stacks per degree
    slot instead of ``c_n * n`` dense Rademacher rows.

    Returns the hashable ``StructuredPlan``.
    """
    from repro.core.feature_map import degree_measure

    kernel.validate_positive_definite(n_max)
    if h01 and measure == "geometric":
        measure = "geometric_ge2"
    a0 = float(kernel.coef(0))
    a1 = float(kernel.coef(1))
    if h01 and a0 == 0.0 and a1 == 0.0:
        raise ValueError(
            f"H0/1 is a no-op for kernel {kernel.name}: a_0 = a_1 = 0 "
            "(e.g. homogeneous polynomial kernels — paper §6.2)."
        )
    min_degree = 2 if h01 else 1
    q = degree_measure(kernel, n_max, p=p, kind=measure, radius=radius,
                       min_degree=min_degree)
    coefs = kernel.coefs(n_max)
    coefs_diag = kernel.coefs(n_max + BIAS_TAIL_DEGREES)

    prefix = (1 + input_dim) if h01 else (1 if a0 > 0.0 else 0)
    budget = max(num_features - prefix, 0)
    counts_all, scales_all = allocate_features(
        coefs, q, budget, stratified=stratified, seed=seed
    )

    degrees, counts, scales = [], [], []
    for n in range(min_degree, n_max + 1):
        c = int(counts_all[n])
        if c > 0 and coefs[n] > 0.0:
            degrees.append(n)
            counts.append(c)
            scales.append(float(scales_all[n]))

    return StructuredPlan(
        degrees=tuple(degrees),
        counts=tuple(counts),
        scales=tuple(scales),
        const=float(np.sqrt(a0)) if (a0 > 0.0 and not h01) else 0.0,
        h01=h01,
        h01_a0=a0 if h01 else 0.0,
        h01_a1=a1 if h01 else 0.0,
        input_dim=input_dim,
        num_random=num_features,
        coefs_host=tuple(float(c) for c in coefs_diag),
        seed=seed,
    )


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------
def init_structured_params(
    plan: StructuredPlan, key: jax.Array, dtype=jnp.float32
) -> Dict[str, jax.Array]:
    """Diagonal Rademacher signs for one plan instance.

    Returns ``{"d1": dtype [total_slots, d_pad], "d2": [total_slots,
    d_pad]}`` — slot s of a stack applies ``diag(d2[s]) H diag(d1[s])``.
    Entries are EXACT +-1.0 floats in any dtype. Slot layout is
    bucket-major, then stack-major, then degree-slot: rows ``[off_n + i*n,
    off_n + (i+1)*n)`` belong to stack i of degree bucket n. Pure traceable
    jax (one ``bernoulli`` draw), so the sharded path can fold keys and
    draw INSIDE ``shard_map`` (repro.distributed.estimator). Like RM omegas
    these are frozen model constants.
    """
    t = jax.random.bernoulli(key, 0.5, (2, plan.total_slots, plan.d_pad))
    signs = jnp.where(t, 1.0, -1.0).astype(dtype)
    return {"d1": signs[0], "d2": signs[1]}


# ---------------------------------------------------------------------------
# packing for the fused kernel
# ---------------------------------------------------------------------------
def pack_structured(
    plan: StructuredPlan, params: Dict[str, jax.Array]
) -> Tuple[jax.Array, jax.Array]:
    """Flat slots ``[total_slots, d_pad]`` x2 -> fused ``(d1, d2)`` tensors.

    Each output is ``[max_degree, total_stacks, d_pad]``: stack i's product
    slots are ``d1/d2[0:stack_degree[i], i, :]``; unused slots are zero
    (masked inside the kernel, never multiplied). Pure reshape/pad/concat —
    same traffic note as ``core.plan.pack_omegas``: callers applying one
    plan repeatedly should pack once and pass ``packed=`` to
    ``apply_structured_plan``.
    """
    m = plan.d_pad
    k = plan.max_degree

    def _pack(flat):
        parts = []
        off = 0
        for n, s in zip(plan.degrees, plan.stacks_per_bucket):
            rows = flat[off : off + s * n].reshape(s, n, m)
            off += s * n
            parts.append(jnp.pad(rows, ((0, 0), (0, k - n), (0, 0))))
        if not parts:
            return jnp.zeros((k, 0, m), flat.dtype)
        packed = jnp.concatenate(parts, axis=0)            # [stacks, k, m]
        return jnp.transpose(packed, (1, 0, 2))            # [k, stacks, m]

    return _pack(params["d1"]), _pack(params["d2"])


# ---------------------------------------------------------------------------
# application — ONE fused launch (or the jnp dense-WHT oracle)
# ---------------------------------------------------------------------------
def apply_structured_plan(
    plan: StructuredPlan,
    params: Dict[str, jax.Array],
    x: jax.Array,
    accum_dtype=jnp.float32,
    use_pallas: Optional[bool] = None,
    interpret: Optional[bool] = None,
    packed: Optional[Tuple[jax.Array, jax.Array]] = None,
    precision=None,
) -> jax.Array:
    """Featurize ``x [..., d] -> [..., plan.output_dim]``.

    The deterministic prefix columns (h01 block / degree-0 const) are exact
    jnp fills; the structured buckets run as ONE fused Pallas launch
    (``repro.kernels.structured_feature``) on TPU, or the dense-WHT matmul
    oracle (``repro.structured.ref.structured_blocks_ref``) elsewhere.
    Either path computes the padded ``total_stacks * d_pad`` columns; the
    surplus tail of each bucket (zero scale by construction) is dropped
    here with one contiguous slice per bucket. Mirrors
    ``core.plan.apply_plan``'s contract so the estimator registry exposes
    all families behind one ``apply``; ``packed`` short-circuits
    ``pack_structured`` for callers that cache the packed tensors.

    ``precision`` selects the input dtype policy: under ``"bf16"`` x and
    the sign tensors enter the kernel in bf16 — the +-1 signs are exact in
    bf16, so only x is rounded — while the running-product accumulator
    stays fp32 (the kernel widens on load).
    """
    from repro.common.dtypes import resolve_precision
    from repro.kernels.structured_feature.ops import structured_feature_fused
    from repro.structured.ref import structured_blocks_ref

    if x.shape[-1] != plan.input_dim:
        raise ValueError(
            f"expected trailing dim {plan.input_dim}, got {x.shape}"
        )
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    prec = resolve_precision(precision)
    compute_dtype = prec.compute_dtype
    batch_shape = x.shape[:-1]
    xf = x.reshape(-1, plan.input_dim).astype(accum_dtype)
    m = plan.d_pad
    feats = []
    if plan.h01:
        feats.append(jnp.full((xf.shape[0], 1), np.sqrt(plan.h01_a0),
                              dtype=accum_dtype))
        feats.append(jnp.asarray(np.sqrt(plan.h01_a1), accum_dtype)
                     * xf.astype(compute_dtype).astype(accum_dtype))
    if plan.const != 0.0:
        feats.append(jnp.full((xf.shape[0], 1), plan.const,
                              dtype=accum_dtype))
    if plan.num_random_cols:
        if use_pallas:
            d1, d2 = (packed if packed is not None
                      else pack_structured(plan, params))
            # zero-pad x to the Hadamard size (exact in any dtype)
            xp = jnp.pad(xf, ((0, 0), (0, m - plan.input_dim)))
            z = structured_feature_fused(
                xp.astype(compute_dtype),
                d1.astype(compute_dtype), d2.astype(compute_dtype),
                jnp.asarray(plan.padded_column_degrees()),
                jnp.asarray(plan.padded_column_scales()),
                use_pallas=True, interpret=interpret,
            ).astype(accum_dtype)
        else:
            z = structured_blocks_ref(
                plan, params, xf.astype(compute_dtype)
            ).astype(accum_dtype)
        # drop each bucket's surplus tail: the real columns are the FIRST
        # c_n of its stack-major padded run, so one slice per bucket
        parts, off = [], 0
        for c, s in zip(plan.counts, plan.stacks_per_bucket):
            parts.append(z[:, off : off + c])
            off += s * m
        feats.append(parts[0] if len(parts) == 1
                     else jnp.concatenate(parts, axis=-1))
    if not feats:
        # fully degenerate plan (a_0 = 0 and no bucket funded): a valid
        # 0-column map, not a concat error — its Gram estimate is
        # identically 0, matching output_dim == 0.
        return jnp.zeros((*batch_shape, 0), accum_dtype)
    out = jnp.concatenate(feats, axis=-1)
    return out.reshape(*batch_shape, out.shape[-1])
