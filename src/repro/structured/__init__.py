"""repro.structured — the Hadamard-structured estimator subsystem
(DESIGN.md §15).

A fourth random-feature family for the paper's dot-product kernels, driven
by the SAME Taylor-coefficient degree measures as Random Maclaurin but
built from D2·H·D1 projection stacks (Choromanski & Sindhwani, *Recycling
Randomness with Structure for Sublinear time Kernel Expansions*, 2016):
diagonal Rademacher signs around an in-VMEM butterfly Walsh-Hadamard
transform replace the dense i.i.d. draws, cutting the apply cost from
O(dF) to O(F log d) and the parameter count from ``sum_n c_n n d`` dense
rows to ``2 d_pad`` signs per degree slot — at per-column distribution
IDENTICAL to RM (each Hadamard-structured column is exactly one Rademacher
projection; only within-stack cross-column correlation differs, see
DESIGN.md §15). Registered as ``"structured"`` in the estimator registry
(``repro.core.registry``); consumers pick estimators by name.
"""
from repro.structured.plan import (
    StructuredPlan,
    apply_structured_plan,
    init_structured_params,
    make_structured_plan,
    pack_structured,
)
from repro.structured.feature_map import (
    StructuredFeatureMap,
    make_structured_feature_map,
)
from repro.structured.ref import (
    hadamard_matrix,
    structured_blocks_ref,
    structured_feature_fused_ref,
)

__all__ = [
    "StructuredPlan",
    "apply_structured_plan",
    "init_structured_params",
    "make_structured_plan",
    "pack_structured",
    "StructuredFeatureMap",
    "make_structured_feature_map",
    "hadamard_matrix",
    "structured_blocks_ref",
    "structured_feature_fused_ref",
]
