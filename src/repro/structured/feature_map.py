"""StructuredFeatureMap — a materialized Hadamard-structured feature map.

The structured counterpart of ``core.feature_map.RMFeatureMap`` /
``ctr.feature_map.CtrFeatureMap``: a thin carrier of (``plan``, ``params``)
with the same duck-typed surface (``__call__`` / ``apply`` / ``output_dim``
/ ``estimate_gram`` / ``truncation_bias``), so every downstream consumer —
``train_featurized_linear``, benchmarks, examples, the sharded execution
layer — takes any registry family without special-casing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.maclaurin import DotProductKernel
from repro.structured.plan import (
    StructuredPlan,
    apply_structured_plan,
    init_structured_params,
    make_structured_plan,
)

__all__ = ["StructuredFeatureMap", "make_structured_feature_map"]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StructuredFeatureMap:
    """(plan, diagonal sign draws) pair; rides through jit/pjit closures
    like the other map objects."""

    plan: StructuredPlan
    params: Dict[str, jax.Array]   # {"d1": [slots, d_pad], "d2": [...]}

    # -- pytree plumbing ------------------------------------------------------
    def tree_flatten(self):
        return (self.params,), (self.plan,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        (params,) = children
        (plan,) = aux
        return cls(plan=plan, params=params)

    # -- metadata -------------------------------------------------------------
    @property
    def input_dim(self) -> int:
        return self.plan.input_dim

    @property
    def num_random(self) -> int:
        return self.plan.num_random

    @property
    def output_dim(self) -> int:
        return self.plan.output_dim

    def truncation_bias(self, radius: float) -> float:
        """Worst-case dropped-degree mass (paper §4.2); see
        ``StructuredPlan.truncation_bias``."""
        return self.plan.truncation_bias(radius)

    # -- application ----------------------------------------------------------
    def __call__(self, x: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
        """Pure-jnp (dense-WHT oracle) path, mirroring
        ``RMFeatureMap.__call__``."""
        return apply_structured_plan(self.plan, self.params, x,
                                     accum_dtype=accum_dtype,
                                     use_pallas=False)

    def apply(
        self,
        x: jax.Array,
        *,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        accum_dtype=jnp.float32,
        precision=None,
    ) -> jax.Array:
        """Backend-routed path: fused Pallas launch on TPU, oracle off.

        ``precision`` ("fp32" | "bf16") is the feature-kernel input dtype
        policy — bf16 inputs/packed signs, fp32 accumulation either way.
        """
        return apply_structured_plan(self.plan, self.params, x,
                                     accum_dtype=accum_dtype,
                                     use_pallas=use_pallas,
                                     interpret=interpret,
                                     precision=precision)

    def estimate_gram(
        self,
        X: jax.Array,
        Y: Optional[jax.Array] = None,
        *,
        row_chunk: int = 4096,
        use_pallas: Optional[bool] = None,
        interpret: Optional[bool] = None,
        axis_name: Optional[str] = None,
        precision=None,
    ) -> jax.Array:
        """Kernel-matrix estimate via row-chunked fused featurization.

        Same plain ``Z(X) Z(Y)^T`` every family uses. ``axis_name``: inside
        a feature-sharded ``shard_map``, psum the partial Gram over that
        mesh axis (DESIGN.md §10). ``precision`` applies the feature-kernel
        dtype policy to the featurization; the Gram matmul stays fp32.
        """
        from repro.core.registry import estimate_gram

        return estimate_gram(
            lambda Z: self.apply(Z, use_pallas=use_pallas,
                                 interpret=interpret, precision=precision),
            X, Y, row_chunk=row_chunk, axis_name=axis_name,
        )


def make_structured_feature_map(
    kernel: DotProductKernel,
    input_dim: int,
    num_features: int,
    key: jax.Array,
    *,
    p: float = 2.0,
    measure: str = "geometric",
    h01: bool = False,
    n_max: int = 24,
    radius: float = 1.0,
    omega_dtype=jnp.float32,
    stratified: bool = True,
    seed: int = 0,
) -> StructuredFeatureMap:
    """Build a ``StructuredFeatureMap`` (same signature as
    ``make_feature_map``)."""
    plan = make_structured_plan(
        kernel, input_dim, num_features,
        p=p, measure=measure, h01=h01, n_max=n_max, radius=radius,
        stratified=stratified, seed=seed,
    )
    return StructuredFeatureMap(
        plan=plan, params=init_structured_params(plan, key, omega_dtype)
    )
