"""Direct (eps, delta) acceptance test of the paper's main theorem.

The paper's guarantee (Lemmas 6-8 / Theorem 12): with D random features,
``|<Z(x), Z(y)> - K(x, y)| <= eps`` uniformly w.p. >= 1 - delta once
``D = Omega(eps^-2 log(1/delta))`` — equivalently the achievable error at
a given D scales as ``O(1/sqrt(D))``. This suite checks the bound the way
the repo ships it: for EVERY registry estimator, the empirical sup over
all point-pairs of a pinned dataset, at a sweep of D values, must

1. stay under the Hoeffding-style bound
   ``eps(D) = sqrt(8 C^2 log(2 n_pairs / delta) / D)`` (``C`` is the
   beyond-paper proportional-measure estimator bound ``f(R^2)`` from
   ``repro.core.bounds`` — the measure these maps actually use) for every
   pinned map seed, and comfortably so at the largest D;
2. shrink at the predicted O(1/sqrt(D)) rate: quadrupling D twice (16x)
   must cut the mean sup error by well over the half-way point
   (predicted factor 4; asserted factor >= 1/0.6).

Everything is derandomized: pinned data key, pinned map seeds, plus a
hypothesis sweep over map seeds running under the repo's derandomized
"ci" profile (tests/conftest.py) — same examples on every machine.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExponentialDotProductKernel, make_feature_map, registry
from repro.core.bounds import constants_for

ESTIMATORS = registry.list_estimators()
KERN = ExponentialDotProductKernel(1.0)
RADIUS = 0.9
DIM = 8
N_POINTS = 16
DELTA = 0.05
D_SWEEP = (128, 512, 2048)
MAP_SEEDS = (100, 101, 102)


def _dataset():
    """Pinned points spanning radii up to RADIUS (not all on the shell)."""
    X = jax.random.normal(jax.random.PRNGKey(0), (N_POINTS, DIM))
    radii = jnp.linspace(0.3, RADIUS, N_POINTS)[:, None]
    return X / jnp.linalg.norm(X, axis=1, keepdims=True) * radii


def _eps_bound(num_features: int, n_pairs: int) -> float:
    """Pointwise Hoeffding + union bound over the pinned pairs, at the
    proportional-measure estimator constant C = f(R^2) (bounds.py)."""
    c = constants_for(KERN, RADIUS, DIM).c_proportional
    return math.sqrt(
        8.0 * c * c * math.log(2.0 * n_pairs / DELTA) / num_features
    )


def _sup_err(name: str, num_features: int, key) -> float:
    fm = make_feature_map(KERN, DIM, num_features, key,
                          estimator=name, measure="proportional")
    X = _dataset()
    G = np.asarray(fm.estimate_gram(X, use_pallas=False))
    K = np.asarray(KERN.gram(X))
    return float(np.max(np.abs(G - K)))


_N_PAIRS = N_POINTS * (N_POINTS + 1) // 2


@pytest.mark.parametrize("name", ESTIMATORS)
def test_sup_error_under_eps_delta_bound(name):
    """Every pinned seed x every D stays under eps(D); the largest D sits
    well inside it (the bound is loose by design — failure here means a
    real estimator regression, not bad luck)."""
    for D in D_SWEEP:
        eps = _eps_bound(D, _N_PAIRS)
        errs = [_sup_err(name, D, jax.random.PRNGKey(s))
                for s in MAP_SEEDS]
        assert all(np.isfinite(errs))
        assert max(errs) <= eps, (name, D, errs, eps)
    assert (np.mean([_sup_err(name, D_SWEEP[-1], jax.random.PRNGKey(s))
                     for s in MAP_SEEDS])
            <= 0.5 * _eps_bound(D_SWEEP[-1], _N_PAIRS)), name


@pytest.mark.parametrize("name", ESTIMATORS)
def test_error_shrinks_at_inverse_sqrt_rate(name):
    """16x the features must shrink the mean sup error past the half-way
    point toward the predicted 4x reduction (seed-averaged; pinned)."""
    mean_lo = np.mean([_sup_err(name, D_SWEEP[0], jax.random.PRNGKey(s))
                       for s in MAP_SEEDS])
    mean_hi = np.mean([_sup_err(name, D_SWEEP[-1], jax.random.PRNGKey(s))
                       for s in MAP_SEEDS])
    assert mean_hi <= 0.6 * mean_lo, (name, mean_lo, mean_hi)


def test_required_d_delivers_its_eps():
    """Inverting the calculator: at D = required_d(eps, delta) the
    pinned-seed empirical sup error lands under eps (paper Theorem 12 via
    bounds.required_num_features at the pointwise/pair-union scale)."""
    eps_target = 0.75
    c = constants_for(KERN, RADIUS, DIM).c_proportional
    D = int(math.ceil(8.0 * c * c / eps_target**2
                      * math.log(2.0 * _N_PAIRS / DELTA)))
    for name in ESTIMATORS:
        err = _sup_err(name, D, jax.random.PRNGKey(MAP_SEEDS[0]))
        assert err <= eps_target, (name, D, err)


def test_hypothesis_map_seed_sweep():
    """Derandomized hypothesis sweep over map seeds (ci profile): the
    theorem's probability statement is over MAP draws, so the seed is the
    right axis to fuzz. delta=0.05 with a ~8x empirical margin means a
    failure is a code regression, not sampling noise."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    eps = _eps_bound(512, _N_PAIRS)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**20))
    def check(seed):
        for name in ESTIMATORS:
            err = _sup_err(name, 512, jax.random.PRNGKey(seed))
            assert err <= eps, (name, seed, err, eps)

    check()