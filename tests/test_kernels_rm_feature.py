"""Pallas rm_feature kernel vs pure-jnp oracle (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExponentialDotProductKernel, make_feature_map
from repro.kernels.rm_feature.ops import apply_feature_map, rm_feature_bucket
from repro.kernels.rm_feature.ref import rm_feature_bucket_ref

SHAPES = [
    # (batch, d, count, degree)
    (8, 16, 32, 1),
    (8, 16, 32, 2),
    (32, 64, 128, 3),
    (7, 33, 19, 4),     # deliberately unaligned -> exercises padding
    (128, 128, 128, 5),
    (1, 8, 1, 7),
    (64, 256, 64, 10),
]


@pytest.mark.parametrize("b,d,count,degree", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bucket_matches_oracle(b, d, count, degree, dtype):
    key = jax.random.PRNGKey(degree * 1000 + d)
    kx, kw = jax.random.split(key)
    x = (jax.random.normal(kx, (b, d)) * 0.3).astype(dtype)
    omega = (2.0 * jax.random.bernoulli(kw, 0.5, (count * degree, d)) - 1.0)
    omega = omega.astype(dtype)
    scale = 0.37

    got = rm_feature_bucket(x, omega, degree, scale, use_pallas=True,
                            interpret=True)
    want = rm_feature_bucket_ref(x, omega, degree, scale)
    assert got.shape == (b, count)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_bucket_batch_dims():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 3, 16)) * 0.2
    omega = 2.0 * jax.random.bernoulli(key, 0.5, (5 * 2, 16)) - 1.0
    got = rm_feature_bucket(x, omega, 2, 1.0, use_pallas=True, interpret=True)
    want = rm_feature_bucket_ref(x.reshape(-1, 16), omega, 2, 1.0).reshape(2, 3, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_full_feature_map_matches_reference_path():
    """apply_feature_map (Pallas) == RMFeatureMap.__call__ (pure jnp),
    including H0/1 layout."""
    kern = ExponentialDotProductKernel(1.0)
    key = jax.random.PRNGKey(1)
    for h01 in (False, True):
        fm = make_feature_map(kern, 24, 256, key, h01=h01)
        x = jax.random.normal(jax.random.PRNGKey(2), (10, 24)) * 0.2
        want = fm(x)
        got = apply_feature_map(fm, x, use_pallas=True, interpret=True)
        assert got.shape == want.shape
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=1e-5)


def test_gram_estimate_through_pallas_path():
    kern = ExponentialDotProductKernel(1.0)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (20, 12))
    x = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) * 1.1)
    fm = make_feature_map(kern, 12, 2048, key, measure="proportional")
    z = apply_feature_map(fm, x, use_pallas=True, interpret=True)
    approx = np.asarray(z @ z.T)
    exact = np.asarray(kern.gram(x))
    assert np.mean(np.abs(approx - exact)) < 0.08


def test_apply_plan_pallas_parity():
    """static_plan.apply_plan routes buckets to the Pallas kernel on TPU;
    interpret-mode parity with the XLA path."""
    from repro.core.static_plan import apply_plan, init_omegas, make_plan_meta

    meta = make_plan_meta(ExponentialDotProductKernel(1.0), 32, 128)
    om = init_omegas(meta, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 32)) * 0.3
    a = apply_plan(meta, om, x, use_pallas=False)
    b = apply_plan(meta, om, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                               atol=1e-5)
