"""Prefill/decode consistency across ALL mixer families: prefill logits must
equal full-forward logits, and prefill->decode must equal forward over the
extended sequence (the invariant the serving engine relies on)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (
    decode_step,
    forward,
    init_model,
    prefill,
)

# one representative per mixer family (plus rm mode)
CASES = [
    ("qwen3-1.7b", "exact"),
    ("qwen3-1.7b", "rm"),
    ("h2o-danube-3-4b", "exact"),       # sliding window
    ("deepseek-v2-lite-16b", "exact"),  # MLA + MoE + shared experts
    ("mixtral-8x7b", "exact"),          # MoE + SWA
    ("jamba-v0.1-52b", "exact"),        # mamba hybrid
    ("xlstm-350m", None),               # mlstm + slstm
]


@pytest.mark.parametrize("arch,mode", CASES,
                         ids=[f"{a}-{m}" for a, m in CASES])
def test_prefill_matches_forward_and_decode_continues(arch, mode):
    cfg = get_config(arch, smoke=True, attention_mode=mode)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    if cfg.moe is not None:
        # capacity-based MoE drops depend on batch composition (prefill sees
        # 12 tokens, forward sees 15) — lift capacity so routing is dropless
        # and the paths are exactly comparable.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_model(cfg, jax.random.PRNGKey(0))
    b, t_prompt, t_extra = 2, 12, 3
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (b, t_prompt + t_extra), 0,
                                cfg.vocab_size)

    # full forward over the whole sequence = ground truth
    full_logits, _ = forward(params, cfg, {"tokens": tokens})

    # prefill over the prompt
    pre_logits, cache = prefill(params, cfg,
                                {"tokens": tokens[:, :t_prompt]}, max_len=32)
    np.testing.assert_allclose(
        np.asarray(pre_logits), np.asarray(full_logits[:, :t_prompt]),
        rtol=2e-3, atol=2e-3,
    )

    # decode the extra tokens one by one; logits must match full forward
    for i in range(t_extra):
        pos = jnp.full((b,), t_prompt + i, jnp.int32)
        step_logits, cache = decode_step(params, cfg, cache,
                                         tokens[:, t_prompt + i][:, None],
                                         pos)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]),
            np.asarray(full_logits[:, t_prompt + i]),
            rtol=5e-3, atol=5e-3,
        )


def test_cell_enumeration_rules():
    from repro.configs import get_config, list_archs
    from repro.launch.shapes import SHAPES, enumerate_cells

    archs = list_archs()
    cfgs = {a: get_config(a) for a in archs}
    cells = enumerate_cells(archs, cfgs)
    assert len(cells) == len(archs) * len(SHAPES)  # 40 cells
    by_key = {(c.arch, c.shape): c for c in cells}
    # encoder-only skips
    assert by_key[("hubert-xlarge", "decode_32k")].skipped
    assert by_key[("hubert-xlarge", "long_500k")].skipped
    assert not by_key[("hubert-xlarge", "prefill_32k")].skipped
    # long_500k: rm for softmax archs, native for ssm/hybrid
    assert by_key[("qwen2-7b", "long_500k")].attention_mode == "rm"
    assert by_key[("mixtral-8x7b", "long_500k")].attention_mode == "rm"
    assert by_key[("xlstm-350m", "long_500k")].attention_mode == "exact"
    assert not by_key[("xlstm-350m", "long_500k")].skipped
    # all other shapes stay in the arch's configured mode
    assert by_key[("qwen2-7b", "train_4k")].attention_mode == "exact"


def test_input_specs_shapes():
    from repro.configs import get_config
    from repro.launch.shapes import input_specs

    cfg = get_config("qwen3-1.7b")
    s = input_specs(cfg, "train_4k")
    assert s["batch"]["tokens"].shape == (256, 4096)
    s = input_specs(cfg, "decode_32k")
    assert s["batch"]["tokens"].shape == (128, 1)
    assert "cache" in s
    # vlm: patch embeds carved out of seq_len
    cfg_v = get_config("internvl2-1b")
    s = input_specs(cfg_v, "train_4k")
    assert s["batch"]["embeds"].shape[1] == 256
    assert s["batch"]["tokens"].shape[1] == 4096 - 256
    # audio: embeds only
    cfg_a = get_config("hubert-xlarge")
    s = input_specs(cfg_a, "prefill_32k")
    assert s["batch"]["embeds"].shape == (32, 32768, 1280)


def test_blockwise_attention_mla_dv_ne_dh(monkeypatch):
    """Regression: blockwise attention with v_head_dim != qk head dim
    (MLA: 192 vs 128) — caught by the deepseek train_4k dry-run."""
    import repro.models.attention as A

    monkeypatch.setattr(A, "_BLOCKWISE_THRESHOLD", 16)
    monkeypatch.setattr(A, "_BLOCK_Q", 16)
    monkeypatch.setattr(A, "_BLOCK_K", 16)
    cfg = get_config("deepseek-v2-lite-16b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 48), 0,
                                cfg.vocab_size)
    logits, _ = forward(params, cfg, {"tokens": tokens})
    assert logits.shape == (2, 48, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # must match the small-path einsum attention
    monkeypatch.setattr(A, "_BLOCKWISE_THRESHOLD", 2048)
    logits2, _ = forward(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits2),
                               rtol=2e-3, atol=2e-3)


def test_moe_shardmap_batch1(monkeypatch):
    """Regression: MoE shard_map with batch=1 (long_500k) falls back to
    replicated tokens instead of failing to shard."""
    from repro.distributed.sharding import logical_rules_context

    cfg = get_config("mixtral-8x7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    tokens = jnp.ones((1, 4), jnp.int32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with logical_rules_context(mesh):
        logits, _ = jax.jit(
            lambda p, b: forward(p, cfg, b))(params, {"tokens": tokens})
    assert not bool(jnp.isnan(logits).any())
