"""Hypothesis property-based tests on the system's invariants.

Paper invariants:
  * Lemma 8: |Z(x) Z(y)| <= p f(p R^2) for x, y in B_1(0, R) (paper measure);
  * proportional-measure bound: |Z(x) Z(y)| <= f(R^2) (DESIGN.md §3);
  * degree measures are normalized distributions on the coefficient support;
  * Theorem 12's D is monotone in 1/eps and 1/delta.

System invariants:
  * int8 quantization round-trip error <= scale/2; error feedback is exact
    over time (sum of dequantized == sum of inputs + final residual);
  * checkpoint flatten/unflatten is a bijection;
  * sharding specs always divide the dims they shard.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.common.tree import flatten_dict, unflatten_dict
from repro.core import (
    ExponentialDotProductKernel,
    PolynomialKernel,
    constants_for,
    degree_measure,
    make_feature_map,
)
from repro.optim.compression import dequantize_int8, quantize_int8

# Explicitly derandomized (conftest.py's "ci" profile also sets this): the
# drawn seeds below feed PRNGKeys, so derandomize=True pins every random
# draw in this module — tier-1 cannot flake on an unlucky example.
_SETTINGS = dict(max_examples=20, deadline=None, derandomize=True)


@settings(**_SETTINGS)
@given(
    seed=st.integers(0, 2**20),
    d=st.integers(2, 12),
    radius=st.floats(0.2, 1.0),
)
def test_lemma8_estimator_bound(seed, d, radius):
    """|Z(x).Z(y)| <= p f(p R^2) uniformly (paper Lemma 8).

    The bound holds per-feature; the concatenated estimate is an average of
    per-feature products so it obeys the same bound.
    """
    kern = ExponentialDotProductKernel(1.0)
    key = jax.random.PRNGKey(seed)
    fm = make_feature_map(kern, d, 64, key, p=2.0, measure="geometric",
                          stratified=False)
    kx, ky = jax.random.split(jax.random.PRNGKey(seed + 1))
    # x, y in B_1(0, R): sample and rescale to L1 norm <= R
    x = jax.random.normal(kx, (16, d))
    y = jax.random.normal(ky, (16, d))
    x = x / jnp.sum(jnp.abs(x), axis=1, keepdims=True) * radius
    y = y / jnp.sum(jnp.abs(y), axis=1, keepdims=True) * radius
    est = np.asarray(fm(x) @ fm(y).T)
    bound = 2.0 * float(kern.f(2.0 * radius**2))
    assert np.abs(est).max() <= bound + 1e-4


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**20), radius=st.floats(0.2, 1.0))
def test_proportional_measure_tighter_bound(seed, radius):
    """With q_n ∝ a_n R^{2n}, |Z(x).Z(y)| <= f(R^2) — the beyond-paper
    constant (strictly smaller than Lemma 8's)."""
    kern = ExponentialDotProductKernel(1.0)
    d = 6
    fm = make_feature_map(kern, d, 64, jax.random.PRNGKey(seed),
                          measure="proportional", stratified=False,
                          radius=radius)
    kx, ky = jax.random.split(jax.random.PRNGKey(seed + 1))
    x = jax.random.normal(kx, (16, d))
    y = jax.random.normal(ky, (16, d))
    x = x / jnp.sum(jnp.abs(x), axis=1, keepdims=True) * radius
    y = y / jnp.sum(jnp.abs(y), axis=1, keepdims=True) * radius
    est = np.asarray(fm(x) @ fm(y).T)
    assert np.abs(est).max() <= float(kern.f(radius**2)) + 1e-4


@settings(**_SETTINGS)
@given(
    n_max=st.integers(4, 32),
    p=st.floats(1.5, 4.0),
    kind=st.sampled_from(["geometric", "geometric_ge2", "proportional"]),
)
def test_degree_measure_is_distribution(n_max, p, kind):
    kern = PolynomialKernel(5, 1.0)
    q = degree_measure(kern, n_max, p=p, kind=kind)
    assert abs(q.sum() - 1.0) < 1e-9
    assert (q >= 0).all()
    coefs = kern.coefs(n_max)
    assert (q[coefs == 0] == 0).all()


@settings(**_SETTINGS)
@given(
    eps=st.floats(0.05, 0.5),
    delta=st.floats(0.001, 0.2),
)
def test_required_d_monotone(eps, delta):
    c = constants_for(ExponentialDotProductKernel(1.0), 1.0, 8)
    assert c.required_d(eps, delta) >= c.required_d(eps * 1.5, delta)
    assert c.required_d(eps, delta) >= c.required_d(eps, delta * 2)
    assert c.required_d(eps, delta, "proportional") <= c.required_d(eps, delta)


@settings(**_SETTINGS)
@given(
    seed=st.integers(0, 2**20),
    scale=st.floats(1e-4, 1e3),
)
def test_int8_quantization_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6 * scale


# ---------------------------------------------------------------------------
# estimator parity: RM and TensorSketch against the exact kernel Gram
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None, derandomize=True)
@given(seed=st.integers(0, 2**16))
def test_estimator_parity_within_eps_bound(seed):
    """Both registry estimators converge to the exact Gram within the paper's
    pointwise Hoeffding ε (proportional measure: per-feature bound
    c = f(R^2), so eps(F, δ) = sqrt(8 c^2 ln(2/δ) / F) — bounds.py), and the
    residual shrinks with the budget. The F=1024 estimate averages two
    independent maps so the empirical tail sits well inside the (loose)
    Hoeffding ε for every seed hypothesis can draw.
    """
    kern = ExponentialDotProductKernel(1.0)
    d, radius = 8, 0.8
    key = jax.random.PRNGKey(seed)
    X = jax.random.normal(key, (8, d))
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * radius
    K = np.asarray(kern.gram(X))
    c = float(kern.f(radius**2))
    eps = np.sqrt(8.0 * c**2 * np.log(2.0 / 0.001) / 1024)

    for estimator in ("rm", "tensor_sketch"):
        errs = {}
        for F in (128, 1024):
            grams = []
            for rep in range(1 if F == 128 else 2):
                fm = make_feature_map(
                    kern, d, F, jax.random.PRNGKey(7 * seed + F + 13 * rep),
                    measure="proportional", estimator=estimator,
                    radius=radius)
                grams.append(np.asarray(fm.estimate_gram(X)))
            errs[F] = np.abs(np.mean(grams, axis=0) - K).max()
        assert errs[1024] <= eps, (estimator, errs, eps)
        assert errs[1024] <= errs[128] + eps / 4, (estimator, errs)


@settings(**_SETTINGS)
@given(seed=st.integers(0, 2**20))
def test_error_feedback_unbiased_over_time(seed):
    """Sum over steps of compressed values + final residual == sum of
    inputs: error feedback never loses mass (1-bit-Adam property)."""
    key = jax.random.PRNGKey(seed)
    xs = jax.random.normal(key, (20, 32))
    residual = jnp.zeros((32,))
    total_sent = jnp.zeros((32,))
    for t in range(20):
        corrected = xs[t] + residual
        q, s = quantize_int8(corrected)
        sent = dequantize_int8(q, s)
        residual = corrected - sent
        total_sent = total_sent + sent
    np.testing.assert_allclose(
        np.asarray(total_sent + residual), np.asarray(xs.sum(0)),
        rtol=1e-4, atol=1e-4,
    )


@settings(**_SETTINGS)
@given(
    keys=st.lists(
        st.text(alphabet="abcdef", min_size=1, max_size=4),
        min_size=1, max_size=6, unique=True,
    ),
    depth=st.integers(1, 3),
)
def test_flatten_unflatten_bijection(keys, depth):
    tree = {}
    node = tree
    for level in range(depth):
        for k in keys:
            node[k] = np.zeros((2,)) if level == depth - 1 else {}
        node = node[keys[0]] if depth > level + 1 else node
    flat = flatten_dict(tree)
    rebuilt = unflatten_dict(flat)
    assert jax.tree_util.tree_structure(tree) == \
        jax.tree_util.tree_structure(rebuilt)


def test_sharding_specs_divide_dims():
    """Every PartitionSpec produced for every arch divides its dims on the
    PRODUCTION meshes (the invariant behind every dry-run compile) — checked
    via AbstractMesh, no devices needed."""
    from jax.sharding import AbstractMesh

    from repro.configs import get_config, list_archs
    from repro.distributed.sharding import params_partition_specs
    from repro.models.transformer import init_model

    for mesh in (AbstractMesh((16, 16), ("data", "model")),
                 AbstractMesh((2, 16, 16), ("pod", "data", "model"))):
        for arch in list_archs():
            cfg = get_config(arch)
            sds = jax.eval_shape(
                lambda c=cfg: init_model(c, jax.random.PRNGKey(0)))
            specs = params_partition_specs(sds, mesh)
            flat_s = flatten_dict(specs)
            flat_p = flatten_dict(sds)
            for path, spec in flat_s.items():
                shape = flat_p[path].shape
                for dim, axis in zip(shape, tuple(spec)):
                    if axis is None:
                        continue
                    axes = axis if isinstance(axis, tuple) else (axis,)
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    assert dim % size == 0, (arch, path, shape, spec)
