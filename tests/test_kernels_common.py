"""Unit tests for the shared kernel-wrapper helpers (kernels/common).

Covers the hoisted backend-detection rule (ONE ``default_interpret``
governing every launch — the per-wrapper duplicates are gone), the
dtype-aware VMEM heuristics, and the measured block-ladder autotuner's
persistent cache.
"""
import time

import jax
import pytest

import repro.kernels.common as kcommon
from repro.kernels import ctr_feature, rm_feature, tensor_sketch


def test_default_interpret_is_the_backend_rule():
    assert kcommon.default_interpret() == (jax.default_backend() != "tpu")


def test_all_wrappers_share_one_interpret_rule():
    """The rm/sketch/ctr ops modules must resolve interpret=None through
    kernels.common.default_interpret — not a re-derived backend check."""
    from repro.kernels.ctr_feature import ops as ctr_ops
    from repro.kernels.rm_feature import ops as rm_ops
    from repro.kernels.tensor_sketch import ops as ts_ops

    for mod in (rm_ops, ts_ops, ctr_ops):
        assert mod._default_interpret is kcommon.default_interpret, mod
    # rm_attention resolves it lazily; the source-level check keeps the
    # rule from being re-duplicated there.
    import inspect

    from repro.kernels.rm_attention import ops as attn_ops

    assert "default_interpret" in inspect.getsource(attn_ops)
    assert 'default_backend() != "tpu"' not in inspect.getsource(attn_ops)


def test_pick_feature_blocks_is_dtype_aware():
    """bf16 inputs halve the x/weight working set, so the heuristic can
    afford at least as large a tile (strictly larger on VMEM-bound shapes)."""
    shape = dict(d=1024, depth=16, b=4096, f=4096)
    bm32, bf32 = kcommon.pick_feature_blocks(
        shape["d"], shape["depth"], shape["b"], shape["f"], itemsize=4)
    bm16, bf16 = kcommon.pick_feature_blocks(
        shape["d"], shape["depth"], shape["b"], shape["f"], itemsize=2)
    assert bm16 * bf16 >= bm32 * bf32
    # and on this shape the budget really binds
    assert bm16 * bf16 > bm32 * bf32


def test_pick_batch_block_is_dtype_aware():
    bm32 = kcommon.pick_batch_block(1024, 6, 2048, 4096, itemsize=4)
    bm16 = kcommon.pick_batch_block(1024, 6, 2048, 4096, itemsize=2)
    assert bm16 >= bm32


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_BLOCK_CACHE", str(tmp_path / "blocks.json"))
    kcommon.clear_block_cache_memo()
    yield tmp_path / "blocks.json"
    kcommon.clear_block_cache_memo()


def test_get_feature_blocks_falls_back_to_heuristic(tmp_cache):
    assert kcommon.get_feature_blocks(
        "rm_feature", 16, 3, 64, 96
    ) == kcommon.pick_feature_blocks(16, 3, 64, 96)


def test_block_cache_round_trip(tmp_cache):
    key = kcommon.cache_key("rm_feature", 16, 3, 64, 96, "float32")
    kcommon.save_block_cache({key: [32, 32]})
    kcommon.clear_block_cache_memo()
    assert kcommon.get_feature_blocks("rm_feature", 16, 3, 64, 96) == (32, 32)
    # a different dtype is a different cache row -> heuristic fallback
    assert kcommon.get_feature_blocks(
        "rm_feature", 16, 3, 64, 96, dtype="bfloat16"
    ) == kcommon.pick_feature_blocks(16, 3, 64, 96, itemsize=2)


def test_autotune_measures_and_persists(tmp_cache):
    """The autotuner must pick the fastest measured candidate and persist
    it where get_feature_blocks finds it (fresh memo included)."""
    calls = []

    def launch(bm, bf):
        calls.append((bm, bf))
        if (bm, bf) != (16, 16):      # every tile but one is slow
            time.sleep(0.003)
        return jax.numpy.zeros(())

    best = kcommon.autotune_feature_blocks(
        "rm_feature", launch, 16, 3, 64, 96,
        candidates=[(32, 32), (16, 16), (8, 8)], repeats=2)
    assert best == (16, 16)
    assert calls  # it really launched
    kcommon.clear_block_cache_memo()
    assert kcommon.get_feature_blocks("rm_feature", 16, 3, 64, 96) == (16, 16)
    assert tmp_cache.exists()


def test_autotuned_blocks_drive_a_real_launch(tmp_cache):
    """End-to-end: a cache row steers the fused rm launch (interpret mode)
    without changing its numbers."""
    import jax.numpy as jnp
    import numpy as np

    x = jax.random.normal(jax.random.PRNGKey(0), (12, 6))
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 6))
    deg = jnp.full((10,), 2, jnp.int32)
    sc = jnp.ones((10,), jnp.float32)
    base = np.asarray(rm_feature.rm_feature_fused(
        x, w, deg, sc, interpret=True))
    key = kcommon.cache_key("rm_feature", 6, 2, 12, 10, "float32")
    kcommon.save_block_cache({key: [8, 8]})
    kcommon.clear_block_cache_memo()
    tuned = np.asarray(rm_feature.rm_feature_fused(
        x, w, deg, sc, interpret=True))
    np.testing.assert_allclose(tuned, base, rtol=1e-6, atol=1e-6)


def test_feasible_candidates_respect_budget():
    cands = kcommon.feasible_feature_blocks(64, 4, 1024, 512)
    assert cands
    for bm, bf in cands:
        working = 4 * (bm * 64 + 4 * bf * 64) + 8 * bm * bf
        assert working <= kcommon.VMEM_BUDGET
