"""Sharded estimator execution: 8-fake-device parity for every registry
entry plus the data-parallel serving smoke (ISSUE 3 acceptance). Runs in a
subprocess so the test process keeps seeing 1 device (see dryrun.py's
device-count note)."""
import os
import subprocess
import sys
from pathlib import Path

SCRIPTS = Path(__file__).parent / "dist_scripts"
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, str(SCRIPTS / script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n"
            f"{proc.stderr[-4000:]}"
        )
    return proc.stdout


def test_sharded_estimators_and_dp_serving():
    out = _run("run_sharded_estimators.py")
    assert "SHARDED ESTIMATORS OK" in out
    assert "DP decode matches single-device generations" in out
