"""Structured (Hadamard) estimator subsystem: kernel parity, variance,
registry protocol, integration.

Covers (DESIGN.md §15):
  * the butterfly WHT inside the fused Pallas kernel vs the materialized
    Sylvester Hadamard matrix (order AND values);
  * fused Pallas kernel (interpret mode) vs the dense-WHT matmul oracle to
    1e-5 on the kernel zoo, plus ONE-launch accounting;
  * per-column RM-equivalence: a single structured column's projection is
    distributed exactly like one Rademacher row (unbiasedness inherits);
  * the ISSUE-8 acceptance claim: at a matched real feature budget the
    structured Gram MSE on the exponential kernel is <= Random Maclaurin's
    (deterministic seeds);
  * registry threading: ``make_feature_map(estimator="structured")``,
    attention forward, and the serving engine with no consumer-side
    special-casing.

Reproducibility: every statistical test draws from PINNED PRNG seeds, so
tier-1 results are identical across runs and machines.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    VovkRealKernel,
    make_feature_map,
    registry,
)
from repro.kernels.structured_feature import structured_feature_fused
from repro.structured import (
    StructuredFeatureMap,
    StructuredPlan,
    hadamard_matrix,
    init_structured_params,
    make_structured_feature_map,
    make_structured_plan,
    pack_structured,
    structured_blocks_ref,
    structured_feature_fused_ref,
)

KERNELS = [
    ExponentialDotProductKernel(1.0),
    PolynomialKernel(3, 1.0),
    HomogeneousPolynomialKernel(2),
    VovkRealKernel(4),
]


# ---------------------------------------------------------------------------
# plan geometry
# ---------------------------------------------------------------------------
def test_plan_pads_to_hadamard_size_and_slices_surplus():
    kern = ExponentialDotProductKernel(1.0)
    plan = make_structured_plan(kern, 10, 192, measure="proportional")
    assert plan.d_pad == 16
    assert plan.output_dim == 192
    # every bucket funds whole stacks; surplus columns carry scale 0
    m = plan.d_pad
    for c, s in zip(plan.counts, plan.stacks_per_bucket):
        assert s == -(-c // m)
    scales = plan.padded_column_scales()
    degs = plan.padded_column_degrees()
    assert scales.shape == degs.shape == (plan.padded_num_cols,)
    assert int((scales > 0).sum()) == plan.num_random_cols
    # packed tensors: one (d1, d2) pair per degree slot, not per column
    params = init_structured_params(plan, jax.random.PRNGKey(0))
    assert params["d1"].shape == (plan.total_slots, m)
    assert set(np.unique(np.asarray(params["d1"]))) <= {-1.0, 1.0}
    d1, d2 = pack_structured(plan, params)
    assert d1.shape == d2.shape == (plan.max_degree, plan.total_stacks, m)
    # sublinear parameter count: far fewer random entries than RM's
    # sum_n c_n * n * d dense rows at the same budget
    rm_rows = sum(c * n for c, n in zip(plan.counts, plan.degrees))
    assert 2 * plan.total_slots * m < rm_rows * plan.input_dim


def test_power_of_two_input_needs_no_padding():
    kern = ExponentialDotProductKernel(1.0)
    plan = make_structured_plan(kern, 16, 128)
    assert plan.d_pad == 16
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 16)) * 0.3
    params = init_structured_params(plan, jax.random.PRNGKey(1))
    est = registry.get("structured")
    z = est.apply(plan, params, x, use_pallas=False)
    assert z.shape == (5, plan.output_dim)
    assert np.isfinite(np.asarray(z)).all()


# ---------------------------------------------------------------------------
# Hadamard transform ground truth
# ---------------------------------------------------------------------------
def test_butterfly_wht_matches_sylvester_matrix():
    """The kernel's trace-time butterfly equals the dense Sylvester H on
    random inputs for every size used by the test zoo."""
    from repro.kernels.structured_feature.structured_feature import _wht

    for m in (1, 2, 4, 8, 16, 32):
        h = hadamard_matrix(m)
        assert np.allclose(h @ h.T, m * np.eye(m))      # orthogonal, +-1
        v = jax.random.normal(jax.random.PRNGKey(m), (3, 2, m))
        want = np.asarray(v) @ h                         # H symmetric
        got = np.asarray(_wht(jnp.asarray(v)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_single_column_is_one_rademacher_projection():
    """Column f of one stack slot is ``<h_f ∘ d1, x>`` — exactly one
    +-1-row projection (the per-column RM-equivalence that carries RM's
    unbiasedness and scales over, DESIGN.md §15)."""
    kern = HomogeneousPolynomialKernel(1)   # degree-1 only: no products
    plan = make_structured_plan(kern, 8, 8)
    assert plan.degrees == (1,) and plan.d_pad == 8
    params = init_structured_params(plan, jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (6, 8))
    z = structured_blocks_ref(plan, params, x)
    h = hadamard_matrix(8)
    d1 = np.asarray(params["d1"][0])
    d2 = np.asarray(params["d2"][0])
    scale = plan.padded_column_scales()
    for f in range(8):
        row = h[f] * d1                       # h_f ∘ d1: a +-1 row
        want = np.asarray(x) @ row * d2[f] * scale[f]
        np.testing.assert_allclose(np.asarray(z[:, f]), want,
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# fused kernel parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
def test_fused_matches_oracle_on_kernel_zoo(kern):
    fm = make_structured_feature_map(kern, 11, 160, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (9, 11)) * 0.3
    want = fm(x)
    got = fm.apply(x, use_pallas=True, interpret=True)
    assert got.shape == (9, fm.output_dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_raw_parity_on_packed_tensors():
    """Array-level parity of the ops wrapper against the jnp mirror on the
    padded column layout (leading batch dims included)."""
    kern = ExponentialDotProductKernel(1.0)
    fm = make_structured_feature_map(kern, 13, 96, jax.random.PRNGKey(5))
    plan = fm.plan
    d1, d2 = pack_structured(plan, fm.params)
    cd = jnp.asarray(plan.padded_column_degrees())
    cs = jnp.asarray(plan.padded_column_scales())
    x = jax.random.normal(jax.random.PRNGKey(6), (3, 5, 13)) * 0.25
    xp = jnp.pad(x, ((0, 0), (0, 0), (0, plan.d_pad - 13)))
    want = structured_feature_fused_ref(xp.reshape(-1, plan.d_pad),
                                        d1, d2, cd, cs)
    got = structured_feature_fused(xp, d1, d2, cd, cs,
                                   use_pallas=True, interpret=True)
    assert got.shape == (3, 5, plan.padded_num_cols)
    np.testing.assert_allclose(np.asarray(got).reshape(-1, want.shape[-1]),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_structured_fused_is_one_pallas_launch():
    """Every degree bucket — all stacks, all depths — ONE launch."""
    kern = ExponentialDotProductKernel(1.0)
    fm = make_structured_feature_map(kern, 16, 256, jax.random.PRNGKey(0))
    assert len(fm.plan.degrees) > 1
    x = jnp.ones((4, 16)) * 0.1

    def count_in(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if "pallas" in eqn.primitive.name:
                total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                    total += count_in(v.jaxpr)
                elif hasattr(v, "eqns"):
                    total += count_in(v)
        return total

    fn = lambda xx: fm.apply(xx, use_pallas=True, interpret=True)
    assert count_in(jax.make_jaxpr(fn)(x).jaxpr) == 1


def test_explicit_blocks_and_bf16_policy():
    """Caller-pinned blocks snap to whole stacks; the bf16 policy rounds
    only the inputs (signs are exact), with fp32 accumulation keeping the
    result close to the fp32 path."""
    kern = ExponentialDotProductKernel(1.0)
    fm = make_structured_feature_map(kern, 10, 128, jax.random.PRNGKey(7))
    plan = fm.plan
    d1, d2 = pack_structured(plan, fm.params)
    cd = jnp.asarray(plan.padded_column_degrees())
    cs = jnp.asarray(plan.padded_column_scales())
    x = jax.random.normal(jax.random.PRNGKey(8), (7, 10)) * 0.3
    xp = jnp.pad(x, ((0, 0), (0, plan.d_pad - 10)))
    want = structured_feature_fused_ref(xp, d1, d2, cd, cs)
    got = structured_feature_fused(xp, d1, d2, cd, cs, use_pallas=True,
                                   interpret=True, blocks=(8, 24))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    z32 = fm.apply(x, use_pallas=True, interpret=True)
    z16 = fm.apply(x, use_pallas=True, interpret=True, precision="bf16")
    assert z16.dtype == jnp.float32            # accumulator stays fp32
    np.testing.assert_allclose(np.asarray(z16), np.asarray(z32),
                               rtol=0.1, atol=0.05)


def test_edge_plans_apply_cleanly():
    kern = PolynomialKernel(3, 1.0)
    x = jax.random.normal(jax.random.PRNGKey(9), (7, 6)) * 0.3
    # const-only plan: no randomness at all
    tiny = make_structured_feature_map(kern, 6, 1, jax.random.PRNGKey(1))
    z = tiny.apply(x, use_pallas=True, interpret=True)
    assert z.shape == (7, tiny.output_dim)
    # fully degenerate: a_0 = 0 (no prefix) AND no bucket funded -> a
    # valid 0-column map, not a concat error
    empty = make_structured_feature_map(HomogeneousPolynomialKernel(3), 6,
                                        0, jax.random.PRNGKey(1))
    assert empty.output_dim == 0
    assert empty(x).shape == (7, 0)
    assert empty.apply(x, use_pallas=True, interpret=True).shape == (7, 0)
    # iid (paper-faithful) allocation mode
    fm = make_structured_feature_map(kern, 6, 64, jax.random.PRNGKey(2),
                                     stratified=False, seed=3)
    assert fm.plan.seed == 3
    np.testing.assert_allclose(
        np.asarray(fm.apply(x, use_pallas=True, interpret=True)),
        np.asarray(fm(x)), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# statistics
# ---------------------------------------------------------------------------
def test_structured_gram_estimates_kernel():
    """Averaged over maps, the structured Gram approaches the exact Gram,
    and the residual shrinks as the budget grows."""
    kern = ExponentialDotProductKernel(1.0)
    d = 12
    X = jax.random.normal(jax.random.PRNGKey(0), (10, d))
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * 0.8
    K = np.asarray(kern.gram(X))

    def err(F, n_maps=8):
        grams = []
        for s in range(n_maps):
            fm = make_structured_feature_map(kern, d, F,
                                             jax.random.PRNGKey(s),
                                             measure="proportional")
            grams.append(np.asarray(fm.estimate_gram(X)))
        return np.abs(np.mean(grams, axis=0) - K).max()

    e_small, e_big = err(64), err(1024)
    assert e_big < e_small
    assert e_big < 0.15 * np.abs(K).max()


def test_structured_gram_mse_leq_rm_at_matched_budget():
    """ISSUE-8 acceptance: deterministic variance comparison — the
    structured Gram MSE on the exponential kernel is <= Random
    Maclaurin's at the SAME feature budget F (the within-stack Hadamard
    coupling is variance-reducing here, measured ~3x lower — DESIGN.md
    §15). Fixed seeds."""
    kern = ExponentialDotProductKernel(1.0)
    d, F, n_draws = 8, 256, 60
    X = jax.random.normal(jax.random.PRNGKey(0), (12, d))
    X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * 0.9
    K = np.asarray(kern.gram(X))

    mse = {}
    for name in ("rm", "structured"):
        errs = []
        for s in range(n_draws):
            fm = make_feature_map(kern, d, F, jax.random.PRNGKey(1000 + s),
                                  estimator=name, measure="proportional")
            G = np.asarray(fm.estimate_gram(X))
            errs.append(np.mean((G - K) ** 2))
        mse[name] = float(np.mean(errs))

    assert mse["structured"] <= mse["rm"], mse


# ---------------------------------------------------------------------------
# registry threading (no consumer-side special-casing)
# ---------------------------------------------------------------------------
def test_make_feature_map_estimator_kwarg_structured():
    kern = PolynomialKernel(3, 1.0)
    fm = make_feature_map(kern, 10, 64, jax.random.PRNGKey(0),
                          estimator="structured")
    assert isinstance(fm, StructuredFeatureMap)
    assert isinstance(fm.plan, StructuredPlan)
    assert fm.output_dim == 64


def test_attention_and_engine_with_structured():
    from repro.configs import get_config
    from repro.models.transformer import forward, init_model
    from repro.serve.engine import Request, ServingEngine

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm",
                     estimator="structured")
    assert cfg.rm.estimator == "structured"
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.ones((2, 16), jnp.int32),
        "positions": jnp.tile(jnp.arange(16), (2, 1)),
    }
    logits, _ = forward(params, cfg, batch)
    assert logits.shape[:2] == (2, 16)
    assert np.isfinite(np.asarray(logits)).all()

    eng = ServingEngine(cfg, params, num_slots=2, max_len=64)
    assert eng.estimator == "structured"
    eng.submit(Request(0, np.arange(5, dtype=np.int32) % 7,
                       max_new_tokens=4))
    done = eng.run(max_iters=50)
    assert len(done[0].generated) == 4
