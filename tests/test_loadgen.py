"""Loadgen + BENCH_serving.json: deterministic under FakeClock, honest SLO
accounting, schema-v1 gate wired through ``python -m repro.bench --check``.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.bench import loadgen, schema
from repro.bench.__main__ import main as bench_main
from repro.configs import get_config
from repro.models import init_model
from repro.obs import Obs, clock
from repro.serve import Scheduler

PROV = {"backend": "test", "device_kind": "test", "device_count": 1,
        "interpret": False, "jax_version": "0"}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return cfg, init_model(cfg, jax.random.PRNGKey(0))


def _run(cfg, params, *, rate=2.0, n=6, slots=2, seed=0):
    clk = clock.FakeClock(step=0.01)
    obs = Obs(clock=clk, provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=slots, max_len=32,
                      rng_seed=seed, obs=obs)
    arrivals = loadgen.poisson_trace(rate, n, seed=seed,
                                     max_new_range=(2, 5))
    raw = loadgen.run_load(sched, arrivals, clock=clk, prompt_seed=seed)
    obs.close()
    return arrivals, raw


# -- arrivals -----------------------------------------------------------------
def test_poisson_trace_is_seeded_and_ordered():
    a = loadgen.poisson_trace(1.5, 20, seed=3)
    b = loadgen.poisson_trace(1.5, 20, seed=3)
    assert a == b
    assert all(y.t >= x.t for x, y in zip(a, a[1:]))
    assert [x.request_id for x in a] == list(range(20))
    assert loadgen.poisson_trace(1.5, 20, seed=4) != a
    with pytest.raises(ValueError, match="rate"):
        loadgen.poisson_trace(0.0, 5)


def test_trace_file_roundtrip(tmp_path):
    a = loadgen.poisson_trace(1.0, 8, seed=1,
                              temperature_choices=(0.0, 0.7),
                              priority_choices=(0, 1))
    p = tmp_path / "trace.jsonl"
    loadgen.save_trace(p, a)
    assert loadgen.load_trace(p) == a

    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"t": 1.0, "request_id": 0, "prompt_len": 4})
                   + "\n" +
                   json.dumps({"t": 0.5, "request_id": 1, "prompt_len": 4})
                   + "\n")
    with pytest.raises(ValueError, match="non-decreasing"):
        loadgen.load_trace(bad)
    dup = tmp_path / "dup.jsonl"
    dup.write_text(json.dumps({"t": 1.0, "request_id": 0, "prompt_len": 4})
                   + "\n" +
                   json.dumps({"t": 2.0, "request_id": 0, "prompt_len": 4})
                   + "\n")
    with pytest.raises(ValueError, match="duplicate"):
        loadgen.load_trace(dup)


# -- the harness --------------------------------------------------------------
def test_run_load_is_deterministic_under_fake_clock(setup):
    cfg, params = setup
    _, raw1 = _run(cfg, params)
    _, raw2 = _run(cfg, params)
    assert loadgen.slo_summary(raw1) == loadgen.slo_summary(raw2)
    tok1 = {i: s.generated for i, s in raw1["finished"].items()}
    tok2 = {i: s.generated for i, s in raw2["finished"].items()}
    assert tok1 == tok2


def test_run_load_finishes_everything_and_accounts_slo(setup):
    cfg, params = setup
    arrivals, raw = _run(cfg, params)
    assert raw["submitted"] == len(arrivals)
    assert raw["truncated"] == 0
    slo = loadgen.slo_summary(raw)
    assert slo["requests_finished"] == len(arrivals)
    assert slo["ttft_s"]["n"] == len(arrivals)
    assert slo["ttft_s"]["p50"] > 0
    assert slo["ttft_s"]["p99"] >= slo["ttft_s"]["p50"]
    assert slo["total_tokens"] == sum(
        len(s.generated) for s in raw["finished"].values())
    # inter-token gaps pool every request's consecutive token pairs
    want_n = sum(max(0, len(s.t_tokens) - 1)
                 for s in raw["finished"].values())
    assert slo["inter_token_s"]["n"] == want_n
    # saturation accounting only counts all-slots-busy steps
    sat = [st for st in raw["steps"] if st.active == raw["num_slots"]]
    assert slo["saturated_steps"] == len(sat)
    if sat:
        assert slo["tokens_per_s_saturated"] == pytest.approx(
            sum(st.new_tokens for st in sat)
            / sum(st.t_end - st.t_start for st in sat))


def test_open_loop_respects_arrival_times(setup):
    """Requests must not be submitted before their scheduled arrival: the
    harness is open-loop, idle-advancing the fake clock to the next
    arrival rather than draining the trace up front."""
    cfg, params = setup
    clk = clock.FakeClock(step=0.01)
    obs = Obs(clock=clk, provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=2, max_len=32, obs=obs)
    arrivals = loadgen.poisson_trace(0.05, 3, seed=0)   # sparse: idle gaps
    raw = loadgen.run_load(sched, arrivals, clock=clk)
    obs.close()
    submit_t = {e["attrs"]["request_id"]: e["ts_us"] / 1e6
                for e in obs.tracer.events("request/submit")}
    for a in arrivals:
        assert submit_t[a.request_id] >= a.t - 1e-9
    assert raw["truncated"] == 0


# -- the artifact + gate ------------------------------------------------------
def test_serving_payload_schema_roundtrip(setup, tmp_path):
    cfg, params = setup
    _, raw = _run(cfg, params)
    payload = loadgen.serving_payload(
        loadgen.slo_summary(raw),
        workload={"arch": "qwen3-1.7b", "scheduler": "continuous",
                  "num_slots": 2, "max_len": 32, "rate": 2.0,
                  "num_requests": 6, "seed": 0},
        provenance=PROV)
    assert schema.check_serving_payload(payload) == []

    # --check dispatches on kind and passes
    p = tmp_path / "BENCH_serving.json"
    p.write_text(json.dumps(payload))
    assert schema.check_file(p) == []
    assert bench_main(["--check", str(p)]) == 0

    # a silently dropped SLO cell fails the gate
    broken = json.loads(p.read_text())
    del broken["slo"]["tokens_per_s_saturated"]
    del broken["slo"]["ttft_s"]["p99"]
    pb = tmp_path / "broken.json"
    pb.write_text(json.dumps(broken))
    errors = schema.check_file(pb)
    assert any("tokens_per_s_saturated" in e for e in errors)
    assert any("p99" in e for e in errors)
    assert bench_main(["--check", str(pb)]) == 1


def test_serving_schema_rejects_empty_and_mislabeled_runs():
    empty = {"kind": "serving",
             "schema_version": loadgen.SERVING_SCHEMA_VERSION,
             "provenance": PROV,
             "workload": {"arch": "a", "scheduler": "continuous",
                          "num_slots": 1, "max_len": 8,
                          "num_requests": 0, "seed": 0},
             "slo": {k: 0 for k in schema.SERVING_REQUIRED_SLO_KEYS}}
    empty["slo"]["ttft_s"] = {"p50": 0, "p99": 0, "mean": 0, "n": 0}
    empty["slo"]["inter_token_s"] = {"p50": 0, "p99": 0, "mean": 0, "n": 0}
    empty["slo"]["requests_finished"] = 0
    errors = schema.check_serving_payload(empty)
    assert any("requests_finished == 0" in e for e in errors)

    wrong = dict(empty, schema_version=99)
    assert any("schema_version" in e
               for e in schema.check_serving_payload(wrong))


def test_loadgen_cli_writes_gated_artifact(tmp_path):
    out = tmp_path / "BENCH_serving.json"
    trace = tmp_path / "arrivals.jsonl"
    rc = loadgen.main(["--quick", "--fake-clock", "--rate", "2.0",
                       "--requests", "6", "--slots", "2",
                       "--max-len", "32", "--save-trace", str(trace),
                       "--out", str(out)])
    assert rc == 0
    payload = json.loads(out.read_text())
    assert schema.check_serving_payload(payload) == []
    assert payload["workload"]["fake_clock"] is True
    # the saved trace replays to the identical artifact
    out2 = tmp_path / "replay.json"
    rc = loadgen.main(["--quick", "--fake-clock", "--trace", str(trace),
                       "--slots", "2", "--max-len", "32",
                       "--out", str(out2)])
    assert rc == 0
    replay = json.loads(out2.read_text())
    assert replay["slo"] == payload["slo"]
