"""Fused single-launch feature map: kernel-zoo parity + launch accounting.

Three paths must agree to fp32 tolerance on every kernel in the zoo,
h01 on/off × stratified on/off:

  * fused Pallas kernel (interpret mode on CPU),
  * fused jnp reference (``RMFeatureMap.__call__`` / ``use_pallas=False``),
  * the legacy per-bucket path (``apply_feature_map_bucketed``).

Also asserts the fused path issues exactly ONE pallas_call per feature-map
application (the legacy path issues one per degree bucket).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    VovkRealKernel,
    make_feature_map,
)
from repro.core.plan import apply_plan, init_omegas, make_feature_plan, pack_omegas
from repro.kernels.rm_feature import (
    apply_feature_map,
    apply_feature_map_bucketed,
    rm_feature_fused,
    rm_feature_fused_ref,
)

KERNELS = [
    ExponentialDotProductKernel(1.0),
    PolynomialKernel(7, 1.0),
    HomogeneousPolynomialKernel(3),
    VovkRealKernel(4),
]


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("h01", [False, True])
@pytest.mark.parametrize("stratified", [False, True])
def test_zoo_parity_fused_vs_reference_vs_bucketed(kern, h01, stratified):
    if h01 and kern.coef(0) == 0.0 and kern.coef(1) == 0.0:
        pytest.skip("H0/1 undefined for homogeneous kernels (paper §6.2)")
    fm = make_feature_map(kern, 24, 192, jax.random.PRNGKey(5), h01=h01,
                          stratified=stratified)
    x = jax.random.normal(jax.random.PRNGKey(6), (11, 24)) * 0.25

    want = fm(x)                                        # fused jnp reference
    got_pallas = apply_feature_map(fm, x, use_pallas=True, interpret=True)
    got_bucketed = apply_feature_map_bucketed(fm, x, use_pallas=False)

    assert want.shape == (11, fm.output_dim)
    np.testing.assert_allclose(np.asarray(got_pallas), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_bucketed), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_is_one_pallas_launch():
    """The whole map — const, h01 block, every degree — in ONE pallas_call."""
    kern = ExponentialDotProductKernel(1.0)
    fm = make_feature_map(kern, 16, 256, jax.random.PRNGKey(0), h01=True)
    assert len(fm.plan.degrees) > 1  # multiple buckets, still one launch
    x = jnp.ones((4, 16)) * 0.1

    def count_in(jaxpr):
        total = 0
        for eqn in jaxpr.eqns:
            if "pallas" in eqn.primitive.name:
                total += 1
            for v in eqn.params.values():
                if hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
                    total += count_in(v.jaxpr)   # ClosedJaxpr (pjit etc.)
                elif hasattr(v, "eqns"):
                    total += count_in(v)
        return total

    def count_launches(fn):
        return count_in(jax.make_jaxpr(fn)(x).jaxpr)

    fused = lambda xx: apply_feature_map(fm, xx, use_pallas=True,
                                         interpret=True)
    legacy = lambda xx: apply_feature_map_bucketed(fm, xx, use_pallas=True,
                                                   interpret=True)
    assert count_launches(fused) == 1
    assert count_launches(legacy) == len(fm.plan.degrees)


def test_fused_batch_dims_and_padding():
    """Unaligned batch/feature sizes exercise the padding path."""
    kern = PolynomialKernel(5, 0.5)
    fm = make_feature_map(kern, 13, 97, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (3, 5, 13)) * 0.2
    want = fm(x)
    got = apply_feature_map(fm, x, use_pallas=True, interpret=True)
    assert got.shape == (3, 5, fm.output_dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)


def test_plan_column_layout_consistency():
    """Host-side column metadata matches the realized output layout."""
    kern = ExponentialDotProductKernel(1.0)
    plan = make_feature_plan(kern, 8, 128, h01=True)
    col_deg = plan.column_degrees()
    col_scale = plan.column_scales()
    assert col_deg.shape == (plan.output_dim,)
    assert col_scale.shape == (plan.output_dim,)
    # prefix: h01 const (deg 0), identity block (deg 1), const column (deg 0)
    assert col_deg[0] == 0
    assert (col_deg[1 : 1 + plan.input_dim] == 1).all()
    # buckets ascending => column degrees are non-decreasing after the prefix
    tail = col_deg[plan.num_prefix_columns :]
    assert (np.diff(tail) >= 0).all()
    # packed tensor shape
    om = init_omegas(plan, jax.random.PRNGKey(0))
    w = pack_omegas(plan, om)
    assert w.shape == (plan.max_degree, plan.output_dim, plan.input_dim)


def test_const_only_plan_degenerate():
    """A plan with no product columns skips the kernel entirely."""
    kern = PolynomialKernel(3, 1.0)
    plan = make_feature_plan(kern, 4, 1, measure="proportional")
    om = init_omegas(plan, jax.random.PRNGKey(0))
    x = jnp.ones((2, 4)) * 0.3
    z = apply_plan(plan, om, x, use_pallas=True, interpret=True)
    assert z.shape == (2, plan.output_dim)
    assert np.isfinite(np.asarray(z)).all()


def test_rm_feature_fused_raw_api():
    """Array-level fused op agrees with its reference on hand-built layouts."""
    key = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(key)
    b, d, f, kmax = 9, 12, 37, 4
    x = jax.random.normal(k1, (b, d)) * 0.3
    w = (2.0 * jax.random.bernoulli(k2, 0.5, (kmax, f, d)) - 1.0)
    col_deg = jnp.asarray(np.random.default_rng(0).integers(0, kmax + 1, f),
                          jnp.int32)
    col_scale = jnp.asarray(np.random.default_rng(1).uniform(0.1, 2.0, f),
                            jnp.float32)
    want = rm_feature_fused_ref(x, w, col_deg, col_scale)
    got = rm_feature_fused(x, w, col_deg, col_scale, use_pallas=True,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-6)
