"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-grad step + (for causal archs) one decode step on CPU; asserts
output shapes and absence of NaNs. The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import (
    decode_step,
    forward,
    init_decode_cache,
    init_model,
    loss_fn,
)

ARCHS = list_archs()


def _smoke_batch(cfg, key, batch=2, seq=32):
    kt, ke = jax.random.split(key)
    if cfg.frontend == "audio_stub":
        return {
            "embeds": jax.random.normal(ke, (batch, seq, cfg.d_model),
                                        jnp.bfloat16),
            "targets": jax.random.randint(kt, (batch, seq), 0,
                                          cfg.vocab_size),
        }
    if cfg.frontend == "vision_stub":
        p = 8
        return {
            "embeds": jax.random.normal(ke, (batch, p, cfg.d_model),
                                        jnp.bfloat16) * 0.02,
            "tokens": jax.random.randint(kt, (batch, seq - p), 0,
                                         cfg.vocab_size),
            "targets": jax.random.randint(kt, (batch, seq - p), 0,
                                          cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
        "targets": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))

    logits, _ = forward(params, cfg, batch)
    total_t = batch["targets"].shape[1] if cfg.frontend != "vision_stub" \
        else batch["tokens"].shape[1] + 8
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert logits.shape[1] == total_t
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    loss, metrics = loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"

    grads = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(not bool(jnp.isnan(g).any()) for g in gleaves), (
        f"{arch}: NaN grads"
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    if not cfg.causal:
        with pytest.raises(ValueError, match="encoder-only"):
            init_decode_cache(cfg, 2, 16)
        return
    params = init_model(cfg, jax.random.PRNGKey(0))
    cache = init_decode_cache(cfg, batch=2, max_len=16)
    tokens = jnp.zeros((2, 1), jnp.int32)
    for step in range(3):
        pos = jnp.full((2,), step, jnp.int32)
        logits, cache = decode_step(params, cfg, cache, tokens, pos)
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN decode"
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "deepseek-v2-lite-16b"])
def test_smoke_rm_mode(arch):
    """The paper's RM attention mode runs on attention archs."""
    cfg = get_config(arch, smoke=True, attention_mode="rm")
    params = init_model(cfg, jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg, jax.random.PRNGKey(1))
    logits, _ = forward(params, cfg, batch)
    assert not bool(jnp.isnan(logits).any())


def test_rm_mode_rejected_for_attention_free():
    with pytest.raises(ValueError, match="attention-free"):
        get_config("xlstm-350m", smoke=True, attention_mode="rm")


def test_full_configs_match_assignment():
    """Spot-check the published numbers we were assigned."""
    c = get_config("qwen3-1.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (28, 2048, 16, 8, 6144, 151936)
    assert c.qk_norm
    c = get_config("mixtral-8x7b")
    assert (c.num_layers, c.d_model, c.moe.num_experts, c.moe.top_k) == \
        (32, 4096, 8, 2)
    assert c.sliding_window > 0
    c = get_config("deepseek-v2-lite-16b")
    assert c.mla.kv_lora_rank == 512 and c.moe.num_experts == 64
    assert c.moe.top_k == 6 and c.moe.num_shared_experts == 2
    c = get_config("jamba-v0.1-52b")
    kinds = [b.split("_")[0] for b in c.block_pattern]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    assert sum("moe" in b for b in c.block_pattern) == 4  # every other layer
    c = get_config("hubert-xlarge")
    assert not c.causal and c.vocab_size == 504 and c.num_layers == 48
    c = get_config("xlstm-350m")
    assert set(b.split("_")[0] for b in c.block_pattern) == {"mlstm", "slstm"}
    c = get_config("olmo-1b")
    assert c.norm_kind == "nonparametric_ln"
    c = get_config("qwen2-7b")
    assert c.qkv_bias and c.d_ff == 18944 and c.num_kv_heads == 4
    c = get_config("internvl2-1b")
    assert c.frontend == "vision_stub" and c.d_model == 896
    c = get_config("h2o-danube-3-4b")
    assert c.sliding_window > 0 and c.d_model == 3840
