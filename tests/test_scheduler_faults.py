"""Fault injection for the continuous scheduler: kill a decode/prefill
step mid-run, assert the recovery contract (docs/serving.md).

The contract — the serving analogue of ``train.fault.run_with_restarts``'s
bounded crash-restart loop: with ``max_restarts=N``, up to N failed steps
re-queue every in-flight request at its ORIGINAL queue position, reset the
decode cache, and continue; the N+1-th failure propagates. Because token
streams are keyed per (request, token index), replayed requests regenerate
bit-identical outputs — a crash is invisible in the results, visible only
in the trace (``request/evict`` reason="restart", ``serve/restart``) and
the ``serve/restarts`` counter. ``StragglerMonitor`` (reused from the
training stack) watches decode wall times across the respawn.
"""
import dataclasses
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.obs import Obs, clock
from repro.serve import Request, Scheduler
from repro.train.fault import StragglerMonitor

sys.path.insert(0, "tools")
from check_trace import check_request_lifecycles  # noqa: E402

PROV = {"backend": "test", "device_kind": "test", "device_count": 1,
        "interpret": False, "jax_version": "0"}
MAX_LEN = 32
VOCAB = 512


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return cfg, init_model(cfg, jax.random.PRNGKey(0))


def _submit_workload(sched, n=3, max_new=4, temperature=0.5):
    rng = np.random.default_rng(7)
    for i in range(n):
        sched.submit(Request(request_id=i,
                             prompt=rng.integers(0, VOCAB, size=5),
                             max_new_tokens=max_new,
                             temperature=temperature))


def _reference(cfg, params, n=3, max_new=4, temperature=0.5, rng_seed=0):
    out = {}
    for i in range(n):
        s = Scheduler(cfg, params, num_slots=1, max_len=MAX_LEN,
                      rng_seed=rng_seed)
        rng = np.random.default_rng(7)
        prompts = [rng.integers(0, VOCAB, size=5) for _ in range(n)]
        s.submit(Request(request_id=i, prompt=prompts[i],
                         max_new_tokens=max_new, temperature=temperature))
        out[i] = s.run()[i].generated
    return out


def _inject_decode_failures(sched, fail_on_calls, monkeypatch):
    """Make executor.decode raise on the given 1-based call numbers."""
    orig = sched.executor.decode
    calls = {"n": 0}

    def flaky(tokens, positions):
        calls["n"] += 1
        if calls["n"] in fail_on_calls:
            raise RuntimeError(f"injected decode failure "
                               f"(call {calls['n']})")
        return orig(tokens, positions)

    monkeypatch.setattr(sched.executor, "decode", flaky)
    return calls


def test_decode_crash_requeues_and_finishes_bit_identically(
        setup, monkeypatch):
    """Kill one decode step mid-run: every in-flight slot is re-queued,
    the run completes, and outputs match an undisturbed sequential run."""
    cfg, params = setup
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=2, max_len=MAX_LEN,
                      rng_seed=0, max_restarts=2, obs=obs)
    _submit_workload(sched)
    _inject_decode_failures(sched, {2}, monkeypatch)
    done = sched.run()
    obs.close()

    assert sorted(done) == [0, 1, 2]
    assert sched.restarts == 1
    assert {i: s.generated for i, s in done.items()} == \
        _reference(cfg, params)
    # the trace records the respawn: every slot in flight at the crash was
    # evicted with reason="restart", then re-admitted; lifecycles stay
    # well-formed through it
    restarts = obs.tracer.events("serve/restart")
    assert len(restarts) == 1
    assert "injected decode failure" in restarts[0]["attrs"]["cause"]
    evs = obs.tracer.events("request/evict")
    assert evs and all(e["attrs"]["reason"] == "restart" for e in evs)
    assert {e["attrs"]["request_id"] for e in evs} == \
        set(restarts[0]["attrs"]["requeued"])
    assert check_request_lifecycles(obs.tracer.records) == []
    # re-admitted requests carry their attempt count
    assert any(done[i].admissions >= 2 for i in done)
    snap = obs.metrics.snapshot(provenance=PROV)
    assert snap["counters"]["serve/restarts"] == 1.0


def test_repeated_crashes_within_budget_still_complete(setup, monkeypatch):
    cfg, params = setup
    sched = Scheduler(cfg, params, num_slots=2, max_len=MAX_LEN,
                      rng_seed=0, max_restarts=3)
    _submit_workload(sched)
    _inject_decode_failures(sched, {2, 4, 7}, monkeypatch)
    done = sched.run()
    assert sched.restarts == 3
    assert {i: s.generated for i, s in done.items()} == \
        _reference(cfg, params)


def test_crash_beyond_budget_propagates(setup, monkeypatch):
    """The N+1-th failure re-raises — bounded restarts, like
    run_with_restarts, never an infinite crash loop."""
    cfg, params = setup
    sched = Scheduler(cfg, params, num_slots=2, max_len=MAX_LEN,
                      rng_seed=0, max_restarts=1)
    _submit_workload(sched)
    _inject_decode_failures(sched, {1, 2}, monkeypatch)
    with pytest.raises(RuntimeError, match="injected decode failure"):
        sched.run()
    assert sched.restarts == 1
    # nothing was lost: the in-flight work is back in the queue
    assert sched.pending()


def test_default_zero_restarts_fails_fast(setup, monkeypatch):
    """max_restarts defaults to 0: recovery is opt-in, so the invariant
    suite (and any caller not expecting at-least-once semantics) sees
    executor failures immediately."""
    cfg, params = setup
    sched = Scheduler(cfg, params, num_slots=1, max_len=MAX_LEN)
    _submit_workload(sched, n=1)
    _inject_decode_failures(sched, {1}, monkeypatch)
    with pytest.raises(RuntimeError, match="injected decode failure"):
        sched.run()


def test_prefill_crash_does_not_lose_the_popped_request(
        setup, monkeypatch):
    """A prefill failure strikes BETWEEN queue pop and slot assignment —
    the request must be re-queued at its original position, not dropped."""
    cfg, params = setup
    sched = Scheduler(cfg, params, num_slots=1, max_len=MAX_LEN,
                      rng_seed=0, max_restarts=1)
    _submit_workload(sched, n=2)
    orig = sched.executor.prefill
    calls = {"n": 0}

    def flaky(prompt):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected prefill failure")
        return orig(prompt)

    monkeypatch.setattr(sched.executor, "prefill", flaky)
    done = sched.run()
    assert sorted(done) == [0, 1]
    assert sched.restarts == 1
    assert {i: s.generated for i, s in done.items()} == \
        _reference(cfg, params, n=2)


def test_straggler_monitor_watches_decode_steps(setup):
    """The training stack's StragglerMonitor plugs into serving: decode
    wall times feed its EWMA, and a deliberately slowed step is flagged."""
    cfg, params = setup
    fake = clock.FakeClock(step=0.001)
    obs = Obs(clock=fake, provenance=PROV)
    monitor = StragglerMonitor(threshold=2.0, warmup_steps=2)
    sched = Scheduler(cfg, params, num_slots=2, max_len=MAX_LEN,
                      rng_seed=0, straggler_monitor=monitor, obs=obs)
    _submit_workload(sched, n=2, max_new=8)
    for _ in range(6):
        if sched.pending():
            sched.step()
    assert monitor.mean is not None and monitor._seen >= 4
    baseline_events = len(monitor.events)
    # one decode step suddenly takes ~1000x the EWMA wall time
    fake.advance(0.0)  # no-op, keep the clock object in scope
    orig = sched.executor.decode

    def slow(tokens, positions):
        fake.advance(10.0)
        return orig(tokens, positions)

    sched.executor.decode = slow
    if sched.pending():
        sched.step()
    sched.executor.decode = orig
    sched.run()
    obs.close()
    assert len(monitor.events) > baseline_events, (
        "slowed decode step was not flagged as a straggler")
