"""Multi-device tests: each runs a script in a subprocess with 8 forced host
devices (the test process itself must keep seeing 1 device — see dryrun.py's
device-count note)."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SCRIPTS = Path(__file__).parent / "dist_scripts"
SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(script: str, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run(
        [sys.executable, str(SCRIPTS / script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{script} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n"
            f"{proc.stderr[-4000:]}"
        )
    return proc.stdout


def test_dp_tp_training_equivalence():
    out = _run("run_dp_tp_equivalence.py")
    assert "DP/TP EQUIVALENCE OK" in out


def test_moe_shardmap_and_compressed_psum():
    out = _run("run_moe_and_compression.py")
    assert "MOE+COMPRESSION OK" in out


def test_dryrun_machinery_on_8_devices():
    out = _run("run_dryrun_tiny.py")
    assert "TINY DRYRUN OK" in out
