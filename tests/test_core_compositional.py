"""Tests for Algorithm 2 (compositional kernels) and the linear models."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExponentialDotProductKernel,
    PolynomialKernel,
    RFFInnerMap,
    RademacherInnerMap,
    make_compositional_feature_map,
    make_feature_map,
    train_kernel_ridge,
    train_kernel_svm,
    train_linear,
)


def _unit_ball(key, n, d):
    x = jax.random.normal(key, (n, d))
    return x / (jnp.linalg.norm(x, axis=1, keepdims=True) * 1.05)


def test_compositional_with_dot_inner_recovers_algorithm1():
    """K_dp composed with the plain dot product == the dot product kernel."""
    kern = PolynomialKernel(4, 1.0)
    key = jax.random.PRNGKey(0)
    X = _unit_ball(key, 24, 8)
    exact = np.asarray(kern.gram(X))

    cfm = make_compositional_feature_map(
        kern,
        lambda k, num: RademacherInnerMap.create(k, num, 8),
        input_dim=8,
        num_features=4096,
        key=key,
        measure="proportional",
        inner_bound=1.0,
    )
    approx = np.asarray(cfm.estimate_gram(X))
    # relative to the kernel's scale ((1+<x,y>)^4 reaches ~13 here)
    assert np.mean(np.abs(approx - exact)) / np.abs(exact).max() < 0.02


def test_compositional_exp_of_rbf():
    """K_co = exp(K_rbf(x,y)) via RFF inner maps (paper §5's genuinely new
    kernel class)."""
    dp = ExponentialDotProductKernel(1.0)
    key = jax.random.PRNGKey(1)
    X = _unit_ball(key, 24, 6)
    inner = RFFInnerMap.create(key, 1, 6, sigma=1.0)
    k_in = np.asarray(inner.exact_kernel(X, X))
    exact = np.exp(k_in)  # f = exp, sigma2 = 1

    cfm = make_compositional_feature_map(
        dp,
        lambda k, num: RFFInnerMap.create(k, num, 6, sigma=1.0),
        input_dim=6,
        num_features=8192,
        key=jax.random.PRNGKey(2),
        measure="proportional",
        inner_bound=2.0,  # C_W for RFF: |W| <= sqrt(2)
    )
    approx = np.asarray(cfm.estimate_gram(X))
    # exact values live in [1, e]; inner-map noise compounds with degree so
    # the tolerance is looser than for Algorithm 1.
    assert np.mean(np.abs(approx - exact)) < 0.25


def test_compositional_output_dim_and_pytree():
    dp = PolynomialKernel(3, 1.0)
    cfm = make_compositional_feature_map(
        dp, lambda k, num: RademacherInnerMap.create(k, num, 4),
        input_dim=4, num_features=64, key=jax.random.PRNGKey(0),
    )
    x = jnp.ones((5, 4)) * 0.3
    z = cfm(x)
    assert z.shape == (5, cfm.output_dim)
    leaves, treedef = jax.tree_util.tree_flatten(cfm)
    cfm2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_allclose(np.asarray(cfm2(x)), np.asarray(z))


# ---------------------------------------------------------------------------
# Linear / kernel classifiers (the Table-1 machinery)
# ---------------------------------------------------------------------------
def _toy_classification(key, n=400, d=10, margin=0.3):
    kx, kw, kn = jax.random.split(key, 3)
    X = jax.random.normal(kx, (n, d))
    X = X / (jnp.linalg.norm(X, axis=1, keepdims=True) * 1.05)
    w = jax.random.normal(kw, (d,))
    y = jnp.sign(X @ w + margin * jax.random.normal(kn, (n,)) * 0.1)
    y = jnp.where(y == 0, 1.0, y)
    return X, y


@pytest.mark.parametrize("loss", ["logistic", "squared_hinge"])
def test_train_linear_separable(loss):
    X, y = _toy_classification(jax.random.PRNGKey(0))
    clf = train_linear(X, y, lam=1e-5, loss=loss)
    assert clf.accuracy(X, y) > 0.97


def test_kernel_ridge_and_svm_fit_nonlinear():
    # XOR-ish data: not linearly separable, polynomial kernel separates it.
    key = jax.random.PRNGKey(1)
    X = jax.random.uniform(key, (300, 2), minval=-1, maxval=1) * 0.7
    y = jnp.sign(X[:, 0] * X[:, 1])
    y = jnp.where(y == 0, 1.0, y)
    kern = PolynomialKernel(2, 0.1)
    gram = kern.gram(X)

    _, ridge = train_kernel_ridge(gram, y, lam=1e-6, kernel_fn=kern.gram, X_train=X)
    assert ridge.accuracy(X, y) > 0.95

    _, svm = train_kernel_svm(gram, y, C=10.0, n_epochs=30,
                              kernel_fn=kern.gram, X_train=X)
    assert svm.accuracy(X, y) > 0.95

    # linear model on raw features CANNOT separate XOR...
    lin_raw = train_linear(X, y, lam=1e-5)
    assert lin_raw.accuracy(X, y) < 0.8
    # ...but a linear model on RM features of the same kernel CAN (the
    # paper's entire point).
    fm = make_feature_map(kern, 2, 512, jax.random.PRNGKey(2),
                          measure="proportional", stratified=True)
    lin_rm = train_linear(fm(X), y, lam=1e-6)
    assert lin_rm.accuracy(fm(X), y) > 0.93
