"""Property-based invariant suite for the continuous-batching Scheduler.

THE correctness contract for serving (ISSUE 9): every future serving
change must keep these properties over random workloads — arrival order,
prompt lengths, token budgets, temperatures, priorities, evictions and
cache pressure:

  * **no slot double-assignment / well-formed lifecycles** — the obs event
    stream replays through ``tools/check_trace.check_records`` clean;
  * **no starvation** — every submitted request finishes (or is reported
    truncated when the step budget is cut short);
  * **oracle bit-identity** — each request's tokens are EXACTLY the tokens
    a sequential one-request-at-a-time run at the same ``rng_seed``
    produces, for greedy and sampled temperatures alike: scheduling is
    invisible in the output (per-request ``fold_in`` key streams);
  * **exact finish reasons** — "eos" / "max_new_tokens" / "cache_full"
    name the ACTUAL stopping condition and agree with the oracle;
  * the whole contract holds across all four registry estimator families.

The oracle runs a 1-slot scheduler per request in isolation, so identity
also proves co-batched requests never leak into each other's lanes.

Two drivers share one workload space (``gen_workload``): a deterministic
seed sweep that always runs (hypothesis is an optional dependency), and
hypothesis-driven wrappers — 270 examples total under the repo's
derandomized ci profile — that explore the same space by drawing the
generator seed. A failure in either reproduces exactly by its printed
seed.
"""
import dataclasses
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import registry
from repro.models import init_model
from repro.obs import Obs, clock
from repro.serve import Request, Scheduler

sys.path.insert(0, "tools")
from check_trace import check_records, check_request_lifecycles  # noqa: E402

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAS_HYPOTHESIS = True
except ImportError:           # local dev without hypothesis: seed sweep only
    HAS_HYPOTHESIS = False

PROV = {"backend": "test", "device_kind": "test", "device_count": 1,
        "interpret": False, "jax_version": "0"}
MAX_LEN = 32
VOCAB = 512


@pytest.fixture(scope="module")
def exact_setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return cfg, init_model(cfg, jax.random.PRNGKey(0))


_EST_CACHE = {}


def estimator_setup(name):
    if name not in _EST_CACHE:
        cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm",
                         estimator=name)
        cfg = dataclasses.replace(cfg, compute_dtype="float32")
        _EST_CACHE[name] = (cfg, init_model(cfg, jax.random.PRNGKey(0)))
    return _EST_CACHE[name]


# -- the shared workload space ------------------------------------------------
def gen_workload(seed, max_requests=4, max_prompt=8, max_new=4,
                 temperatures=(0.0, 0.7)):
    """Random workload from one generator seed — the single sample space
    both the deterministic sweep and the hypothesis driver draw from."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, max_requests + 1))
    reqs = []
    for i in range(n):
        reqs.append({
            "request_id": i,
            "prompt_seed": int(rng.integers(0, 2**16)),
            "prompt_len": int(rng.integers(1, max_prompt + 1)),
            "max_new_tokens": int(rng.integers(1, max_new + 1)),
            "temperature": float(rng.choice(np.asarray(temperatures))),
            "priority": int(rng.integers(0, 3)),
            # a tiny eos id sometimes fires on random logits, exercising
            # the eos finish path without forcing it
            "eos_token": 3 if rng.integers(0, 2) else None,
        })
    slots = int(rng.integers(1, 4))
    rng_seed = int(rng.integers(0, 2**16))
    return reqs, slots, rng_seed


def make_request(spec):
    rng = np.random.default_rng((spec["prompt_seed"], spec["request_id"]))
    return Request(request_id=spec["request_id"],
                   prompt=rng.integers(0, VOCAB, size=spec["prompt_len"]),
                   max_new_tokens=spec["max_new_tokens"],
                   temperature=spec["temperature"],
                   priority=spec["priority"],
                   eos_token=spec["eos_token"])


def oracle_run(cfg, params, spec, rng_seed, max_len=MAX_LEN):
    """One request, one slot, nothing else in the system: the sequential
    reference the batched run must reproduce bit-for-bit."""
    s = Scheduler(cfg, params, num_slots=1, max_len=max_len,
                  rng_seed=rng_seed)
    s.submit(make_request(spec))
    return s.run()[spec["request_id"]]


def assert_matches_oracle(cfg, params, done, reqs, rng_seed,
                          max_len=MAX_LEN):
    for spec in reqs:
        rid = spec["request_id"]
        ref = oracle_run(cfg, params, spec, rng_seed, max_len=max_len)
        assert done[rid].generated == ref.generated, (
            f"request {rid}: scheduled tokens {done[rid].generated} != "
            f"sequential oracle {ref.generated}")
        assert done[rid].finish_reason == ref.finish_reason


# -- the property checks (seed in, invariants out) ----------------------------
def check_main_invariants(cfg, params, seed):
    reqs, slots, rng_seed = gen_workload(seed)
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=slots, max_len=MAX_LEN,
                      rng_seed=rng_seed, obs=obs)
    for spec in reqs:
        sched.submit(make_request(spec))
    done = sched.run()
    obs.close()

    assert sorted(done) == [s["request_id"] for s in reqs]   # no starvation
    assert not sched.pending()
    spans = ("prefill", "decode/step") if any(
        len(done[s["request_id"]].generated) > 1 for s in reqs) \
        else ("prefill",)
    errors = check_records(obs.tracer.records, require_spans=spans)
    assert errors == [], errors
    assert_matches_oracle(cfg, params, done, reqs, rng_seed)


def check_eviction_replay(cfg, params, seed, evict_step, evict_pick):
    """Preempting a random in-flight slot mid-run discards its tokens, yet
    the finished output is still oracle-identical (restart-from-scratch
    replay on the request's own key stream) and the trace stays clean."""
    reqs, slots, rng_seed = gen_workload(seed, max_requests=3)
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=slots, max_len=MAX_LEN,
                      rng_seed=rng_seed, obs=obs)
    for spec in reqs:
        sched.submit(make_request(spec))
    for _ in range(evict_step):
        if sched.pending():
            sched.step()
    occupied = [i for i, s in enumerate(sched.slots) if s is not None]
    evicted_rid = None
    if occupied:
        slot = occupied[evict_pick % len(occupied)]
        had = len(sched.slots[slot].generated)
        evicted_rid = sched.evict(slot, reason="test-preempt").request_id
        assert sched.slots[slot] is None
        assert had >= 1                       # it really was mid-flight
    done = sched.run()
    obs.close()

    assert sorted(done) == [s["request_id"] for s in reqs]
    if evicted_rid is not None:
        assert done[evicted_rid].admissions >= 2
        evs = obs.tracer.events("request/evict")
        assert any(e["attrs"]["request_id"] == evicted_rid for e in evs)
    assert check_request_lifecycles(obs.tracer.records) == []
    assert_matches_oracle(cfg, params, done, reqs, rng_seed)


def check_cache_pressure(cfg, params, seed):
    """A prompt near max_len must stop with reason "cache_full" — exactly
    when its position hits the cache bound, matching the oracle — while
    co-batched short requests finish normally."""
    max_len = 16
    reqs, slots, rng_seed = gen_workload(seed, max_requests=3,
                                         max_prompt=4, max_new=12)
    long_spec = {"request_id": len(reqs), "prompt_seed": seed,
                 "prompt_len": max_len - 3, "max_new_tokens": 12,
                 "temperature": 0.0, "priority": 0, "eos_token": None}
    reqs = reqs + [long_spec]
    sched = Scheduler(cfg, params, num_slots=slots, max_len=max_len,
                      rng_seed=rng_seed)
    for spec in reqs:
        sched.submit(make_request(spec))
    done = sched.run()

    rid = long_spec["request_id"]
    assert done[rid].finish_reason == "cache_full"
    # generated exactly up to the cache bound (the last decode writes at
    # position max_len - 2; max_len - 1 is the idle-lane scratch slot):
    # prompt positions + decoded positions fill the whole cache
    assert long_spec["prompt_len"] + len(done[rid].generated) == max_len
    assert_matches_oracle(cfg, params, done, reqs, rng_seed,
                          max_len=max_len)


def check_estimator_invariants(estimator, seed):
    """The full contract — completion, clean lifecycles, oracle identity,
    exact reasons — per registry estimator family."""
    cfg, params = estimator_setup(estimator)
    reqs, slots, rng_seed = gen_workload(seed, max_requests=2,
                                         max_prompt=6, max_new=3)
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=slots, max_len=MAX_LEN,
                      rng_seed=rng_seed, obs=obs)
    assert sched.estimator == estimator
    for spec in reqs:
        sched.submit(make_request(spec))
    done = sched.run()
    obs.close()

    assert sorted(done) == [s["request_id"] for s in reqs]
    assert check_request_lifecycles(obs.tracer.records) == []
    assert_matches_oracle(cfg, params, done, reqs, rng_seed)


def check_priority_order(cfg, params, prios, rng_seed):
    """With one slot and everything queued up front, admission order is
    strictly (priority desc, submission order asc) — the heap key."""
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=1, max_len=MAX_LEN,
                      rng_seed=rng_seed, obs=obs)
    for i, p in enumerate(prios):
        rng = np.random.default_rng(i)
        sched.submit(Request(request_id=i,
                             prompt=rng.integers(0, VOCAB, size=3),
                             max_new_tokens=1, priority=p))
    sched.run()
    obs.close()
    admitted = [e["attrs"]["request_id"]
                for e in obs.tracer.events("request/admit")]
    expect = [i for _, i in sorted(((-p, i) for i, p in enumerate(prios)))]
    assert admitted == expect


# -- deterministic seed sweep (always runs) -----------------------------------
@pytest.mark.parametrize("seed", range(10))
def test_sweep_random_workload_matches_sequential_oracle(exact_setup, seed):
    check_main_invariants(*exact_setup, seed)


@pytest.mark.parametrize("seed", range(6))
def test_sweep_eviction_replays_bit_identically(exact_setup, seed):
    check_eviction_replay(*exact_setup, seed, evict_step=seed % 3,
                          evict_pick=seed)


@pytest.mark.parametrize("seed", range(4))
def test_sweep_cache_pressure_exact_reason(exact_setup, seed):
    check_cache_pressure(*exact_setup, seed)


@pytest.mark.parametrize("estimator", registry.list_estimators())
@pytest.mark.parametrize("seed", range(3))
def test_sweep_every_estimator(estimator, seed):
    check_estimator_invariants(estimator, seed)


@pytest.mark.parametrize("seed", range(5))
def test_sweep_priority_then_fifo(exact_setup, seed):
    rng = np.random.default_rng(seed)
    prios = [int(p) for p in rng.integers(0, 4, size=rng.integers(2, 6))]
    check_priority_order(*exact_setup, prios, int(rng.integers(0, 2**16)))


# -- hypothesis drivers (the >= 200-example CI gate) --------------------------
if HAS_HYPOTHESIS:
    SEEDS = st.integers(0, 2**32 - 1)

    @settings(max_examples=100, deadline=None)
    @given(seed=SEEDS)
    def test_hyp_random_workload_matches_sequential_oracle(
            exact_setup, seed):
        check_main_invariants(*exact_setup, seed)

    @settings(max_examples=50, deadline=None)
    @given(seed=SEEDS, evict_step=st.integers(0, 2),
           evict_pick=st.integers(0, 7))
    def test_hyp_eviction_replays_bit_identically(
            exact_setup, seed, evict_step, evict_pick):
        check_eviction_replay(*exact_setup, seed, evict_step, evict_pick)

    @settings(max_examples=30, deadline=None)
    @given(seed=SEEDS)
    def test_hyp_cache_pressure_exact_reason(exact_setup, seed):
        check_cache_pressure(*exact_setup, seed)

    @pytest.mark.parametrize("estimator", registry.list_estimators())
    @settings(max_examples=15, deadline=None)
    @given(seed=SEEDS)
    def test_hyp_every_estimator(estimator, seed):
        check_estimator_invariants(estimator, seed)

    @settings(max_examples=30, deadline=None)
    @given(prios=st.lists(st.integers(0, 3), min_size=2, max_size=5),
           rng_seed=st.integers(0, 2**16))
    def test_hyp_priority_then_fifo(exact_setup, prios, rng_seed):
        check_priority_order(*exact_setup, prios, rng_seed)


# -- deterministic edges ------------------------------------------------------
def test_truncated_run_reports_every_unfinished_request(exact_setup):
    """ISSUE invariant "finishes or is reported truncated": an expired
    step budget warns, counts the leftovers, and keeps them pending for a
    later run — nothing silently vanishes."""
    cfg, params = exact_setup
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=1, max_len=MAX_LEN,
                      rng_seed=0, obs=obs)
    rng = np.random.default_rng(0)
    for i in range(3):
        sched.submit(Request(request_id=i,
                             prompt=rng.integers(0, VOCAB, size=4),
                             max_new_tokens=8))
    with pytest.warns(RuntimeWarning, match="truncated"):
        done = sched.run(max_iters=1)
    pending_ids = {s.request.request_id for s in sched.slots
                   if s is not None}
    queued_ids = {r.request_id for _, _, r in sched._heap}
    assert set(done) | pending_ids | queued_ids == {0, 1, 2}
    snap = obs.metrics.snapshot(provenance=PROV)
    assert snap["counters"]["serve/truncated"] == \
        len(pending_ids) + len(queued_ids)
    # the truncated run resumes cleanly
    done = sched.run()
    assert sorted(done) == [0, 1, 2]
    obs.close()


def test_duplicate_request_id_rejected(exact_setup):
    cfg, params = exact_setup
    sched = Scheduler(cfg, params, num_slots=1, max_len=MAX_LEN)
    sched.submit(Request(request_id=7, prompt=np.zeros(3, np.int64),
                         max_new_tokens=1))
    with pytest.raises(ValueError, match="duplicate request_id 7"):
        sched.submit(Request(request_id=7, prompt=np.zeros(3, np.int64)))


def test_scheduler_output_independent_of_obs(exact_setup):
    """obs=None and a full Obs produce identical tokens — instrumentation
    never touches a jax value (same contract the engine pins)."""
    cfg, params = exact_setup

    def run(obs):
        sched = Scheduler(cfg, params, num_slots=2, max_len=MAX_LEN,
                          rng_seed=3, obs=obs)
        rng = np.random.default_rng(1)
        for i in range(3):
            sched.submit(Request(request_id=i,
                                 prompt=rng.integers(0, VOCAB, size=5),
                                 max_new_tokens=3, temperature=0.5))
        return {i: s.generated for i, s in sched.run().items()}

    off = run(None)
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    on = run(obs)
    obs.close()
    assert off == on
