"""Tests for Algorithm 1 (Random Maclaurin feature maps), H0/1, §4.2, bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ExponentialDotProductKernel,
    HomogeneousPolynomialKernel,
    PolynomialKernel,
    VovkRealKernel,
    constants_for,
    degree_measure,
    make_feature_map,
    make_truncated_feature_map,
    pointwise_failure_prob,
    truncation_degree,
)

KERNELS = [
    ExponentialDotProductKernel(1.0),
    PolynomialKernel(7, 1.0),
    HomogeneousPolynomialKernel(3),
    VovkRealKernel(4),
]


def _unit_ball_points(key, n, d):
    x = jax.random.normal(key, (n, d))
    return x / (jnp.linalg.norm(x, axis=1, keepdims=True) * 1.05)


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
@pytest.mark.parametrize("stratified", [False, True])
def test_gram_approximation_converges(kern, stratified):
    key = jax.random.PRNGKey(42)
    X = _unit_ball_points(key, 32, 10)
    exact = np.asarray(kern.gram(X), dtype=np.float64)
    scale = max(1.0, np.abs(exact).max())
    errs = []
    for D in (128, 2048):
        # average the error over independent map draws so the 1/sqrt(D)
        # convergence is visible through seed noise (iid-geometric is heavy
        # tailed for polynomial kernels — paper Fig 1b shows the same).
        e = 0.0
        for s in range(3):
            fm = make_feature_map(
                kern, 10, D, jax.random.PRNGKey(7 + s), stratified=stratified,
                measure="proportional" if stratified else "geometric",
            )
            approx = np.asarray(fm.estimate_gram(X), dtype=np.float64)
            e += np.mean(np.abs(approx - exact)) / scale
        errs.append(e / 3.0)
    # 16x features ~> 4x error drop; accept 1.6x for robustness, or already
    # tiny error at the large D.
    assert errs[1] < errs[0] / 1.6 or errs[1] < 0.01, errs
    assert errs[1] < 0.15, errs


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: k.name)
def test_unbiasedness_over_map_draws(kern):
    """E over feature-map draws of <Z(x),Z(y)> equals K(x,y) (iid mode)."""
    key = jax.random.PRNGKey(0)
    X = _unit_ball_points(key, 8, 6)
    exact = np.asarray(kern.gram(X), dtype=np.float64)
    acc = np.zeros_like(exact)
    reps = 12
    for i in range(reps):
        fm = make_feature_map(kern, 6, 256, jax.random.PRNGKey(100 + i),
                              stratified=False)
        acc += np.asarray(fm.estimate_gram(X), dtype=np.float64)
    mean = acc / reps
    scale = max(1.0, np.abs(exact).max())
    # Monte-Carlo mean over 12 x 256 features: tolerate ~3 sigma.
    assert np.mean(np.abs(mean - exact)) / scale < 0.05


def test_homogeneous_only_samples_its_degree():
    kern = HomogeneousPolynomialKernel(5)
    fm = make_feature_map(kern, 8, 256, jax.random.PRNGKey(0))
    assert fm.degrees == (5,)
    assert fm.counts == (256,)
    assert fm.const is None


def test_h01_exact_low_order_terms():
    """With D=tiny, H0/1 still gets a_0 + a_1<x,y> exactly right."""
    kern = PolynomialKernel(2, 1.0)  # (1+x)^2 = 1 + 2x + x^2
    key = jax.random.PRNGKey(3)
    X = _unit_ball_points(key, 16, 5)
    fm = make_feature_map(kern, 5, 4096, key, h01=True)
    approx = np.asarray(fm.estimate_gram(X))
    exact = np.asarray(kern.gram(X))
    assert np.mean(np.abs(approx - exact)) < 0.05
    # degree <= 1 features are exact: subtracting them leaves only x^2 term
    lin_part = 1.0 + 2.0 * np.asarray(X @ X.T)
    z = np.asarray(fm(X))
    got_lin = z[:, : 1 + 5] @ z[:, : 1 + 5].T
    np.testing.assert_allclose(got_lin, lin_part, rtol=1e-4, atol=1e-4)


def test_h01_rejects_homogeneous():
    with pytest.raises(ValueError, match="no-op"):
        make_feature_map(HomogeneousPolynomialKernel(4), 5, 64,
                         jax.random.PRNGKey(0), h01=True)


def test_degree_measure_properties():
    kern = ExponentialDotProductKernel(1.0)
    for kind in ("geometric", "geometric_ge2", "proportional"):
        q = degree_measure(kern, 24, kind=kind)
        assert abs(q.sum() - 1.0) < 1e-12
        assert (q >= 0).all()
    q2 = degree_measure(kern, 24, kind="geometric_ge2")
    assert q2[0] == 0.0 and q2[1] == 0.0
    # zero-coefficient degrees excluded from support
    qh = degree_measure(HomogeneousPolynomialKernel(3), 24, kind="geometric")
    assert qh[3] == 1.0 and qh.sum() == 1.0


def test_truncation_degree_monotone():
    kern = ExponentialDotProductKernel(1.0)
    k1, t1 = truncation_degree(kern, 1.0, 1e-2)
    k2, t2 = truncation_degree(kern, 1.0, 1e-6)
    assert k2 > k1
    assert t1 <= 1e-2 and t2 <= 1e-6


def test_truncated_map_bias_bounded():
    kern = ExponentialDotProductKernel(1.0)
    fm = make_truncated_feature_map(kern, 6, 2000, jax.random.PRNGKey(0),
                                    radius=1.0, eps_trunc=1e-3)
    assert fm.truncation_bias(1.0) < 2e-3


def test_bounds_paper_vs_proportional():
    kern = ExponentialDotProductKernel(1.0)
    c = constants_for(kern, radius=1.0, dim=16, p=2.0)
    # paper: C = p f(p R^2) = 2 e^2; proportional: f(R^2) = e
    assert np.isclose(c.c_omega, 2.0 * np.e**2)
    assert np.isclose(c.c_proportional, np.e)
    assert c.required_d(0.1, 0.01, "proportional") < c.required_d(0.1, 0.01)
    # pointwise Hoeffding decays with D
    p1 = pointwise_failure_prob(c, 1000, 0.5)
    p2 = pointwise_failure_prob(c, 100000, 0.5)
    assert p2 < p1 < 2.0


def test_bounds_radius_guard():
    from repro.core import VovkInfiniteKernel

    with pytest.raises(ValueError, match="radius"):
        constants_for(VovkInfiniteKernel(), radius=1.0, dim=4, p=2.0)


def test_feature_map_is_pytree():
    kern = ExponentialDotProductKernel(1.0)
    fm = make_feature_map(kern, 4, 64, jax.random.PRNGKey(0))
    leaves, treedef = jax.tree_util.tree_flatten(fm)
    fm2 = jax.tree_util.tree_unflatten(treedef, leaves)
    x = jnp.ones((3, 4)) * 0.2
    np.testing.assert_allclose(np.asarray(fm(x)), np.asarray(fm2(x)))

    @jax.jit
    def apply(m, x):
        return m(x)

    np.testing.assert_allclose(np.asarray(apply(fm, x)), np.asarray(fm(x)),
                               rtol=1e-6)


def test_batch_shape_handling():
    kern = PolynomialKernel(3, 1.0)
    fm = make_feature_map(kern, 8, 128, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 8)) * 0.1
    z = fm(x)
    assert z.shape == (2, 5, fm.output_dim)
    z_flat = fm(x.reshape(10, 8))
    np.testing.assert_allclose(np.asarray(z.reshape(10, -1)),
                               np.asarray(z_flat), rtol=1e-6)
