"""Serving observability: the instrumented engine must (a) emit the exact
request lifecycle on a deterministic clock, and (b) be bit-identical to the
uninstrumented engine — observability can never touch a decoded token."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.obs import Obs, clock
from repro.serve import Request, ServingEngine
from repro.serve.engine import _bucket

PROV = {"backend": "test", "device_kind": "test", "device_count": 1,
        "interpret": False, "jax_version": "0"}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    params = init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run_two_requests(cfg, params, obs):
    rng = np.random.default_rng(0)
    engine = ServingEngine(cfg, params, num_slots=1, max_len=64, obs=obs)
    for i in range(2):
        engine.submit(Request(request_id=i,
                              prompt=rng.integers(0, cfg.vocab_size, size=5),
                              max_new_tokens=2))
    return engine, engine.run()


def test_lifecycle_event_sequence_on_fake_clock(setup):
    """One slot, two requests, two tokens each: the trace must show the
    full scripted lifecycle — submit x2, then admit -> prefill ->
    finish-inside-decode per request (spans are recorded at close, so the
    decode/step span lands after the finish event it contains)."""
    cfg, params = setup
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    engine, done = _run_two_requests(cfg, params, obs)

    names = [r["name"] for r in obs.tracer.records if r["type"] != "meta"]
    assert names == [
        "request/submit", "request/submit",
        "request/admit", "prefill", "request/finish", "decode/step",
        "request/admit", "prefill", "request/finish", "decode/step",
    ]

    submits = obs.tracer.events("request/submit")
    assert [e["attrs"]["request_id"] for e in submits] == [0, 1]
    assert all(e["attrs"]["prompt_len"] == 5 for e in submits)
    admits = obs.tracer.events("request/admit")
    assert [e["attrs"]["slot"] for e in admits] == [0, 0]
    assert all(e["attrs"]["bucket"] == 32 for e in admits)
    finishes = obs.tracer.events("request/finish")
    assert [e["attrs"]["tokens"] for e in finishes] == [2, 2]
    assert all(e["attrs"]["reason"] == "max_new_tokens" for e in finishes)
    for sp in obs.tracer.spans("prefill"):
        assert sp["attrs"]["bucket"] == 32 and sp["attrs"]["prompt_len"] == 5
        assert sp["dur_us"] > 0
    obs.close()


def test_lifecycle_histograms_hold_exact_fake_clock_values(setup):
    """Histogram VALUES (not just counts) are pinned by the fake clock:
    every duration is a difference of deterministic clock reads, so the
    recorded TTFTs equal the engine's own timestamp fields exactly."""
    cfg, params = setup
    obs = Obs(clock=clock.FakeClock(step=1.0), provenance=PROV)
    engine, done = _run_two_requests(cfg, params, obs)

    ttft = obs.metrics.histogram("serve/ttft_s")
    expect = sorted(s.t_first_token - s.t_enqueue for s in done.values())
    assert sorted(ttft._vals) == expect
    assert ttft.count == 2
    # every fake-clock duration is a whole number of 1.0s steps and spans
    # real work: submit->first-token crosses the prefill span (>= 2 reads)
    assert all(v == int(v) and v >= 2.0 for v in ttft._vals)

    lat = obs.metrics.histogram("serve/token_latency_s")
    assert lat.count == 2                      # one decode iteration per req
    assert all(v == int(v) and v > 0 for v in lat._vals)
    tps = obs.metrics.histogram("serve/tokens_per_s")
    assert tps.count == 2
    expect_tps = sorted(2.0 / (s.t_done - s.t_enqueue)
                        for s in done.values())
    assert sorted(tps._vals) == expect_tps

    snap = obs.metrics.snapshot(provenance=PROV)
    assert snap["counters"]["serve/requests_submitted"] == 2.0
    assert snap["counters"]["serve/tokens_generated"] == 2.0
    assert snap["gauges"]["serve/queue_depth"] == 0.0
    assert snap["gauges"]["serve/slots_occupied"] == 0.0
    obs.close()


def test_obs_disabled_is_bit_identical(setup):
    """obs=None and a fully-enabled Obs must produce the same tokens —
    instrumentation never touches a jax value."""
    cfg, params = setup
    _, done_off = _run_two_requests(cfg, params, None)
    obs = Obs(clock=clock.FakeClock(), provenance=PROV,
              install_kernel_tracing=True)
    _, done_on = _run_two_requests(cfg, params, obs)
    obs.close()
    assert {i: s.generated for i, s in done_off.items()} == \
           {i: s.generated for i, s in done_on.items()}


def test_bucket_raises_clear_valueerror_on_oversized_prompt():
    """Regression: prompts beyond the largest bucket used to fall into an
    unbounded round-up; now they fail fast with the max length named."""
    assert _bucket(2048) == 2048
    with pytest.raises(ValueError, match="2048"):
        _bucket(2049)


def test_submit_rejects_prompt_at_or_beyond_max_len(setup):
    cfg, params = setup
    engine = ServingEngine(cfg, params, num_slots=1, max_len=16)
    with pytest.raises(ValueError, match="max_len 16"):
        engine.submit(Request(request_id=0,
                              prompt=np.zeros(16, np.int64)))
    # one-under still admits fine at the engine API level
    engine.submit(Request(request_id=1, prompt=np.zeros(15, np.int64),
                          max_new_tokens=1))
