"""Integration: the Trainer learns, checkpoints, resumes deterministically;
the data pipeline is step-indexed & host-shardable."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.synthetic import SyntheticLMDataset
from repro.models.config import ModelConfig
from repro.train.steps import TrainHyper
from repro.train.trainer import Trainer

CFG = ModelConfig(name="itiny", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=2, d_ff=128, vocab_size=128,
                  tie_embeddings=True).validate()


def _mk_trainer(tmp=None, steps=24):
    data = SyntheticLMDataset(vocab_size=128, seq_len=64, global_batch=4,
                              num_contexts=64)
    hyper = TrainHyper(peak_lr=5e-3, warmup_steps=3, total_steps=steps)
    return Trainer(CFG, hyper, data, ckpt_dir=tmp, log_every=100,
                   checkpoint_every=10)


def test_loss_decreases():
    tr = _mk_trainer(steps=25)
    tr.train(25)
    first = tr.metrics_log[0]["ce"]
    last = tr.metrics_log[-1]["ce"]
    assert last < first - 0.2, (first, last)


def test_checkpoint_resume_is_deterministic(tmp_path):
    # run A: 20 straight steps
    tr_a = _mk_trainer(str(tmp_path / "a"), steps=20)
    state_a = tr_a.train(20)
    # run B: 10 steps, "crash", new trainer resumes from step 10
    tr_b1 = _mk_trainer(str(tmp_path / "b"), steps=20)
    tr_b1.train(10)
    tr_b2 = _mk_trainer(str(tmp_path / "b"), steps=20)
    state_b = tr_b2.train(20)
    wa = np.asarray(state_a["params"]["embed"]["embedding"])
    wb = np.asarray(state_b["params"]["embed"]["embedding"])
    np.testing.assert_allclose(wa, wb, rtol=1e-5, atol=1e-6)
    assert int(state_a["step"]) == int(state_b["step"]) == 20


def test_dataset_host_sharding_partitions_batch():
    full = SyntheticLMDataset(vocab_size=64, seq_len=16, global_batch=4,
                              seed=7)
    parts = [
        SyntheticLMDataset(vocab_size=64, seq_len=16, global_batch=4,
                           seed=7, num_hosts=2, host_index=i)
        for i in range(2)
    ]
    b_full = full.batch_at(3)
    b0, b1 = parts[0].batch_at(3), parts[1].batch_at(3)
    assert b0["tokens"].shape == (2, 16)
    # deterministic per (step, host): re-evaluation is identical
    np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                  np.asarray(parts[0].batch_at(3)["tokens"]))
    # and full-batch generation is reproducible
    np.testing.assert_array_equal(np.asarray(b_full["tokens"]),
                                  np.asarray(full.batch_at(3)["tokens"]))


def test_grad_accum_equivalence():
    """grad_accum=2 must produce the same update as accum=1 on the same
    global batch (linearity of gradients + mean loss)."""
    from repro.train.steps import init_train_state, make_train_step

    cfg = dataclasses.replace(CFG, compute_dtype="float32", remat=False)
    data = SyntheticLMDataset(vocab_size=128, seq_len=32, global_batch=4)
    batch = data.batch_at(0)
    h1 = TrainHyper(peak_lr=1e-3, warmup_steps=1, total_steps=10,
                    grad_accum=1)
    h2 = dataclasses.replace(h1, grad_accum=2)
    s1 = init_train_state(cfg, jax.random.PRNGKey(0), h1)
    s2 = init_train_state(cfg, jax.random.PRNGKey(0), h2)
    s1, m1 = jax.jit(make_train_step(cfg, h1))(s1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, h2))(s2, batch)
    np.testing.assert_allclose(float(m1["ce"]), float(m2["ce"]), rtol=1e-5)
    w1 = np.asarray(s1["params"]["embed"]["embedding"])
    w2 = np.asarray(s2["params"]["embed"]["embedding"])
    np.testing.assert_allclose(w1, w2, rtol=1e-4, atol=1e-6)
