"""repro.obs under concurrent serving: interleaved multi-request traces
must validate through ``tools/check_trace`` IN-PROCESS (not just the CI
smoke job), including the scheduler's admission/eviction spans — and the
lifecycle checker itself must actually reject malformed interleavings.
"""
import dataclasses
import sys

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_model
from repro.obs import Obs, clock
from repro.serve import Request, Scheduler

sys.path.insert(0, "tools")
from check_trace import (  # noqa: E402
    check_records,
    check_request_lifecycles,
)

PROV = {"backend": "test", "device_kind": "test", "device_count": 1,
        "interpret": False, "jax_version": "0"}
VOCAB = 512


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-1.7b", smoke=True)
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return cfg, init_model(cfg, jax.random.PRNGKey(0))


def _interleaved_run(cfg, params, *, evict=False):
    """More requests than slots, staggered submits, optional preemption:
    admissions, decodes and finishes interleave across requests."""
    obs = Obs(clock=clock.FakeClock(), provenance=PROV)
    sched = Scheduler(cfg, params, num_slots=2, max_len=32, rng_seed=0,
                      obs=obs)
    rng = np.random.default_rng(0)
    for i in range(3):
        sched.submit(Request(request_id=i,
                             prompt=rng.integers(0, VOCAB, size=4 + i),
                             max_new_tokens=4))
    sched.step()
    # late arrival lands while slots are mid-decode
    sched.submit(Request(request_id=3,
                         prompt=rng.integers(0, VOCAB, size=6),
                         max_new_tokens=3))
    if evict:
        occupied = [i for i, s in enumerate(sched.slots) if s is not None]
        sched.evict(occupied[0], reason="preempted")
    sched.run()
    obs.close()
    return obs


def test_interleaved_trace_validates_in_process(setup):
    """The live Tracer.records of an interleaved 4-request/2-slot run pass
    the full check_records gate — spans, events, lifecycles, Chrome
    conversion — without a file round-trip."""
    obs = _interleaved_run(*setup)
    errors = check_records(obs.tracer.records)
    assert errors == [], errors
    # the run genuinely interleaved: an admit lands after the first finish
    names = [r["name"] for r in obs.tracer.records
             if r["type"] == "event" and r["name"].startswith("request/")]
    first_finish = names.index("request/finish")
    assert "request/admit" in names[first_finish:]


def test_admission_spans_carry_slot_and_bucket(setup):
    obs = _interleaved_run(*setup)
    admits = obs.tracer.spans("admit")
    assert len(admits) == 4
    for sp in admits:
        assert sp["attrs"]["slot"] in (0, 1)
        assert sp["attrs"]["bucket"] == 32
        assert sp["attrs"]["attempt"] >= 1
        assert sp["dur_us"] > 0
    # queue-age gauge was maintained while requests waited
    snap = obs.metrics.snapshot(provenance=PROV)
    assert "serve/queue_age_s" in snap["gauges"]


def test_eviction_spans_validate_and_carry_reason(setup):
    obs = _interleaved_run(*setup, evict=True)
    errors = check_records(obs.tracer.records)
    assert errors == [], errors
    evs = obs.tracer.spans("evict")
    assert len(evs) == 1
    assert evs[0]["attrs"]["reason"] == "preempted"
    discards = obs.tracer.events("request/evict")
    assert len(discards) == 1
    assert discards[0]["attrs"]["tokens_discarded"] >= 1
    # the evicted request was re-admitted: 5 admits for 4 requests
    assert len(obs.tracer.spans("admit")) == 5


# -- the checker must catch malformed interleavings ---------------------------
def _ev(name, **attrs):
    return {"type": "event", "name": name, "ts_us": 0.0, "attrs": attrs}


def test_checker_flags_slot_double_assignment():
    records = [
        _ev("request/submit", request_id=0),
        _ev("request/submit", request_id=1),
        _ev("request/admit", request_id=0, slot=0),
        _ev("request/admit", request_id=1, slot=0),   # 0 still running!
    ]
    errors = check_request_lifecycles(records)
    assert any("double-assignment" in e for e in errors), errors


def test_checker_flags_admit_without_submit_and_after_finish():
    records = [
        _ev("request/admit", request_id=0, slot=0),   # never submitted
        _ev("request/submit", request_id=1),
        _ev("request/admit", request_id=1, slot=1),
        _ev("request/finish", request_id=1, slot=1, tokens=1, reason="eos"),
        _ev("request/admit", request_id=1, slot=1),   # admit after finish
    ]
    errors = check_request_lifecycles(records)
    assert any("never submitted" in e for e in errors), errors
    assert any("'done'" in e for e in errors), errors


def test_checker_flags_duplicate_submit_and_orphan_evict():
    records = [
        _ev("request/submit", request_id=0),
        _ev("request/submit", request_id=0),          # duplicate
        _ev("request/evict", request_id=0, slot=0),   # evict while queued
    ]
    errors = check_request_lifecycles(records)
    assert any("duplicate submit" in e for e in errors), errors
    assert any("evict while" in e for e in errors), errors


def test_checker_accepts_evict_readmit_cycle():
    records = [
        _ev("request/submit", request_id=0),
        _ev("request/admit", request_id=0, slot=0),
        _ev("request/evict", request_id=0, slot=0),
        _ev("request/admit", request_id=0, slot=1),
        _ev("request/finish", request_id=0, slot=1, tokens=2,
            reason="max_new_tokens"),
    ]
    assert check_request_lifecycles(records) == []


def test_checker_accepts_truncated_inflight_requests():
    """Requests still queued or running at trace end are legal."""
    records = [
        _ev("request/submit", request_id=0),
        _ev("request/submit", request_id=1),
        _ev("request/admit", request_id=0, slot=0),
    ]
    assert check_request_lifecycles(records) == []
