"""Tests for the unified benchmark subsystem (repro.bench).

The runner is exercised on a micro grid (interpret-mode fused path, one
tiny shape) so CI holds the mechanism — spec -> cells -> canonical JSON ->
coverage gate — without paying real benchmark time. The committed
``BENCH_core.json`` trajectory artifact is itself schema-checked here, so
a PR that regenerates it with missing cells fails tier-1 before the
bench-core CI job even runs.
"""
import dataclasses
import json
from pathlib import Path

import pytest

from repro.bench import (
    AttnShapeSpec,
    BenchSpec,
    ShapeSpec,
    analytic_cost,
    cell_key,
    check_file,
    check_payload,
    diff_coverage,
    make_kernel,
    quick_spec,
)
from repro.bench.runner import run_spec

REPO_ROOT = Path(__file__).resolve().parent.parent

_MICRO = BenchSpec(
    shapes=(ShapeSpec("micro_exp", "exp", d=4, F=16, batch=8,
                      gram_points=6),),
    attention_shapes=(AttnShapeSpec("micro_attn", "exp", d=4, F=16,
                                    heads=1, T=16, dv=4, batch=1, chunk=8),),
    repeats=1,
    interpret=True,
    quick=True,
)


@pytest.fixture(scope="module")
def micro_payload():
    rows = []
    payload = run_spec(_MICRO, emit=rows.append)
    return payload, rows


def test_run_spec_full_coverage(micro_payload):
    payload, rows = micro_payload
    assert check_payload(payload, min_shapes=1) == []
    assert rows  # the runner narrates
    cells = payload["results"]["micro_exp"]["cells"]
    from repro.core import registry

    for est in registry.list_estimators():
        for prec in ("fp32", "bf16"):
            cell = cells[cell_key(est, prec)]
            assert cell["fused_us"] > 0 and cell["oracle_us"] > 0
            assert cell["gram_rmse"] >= 0
            assert cell["flops"] > 0 and cell["bytes_moved"] > 0

    attn_cells = payload["fused_attention"]["micro_attn"]["cells"]
    for est in registry.list_estimators():
        supported = registry.get(est).fused_attention_supported
        for prec in ("fp32", "bf16"):
            cell = attn_cells[cell_key(est, prec)]
            assert cell["fused_us"] > 0 and cell["two_launch_us"] > 0
            assert cell["speedup"] > 0
            assert cell["fused_supported"] == supported
            if supported:
                # the removed Z(x) round-trip shows up in the analytic bytes
                assert (cell["hbm_bytes_fused"]
                        < cell["hbm_bytes_two_launch"])
            else:
                assert (cell["hbm_bytes_fused"]
                        == cell["hbm_bytes_two_launch"])
                assert cell["speedup"] == 1.0


def test_payload_is_json_round_trippable(micro_payload, tmp_path):
    payload, _ = micro_payload
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(payload))
    assert check_file(p, min_shapes=1) == []


def test_coverage_gate_catches_missing_cells(micro_payload):
    payload, _ = micro_payload
    broken = json.loads(json.dumps(payload))        # deep copy
    removed = cell_key("rm", "bf16")
    del broken["results"]["micro_exp"]["cells"][removed]
    errs = check_payload(broken, min_shapes=1)
    assert any(removed in e for e in errs)
    diffs = diff_coverage(payload, broken)
    assert any(removed in d for d in diffs)
    # symmetric direction
    diffs_rev = diff_coverage(broken, payload)
    assert any(removed in d for d in diffs_rev)


def test_coverage_gate_catches_missing_attention_cells(micro_payload):
    """Schema v2: losing a fused_attention cell (or the whole section)
    fails both the payload check and the cross-artifact diff."""
    payload, _ = micro_payload
    broken = json.loads(json.dumps(payload))
    removed = cell_key("rm", "fp32")
    del broken["fused_attention"]["micro_attn"]["cells"][removed]
    errs = check_payload(broken, min_shapes=1)
    assert any("fused_attention" in e and removed in e for e in errs)
    assert any("fused_attention" in d and removed in d
               for d in diff_coverage(payload, broken))
    gone = dict(payload, fused_attention={})
    assert any("fused_attention" in e
               for e in check_payload(gone, min_shapes=1))


def test_schema_rejects_wrong_version(micro_payload):
    payload, _ = micro_payload
    stale = dict(payload, schema_version=0)
    assert any("schema_version" in e
               for e in check_payload(stale, min_shapes=1))


def test_analytic_cost_precision_aware():
    from repro.core import make_feature_map
    import jax

    kern = make_kernel("exp")
    for est in ("rm", "ctr", "tensor_sketch"):
        fm = make_feature_map(kern, 8, 64, jax.random.PRNGKey(0),
                              estimator=est, measure="proportional")
        c32 = analytic_cost(est, fm.plan, 128, "fp32")
        c16 = analytic_cost(est, fm.plan, 128, "bf16")
        assert c32["flops"] == c16["flops"]          # same math
        assert c16["bytes_moved"] < c32["bytes_moved"]  # half the operands
        assert c16["intensity_flops_per_byte"] > c32[
            "intensity_flops_per_byte"]


def test_make_kernel_names():
    assert make_kernel("exp").name.startswith("exp")
    assert make_kernel("poly3") is not None
    with pytest.raises(ValueError):
        make_kernel("rbf")


def test_quick_spec_meets_ci_coverage_floor():
    """The CI bench-core job runs --quick and fails on missing cells, so
    quick mode itself must span >= 3 shapes x both precisions."""
    spec = quick_spec()
    assert len(spec.shapes) >= 3
    assert set(spec.precisions) >= {"fp32", "bf16"}
    # schema v2: quick mode must also cover the fused_attention section
    assert len(spec.attention_shapes) >= 1


def test_committed_bench_core_artifact_passes_gate():
    """BENCH_core.json at the repo root must carry full estimator x
    {fp32, bf16} x >= 3-shape coverage (acceptance criterion)."""
    path = REPO_ROOT / "BENCH_core.json"
    assert path.exists(), "BENCH_core.json missing at repo root"
    assert check_file(path, min_shapes=3) == []


def test_cli_check_mode(tmp_path, micro_payload):
    payload, _ = micro_payload
    from repro.bench.__main__ import main

    good = tmp_path / "good.json"
    good.write_text(json.dumps(payload))
    # micro payload has 1 shape < 3 -> the CLI min_shapes=3 gate trips
    assert main(["--check", str(good)]) == 1
    assert main(["--check", str(REPO_ROOT / "BENCH_core.json")]) == 0
    assert main(["--check", str(REPO_ROOT / "BENCH_core.json"),
                 "--against", str(REPO_ROOT / "BENCH_core.json")]) == 0