"""Fused featurize+attention kernels vs the two-launch composition.

The fused ops (kernels/rm_attention/fused.py, DESIGN.md §13) compute
``rm_attention_*(Z(q), Z(k), v)`` without ever materializing Z in HBM.
Contracts held here:

* parity at 1e-5 against BOTH the two-launch Pallas composition
  (featurize launches + attention launch) and the jnp oracle — causal,
  noncausal, prefill (outputs AND final state) and the decode step;
* bf16-in / fp32-accum: the in-VMEM featurize and the state accumulation
  keep fp32 accumulators under the bf16 precision policy (adversarial
  2^-9 probe, same discipline as tests/test_precision.py);
* edge shapes: batch=0, one-tile F, chunk > T, T=1;
* kvalid masks padded keys out of scores and state;
* gradients flow (custom VJP over the chunked-XLA formulation);
* model level: ``cfg.rm.fuse_featurize`` "on" matches "off", and the
  serving engine validates/reports the flag.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ExponentialDotProductKernel, make_feature_map, registry
from repro.kernels.rm_attention import (
    rm_attention_causal,
    rm_attention_decode_step,
    rm_attention_fused_causal,
    rm_attention_fused_decode_step,
    rm_attention_fused_noncausal,
    rm_attention_fused_prefill,
    rm_attention_noncausal,
)
from repro.kernels.rm_attention.ops import rm_attention_prefill_final_state

KERN = ExponentialDotProductKernel(1.0)


def _setup(d=12, F=96, seed=0):
    """Feature map + packed fused tensors for the rm registry entry."""
    fm = make_feature_map(KERN, d, F, jax.random.PRNGKey(seed),
                          measure="proportional")
    est = registry.get("rm")
    w, col_deg, col_scale = est.pack_fused(fm.plan, {"omegas": fm.omegas})
    return fm, jnp.asarray(w), col_deg, col_scale


def _qkv(key, b, h, t, d, dv, scale=0.3):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (b, h, t, d)) * scale
    k = jax.random.normal(kk, (b, h, t, d)) * scale
    v = jax.random.normal(kv, (b, h, t, dv))
    return q, k, v


def _featurize(fm, x, **kw):
    b, h, t, d = x.shape
    return fm.apply(x.reshape(-1, d), **kw).reshape(b, h, t, -1)


SHAPES = [
    # (b, h, t, d, dv, chunk)
    (1, 1, 16, 8, 8, 8),
    (2, 2, 48, 12, 8, 16),
    (1, 2, 37, 12, 4, 16),    # t not divisible by chunk
]


@pytest.mark.parametrize("b,h,t,d,dv,chunk", SHAPES)
def test_fused_causal_matches_two_launch_and_oracle(b, h, t, d, dv, chunk):
    fm, w, deg, sc = _setup(d=d)
    q, k, v = _qkv(jax.random.PRNGKey(t), b, h, t, d, dv)
    got = rm_attention_fused_causal(q, k, v, w, deg, sc, chunk=chunk,
                                    use_pallas=True, interpret=True)
    # two-launch Pallas composition: featurize launches + attention launch
    zq = _featurize(fm, q, use_pallas=True, interpret=True)
    zk = _featurize(fm, k, use_pallas=True, interpret=True)
    two = rm_attention_causal(zq, zk, v, chunk=chunk, use_pallas=True,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(two),
                               rtol=1e-5, atol=1e-5)
    # jnp oracle (also the custom-VJP backward formulation)
    oracle = rm_attention_fused_causal(q, k, v, w, deg, sc, chunk=chunk,
                                       use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b,h,t,d,dv,chunk", SHAPES)
def test_fused_noncausal_matches_two_launch(b, h, t, d, dv, chunk):
    fm, w, deg, sc = _setup(d=d)
    q, k, v = _qkv(jax.random.PRNGKey(100 + t), b, h, t, d, dv)
    got = rm_attention_fused_noncausal(q, k, v, w, deg, sc, chunk=chunk,
                                       use_pallas=True, interpret=True)
    zq = _featurize(fm, q, use_pallas=False)
    zk = _featurize(fm, k, use_pallas=False)
    want = rm_attention_noncausal(zq, zk, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_fused_prefill_outputs_and_state():
    """One launch yields causal outputs AND the whole-prefix decode state."""
    fm, w, deg, sc = _setup()
    b, h, t, d, dv = 1, 2, 40, 12, 8
    q, k, v = _qkv(jax.random.PRNGKey(7), b, h, t, d, dv)
    out, s, n = rm_attention_fused_prefill(q, k, v, w, deg, sc, chunk=16,
                                           use_pallas=True, interpret=True)
    zq = _featurize(fm, q, use_pallas=False)
    zk = _featurize(fm, k, use_pallas=False)
    want_out = rm_attention_causal(zq, zk, v, chunk=16, use_pallas=False)
    want_s, want_n = rm_attention_prefill_final_state(zk, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(n), np.asarray(want_n),
                               rtol=1e-5, atol=1e-5)


def test_fused_decode_step_matches_two_launch_decode():
    """Fused decode (one featurize launch for q+k) == featurize-then-update;
    decoding from a fused-prefill state tracks the full causal oracle."""
    fm, w, deg, sc = _setup()
    b, h, t, d, dv = 1, 2, 24, 12, 8
    q, k, v = _qkv(jax.random.PRNGKey(9), b, h, t + 4, d, dv)
    zq = _featurize(fm, q, use_pallas=False)
    zk = _featurize(fm, k, use_pallas=False)
    full = rm_attention_causal(zq, zk, v, chunk=8, use_pallas=False)

    _, s, n = rm_attention_fused_prefill(
        q[:, :, :t], k[:, :, :t], v[:, :, :t], w, deg, sc, chunk=8,
        use_pallas=True, interpret=True)
    s2, n2 = jnp.asarray(s), jnp.asarray(n)
    for i in range(4):
        o, s, n = rm_attention_fused_decode_step(
            q[:, :, t + i], k[:, :, t + i], v[:, :, t + i], s, n,
            w, deg, sc, use_pallas=True, interpret=True)
        o2, s2, n2 = rm_attention_decode_step(
            zq[:, :, t + i], zk[:, :, t + i], v[:, :, t + i], s2, n2)
        np.testing.assert_allclose(np.asarray(o), np.asarray(o2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(o), np.asarray(full[:, :, t + i]),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# bf16-in / fp32-accum
# ---------------------------------------------------------------------------
_VAL = 2.0 ** -9   # exact in bf16; 4096 * _VAL = 8.0 only under fp32 accum


def test_fused_attention_accumulates_fp32():
    """All-ones depth-1 probe: each feature is a 4096-term sum of 2^-9
    (exactly 8.0 under fp32 accumulation, stalls near 1.0 under bf16), and
    the state rows sum 16 of those. Any bf16 accumulator — featurize, score,
    or state — would miss by >10x the tolerance."""
    d_big, f, t = 4096, 8, 16
    w = jnp.ones((1, f, d_big), jnp.bfloat16)
    deg = tuple([1] * f)
    sc = tuple([1.0] * f)
    q = jnp.full((1, 1, t, d_big), _VAL, jnp.bfloat16)
    k = jnp.full((1, 1, t, d_big), _VAL, jnp.bfloat16)
    v = jnp.ones((1, 1, t, 4), jnp.float32)
    out, s, n = rm_attention_fused_prefill(q, k, v, w, deg, sc, chunk=8,
                                           use_pallas=True, interpret=True)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(n), t * 8.0, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), t * 8.0, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-3)


def test_fused_bf16_budget_vs_fp32():
    """bf16 inputs/weights may only move the causal outputs by the rounding
    of x and w — fp32 accumulation keeps the rest."""
    fm, w, deg, sc = _setup(d=16, F=128)
    q, k, v = _qkv(jax.random.PRNGKey(11), 1, 2, 48, 16, 8)
    out32 = rm_attention_fused_causal(q, k, v, w, deg, sc, chunk=16,
                                      use_pallas=True, interpret=True)
    outb = rm_attention_fused_causal(
        q.astype(jnp.bfloat16), k.astype(jnp.bfloat16), v,
        w.astype(jnp.bfloat16), deg, sc, chunk=16,
        use_pallas=True, interpret=True)
    assert outb.dtype == jnp.float32
    scale = max(float(np.max(np.abs(np.asarray(out32)))), 1e-6)
    assert float(np.max(np.abs(np.asarray(outb - out32)))) <= 5e-2 * scale


# ---------------------------------------------------------------------------
# edge shapes + masking
# ---------------------------------------------------------------------------
def test_edge_batch_zero():
    _, w, deg, sc = _setup()
    q = jnp.zeros((0, 2, 8, 12))
    v = jnp.zeros((0, 2, 8, 4))
    out = rm_attention_fused_causal(q, q, v, w, deg, sc, chunk=8,
                                    use_pallas=True, interpret=True)
    assert out.shape == (0, 2, 8, 4)
    out, s, n = rm_attention_fused_prefill(q, q, v, w, deg, sc, chunk=8,
                                           use_pallas=True, interpret=True)
    assert out.shape == (0, 2, 8, 4)
    assert s.shape[0] == 0 and n.shape[0] == 0


def test_edge_one_tile_features_and_chunk_gt_t():
    """F below one feature block and chunk far above T: a single-cell grid,
    fully exercised by the padding invariants (deg=0/scale=0 columns)."""
    fm, w, deg, sc = _setup(d=6, F=8)
    q, k, v = _qkv(jax.random.PRNGKey(13), 1, 1, 5, 6, 3)
    got = rm_attention_fused_causal(q, k, v, w, deg, sc, chunk=512,
                                    block_f=512, use_pallas=True,
                                    interpret=True)
    want = rm_attention_fused_causal(q, k, v, w, deg, sc, chunk=4,
                                     use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_edge_t_one():
    _, w, deg, sc = _setup()
    q, k, v = _qkv(jax.random.PRNGKey(14), 2, 1, 1, 12, 4)
    got = rm_attention_fused_causal(q, k, v, w, deg, sc, chunk=8,
                                    use_pallas=True, interpret=True)
    want = rm_attention_fused_causal(q, k, v, w, deg, sc, chunk=8,
                                     use_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kvalid_masks_padded_keys():
    """Zeroed kvalid rows contribute nothing: outputs at valid positions
    equal the run over the unpadded prefix."""
    _, w, deg, sc = _setup()
    b, h, t, tv = 2, 2, 20, 14
    q, k, v = _qkv(jax.random.PRNGKey(15), b, h, t, 12, 4)
    kvalid = (jnp.arange(t) < tv).astype(jnp.float32)[None, :].repeat(b, 0)
    got = rm_attention_fused_causal(q, k, v, w, deg, sc, kvalid=kvalid,
                                    chunk=8, use_pallas=True, interpret=True)
    want = rm_attention_fused_causal(q[:, :, :tv], k[:, :, :tv],
                                     v[:, :, :tv], w, deg, sc, chunk=8,
                                     use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got[:, :, :tv]),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fused_causal_grads_flow():
    _, w, deg, sc = _setup(d=8, F=48)
    q, k, v = _qkv(jax.random.PRNGKey(17), 1, 2, 24, 8, 4)

    def loss(q_, k_, v_, w_):
        out = rm_attention_fused_causal(q_, k_, v_, w_, deg, sc, chunk=8,
                                        use_pallas=True, interpret=True)
        return jnp.sum(out ** 2)

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, w)
    for g in grads:
        assert np.isfinite(np.asarray(g)).all()
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in grads)


# ---------------------------------------------------------------------------
# model + serving integration
# ---------------------------------------------------------------------------
def _rm_cfg(mode):
    from repro.configs import get_config

    cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
    cfg = dataclasses.replace(cfg, compute_dtype="float32")
    return dataclasses.replace(
        cfg, rm=dataclasses.replace(cfg.rm, fuse_featurize=mode))


def test_model_fuse_on_matches_off():
    """cfg.rm.fuse_featurize="on" (fused formulation) == "off" (two-launch)
    at the logits level — the flag changes the launch structure, never the
    math."""
    from repro.models import forward, init_model

    cfg_off = _rm_cfg("off")
    cfg_on = _rm_cfg("on")
    params = init_model(cfg_off, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg_off.vocab_size)
    lo_off, _ = forward(params, cfg_off, {"tokens": tokens})
    lo_on, _ = forward(params, cfg_on, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(lo_on), np.asarray(lo_off),
                               rtol=2e-3, atol=2e-3)


def test_model_fused_prefill_decode_consistency():
    """Fused prefill (out + state from one formulation) then fused decode
    must track the fused full forward — the serving invariant."""
    from repro.models import decode_step, forward, init_model, prefill

    cfg = _rm_cfg("on")
    params = init_model(cfg, jax.random.PRNGKey(0))
    b, t_prompt, t_extra = 2, 12, 3
    tokens = jax.random.randint(jax.random.PRNGKey(1),
                                (b, t_prompt + t_extra), 0, cfg.vocab_size)
    full_logits, _ = forward(params, cfg, {"tokens": tokens})
    pre_logits, cache = prefill(params, cfg,
                                {"tokens": tokens[:, :t_prompt]}, max_len=32)
    np.testing.assert_allclose(
        np.asarray(pre_logits), np.asarray(full_logits[:, :t_prompt]),
        rtol=2e-3, atol=2e-3)
    for i in range(t_extra):
        pos = jnp.full((b,), t_prompt + i, jnp.int32)
        step_logits, cache = decode_step(params, cfg, cache,
                                         tokens[:, t_prompt + i][:, None],
                                         pos)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]),
            np.asarray(full_logits[:, t_prompt + i]),
            rtol=5e-3, atol=5e-3)


def test_engine_reports_and_validates_fuse_flag():
    from repro.models import init_model
    from repro.serve import ServingEngine

    cfg = _rm_cfg("on")
    params = init_model(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, num_slots=2, max_len=32)
    assert eng.fused_attention is True
    cfg_off = _rm_cfg("off")
    assert ServingEngine(cfg_off, params, num_slots=2,
                         max_len=32).fused_attention is False
    with pytest.raises(ValueError, match="fuse_featurize"):
        ServingEngine(_rm_cfg("bogus"), params, num_slots=2, max_len=32)
