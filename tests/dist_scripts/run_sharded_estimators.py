"""Subprocess script: sharded estimator execution over 8 host devices.

For BOTH registry estimators ("rm", "tensor_sketch"):
  * per-shard params drawn on-device with fold_in(key, mesh coordinate) are
    bit-identical to the host-loop stack;
  * sharded apply (features over the "rm_features" axis) is bit-identical
    to the single-device reference;
  * sharded estimate_gram (ONE psum of per-shard partial Grams) matches the
    single-device result to 1e-5;
plus a data-parallel serving-engine smoke decode whose greedy generations
match the meshless engine.

Launched by tests/test_distributed_estimators.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "launch via test_distributed_estimators"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ExponentialDotProductKernel, make_feature_map  # noqa: E402
from repro.core.registry import list_estimators  # noqa: E402
from repro.distributed import shard_init_params  # noqa: E402
from repro.launch.mesh import make_feature_mesh  # noqa: E402

assert len(jax.devices()) == 8

kern = ExponentialDotProductKernel(1.0)
mesh = make_feature_mesh()
d, F = 12, 1024
key = jax.random.PRNGKey(0)
X = jax.random.normal(jax.random.PRNGKey(1), (33, d))
X = X / jnp.linalg.norm(X, axis=1, keepdims=True) * 0.8
Y = jax.random.normal(jax.random.PRNGKey(2), (9, d)) * 0.2

for name in list_estimators():
    fm = make_feature_map(kern, d, F, key, estimator=name,
                          measure="proportional", mesh=mesh)
    # (RM collapses its per-shard degree-0 allocation into one const column,
    # so output_dim <= F; the shard split itself must be exact.)
    assert fm.num_shards == 8
    assert fm.output_dim == 8 * fm.shard_output_dim

    # fold-in rule: on-device init == host loop, bit-for-bit
    host = shard_init_params(name, fm.plan, key, fm.num_shards)
    same = jax.tree_util.tree_map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()),
        fm.params, host)
    assert all(jax.tree_util.tree_leaves(same)), (name, same)

    # sharded apply: bit-identical to the single-device reference
    z_mesh = np.asarray(fm.apply(X, sharded=True, use_pallas=False))
    z_ref = np.asarray(fm.apply(X, sharded=False, use_pallas=False))
    assert z_mesh.shape == (33, fm.output_dim)
    assert (z_mesh == z_ref).all(), name

    # sharded Gram (single psum) vs single-device, symmetric + rectangular
    for args in ((X,), (X, Y)):
        g_mesh = np.asarray(fm.estimate_gram(*args, sharded=True))
        g_ref = np.asarray(fm.estimate_gram(*args, sharded=False))
        err = np.abs(g_mesh - g_ref).max()
        assert err < 1e-5, (name, err)

    # row-chunked sharded path stays consistent
    g_chunk = np.asarray(fm.estimate_gram(X, sharded=True, row_chunk=7))
    assert np.abs(g_chunk - np.asarray(
        fm.estimate_gram(X, sharded=False))).max() < 1e-5

    # the fused Pallas launch (interpret mode) works INSIDE the shard_map:
    # one launch per feature shard, parity with the sharded jnp path
    z_pal = np.asarray(fm.apply(X[:8], sharded=True, use_pallas=True,
                                interpret=True))
    assert np.abs(z_pal - z_ref[:8]).max() < 1e-5, name

    # ...and the estimate actually approximates the kernel
    K = np.asarray(kern.gram(X))
    rel = np.abs(np.asarray(fm.estimate_gram(X, sharded=True)) - K).max()
    assert rel < 0.35 * np.abs(K).max(), (name, rel)
    print(f"  {name}: sharded apply/gram OK (output_dim={fm.output_dim})")

# ---- DP serving-engine smoke decode ----------------------------------------
import dataclasses  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import init_model  # noqa: E402
from repro.serve import Request, ServingEngine  # noqa: E402

cfg = get_config("qwen3-1.7b", smoke=True, attention_mode="rm")
cfg = dataclasses.replace(cfg, compute_dtype="float32")
params = init_model(cfg, jax.random.PRNGKey(0))
prompts = [np.arange(5, dtype=np.int32) + i for i in range(4)]


def run(mesh):
    eng = ServingEngine(cfg, params, num_slots=4, max_len=48, mesh=mesh)
    for i, p in enumerate(prompts):
        eng.submit(Request(request_id=i, prompt=p, max_new_tokens=4))
    done = eng.run(max_iters=100)
    return {i: done[i].generated for i in done}


got_dp = run(make_host_mesh())
got_1d = run(None)
assert len(got_dp) == 4 and all(len(g) == 4 for g in got_dp.values())
assert got_dp == got_1d, (got_dp, got_1d)
print("DP decode matches single-device generations")
print("SHARDED ESTIMATORS OK")
