"""Subprocess script: the dry-run machinery end-to-end on an 8-device mesh
with smoke configs — proves lower+compile+roofline extraction works on a
REAL multi-device mesh (the 512-device run uses the same code path).
"""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.analysis.roofline import roofline_from_compiled  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    batch_partition_specs,
    logical_rules_context,
    params_partition_specs,
)
from repro.train.steps import (  # noqa: E402
    TrainHyper,
    init_train_state,
    make_train_step,
)

assert len(jax.devices()) == 8
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

for arch in ("qwen3-1.7b", "mixtral-8x7b", "jamba-v0.1-52b", "xlstm-350m"):
    cfg = get_config(arch, smoke=True)
    hyper = TrainHyper()
    with logical_rules_context(mesh) as rules:
        state_sds = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0), hyper))
        pspec = params_partition_specs(state_sds["params"], mesh, rules)
        sspec = {"params": pspec,
                 "opt": {"mu": pspec, "nu": pspec, "step": P()}, "step": P()}
        sshard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), sspec,
            is_leaf=lambda s: isinstance(s, P))
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((8, 32), np.int32),
            "targets": jax.ShapeDtypeStruct((8, 32), np.int32),
        }
        bspec = batch_partition_specs(batch_sds, mesh, rules)
        bshard = jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), bspec,
            is_leaf=lambda s: isinstance(s, P))
        step = make_train_step(cfg, hyper)
        lowered = jax.jit(step, in_shardings=(sshard, bshard),
                          out_shardings=(sshard, None)).lower(
            state_sds, batch_sds)
        compiled = lowered.compile()
        roof = roofline_from_compiled(compiled, mesh.size)
        assert roof["per_device_flops"] > 0
        mem = roof["memory_analysis"]
        assert mem.get("temp_size_in_bytes") is not None
        print(f"{arch}: flops/dev={roof['per_device_flops']:.3g} "
              f"coll/dev={roof['per_device_collective_bytes']:.3g} OK")
print("TINY DRYRUN OK")
