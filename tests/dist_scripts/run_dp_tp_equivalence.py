"""Subprocess script: training on a (2, 4) DP x TP mesh must match
single-device training numerically (the core SPMD-correctness invariant).

Launched by tests/test_distributed.py with
XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "launch via test_distributed.py"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data.synthetic import SyntheticLMDataset  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    batch_partition_specs,
    logical_rules_context,
    params_partition_specs,
)
from repro.train.steps import (  # noqa: E402
    TrainHyper,
    init_train_state,
    make_train_step,
)

assert len(jax.devices()) == 8

cfg = get_config("qwen3-1.7b", smoke=True)
# fp32 end-to-end so single-device and sharded runs are bit-comparable
import dataclasses  # noqa: E402

cfg = dataclasses.replace(cfg, compute_dtype="float32", remat=False)
hyper = TrainHyper(peak_lr=1e-3, warmup_steps=2, total_steps=10)
data = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=64,
                          global_batch=8)
step_fn = make_train_step(cfg, hyper)

# ---- single device ---------------------------------------------------------
state1 = init_train_state(cfg, jax.random.PRNGKey(0), hyper)
step1 = jax.jit(step_fn)
losses1 = []
for i in range(4):
    state1, m = step1(state1, data.batch_at(i))
    losses1.append(float(m["loss"]))

# ---- 2x4 mesh ---------------------------------------------------------------
mesh = jax.make_mesh((2, 4), ("data", "model"))
with logical_rules_context(mesh) as rules:
    state2 = init_train_state(cfg, jax.random.PRNGKey(0), hyper)
    pspec = params_partition_specs(state2["params"], mesh, rules)
    sspec = {"params": pspec, "opt": {"mu": pspec, "nu": pspec, "step": P()},
             "step": P()}
    sshard = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), sspec,
        is_leaf=lambda s: isinstance(s, P))
    state2 = jax.device_put(state2, sshard)
    bspec = batch_partition_specs(data.batch_at(0), mesh, rules)
    bshard = jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), bspec,
                                    is_leaf=lambda s: isinstance(s, P))
    step2 = jax.jit(step_fn, in_shardings=(sshard, bshard),
                    out_shardings=(sshard, None))
    losses2 = []
    for i in range(4):
        batch = jax.device_put(data.batch_at(i), bshard)
        state2, m = step2(state2, batch)
        losses2.append(float(m["loss"]))

print("single:", losses1)
print("mesh  :", losses2)
# fp32 end-to-end, but XLA's sharded all-reduce ordering differs from the
# single-device reduction; observed divergence on CPU pins is ~6e-4 after
# 4 steps, so the bound is 1e-3 (still catches real SPMD bugs, which show
# up at 1e-1+ or as NaNs).
np.testing.assert_allclose(losses1, losses2, rtol=1e-3, atol=1e-3)
assert losses1[-1] < losses1[0], "loss should decrease"
print("DP/TP EQUIVALENCE OK")
