"""Subprocess script: (a) MoE shard_map path on a real multi-device mesh
matches the single-device path; (b) int8-compressed cross-pod psum with
error feedback stays close to the exact all-reduce over steps.
"""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.distributed.sharding import logical_rules_context  # noqa: E402
from repro.models import forward, init_model, moe as moe_mod  # noqa: E402
from repro.optim.compression import (  # noqa: E402
    compressed_psum_with_feedback,
)

assert len(jax.devices()) == 8

# ---- (a) MoE parity ---------------------------------------------------------
# capacity is computed PER DP SHARD (standard practice), so drop patterns
# legitimately differ between 1-device and mesh runs; lift capacity so the
# routing is dropless and the comparison is exact.
cfg = get_config("mixtral-8x7b", smoke=True)
cfg = dataclasses.replace(
    cfg, compute_dtype="float32", remat=False,
    moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
params = init_model(cfg, jax.random.PRNGKey(0))
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                 cfg.vocab_size),
    "targets": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                  cfg.vocab_size),
}
logits_local, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
with logical_rules_context(mesh):
    logits_mesh, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
err = float(jnp.abs(logits_local - logits_mesh).max())
print("moe mesh parity max err:", err)
assert err < 2e-3, err

# ---- (b) compressed cross-pod psum -----------------------------------------
mesh2 = jax.make_mesh((4, 2), ("pod", "data"))
grads = jax.random.normal(jax.random.PRNGKey(3), (4, 128)) * 0.1

def body(g, r):
    out, new_r = compressed_psum_with_feedback({"g": g}, {"g": r}, "pod")
    return out["g"], new_r["g"]

from repro.distributed.sharding import shard_map  # noqa: E402

shmapped = jax.jit(shard_map(
    body, mesh2,
    in_specs=(P("pod"), P("pod")),
    out_specs=(P("pod"), P("pod")),
))
r = jnp.zeros_like(grads).reshape(4, 128)
total_err = []
acc_exact = jnp.zeros((1, 128))
acc_comp = jnp.zeros((1, 128))
for step in range(10):
    g = jax.random.normal(jax.random.PRNGKey(10 + step), (4, 128)) * 0.1
    exact = jnp.mean(g, axis=0, keepdims=True)
    comp, r = shmapped(g, r)
    acc_exact += exact
    acc_comp += comp[:1]
    total_err.append(float(jnp.abs(acc_comp - acc_exact).max()))
print("compressed psum cumulative err:", total_err[-1])
# error feedback keeps the CUMULATIVE average error bounded (not growing)
assert total_err[-1] < 0.01
print("MOE+COMPRESSION OK")
